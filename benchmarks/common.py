"""Shared benchmark utilities: wall-clock timing + CSV emission."""

from __future__ import annotations

import jax

from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram


def time_fn_stats(fn, *args, warmup: int = 2, iters: int = 5) -> dict:
    """Wall-clock stats per call (block_until_ready), in microseconds.

    Samples go through the shared obs Histogram so benchmarks and the serve
    loop report percentiles from one implementation. Returns
    {"p50_us", "p95_us", "mean_us", "min_us", "max_us", "count"}."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    h = Histogram("bench_call_us", {})
    for _ in range(iters):
        t0 = obs_trace.now()
        jax.block_until_ready(fn(*args))
        h.observe((obs_trace.now() - t0) * 1e6)
    return {
        "p50_us": h.percentile(0.5),
        "p95_us": h.percentile(0.95),
        "mean_us": h.mean,
        "min_us": h.min,
        "max_us": h.max,
        "count": h.count,
    }


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (block_until_ready)."""
    return time_fn_stats(fn, *args, warmup=warmup, iters=iters)["p50_us"]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def iter_measured_runs(*, steps: int, batch: int,
                       tuned_policy: str | None = None, archs=None):
    """Yield (arch, policy_label, MeasuredDecode) for each measured operating
    point × policy — the shared driver behind the tuned-vs-default modes of
    benchmarks/{speedup,energy}.py.

    With `tuned_policy` (a repro.tune table JSON) each arch runs twice,
    "default" then "tuned", both with the host-side mode refresh live (the
    comparison is between live policies, not pinned modes). Unknown names in
    `archs` are an error — a silently-empty filter would let CI pass while
    measuring nothing."""
    from repro.sensor.runner import MEASURED_OPERATING_POINTS, run_measured_decode

    known = [a for a, _ in MEASURED_OPERATING_POINTS]
    if archs is not None:
        unknown = sorted(set(archs) - set(known))
        if unknown:
            raise SystemExit(
                f"unknown measured arch(s) {unknown}; operating points "
                f"exist for {known}")
    policies = [("default", None)]
    if tuned_policy is not None:
        from repro.tune.table import load_tuned_policy

        policies.append(("tuned", load_tuned_policy(tuned_policy)))
    refresh = tuned_policy is not None
    for arch, corr in MEASURED_OPERATING_POINTS:
        if archs is not None and arch not in archs:
            continue
        for label, pol in policies:
            yield arch, label, run_measured_decode(
                arch, steps=steps, batch=batch, correlation=corr,
                policy=pol, refresh_policy=refresh)


def measured_cli(description: str):
    """Parsed args for the measured benchmark CLIs (shared flag set)."""
    import argparse

    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--measured", action="store_true")
    ap.add_argument("--tuned-policy", default=None,
                    help="tuned-table JSON (python -m repro.tune.fit output); "
                    "adds a tuned-policy run and reports tuned-vs-default "
                    "deltas (implies --measured)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--archs", nargs="*", default=None)
    return ap.parse_args()
