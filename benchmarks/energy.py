"""Paper Fig. 13/14: total-energy reduction from computation reuse.

gem5+McPAT is replaced by an analytic TPU energy model driven by the cost
model's per-step FLOPs/bytes: dynamic energy = flops·e_mac + hbm·e_hbm +
ici·e_ici; static energy scales with step time. The per-op constants live in
`repro.sensor.cost_model` (shared with the measured accounting); the
reproduced object is the STRUCTURE of Fig. 13 (dynamic savings from skipped
work + static savings from shorter steps), not absolute joules.

Two paths:

* analytic (default) — the paper's Table-I similarity operating points drive
  the roofline model (the projection the seed shipped);
* ``--measured``     — real decode steps run on reduced archs with the reuse
  engine threaded, and the reduction comes from the SENSOR COUNTERS the
  kernels produced (skipped MACs / skipped weight bytes). No similarity
  constant appears anywhere on this path.
"""

from __future__ import annotations

from repro.configs import ARCHS
from repro.launch.specs import SHAPES
from repro.roofline.model_cost import POD_MESH, cell_cost
from repro.sensor.cost_model import E_HBM, E_ICI, E_MAC, STATIC_W, sensor_energy

PAPER_SIMILARITY = {
    "qwen3-32b": 0.41,
    "mixtral-8x7b": 0.45,
    "rwkv6-7b": 0.68,
    "zamba2-2.7b": 0.55,
    "gemma3-12b": 0.27,
}

def step_energy(cost) -> dict:
    dyn = (cost.flops * E_MAC + cost.hbm_bytes * E_HBM
           + cost.coll_bytes * E_ICI)
    static = STATIC_W * cost.step_s
    return {"dynamic": dyn, "static": static, "total": dyn + static}


def analytic(emit):
    rows = []
    for arch, sim in PAPER_SIMILARITY.items():
        cfg = ARCHS[arch]
        cell = SHAPES["decode_32k"]
        base = step_energy(cell_cost(cfg, cell, POD_MESH))
        harvest = 0.8 * sim
        reuse = step_energy(
            cell_cost(cfg, cell, POD_MESH, reuse_skip_fraction=harvest))
        red = 1 - reuse["total"] / base["total"]
        dyn_red = 1 - reuse["dynamic"] / base["dynamic"]
        rows.append((arch, sim, red, dyn_red))
        emit(f"energy/{arch}", 0.0,
             f"sim={sim};total_energy_reduction={red:.1%};"
             f"dynamic_reduction={dyn_red:.1%} "
             f"(paper: 74% total / 47% dynamic at its 8x-speedup point)")
    return rows


def measured(emit, *, steps: int = 10, batch: int = 2,
             tuned_policy: str | None = None, archs=None):
    """Energy accounting from live sensor counters (no PAPER_SIMILARITY).

    With `tuned_policy`, each arch is measured under both the default
    global-constant policy and the tuned per-site table (mode refresh live
    for both) and the reduction delta is reported."""
    from benchmarks.common import iter_measured_runs

    rows = []
    per_arch: dict[str, dict] = {}
    for arch, label, md in iter_measured_runs(
            steps=steps, batch=batch, tuned_policy=tuned_policy, archs=archs):
        e = sensor_energy(md.report)
        fr = md.skip_fractions
        # project the measured harvest through the full-model roofline
        cfg = ARCHS[arch]
        cell = SHAPES["decode_32k"]
        base = step_energy(cell_cost(cfg, cell, POD_MESH))
        reuse = step_energy(cell_cost(
            cfg, cell, POD_MESH,
            reuse_skip_fraction=fr["weight_byte_skip_rate"]))
        red = 1 - reuse["total"] / base["total"]
        per_arch.setdefault(arch, {})[label] = (e, red)
        suffix = "" if label == "default" else "_tuned"
        emit(f"energy/measured_{arch}{suffix}", 0.0,
             f"measured_tile_skip={fr['tile_skip_rate']:.1%};"
             f"measured_hit_rate={fr['hit_rate']:.3f};"
             f"site_dynamic_reduction={e['dynamic_reduction']:.1%};"
             f"saved_dynamic_j={e['saved_dynamic_j']:.3e};"
             f"projected_total_reduction={red:.1%} "
             f"(from sensor counters over {steps} real decode steps)")
        rows.append((arch, label, fr, e, red))
        if label == "tuned":
            (e_d, red_d), (e_t, red_t) = per_arch[arch]["default"], (e, red)
            emit(f"energy/tuned_delta_{arch}", 0.0,
                 f"dynamic_reduction {e_d['dynamic_reduction']:.1%}->"
                 f"{e_t['dynamic_reduction']:.1%};"
                 f"projected_total {red_d:.1%}->{red_t:.1%}")
    return rows


def main(emit, *, measured_mode: bool = False, tuned_policy: str | None = None,
         steps: int = 10, batch: int = 2, archs=None):
    if measured_mode:
        return measured(emit, steps=steps, batch=batch,
                        tuned_policy=tuned_policy, archs=archs)
    return analytic(emit)


if __name__ == "__main__":
    from benchmarks.common import emit, measured_cli

    args = measured_cli("Fig. 13/14 energy: analytic or measured reduction")
    main(emit, measured_mode=args.measured or bool(args.tuned_policy),
         tuned_policy=args.tuned_policy, steps=args.steps, batch=args.batch,
         archs=args.archs)
