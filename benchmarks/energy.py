"""Paper Fig. 13/14: total-energy reduction from computation reuse.

gem5+McPAT is replaced by an analytic TPU energy model driven by the cost
model's per-step FLOPs/bytes: dynamic energy = flops·e_mac + hbm·e_hbm +
ici·e_ici; static energy scales with step time. Constants are public
order-of-magnitude figures for a 7nm-class accelerator; the reproduced
object is the STRUCTURE of Fig. 13 (dynamic savings from skipped work +
static savings from shorter steps), not absolute joules.
"""

from __future__ import annotations

from repro.configs import ARCHS
from repro.launch.specs import SHAPES
from repro.roofline.model_cost import POD_MESH, cell_cost

E_MAC = 0.3e-12      # J/FLOP (bf16 MXU, incl. local movement)
E_HBM = 12e-12       # J/byte HBM access
E_ICI = 20e-12       # J/byte off-chip link
STATIC_W = 80.0      # W per chip static/other

PAPER_SIMILARITY = {
    "qwen3-32b": 0.41,
    "mixtral-8x7b": 0.45,
    "rwkv6-7b": 0.68,
    "zamba2-2.7b": 0.55,
    "gemma3-12b": 0.27,
}


def step_energy(cost) -> dict:
    dyn = (cost.flops * E_MAC + cost.hbm_bytes * E_HBM
           + cost.coll_bytes * E_ICI)
    static = STATIC_W * cost.step_s
    return {"dynamic": dyn, "static": static, "total": dyn + static}


def main(emit):
    rows = []
    for arch, sim in PAPER_SIMILARITY.items():
        cfg = ARCHS[arch]
        cell = SHAPES["decode_32k"]
        base = step_energy(cell_cost(cfg, cell, POD_MESH))
        harvest = 0.8 * sim
        reuse = step_energy(
            cell_cost(cfg, cell, POD_MESH, reuse_skip_fraction=harvest))
        red = 1 - reuse["total"] / base["total"]
        dyn_red = 1 - reuse["dynamic"] / base["dynamic"]
        rows.append((arch, sim, red, dyn_red))
        emit(f"energy/{arch}", 0.0,
             f"sim={sim};total_energy_reduction={red:.1%};"
             f"dynamic_reduction={dyn_red:.1%} "
             f"(paper: 74% total / 47% dynamic at its 8x-speedup point)")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
