"""Paper Sec. III-B: skip granularity vs harvestable similarity.

The paper: SVE sdot needs a whole 4-element sub-vector of deltas at zero —
only 13.9 % of ResNet's raw similarity survives that constraint — motivating
per-scalar mla8. The TPU skip unit is a (block_m × block_k) tile; this
benchmark measures the harvest ratio across tile widths for (a) unstructured
random similarity and (b) structured similarity (persistent zero/saturated
channels, what int8+ReLU activations actually produce — cf. similarity.py).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.similarity import harvestable_similarity

BLOCK_KS = (1, 32, 128, 256, 512)


def make_streams(rng, m, k, sim, structured: bool):
    cur = rng.integers(-20, 21, size=(m, k)).astype(np.int8)
    if structured:
        # contiguous channel GROUPS persist (ReLU-dead / saturated regions
        # of int8 activations are spatially clustered) — group width 128
        g = 128
        groups = rng.random(k // g) < sim
        keep = np.broadcast_to(np.repeat(groups, g)[None, :], (m, k))
    else:
        keep = rng.random((m, k)) < sim
    prev = np.where(keep, cur, cur + 3).astype(np.int8)
    return jnp.asarray(cur), jnp.asarray(prev)


def main(emit):
    rng = np.random.default_rng(0)
    m, k = 64, 4096
    rows = []
    for structured in (False, True):
        cur, prev = make_streams(rng, m, k, 0.45, structured)
        raw = float(jnp.mean((cur == prev).astype(jnp.float32)))
        for bk in BLOCK_KS:
            h = float(harvestable_similarity(cur, prev, 1, bk))
            ratio = h / max(raw, 1e-9)
            rows.append((structured, bk, raw, h))
            kind = "structured" if structured else "unstructured"
            emit(f"granularity/{kind}_bk{bk}", 0.0,
                 f"raw_sim={raw:.3f};harvest={h:.3f};ratio={ratio:.3f}")
    emit("granularity/paper_ref", 0.0,
         "paper: sdot(4-wide) harvests 13.9% of ResNet similarity; "
         "unstructured tiles collapse the same way, structured channels "
         "survive wide tiles — compaction path covers the gap")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
