"""Kernel-level microbench: the Pallas block-skip GEMM (interpret mode) vs
oracle, plus the fused delta-quant pass. Interpret mode runs the kernel body
in Python — correctness evidence and relative skip accounting, not TPU
wall-clock (the TPU target numbers live in the §Roofline model)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.similarity import block_zero_mask
from repro.kernels import ops


def main(emit):
    rng = np.random.default_rng(0)
    m, k, n, bm, bn, bk = 128, 1024, 512, 32, 128, 256
    delta = rng.normal(size=(m, k)).astype(np.float32)
    gm, gk = m // bm, k // bk
    for i in range(gm):
        for j in range(gk):
            if rng.random() < 0.55:
                delta[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0.0
    delta = jnp.asarray(delta)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    skip = 1 - float(mask.mean())

    t_ref = time_fn(
        jax.jit(lambda d, w, p, m_: ops.reuse_matmul_ref(d, w, p, m_, bm, bk)),
        delta, w, prev, mask)
    emit("kernels/reuse_matmul_oracle", t_ref, f"skip_fraction={skip:.2f}")

    out_k = ops.reuse_matmul(delta, w, prev, mask, block_m=bm, block_n=bn,
                             block_k=bk, interpret=True)
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    err = float(jnp.max(jnp.abs(out_k - ref)))
    emit("kernels/reuse_matmul_pallas_interpret", 0.0,
         f"allclose_err={err:.2e};skipped_weight_tiles={skip:.2f};"
         "DMA+MXU skipped per masked tile on TPU target")

    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    prev_q = jnp.zeros((m, k), jnp.int8)
    q, d, msk = ops.delta_quant_fused(x, prev_q, jnp.float32(0.05),
                                      block_m=bm, block_k=bk, interpret=True)
    q2, d2, m2 = ops.delta_quant_ref(x, prev_q, jnp.float32(0.05), bm, bk)
    emit("kernels/delta_quant_fused", 0.0,
         f"codes_exact={bool(jnp.all(q == q2))};mask_exact={bool(jnp.all(msk == m2))}")
    return {"skip": skip, "err": err}


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
