"""Router stickiness: P[a stream keeps its expert across decode steps].

Grounds the per-(slot, expert) reuse extension (beyond-paper, §Perf cell 2):
expert weight-tile skipping requires the dispatched stream to revisit the
same expert — measured here on reduced mixtral with correlated streams.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params
from repro.models.layers import apply_norm
from repro.serve.serve_step import init_serve_state
from repro.models import forward


def main(emit):
    cfg = ARCHS["mixtral-8x7b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, steps = 4, 16

    state = init_serve_state(cfg, b, 64)
    anchor = rng.integers(0, cfg.vocab, (b, 1)).astype(np.int32)
    tok = jnp.asarray(anchor)
    moe0 = jax.tree.map(lambda x: x[0], params["blocks"])["moe"]

    prev_top = None
    rows = []
    for corr in (0.0, 0.6, 0.9):
        state = init_serve_state(cfg, b, 64)
        prev_top, agree, n = None, 0, 0
        for i in range(steps):
            h, state, _, _ = forward(params, cfg, {"tokens": tok},
                                     decode_state=state)
            hn = apply_norm(moe0["norm"], h, cfg.norm_eps).reshape(-1, cfg.d_model)
            logits = hn.astype(jnp.float32) @ moe0["router"]
            top = np.asarray(jnp.argsort(logits, axis=-1)[:, -cfg.top_k:])
            if prev_top is not None:
                for s_ in range(b):
                    agree += len(set(top[s_]) & set(prev_top[s_]))
                    n += cfg.top_k
            prev_top = top
            keep = rng.random((b, 1)) < corr
            nxt = rng.integers(0, cfg.vocab, (b, 1)).astype(np.int32)
            tok = jnp.asarray(np.where(keep, anchor, nxt).astype(np.int32))
        pi = agree / max(n, 1)
        rows.append((corr, pi))
        emit(f"moe_stickiness/corr{int(corr * 100):02d}", 0.0,
             f"P(expert kept)={pi:.3f} over {steps} steps, top{cfg.top_k}")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
