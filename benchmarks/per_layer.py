"""Paper Fig. 12: per-layer time reduction vs similarity, incl. the
saturation effect — 99 % similarity does NOT give 99 % reduction because the
engine still loads current/previous inputs, computes deltas and writes
outputs (layer K in the paper: 60 % reduction at 99 % similarity).

Layers A-K analogue: a pool spanning small/large and input-heavy/output-heavy
aspect ratios, timed on the compaction path at several similarity levels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.kernels import ops

# (name, M, K, N) — A-D small-output/large-input, E-K balanced or output-heavy
LAYERS = [
    ("A_small_out", 64, 4096, 256),
    ("B_small_out", 64, 8192, 512),
    ("C_small", 32, 512, 512),
    ("E_balanced", 128, 2048, 2048),
    ("G_large", 128, 4096, 4096),
    ("K_large_out", 128, 2048, 8192),
]

SIMS = (0.10, 0.45, 0.80, 0.99)


def main(emit):
    rng = np.random.default_rng(0)
    bk = 256
    results = []
    for name, m, k, n in LAYERS:
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        t_dense = time_fn(jax.jit(lambda x, w: x @ w), x, w)
        gk = k // bk
        for sim in SIMS:
            nb = max(int(round(gk * (1 - sim))), 1)
            kmask = jnp.asarray((np.arange(gk) < nb).astype(np.int32))
            delta = jnp.asarray(np.where(
                np.repeat(np.asarray(kmask), bk)[None, :],
                rng.normal(size=(m, k)), 0.0).astype(np.float32))
            fn = jax.jit(lambda d, w, p, km, nb=nb: ops.reuse_matmul_compact(
                d, w, p, km, block_k=bk, max_blocks=nb))
            t = time_fn(fn, delta, w, prev, kmask)
            red = 1 - t / t_dense
            results.append((name, sim, red))
            emit(f"per_layer/{name}_sim{int(sim * 100):02d}", t,
                 f"time_reduction={red:+.1%} (dense {t_dense:.0f}us)")
    # saturation check: the 99%-similarity rows must stay well below 99%
    sat = [r for n_, s, r in results if s == 0.99]
    emit("per_layer/saturation", 0.0,
         f"max_reduction_at_99pct_sim={max(sat):.1%} "
         "(paper layer K: 60% — cache/delta traffic is not skippable)")
    return results


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
