"""Paper Fig. 12, measured: per-layer reuse profile of one stacked config.

The paper's central per-layer observation — input similarity, and therefore
profitable reuse, varies layer by layer — used to be illustrated here with a
synthetic similarity sweep. The rows are now MEASURED: a reduced stacked
config (qwen3-32b: scan-over-superblocks, every reuse site stacked) decodes a
correlated stream, and the table comes from the per-layer sensor counters and
the array-resident per-layer control block — each row is one LAYER of one
site, with the kernelMode that layer's ctrl lane actually settled to, its
measured tile/MAC skip, its lane similarity and its budget-occupancy EMA.

The synthetic layer-pool timing sweep (the saturation effect: 99 % similarity
does NOT give 99 % reduction — layer K in the paper: 60 %) is kept as
`synthetic_saturation`, runnable via `--synthetic`.

Run:  PYTHONPATH=src python -m benchmarks.per_layer [--synthetic]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.kernels import ops

MEASURED_ARCH = "qwen3-32b"   # scanned stack: every site carries layer lanes
MEASURED_STEPS = 10
MEASURED_CORRELATION = 0.95


def main(emit):
    """Measured per-layer mode/skip table from the live control block."""
    from repro.core.policy import ReusePolicy
    from repro.sensor.runner import run_measured_decode

    # Admission floor lifted (reduced-scale sites sit below the production
    # min_work cutoff) so the live per-layer refresh decides from MEASURED
    # similarity; modes in the table are what each layer's ctrl lane settled.
    md = run_measured_decode(
        MEASURED_ARCH, steps=MEASURED_STEPS, batch=2,
        correlation=MEASURED_CORRELATION, refresh_policy=True,
        policy=ReusePolicy(min_work_flops=0.0),
    )
    rows = []
    for s in md.report.per_layer:
        sim = float(np.mean([r for r, st in zip(s.slot_hit_rates, s.slot_steps)
                             if st > 0] or [0.0]))
        rows.append((s.site, s.layer, s.mode, s.tile_skip_rate,
                     s.mac_skip_rate, sim, s.budget_occupancy))
        emit(
            f"per_layer/{s.site}_L{s.layer}", 0.0,
            f"mode={s.mode};tile_skip={s.tile_skip_rate:.1%};"
            f"mac_skip={s.mac_skip_rate:.1%};sim={sim:.2f};"
            f"occupancy={s.budget_occupancy:.2f}",
        )
    per_site_modes = {}
    for site, layer, mode, *_ in rows:
        per_site_modes.setdefault(site, set()).add(mode)
    mixed = sorted(n for n, m in per_site_modes.items() if len(m) > 1)
    emit(
        "per_layer/summary", 0.0,
        f"arch={MEASURED_ARCH};layers={len(rows)};"
        f"sites={len(per_site_modes)};mixed_mode_sites={len(mixed)};"
        f"model_mac_skip={md.report.model['mac_skip_rate']:.1%}",
    )
    return rows


# ------------------------------------------------- synthetic saturation sweep

# (name, M, K, N) — A-D small-output/large-input, E-K balanced or output-heavy
LAYERS = [
    ("A_small_out", 64, 4096, 256),
    ("B_small_out", 64, 8192, 512),
    ("C_small", 32, 512, 512),
    ("E_balanced", 128, 2048, 2048),
    ("G_large", 128, 4096, 4096),
    ("K_large_out", 128, 2048, 8192),
]

SIMS = (0.10, 0.45, 0.80, 0.99)


def synthetic_saturation(emit):
    """Layer-pool timing sweep at forced similarity levels (the saturation
    check: cache/delta traffic is not skippable)."""
    rng = np.random.default_rng(0)
    bk = 256
    results = []
    for name, m, k, n in LAYERS:
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        t_dense = time_fn(jax.jit(lambda x, w: x @ w), x, w)
        gk = k // bk
        for sim in SIMS:
            nb = max(int(round(gk * (1 - sim))), 1)
            kmask = jnp.asarray((np.arange(gk) < nb).astype(np.int32))
            delta = jnp.asarray(np.where(
                np.repeat(np.asarray(kmask), bk)[None, :],
                rng.normal(size=(m, k)), 0.0).astype(np.float32))
            fn = jax.jit(lambda d, w, p, km, nb=nb: ops.reuse_matmul_compact(
                d, w, p, km, block_k=bk, max_blocks=nb))
            t = time_fn(fn, delta, w, prev, kmask)
            red = 1 - t / t_dense
            results.append((name, sim, red))
            emit(f"per_layer/{name}_sim{int(sim * 100):02d}", t,
                 f"time_reduction={red:+.1%} (dense {t_dense:.0f}us)")
    # saturation check: the 99%-similarity rows must stay well below 99%
    sat = [r for n_, s, r in results if s == 0.99]
    emit("per_layer/saturation", 0.0,
         f"max_reduction_at_99pct_sim={max(sat):.1%} "
         "(paper layer K: 60% — cache/delta traffic is not skippable)")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.common import emit

    if "--synthetic" in sys.argv:
        synthetic_saturation(emit)
    else:
        main(emit)
