"""§Roofline: emit the full per-(arch × shape × mesh) table.

Terms come from the analytic cost model (roofline/model_cost.py); the
compiled dry-run artifacts provide the fit/shard proof and the HLO
cross-check (roofline/validate.py). The reuse column models the paper's
technique at its Table-I similarity operating point (harvest = 0.8·sim,
granularity.py) on decode cells.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS
from repro.launch.specs import SHAPES, cell_runnable
from repro.roofline.model_cost import roofline_row

PAPER_SIM = {
    "llama4-scout-17b-a16e": 0.41, "mixtral-8x7b": 0.45,
    "nemotron-4-15b": 0.41, "gemma3-12b": 0.27, "qwen3-32b": 0.41,
    "qwen2-72b": 0.41, "rwkv6-7b": 0.68, "hubert-xlarge": 0.68,
    "qwen2-vl-7b": 0.41, "zamba2-2.7b": 0.55,
}


def build_table(mesh: str = "pod") -> list[dict]:
    rows = []
    for arch, cfg in ARCHS.items():
        for shape in SHAPES:
            row = roofline_row(cfg, shape, mesh)
            if "skipped" not in row and SHAPES[shape].kind == "decode":
                reuse = roofline_row(
                    cfg, shape, mesh,
                    reuse_skip_fraction=0.8 * PAPER_SIM[arch],
                )
                row["reuse_step_s"] = reuse["step_s"]
                row["reuse_gain"] = row["step_s"] / reuse["step_s"]
            rows.append(row)
    return rows


def to_markdown(rows: list[dict], dryrun_dir: str | None = None) -> str:
    compiled = {}
    if dryrun_dir:
        for p in Path(dryrun_dir).glob("*.json"):
            rec = json.loads(p.read_text())
            if not rec.get("reuse") and not rec.get("pipeline"):
                compiled[(rec["arch"], rec["shape"])] = rec

    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " step s | useful (6ND/HLO) | roofline frac | reuse gain | compiled |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — |"
                f" — | skipped: {r['skipped']} |")
            continue
        rec = compiled.get((r["arch"], r["shape"]), {})
        ok = "✓" if rec.get("status") == "ok" else "?"
        gain = f"{r['reuse_gain']:.2f}x" if "reuse_gain" in r else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} |"
            f" {r['memory_s']:.4g} | {r['collective_s']:.4g} |"
            f" {r['dominant']} | {r['step_s']:.4g} |"
            f" {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
            f" {gain} | {ok} |")
    return "\n".join(lines)


def main(emit):
    rows = build_table("pod")
    n_ok = sum(1 for r in rows if "skipped" not in r)
    worst = min((r for r in rows if "skipped" not in r),
                key=lambda r: r["roofline_fraction"])
    coll = [r for r in rows if r.get("dominant") == "collective"]
    emit("roofline/cells", 0.0,
         f"runnable={n_ok};skipped={len(rows) - n_ok};"
         f"worst_fraction={worst['arch']}/{worst['shape']}"
         f"={worst['roofline_fraction']:.4f};collective_bound={len(coll)}")
    out = Path("experiments/roofline_pod.md")
    out.parent.mkdir(exist_ok=True, parents=True)
    out.write_text(to_markdown(rows, "experiments/dryrun/pod"))
    rows_mp = build_table("multipod")
    Path("experiments/roofline_multipod.md").write_text(
        to_markdown(rows_mp, "experiments/dryrun/multipod"))
    emit("roofline/tables", 0.0,
         "written to experiments/roofline_{pod,multipod}.md")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
