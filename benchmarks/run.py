"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Output: ``name,us_per_call,derived`` CSV rows.

  similarity       Fig. 3 / Fig. 4 / Table I   per-layer input similarity
  granularity      Sec. III-B                  sdot-vs-mla8 harvest analogue
  software_reuse   Sec. III                    SW reuse loses; skipping wins
  speedup          Fig. 10                     measured sweep + modeled TPU
  per_layer        Fig. 12                     layer pool + saturation
  energy           Fig. 13/14                  analytic energy reduction
  kernels          (implementation)            Pallas interpret vs oracle
  wallclock        (implementation)            measured step time per exec path
  roofline_table   §Roofline deliverable       full cell table -> markdown
"""

from __future__ import annotations

import sys


def _run(name, fn, emit):
    try:
        fn(emit)
    except Exception as e:  # keep the harness going; failures are visible
        emit(f"{name}/FAILED", 0.0, f"{type(e).__name__}: {e}")
        import traceback

        traceback.print_exc(file=sys.stderr)


def main() -> None:
    from benchmarks import (
        energy,
        granularity,
        kernels as kernel_bench,
        moe_stickiness,
        per_layer,
        roofline_table,
        similarity,
        software_reuse,
        speedup,
        wallclock,
    )
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    _run("granularity", granularity.main, emit)
    _run("software_reuse", software_reuse.main, emit)
    _run("speedup", speedup.main, emit)
    _run("per_layer", per_layer.main, emit)
    _run("energy", energy.main, emit)
    _run("similarity", similarity.main, emit)
    _run("moe_stickiness", moe_stickiness.main, emit)
    _run("kernels", kernel_bench.main, emit)
    _run("wallclock", lambda _emit: wallclock.main(["--tiny"]), emit)
    _run("roofline_table", roofline_table.main, emit)


if __name__ == "__main__":
    main()
