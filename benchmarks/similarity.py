"""Paper Fig. 3 + Fig. 4 + Table I: input similarity across layers and archs.

Measured by serving reduced-scale models on token streams of varying
correlation and reading the per-layer code-similarity statistics the reuse
engine accumulates (int8 code domain — the paper's definition). Fig. 4's
zero/nonzero split is computed from consecutive cache snapshots at one site.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core.similarity import similarity_breakdown
from repro.models import init_params
from repro.serve.serve_step import (
    build_reuse_engine,
    decode_step,
    greedy_sample,
    init_serve_state,
)

# Archs paired with stream correlation regimes mirroring the paper's table:
# sequence-processing (audio-like, high corr), weakly correlated text,
# uncorrelated (ResNet-analogue random streams still show similarity via int8).
BENCH_ARCHS = [
    ("qwen3-32b", 0.9),
    ("mixtral-8x7b", 0.6),
    ("rwkv6-7b", 0.9),
    ("zamba2-2.7b", 0.6),
    ("qwen2-vl-7b", 0.0),
]


def run_arch(arch: str, correlation: float, *, steps: int = 12, batch: int = 2):
    cfg = ARCHS[arch].reduced()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = build_reuse_engine(cfg, impl="jnp")
    rcache = engine.init_cache(batch)
    state = init_serve_state(cfg, batch, 128)

    anchor = rng.integers(0, cfg.vocab, (batch, 1)).astype(np.int32)
    tok = jnp.asarray(anchor)
    snapshots = []
    step = jax.jit(lambda p, t, s, rc: decode_step(
        p, cfg, t, s, engine=engine, reuse_cache=rc))
    for i in range(steps):
        if i == steps - 1:
            snapshots.append(jax.tree.map(lambda x: x, rcache))
        logits, state, rcache = step(params, tok, state, rcache)
        nxt = np.asarray(greedy_sample(logits))[:, :1]
        keep = rng.random((batch, 1)) < correlation
        tok = jnp.asarray(np.where(keep, anchor, nxt).astype(np.int32))

    per_layer = {}
    for site, entry in rcache.items():
        per_layer[site] = np.asarray(entry["sim_ema"], np.float32)

    # Fig-4 split at the first registered site, last step
    site0 = next(iter(engine.sites))
    prev_q = snapshots[-1][site0]["prev_q"]
    cur_q = rcache[site0]["prev_q"]
    split = similarity_breakdown(
        cur_q.reshape(-1, cur_q.shape[-1]), prev_q.reshape(-1, prev_q.shape[-1])
    )
    return per_layer, {k: float(v) for k, v in split.items()}


def main(emit):
    rows = []
    for arch, corr in BENCH_ARCHS:
        per_layer, split = run_arch(arch, corr)
        sims = np.concatenate([v.ravel() for v in per_layer.values()])
        rows.append((arch, corr, sims, split))
        emit(
            f"similarity/{arch}",
            0.0,
            f"corr={corr};mean_sim={sims.mean():.3f};min={sims.min():.3f};"
            f"max={sims.max():.3f};zero_frac={split['zero_similarity']:.3f};"
            f"nonzero_frac={split['nonzero_similarity']:.3f}",
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    main(emit)
