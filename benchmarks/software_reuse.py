"""Paper Sec. III: software-only computation reuse LOSES on real hardware.

The paper measured −9.7 % at 45 % similarity for a branch-based sdot reuse
kernel on a Cortex-A76. The vector-hardware analogue of "software reuse" is
the branchless masked path: compute deltas, mask them, still issue the full
GEMM — all the bookkeeping, none of the skipping. We wall-clock it on this
host against the dense baseline at the paper's similarity operating point,
and also time the structural-skipping path (compaction) that plays the role
of the hardware scheme.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.kernels import ops


def build_case(rng, m, k, n, similarity, block_k=256):
    x_prev = rng.normal(size=(m, k)).astype(np.float32)
    keep = rng.random((m, k)) < similarity
    x_cur = np.where(keep, x_prev, x_prev + rng.normal(size=(m, k)) * 0.5)
    # structured variant: similarity concentrated in whole K-blocks (what
    # real activation streams look like after int8 — see similarity.py)
    gk = k // block_k
    blk_keep = rng.random(gk) < similarity
    x_blk = np.where(
        np.repeat(blk_keep, block_k)[None, :], x_prev,
        x_prev + rng.normal(size=(m, k)) * 0.5,
    )
    w = rng.normal(size=(k, n)).astype(np.float32)
    prev_out = (x_prev @ w).astype(np.float32)
    return (jnp.asarray(x_cur - x_prev), jnp.asarray(x_blk - x_prev),
            jnp.asarray(w), jnp.asarray(prev_out),
            jnp.asarray(~blk_keep, jnp.int32))


def main(emit, *, measured_mode: bool = False):
    rng = np.random.default_rng(0)
    m, k, n = 256, 4096, 4096
    block_k = 256
    sim = 0.45  # the paper's operating point (analytic default)
    if measured_mode:
        # Operating point measured from live sensor counters instead of the
        # paper constant. build_case's `similarity` is a BLOCK-level keep
        # probability, so the matching measured quantity is the block-granular
        # tile_skip_rate (hit_rate, the per-element match fraction, is
        # systematically higher — harvest/sim ~0.7-0.9, see granularity.py).
        from repro.sensor.runner import MEASURED_OPERATING_POINTS, run_measured_decode

        arch, corr = MEASURED_OPERATING_POINTS[0]
        md = run_measured_decode(arch, steps=10, batch=2, correlation=corr)
        fr = md.skip_fractions
        sim = max(fr["tile_skip_rate"], 0.05)
        emit("software_reuse/measured_operating_point", 0.0,
             f"tile_skip={fr['tile_skip_rate']:.3f};hit_rate={fr['hit_rate']:.3f}"
             " (sensor counters from 10 real decode steps)")
    delta, delta_blk, w, prev, kmask = build_case(rng, m, k, n, sim, block_k)
    x = delta + 1.0  # stand-in activations for the dense baseline

    dense = jax.jit(lambda x, w: x @ w)
    masked = jax.jit(ops.reuse_matmul_masked)
    compact = jax.jit(
        lambda d, w, p, km: ops.reuse_matmul_compact(
            d, w, p, km, block_k=block_k,
            max_blocks=int(np.asarray(kmask).sum()) or 1,
        )
    )

    t_dense = time_fn(dense, x, w)
    t_masked = time_fn(masked, delta, w, prev)
    t_compact = time_fn(compact, delta_blk, w, prev, kmask)

    emit("software_reuse/dense_baseline", t_dense, "GEMM 256x4096x4096")
    emit(
        "software_reuse/masked_sw_reuse", t_masked,
        f"slowdown={t_masked / t_dense - 1:+.1%} at {sim:.0%} sim "
        "(paper: +9.7% at 45% — software reuse must not win)",
    )
    emit(
        "software_reuse/structural_skip", t_compact,
        f"speedup={t_dense / t_compact:.2f}x at {sim:.0%} block similarity "
        "(skipping must be structural, the paper's thesis)",
    )
    return {"dense": t_dense, "masked": t_masked, "compact": t_compact}


if __name__ == "__main__":
    import sys

    from benchmarks.common import emit

    main(emit, measured_mode="--measured" in sys.argv)
