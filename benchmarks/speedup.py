"""Paper Fig. 10: end-to-end speedup from computation reuse.

Two views:

1. MEASURED (this host): decode-shaped reuse GEMM (compaction path) vs dense
   baseline across the similarity sweep — the shape of Fig. 10/12 on real
   hardware. CPU BLAS stands in for the MXU; the scaling with similarity is
   the reproduced object, not the absolute ratio.

2. MODELED (TPU v5e target): per-arch decode-step roofline speedup at the
   paper's Table-I similarity operating points, using the §Roofline cost
   model with the measured block-skip fraction. The paper's 8x includes a
   6.4x front-end-bypass component with no TPU analogue (XLA has no
   fetch/decode front-end); the transferable component is the skipped weight
   traffic + MACs, reported here.

3. ``--measured``: real decode steps on reduced archs with the reuse engine
   threaded; the skip fraction fed to the roofline model comes from the
   SENSOR COUNTERS (repro.sensor), not from the PAPER_SIMILARITY table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.configs import ARCHS
from repro.kernels import ops
from repro.launch.specs import SHAPES
from repro.roofline.model_cost import POD_MESH, cell_cost

# Table I similarity per workload class; mapped onto our archs
PAPER_SIMILARITY = {
    "qwen3-32b": 0.41,        # ResNet-like uncorrelated: 41%
    "mixtral-8x7b": 0.45,     # paper's "typical" operating point
    "rwkv6-7b": 0.68,         # 3DUnet-like sequence workload: 68%
    "zamba2-2.7b": 0.55,      # Minigo: 55%
    "gemma3-12b": 0.27,       # DeepSpeech: 27%
}


def measured_sweep(emit):
    rng = np.random.default_rng(0)
    m, k, n, bk = 128, 4096, 4096, 256
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    dense = jax.jit(lambda x, w: x @ w)
    t_dense = time_fn(dense, x, w)

    out = []
    gk = k // bk
    for sim in (0.0, 0.25, 0.45, 0.68, 0.9, 0.99):
        nb = max(int(round(gk * (1 - sim))), 1)
        kmask = jnp.asarray(
            (np.arange(gk) < nb).astype(np.int32))
        delta = jnp.asarray(
            np.where(np.repeat(np.asarray(kmask), bk)[None, :],
                     rng.normal(size=(m, k)), 0.0).astype(np.float32))
        fn = jax.jit(lambda d, w, p, km, nb=nb: ops.reuse_matmul_compact(
            d, w, p, km, block_k=bk, max_blocks=nb))
        t = time_fn(fn, delta, w, prev, kmask)
        speed = t_dense / t
        out.append((sim, speed))
        emit(f"speedup/measured_sim{int(sim * 100):02d}", t,
             f"speedup={speed:.2f}x vs dense {t_dense:.0f}us")
    return out


def modeled_tpu(emit):
    rows = []
    for arch, sim in PAPER_SIMILARITY.items():
        cfg = ARCHS[arch]
        cell = SHAPES["decode_32k"]
        base = cell_cost(cfg, cell, POD_MESH)
        # block-granular harvest: real activation similarity is structured;
        # granularity.py measures harvest/sim ratios ~0.7-0.9 at block_k=256
        harvest = 0.8 * sim
        reuse = cell_cost(cfg, cell, POD_MESH, reuse_skip_fraction=harvest)
        sp = base.step_s / reuse.step_s
        rows.append((arch, sim, sp))
        emit(f"speedup/modeled_tpu_{arch}", base.step_s * 1e6,
             f"paper_sim={sim};harvest={harvest:.2f};"
             f"reuse_step_us={reuse.step_s * 1e6:.0f};speedup={sp:.2f}x")
    return rows


def measured_decode(emit, *, steps: int = 10, batch: int = 2,
                    tuned_policy: str | None = None, archs=None):
    """Sensor-counter-driven speedup: run real decode steps, read the skip
    rates the kernels actually achieved, and feed THOSE to the roofline
    model (plus the site-local roofline speedup from the cost model).

    With `tuned_policy` (a repro.tune table JSON), each arch runs twice —
    default global-constant policy vs tuned per-site policy, both with the
    host-side mode refresh live — and the delta is reported."""
    from benchmarks.common import iter_measured_runs
    from repro.sensor.cost_model import sensor_speedup

    per_arch: dict[str, dict] = {}
    for arch, label, md in iter_measured_runs(
            steps=steps, batch=batch, tuned_policy=tuned_policy, archs=archs):
        fr = md.skip_fractions
        sp_site = sensor_speedup(md.report)
        cfg = ARCHS[arch]
        cell = SHAPES["decode_32k"]
        base = cell_cost(cfg, cell, POD_MESH)
        reuse = cell_cost(cfg, cell, POD_MESH,
                          reuse_skip_fraction=fr["weight_byte_skip_rate"])
        sp = base.step_s / reuse.step_s
        per_arch.setdefault(arch, {})[label] = (fr, sp)
        suffix = "" if label == "default" else "_tuned"
        emit(f"speedup/measured_decode_{arch}{suffix}", base.step_s * 1e6,
             f"measured_weight_byte_skip={fr['weight_byte_skip_rate']:.1%};"
             f"measured_tile_skip={fr['tile_skip_rate']:.1%};"
             f"site_roofline_speedup={sp_site['site_speedup']:.2f}x;"
             f"projected_step_speedup={sp:.2f}x "
             f"(from sensor counters over {steps} real decode steps)")
        if label == "tuned":
            (fr_d, sp_d), (fr_t, sp_t) = per_arch[arch]["default"], (fr, sp)
            emit(f"speedup/tuned_delta_{arch}", 0.0,
                 f"mac_skip {fr_d['mac_skip_rate']:.1%}->"
                 f"{fr_t['mac_skip_rate']:.1%};"
                 f"projected_speedup {sp_d:.2f}x->{sp_t:.2f}x")
    return sorted(per_arch.items())


def main(emit, *, measured_mode: bool = False, tuned_policy: str | None = None,
         steps: int = 10, batch: int = 2, archs=None):
    if measured_mode:
        return {"measured_decode": measured_decode(
            emit, steps=steps, batch=batch, tuned_policy=tuned_policy,
            archs=archs)}
    a = measured_sweep(emit)
    b = modeled_tpu(emit)
    return {"measured": a, "modeled": b}


if __name__ == "__main__":
    from benchmarks.common import emit, measured_cli

    args = measured_cli("Fig. 10 speedup: analytic sweep or measured decode")
    main(emit, measured_mode=args.measured or bool(args.tuned_policy),
         tuned_policy=args.tuned_policy, steps=args.steps, batch=args.batch,
         archs=args.archs)
