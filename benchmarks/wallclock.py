"""Measured wall-clock per execution path — the skip-rate → step-time payoff.

The sensor subsystem measures skip RATES; this benchmark measures what those
rates buy in STEP TIME, per execution path, on a high-similarity stream
(≥ 70 % of tiles skippable — the operating regime the paper's Table I
workloads sit in). The masked-grid kernel path suppresses the DMA and the MXU
op for a skipped tile but still walks the grid step; the ragged compacted-grid
path sizes the grid by the measured occupancy, so skipped tiles cost zero
steps — the difference is directly visible as wall-clock here, on the same
inputs, with bitwise-identical outputs.

Methodology notes:

* Operands are integer-valued floats (|v| small), so every path's f32
  accumulation is EXACT regardless of summation order — output equality
  across paths is asserted bitwise, not allclose.
* The Pallas paths run in interpret mode on CPU: the grid loop is unrolled
  into the jitted HLO, so step count translates to executed work exactly the
  way it does on the TPU pipeline (relative ordering is the reproduced
  object; absolute microseconds are CPU numbers).
* Results land in BENCH_kernels.json — the perf TRAJECTORY artifact: each run
  APPENDS one timestamped JSONL row (a legacy single-object file from older
  builds is absorbed as the first row), so consecutive runs accumulate a real
  history instead of overwriting it. The CI bench-smoke job runs the
  benchmark twice and asserts the file grew between runs.

Run:  PYTHONPATH=src python -m benchmarks.wallclock [--tiny] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn_stats
from repro.core.similarity import block_zero_mask
from repro.kernels import ops


def load_runs(path: str) -> list[dict]:
    """Previous runs from a trajectory file: JSONL rows, or — for a file
    written by a pre-trajectory build — one pretty-printed JSON object,
    absorbed as the single prior run."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        content = f.read().strip()
    if not content:
        return []
    try:
        return [json.loads(line) for line in content.splitlines() if line]
    except json.JSONDecodeError:
        pass
    try:
        return [json.loads(content)]  # legacy single-doc format
    except json.JSONDecodeError:
        print(f"warning: {path} is neither JSONL nor JSON; starting fresh")
        return []


def _is_jsonl(path: str) -> bool:
    try:
        with open(path) as f:
            for line in f:
                if line.strip():
                    json.loads(line)
        return True
    except (OSError, json.JSONDecodeError):
        return False


def append_run(path: str, doc: dict) -> int:
    """Append one run to the trajectory. A legacy pretty-printed single-doc
    file is migrated to JSONL once, via write-temp-then-rename so a crash
    can never truncate the accumulated history; steady state is a true O(1)
    append. Returns the number of runs now on file."""
    runs = load_runs(path)
    if runs and not _is_jsonl(path):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            for run in runs:
                f.write(json.dumps(run, sort_keys=True) + "\n")
        os.replace(tmp, path)
    with open(path, "a") as f:
        f.write(json.dumps(doc, sort_keys=True) + "\n")
    return len(runs) + 1


def build_stream(rng, m, k, bm, bk, skip_prob):
    """Integer-valued [M, K] delta with ~skip_prob of its tiles all-zero."""
    delta = rng.integers(-2, 3, size=(m, k)).astype(np.float32)
    gm, gk = m // bm, k // bk
    for i in range(gm):
        for j in range(gk):
            if rng.random() < skip_prob:
                delta[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0.0
    return delta


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Wall-clock per reuse execution path (BENCH_kernels.json)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized problem (seconds, not minutes)")
    ap.add_argument("--skip", type=float, default=0.80,
                    help="target tile-skip probability of the stream")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)

    if args.tiny:
        m, k, n, bm, bn, bk = 16, 1024, 256, 8, 128, 256
    else:
        m, k, n, bm, bn, bk = 64, 2048, 256, 8, 128, 256
    rng = np.random.default_rng(0)
    delta_np = build_stream(rng, m, k, bm, bk, args.skip)
    delta = jnp.asarray(delta_np)
    w = jnp.asarray(rng.integers(-3, 4, size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.integers(-5, 6, size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    mask_np = np.asarray(mask)
    gm, gk, gn = m // bm, k // bk, -(-n // bn)
    counts = mask_np.sum(axis=1)
    skip_rate = 1.0 - mask_np.mean()
    # The policy's budget from the measured occupancy; the stream is fixed
    # here, so the budget never trips the overflow fallback.
    budget = max(1, int(counts.max()))
    k_mask = jnp.asarray((mask_np.max(axis=0)).astype(np.int32))
    shared_budget = max(1, int(mask_np.max(axis=0).sum()))

    oracle = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)

    paths = {
        "dense_gemm": (
            jax.jit(lambda d, w, p: p + jnp.dot(
                d, w, preferred_element_type=jnp.float32)),
            (delta, w, prev),
            gm * gk * gn,  # walks every tile of every row
        ),
        "masked_ref": (
            jax.jit(lambda d, w, p, ms: ops.reuse_matmul_ref(
                d, w, p, ms, bm, bk)),
            (delta, w, prev, mask),
            gm * gk * gn,
        ),
        "kernel": (
            jax.jit(lambda d, w, p, ms: ops.reuse_matmul(
                d, w, p, ms, block_m=bm, block_n=bn, block_k=bk,
                interpret=True)),
            (delta, w, prev, mask),
            gm * gk * gn,  # full grid walked; DMA+MXU suppressed per tile
        ),
        "ragged": (
            jax.jit(lambda d, w, p, ms: ops.reuse_matmul_ragged(
                d, w, p, ms, block_m=bm, block_n=bn, block_k=bk,
                max_active_k=budget, interpret=True)),
            (delta, w, prev, mask),
            gm * budget * gn,  # skipped tiles cost zero grid steps
        ),
        "compact": (
            jax.jit(lambda d, w, p, km: ops.reuse_matmul_compact(
                d, w, p, km, block_k=bk, max_blocks=shared_budget)),
            (delta, w, prev, k_mask),
            gm * shared_budget * gn,
        ),
    }

    results = {}
    for name, (fn, fn_args, grid_steps) in paths.items():
        stats = time_fn_stats(fn, *fn_args)
        us = stats["p50_us"]
        out = fn(*fn_args)
        exact = bool(jnp.all(out == oracle))
        # New rows are a superset of the old schema (append-only trajectory:
        # old rows keep loading, tooling keys on us_per_call as before).
        results[name] = {
            "us_per_call": us,
            "p50_us": stats["p50_us"],
            "p95_us": stats["p95_us"],
            "grid_steps": grid_steps,
            "exact_vs_oracle": exact,
        }
        emit(f"wallclock/{name}", us,
             f"grid_steps={grid_steps};exact={exact};"
             f"p95_us={stats['p95_us']:.1f}")

    ragged_speedup = results["kernel"]["us_per_call"] / max(
        results["ragged"]["us_per_call"], 1e-9)
    doc = {
        "bench": "wallclock",
        "ts": time.time(),
        "config": {
            "m": m, "k": k, "n": n, "block_m": bm, "block_n": bn,
            "block_k": bk, "tile_skip_rate": float(skip_rate),
            "max_active_k": budget, "gk": gk,
        },
        "results": results,
        "ragged_vs_kernel_speedup": ragged_speedup,
    }
    n_runs = append_run(args.out, doc)
    print(f"skip_rate={skip_rate:.2f} budget={budget}/{gk} "
          f"ragged_vs_kernel_speedup={ragged_speedup:.2f}x -> {args.out} "
          f"(trajectory: {n_runs} runs)")

    for name, r in results.items():
        assert r["exact_vs_oracle"], f"{name} diverged from the oracle"
    if skip_rate >= 0.70:
        assert ragged_speedup > 1.0, (
            "ragged compacted grid must beat the masked full grid at "
            f">=70% skip (got {ragged_speedup:.2f}x)")
    return doc


if __name__ == "__main__":
    main()
