"""Measured wall-clock per execution path — the skip-rate → step-time payoff.

The sensor subsystem measures skip RATES; this benchmark measures what those
rates buy in STEP TIME, per execution path, on a high-similarity stream
(≥ 70 % of tiles skippable — the operating regime the paper's Table I
workloads sit in). The masked-grid kernel path suppresses the DMA and the MXU
op for a skipped tile but still walks the grid step; the ragged compacted-grid
path sizes the grid by the measured occupancy, so skipped tiles cost zero
steps — the difference is directly visible as wall-clock here, on the same
inputs, with bitwise-identical outputs.

Methodology notes:

* Operands are integer-valued floats (|v| small), so every path's f32
  accumulation is EXACT regardless of summation order — output equality
  across paths is asserted bitwise, not allclose.
* The kernel/ragged paths of the GRID-STEP comparison run interpret-mode
  Pallas on CPU: the grid loop is unrolled into the jitted HLO, so step
  count translates to executed work exactly the way it does on the TPU
  pipeline (relative ordering is the reproduced object; absolute
  microseconds are CPU numbers). Their rows are tagged
  backend="pallas_interpret" so downstream pricing can never mistake them
  for compiled measurements.
* The SWEEP (on by default; --no-sweep disables) is all-compiled: every
  path dispatches through kernels/backend.resolve(None) — the process's
  best compiled substrate — across skip ∈ {0, .25, .5, .75, .9}, checked
  bitwise against the interpret-mode Pallas oracle per point. The sweep
  re-derives the break-even skip from the measured curves
  (tune.harvest.derive_break_even_skip), records the exec-path gate that
  break-even implies, and validates the curves against the roofline
  kernel work model (roofline.validate.validate_kernel_sweep).
* Results land in BENCH_kernels.json — the perf TRAJECTORY artifact: each run
  APPENDS one timestamped JSONL row (a legacy single-object file from older
  builds is absorbed as the first row), so consecutive runs accumulate a real
  history instead of overwriting it. The CI bench-smoke job runs the
  benchmark twice and asserts the file grew between runs.

Run:  PYTHONPATH=src python -m benchmarks.wallclock [--tiny] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn_stats
from repro.core.policy import RAGGED_BREAK_EVEN_SKIP, ReusePolicy
from repro.core.reuse_cache import ReuseSiteSpec
from repro.core.similarity import block_zero_mask
from repro.kernels import backend as kernel_backend
from repro.kernels import ops
from repro.roofline.validate import validate_kernel_sweep
from repro.tune.harvest import derive_break_even_skip

# Compiled skip-rate sweep operating points: the regimes the paper's
# Table I workloads span, parity point (0) to deep-reuse decode (0.9).
SWEEP_SKIPS = (0.0, 0.25, 0.5, 0.75, 0.9)


def load_runs(path: str) -> list[dict]:
    """Previous runs from a trajectory file: JSONL rows, or — for a file
    written by a pre-trajectory build — one pretty-printed JSON object,
    absorbed as the single prior run."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        content = f.read().strip()
    if not content:
        return []
    try:
        return [json.loads(line) for line in content.splitlines() if line]
    except json.JSONDecodeError:
        pass
    try:
        return [json.loads(content)]  # legacy single-doc format
    except json.JSONDecodeError:
        print(f"warning: {path} is neither JSONL nor JSON; starting fresh")
        return []


def _is_jsonl(path: str) -> bool:
    try:
        with open(path) as f:
            for line in f:
                if line.strip():
                    json.loads(line)
        return True
    except (OSError, json.JSONDecodeError):
        return False


def append_run(path: str, doc: dict) -> int:
    """Append one run to the trajectory. A legacy pretty-printed single-doc
    file is migrated to JSONL once, via write-temp-then-rename so a crash
    can never truncate the accumulated history; steady state is a true O(1)
    append. Returns the number of runs now on file."""
    runs = load_runs(path)
    if runs and not _is_jsonl(path):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            for run in runs:
                f.write(json.dumps(run, sort_keys=True) + "\n")
        os.replace(tmp, path)
    with open(path, "a") as f:
        f.write(json.dumps(doc, sort_keys=True) + "\n")
    return len(runs) + 1


def build_stream(rng, m, k, bm, bk, skip_prob):
    """Integer-valued [M, K] delta with ~skip_prob of its tiles all-zero."""
    delta = rng.integers(-2, 3, size=(m, k)).astype(np.float32)
    gm, gk = m // bm, k // bk
    for i in range(gm):
        for j in range(gk):
            if rng.random() < skip_prob:
                delta[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0.0
    return delta


def run_sweep(m, k, n, bm, bn, bk, *, skips=SWEEP_SKIPS):
    """Compiled ragged-vs-skip-rate sweep: dense vs compiled reuse tiers.

    Every path here runs through `backend.resolve(None)` — the process's
    best COMPILED substrate (XLA tier on CPU, Pallas on TPU) — and each
    measurement is checked BITWISE against the interpret-mode Pallas masked
    kernel on the same inputs (the oracle the parity suite pins). The sweep
    yields the measured break-even skip (tune.harvest.derive_break_even_skip),
    the exec-path gate re-derived from it, and the roofline work-model
    validation (repro.roofline.validate.validate_kernel_sweep).
    """
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.integers(-3, 4, size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.integers(-5, 6, size=(m, n)).astype(np.float32))
    gk = k // bk
    tag = kernel_backend.tag()  # compiled substrate stamp, one per process
    rows = []
    for target in skips:
        delta = jnp.asarray(build_stream(rng, m, k, bm, bk, target))
        mask = block_zero_mask(delta, bm, bk)
        mask_np = np.asarray(mask)
        measured_skip = 1.0 - float(mask_np.mean())
        budget = max(1, int(mask_np.sum(axis=1).max()))
        k_mask = jnp.asarray(mask_np.max(axis=0).astype(np.int32))
        shared_budget = max(1, int(mask_np.max(axis=0).sum()))
        oracle = ops.reuse_matmul(
            delta, w, prev, mask, block_m=bm, block_n=bn, block_k=bk,
            interpret=True)

        paths = {
            "dense_gemm": (
                jax.jit(lambda d, w, p: p + jnp.dot(
                    d, w, preferred_element_type=jnp.float32)),
                (delta, w, prev), None),
            "kernel": (
                jax.jit(lambda d, w, p, ms: ops.reuse_matmul(
                    d, w, p, ms, block_m=bm, block_n=bn, block_k=bk)),
                (delta, w, prev, mask), None),
            "compact": (
                jax.jit(lambda d, w, p, km: ops.reuse_matmul_compact(
                    d, w, p, km, block_k=bk, max_blocks=shared_budget)),
                (delta, w, prev, k_mask), shared_budget),
            "ragged": (
                jax.jit(lambda d, w, p, ms: ops.reuse_matmul_ragged(
                    d, w, p, ms, block_m=bm, block_n=bn, block_k=bk,
                    max_active_k=budget)),
                (delta, w, prev, mask), budget),
        }
        for name, (fn, fn_args, max_ak) in paths.items():
            stats = time_fn_stats(fn, *fn_args)
            exact = bool(jnp.all(fn(*fn_args) == oracle))
            rows.append({
                "skip": float(target),
                "measured_skip_rate": measured_skip,
                "path": name,
                "us": stats["p50_us"], "p95_us": stats["p95_us"],
                "exact_vs_oracle": exact,
                "m": m, "k": k, "n": n,
                "block_m": bm, "block_n": bn, "block_k": bk,
                "max_active_k": max_ak,
                **tag,
            })
            emit(f"wallclock/sweep/{name}@{target}", stats["p50_us"],
                 f"exact={exact};backend={tag['backend']}")

    by_skip = {}
    for r in rows:
        by_skip.setdefault(r["skip"], {})[r["path"]] = r["us"]
    # The break-even being derived is the COMPACTION crossing (it gates
    # promotion to ragged/compact): the masked "kernel" path does dense
    # work by construction, so near-parity noise on it must not move the
    # gate — only the compaction paths compete against dense here.
    points = [
        (s, min(d["compact"], d["ragged"]), d["dense_gemm"])
        for s, d in sorted(by_skip.items())
    ]
    derived = derive_break_even_skip(points)
    # Gate re-derived from the compiled curves: a derived 2.0 ("compaction
    # never wins on this shape") demotes every skip level back to dense.
    policy = ReusePolicy(ragged_break_even_skip=derived)
    spec = ReuseSiteSpec(name="sweep", in_features=k, out_features=n,
                         block_m=bm, block_k=bk, block_n=bn)
    gate = {f"{s:.2f}": policy.decide_exec_path(spec, s, impl="jnp")
            for s in skips}
    validation = validate_kernel_sweep(rows)
    return {
        "skips": list(skips),
        "rows": rows,
        "derived_break_even_skip": derived,
        "default_break_even_skip": RAGGED_BREAK_EVEN_SKIP,
        "gate_exec_path": gate,
        "roofline": validation,
    }


def run_shard_sweep(mesh_spec, *, m=8, k=1024, n=512, bm=4, bk=128,
                    skips=(0.0, 0.5, 0.9), steps=16, warmup=4):
    """Sharded serve-step sweep: the donated reuse step on a model-sharded
    mesh vs its unsharded oracle, per skip regime.

    Three engines per operating point, on the SAME input stream:

      oracle  — unsharded, full [K, N] site: the bitwise truth for outputs
                and (collapsed) counters;
      local   — unsharded site at N/S output columns: the matched-per-shard-
                work baseline a shard's latency is compared against;
      sharded — the S-way engine with its cache device_put on the mesh model
                axis, stepped through a donated jit exactly like serve.

    Hard assertions are the sharded design's invariants: outputs and shard-
    summed counters bitwise-equal to the oracle, and zero all-gather/
    all-to-all touching cache buffers in the compiled step's post-SPMD HLO.
    `per_shard_latency_ratio` (sharded step time / matched-local step time)
    is RECORDED per row — on a real mesh it sits near 1.0; on a mocked
    host mesh the "devices" are host threads sharing the same cores, so the
    ratio is provenance-stamped ({mesh_shape, backend}) rather than gated.
    """
    from functools import partial

    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core.engine import ReuseEngine
    from repro.dist.shard import cache_shardings, cache_shape_signatures
    from repro.launch.mesh import mesh_axes, parse_mesh_spec
    from repro.roofline.hlo_parse import cache_collective_violations
    from repro.sensor.counters import COUNTER_SHARD_REDUCE

    mesh = parse_mesh_spec(mesh_spec)
    S = mesh_axes(mesh)["model_size"]
    replicated = NamedSharding(mesh, PartitionSpec())
    tag = kernel_backend.tag()

    def build(n_out, n_shards):
        eng = ReuseEngine(impl="jnp")
        eng.register("site", k, n_out, block_m=bm, block_k=bk)
        if n_shards > 1:
            eng.shard_sites(n_shards)
        return eng

    def make_step(eng):
        @partial(jax.jit, donate_argnums=(2,))
        def step(x, w, entry):
            out, entry, _ = eng.apply("site", x, w, None, entry)
            return out, entry

        return step

    def run_chain(step, xs, w, entry):
        outs, times = [], []
        for x in xs:
            t0 = time.perf_counter()
            out, entry = step(x, w, entry)
            jax.block_until_ready(out)
            times.append((time.perf_counter() - t0) * 1e6)
            outs.append(np.asarray(out))
        return outs, entry, float(np.median(times[warmup:]))

    def collapse(sensor):
        host = jax.device_get(sensor)
        return {
            key: (np.asarray(v).sum(axis=0)
                  if COUNTER_SHARD_REDUCE.get(key, "first") == "sum"
                  else np.take(np.asarray(v), 0, axis=0))
            for key, v in host.items()
        }

    rng = np.random.default_rng(11)
    w_full = rng.integers(-3, 4, size=(k, n)).astype(np.float32)
    rows = []
    for target in skips:
        # integer-valued stream: each step keeps ~target of its k-tiles
        # identical to the previous step (those tiles' deltas are zero)
        xs = [rng.integers(-2, 3, size=(m, k)).astype(np.float32)]
        for _ in range(steps - 1):
            nxt = xs[-1].copy()
            for j in range(k // bk):
                if rng.random() >= target:
                    nxt[:, j * bk:(j + 1) * bk] = rng.integers(
                        -2, 3, size=(m, bk))
            xs.append(nxt)
        xs_j = [jnp.asarray(x) for x in xs]

        eng_o = build(n, 1)
        outs_o, entry_o, _ = run_chain(
            make_step(eng_o), xs_j, jnp.asarray(w_full),
            eng_o.init_cache(m)["site"])

        eng_l = build(n // S, 1)
        _, _, p50_local = run_chain(
            make_step(eng_l), xs_j, jnp.asarray(w_full[:, : n // S]),
            eng_l.init_cache(m)["site"])

        eng_s = build(n, S)
        cache_s = eng_s.init_cache(m)
        cache_s = jax.device_put(
            cache_s, cache_shardings(eng_s, mesh, cache_s))
        entry_s = cache_s["site"]
        w_dev = jax.device_put(jnp.asarray(w_full), replicated)
        xs_dev = [jax.device_put(x, replicated) for x in xs_j]
        step_s = make_step(eng_s)

        def aval(a):
            return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=a.sharding)

        hlo = step_s.lower(
            aval(xs_dev[0]), aval(w_dev), jax.tree.map(aval, entry_s)
        ).compile().as_text()
        violations = cache_collective_violations(
            hlo, cache_shape_signatures(entry_s))

        outs_s, entry_s, p50_s = run_chain(step_s, xs_dev, w_dev, entry_s)

        bitwise_out = all(
            (a == b).all() for a, b in zip(outs_o, outs_s))
        sen_o = jax.device_get(entry_o["sensor"])
        sen_s = collapse(entry_s["sensor"])
        bitwise_counters = all(
            np.array_equal(np.asarray(sen_o[key]), sen_s[key])
            for key in sen_s)
        rows.append({
            "skip": float(target),
            "mesh_shape": {str(a): int(s) for a, s in mesh.shape.items()},
            "n_shards": S,
            "m": m, "k": k, "n": n, "block_m": bm, "block_k": bk,
            "sharded_step_us": p50_s,
            "matched_local_step_us": p50_local,
            "per_shard_latency_ratio": p50_s / max(p50_local, 1e-9),
            "bitwise_outputs_vs_oracle": bitwise_out,
            "bitwise_counters_vs_oracle": bitwise_counters,
            "hlo_cache_gather_free": not violations,
            "hlo_violations": violations,
            **tag,
        })
        emit(f"wallclock/shard/{mesh_spec}@{target}", p50_s,
             f"ratio={rows[-1]['per_shard_latency_ratio']:.2f};"
             f"bitwise={bitwise_out and bitwise_counters};"
             f"gather_free={not violations}")
    return {"mesh_spec": mesh_spec, "rows": rows}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Wall-clock per reuse execution path (BENCH_kernels.json)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized problem (seconds, not minutes)")
    ap.add_argument("--skip", type=float, default=0.80,
                    help="target tile-skip probability of the stream")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--no-sweep", action="store_true",
                    help="skip the compiled skip-rate sweep (grid-step "
                    "comparison only)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="also run the sharded serve-step sweep on this mesh "
                    "(repro.launch.mesh spec, e.g. host:8 — requires "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8); "
                    "asserts bitwise parity vs the unsharded oracle and a "
                    "cache-gather-free compiled step, records per-shard "
                    "latency vs matched-local-work with {mesh_shape, "
                    "backend} provenance")
    args = ap.parse_args(argv)

    if args.tiny:
        m, k, n, bm, bn, bk = 16, 1024, 256, 8, 128, 256
    else:
        m, k, n, bm, bn, bk = 64, 2048, 256, 8, 128, 256
    rng = np.random.default_rng(0)
    delta_np = build_stream(rng, m, k, bm, bk, args.skip)
    delta = jnp.asarray(delta_np)
    w = jnp.asarray(rng.integers(-3, 4, size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.integers(-5, 6, size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    mask_np = np.asarray(mask)
    gm, gk, gn = m // bm, k // bk, -(-n // bn)
    counts = mask_np.sum(axis=1)
    skip_rate = 1.0 - mask_np.mean()
    # The policy's budget from the measured occupancy; the stream is fixed
    # here, so the budget never trips the overflow fallback.
    budget = max(1, int(counts.max()))
    k_mask = jnp.asarray((mask_np.max(axis=0)).astype(np.int32))
    shared_budget = max(1, int(mask_np.max(axis=0).sum()))

    oracle = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)

    paths = {
        "dense_gemm": (
            jax.jit(lambda d, w, p: p + jnp.dot(
                d, w, preferred_element_type=jnp.float32)),
            (delta, w, prev),
            gm * gk * gn,  # walks every tile of every row
        ),
        "masked_ref": (
            jax.jit(lambda d, w, p, ms: ops.reuse_matmul_ref(
                d, w, p, ms, bm, bk)),
            (delta, w, prev, mask),
            gm * gk * gn,
        ),
        "kernel": (
            jax.jit(lambda d, w, p, ms: ops.reuse_matmul(
                d, w, p, ms, block_m=bm, block_n=bn, block_k=bk,
                interpret=True)),
            (delta, w, prev, mask),
            gm * gk * gn,  # full grid walked; DMA+MXU suppressed per tile
        ),
        "ragged": (
            jax.jit(lambda d, w, p, ms: ops.reuse_matmul_ragged(
                d, w, p, ms, block_m=bm, block_n=bn, block_k=bk,
                max_active_k=budget, interpret=True)),
            (delta, w, prev, mask),
            gm * budget * gn,  # skipped tiles cost zero grid steps
        ),
        "compact": (
            jax.jit(lambda d, w, p, km: ops.reuse_matmul_compact(
                d, w, p, km, block_k=bk, max_blocks=shared_budget)),
            (delta, w, prev, k_mask),
            gm * shared_budget * gn,
        ),
    }

    # Substrate provenance per path: the kernel/ragged grid-step comparison
    # deliberately runs interpret-mode Pallas (grid-step accounting is the
    # reproduced object); the jnp paths are compiled XLA.
    path_tags = {
        "dense_gemm": kernel_backend.tag(kernel_backend.XLA),
        "masked_ref": kernel_backend.tag(kernel_backend.XLA),
        "kernel": kernel_backend.tag(kernel_backend.INTERPRET),
        "ragged": kernel_backend.tag(kernel_backend.INTERPRET),
        "compact": kernel_backend.tag(kernel_backend.XLA),
    }

    results = {}
    for name, (fn, fn_args, grid_steps) in paths.items():
        stats = time_fn_stats(fn, *fn_args)
        us = stats["p50_us"]
        out = fn(*fn_args)
        exact = bool(jnp.all(out == oracle))
        # New rows are a superset of the old schema (append-only trajectory:
        # old rows keep loading, tooling keys on us_per_call as before).
        results[name] = {
            "us_per_call": us,
            "p50_us": stats["p50_us"],
            "p95_us": stats["p95_us"],
            "grid_steps": grid_steps,
            "exact_vs_oracle": exact,
            **path_tags[name],
        }
        emit(f"wallclock/{name}", us,
             f"grid_steps={grid_steps};exact={exact};"
             f"p95_us={stats['p95_us']:.1f}")

    ragged_speedup = results["kernel"]["us_per_call"] / max(
        results["ragged"]["us_per_call"], 1e-9)
    doc = {
        "bench": "wallclock",
        "ts": time.time(),
        "config": {
            "m": m, "k": k, "n": n, "block_m": bm, "block_n": bn,
            "block_k": bk, "tile_skip_rate": float(skip_rate),
            "max_active_k": budget, "gk": gk,
        },
        "substrate": kernel_backend.tag(),
        "results": results,
        "ragged_vs_kernel_speedup": ragged_speedup,
    }

    if not args.no_sweep:
        sweep = run_sweep(m, k, n, bm, bn, bk)
        doc["sweep"] = sweep
        be = sweep["derived_break_even_skip"]
        val = sweep["roofline"]
        print(f"sweep: derived_break_even_skip="
              f"{'never' if be >= 2.0 else f'{be:.2f}'} "
              f"(default {RAGGED_BREAK_EVEN_SKIP}) "
              f"gate={sweep['gate_exec_path']}")
        print(f"sweep: roofline predicted_break_even="
              f"{val['predicted_break_even_skip']:.2f} "
              f"direction_agreement={val['direction_agreement']:.2f} "
              f"ok={val['ok']}")

    if args.mesh:
        shard = run_shard_sweep(args.mesh)
        doc["shard_sweep"] = shard
        for r in shard["rows"]:
            print(f"shard sweep @skip={r['skip']}: "
                  f"sharded={r['sharded_step_us']:.0f}us "
                  f"matched-local={r['matched_local_step_us']:.0f}us "
                  f"ratio={r['per_shard_latency_ratio']:.2f} "
                  f"bitwise={r['bitwise_outputs_vs_oracle'] and r['bitwise_counters_vs_oracle']} "
                  f"gather_free={r['hlo_cache_gather_free']}")

    n_runs = append_run(args.out, doc)
    print(f"skip_rate={skip_rate:.2f} budget={budget}/{gk} "
          f"ragged_vs_kernel_speedup={ragged_speedup:.2f}x -> {args.out} "
          f"(trajectory: {n_runs} runs)")

    for name, r in results.items():
        assert r["exact_vs_oracle"], f"{name} diverged from the oracle"
    if skip_rate >= 0.70:
        assert ragged_speedup > 1.0, (
            "ragged compacted grid must beat the masked full grid at "
            f">=70% skip (got {ragged_speedup:.2f}x)")
    if "sweep" in doc:
        for r in doc["sweep"]["rows"]:
            assert r["exact_vs_oracle"], (
                f"compiled {r['path']}@skip={r['skip']} diverged from the "
                "interpret-mode oracle")
        assert doc["sweep"]["roofline"]["ok"], (
            "compiled sweep disagrees with the roofline kernel work model "
            f"beyond tolerance: {doc['sweep']['roofline']}")
    if "shard_sweep" in doc:
        for r in doc["shard_sweep"]["rows"]:
            assert r["bitwise_outputs_vs_oracle"], (
                f"sharded step @skip={r['skip']} outputs diverged from the "
                "unsharded oracle")
            assert r["bitwise_counters_vs_oracle"], (
                f"sharded step @skip={r['skip']} shard-summed counters "
                "diverged from the unsharded oracle")
            assert r["hlo_cache_gather_free"], (
                f"sharded step @skip={r['skip']} gathers cache state: "
                f"{r['hlo_violations']}")
    return doc


if __name__ == "__main__":
    main()
