"""Lower + compile one production cell (512 virtual devices) and print its
memory/cost/collective summary — the multi-pod dry-run in miniature.

    python examples/dryrun_single_cell.py --arch rwkv6-7b --shape long_500k
"""

import argparse
import json
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--shape", default="long_500k")
    ap.add_argument("--mesh", default="multipod")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell  # sets XLA_FLAGS on import

    rec = run_cell(args.arch, args.shape, args.mesh)
    rec.pop("traceback", None)
    print(json.dumps(rec, indent=2)[:4000])


if __name__ == "__main__":
    main()
