"""Quickstart: the ReuseSense engine on one linear site, end to end.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's algebra in ten lines: cache a site's previous input/output,
delta-encode the next input, skip zero tiles, and verify the output equals
the quantized dense GEMM exactly (the telescoping invariant).
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReuseEngine
from repro.quant import dequantize_int8, quantize_int8


def main():
    rng = np.random.default_rng(0)
    engine = ReuseEngine(impl="jnp")
    engine.register("mlp_in", in_features=1024, out_features=2048,
                    block_m=8, block_k=128)
    cache = engine.init_cache(batch=16)

    w = jnp.asarray(rng.normal(size=(1024, 2048)).astype(np.float32) * 0.05)
    x = jnp.asarray(rng.normal(size=(16, 1024)).astype(np.float32))

    print("step  similarity  skip_fraction  max|reuse - dense|")
    entry = cache["mlp_in"]
    for step in range(6):
        # consecutive inputs share ~70% of values in persistent channel
        # GROUPS (dead/saturated int8-activation regions persist in
        # contiguous runs; granularity.py quantifies block-alignment
        # sensitivity — unaligned similarity harvests ~0 at this tile width)
        if step:
            groups = rng.random(1024 // 128) < 0.7
            channels = np.repeat(groups, 128)
            x = jnp.asarray(np.where(channels[None, :], np.asarray(x),
                                     rng.normal(size=(16, 1024))).astype(np.float32))
        out, entry, stats = engine.apply("mlp_in", x, w, None, entry)
        xq = dequantize_int8(quantize_int8(x, entry["scale"]), entry["scale"])
        err = float(jnp.max(jnp.abs(out - xq @ w)))
        print(f"{step:4d}  {float(stats.similarity):10.3f}  "
              f"{float(stats.skip_fraction):13.3f}  {err:.2e}")

    print("\nThe skip_fraction column is the fraction of weight tiles whose "
          "HBM DMA + MXU work the Pallas kernel elides on TPU.")


if __name__ == "__main__":
    main()
