"""Adaptive-serving scenario: the online control plane (repro.control) rides
the serving loop. Every 4 decode steps the controller retunes per-site
tunables from windowed live counters, adapts `max_active_k` budgets from the
measured overflow-fallback rate, and the learned admission predictor places
requests by per-session similarity estimated from retirement telemetry — no
offline record→fit→reload round trip. Watch for `ControlReport` lines (one
per decision) and the decision-journal summary at the end.

    PYTHONPATH=src python examples/serve_adaptive.py

This is a thin driver over the production CLI path:
    python -m repro.launch.serve --arch qwen3-32b --reduced --reuse \
        --control-every 4 --control-journal decisions.jsonl
"""

import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import serve


def main():
    journal = tempfile.NamedTemporaryFile(
        mode="w", suffix=".jsonl", prefix="decisions-", delete=False
    )
    sys.argv = [
        "serve", "--arch", "qwen3-32b", "--reduced",
        "--requests", "8", "--batch-slots", "4",
        "--prompt-len", "24", "--cache-len", "96",
        "--max-new", "16", "--reuse",
        "--control-every", "4", "--control-journal", journal.name,
    ]
    serve.main()
    print(f"replay the run's decisions from {journal.name} with "
          f"repro.control.load_journal")


if __name__ == "__main__":
    main()
