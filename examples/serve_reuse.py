"""Serving scenario: continuous batching + ReuseSense decode on a reduced
Mixtral, with measured sensor telemetry (the live Fig.-12 analogue): a
per-request `SensorReport rid=... slot=... steps=... hit_rate=...` line is
printed at each slot retirement, and the full per-site report at the end.

    PYTHONPATH=src python examples/serve_reuse.py

This is a thin driver over the production CLI path:
    python -m repro.launch.serve --arch mixtral-8x7b --reduced --reuse
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve


def main():
    sys.argv = [
        "serve", "--arch", "mixtral-8x7b", "--reduced",
        "--requests", "8", "--batch-slots", "4",
        "--prompt-len", "24", "--cache-len", "96",
        "--max-new", "12", "--reuse",
    ]
    serve.main()


if __name__ == "__main__":
    main()
