"""End-to-end training driver: a ~2M-param qwen3-family model for a few
hundred steps on CPU, with async checkpointing, a mid-run simulated
preemption + resume, and a loss-decrease assertion.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""

import argparse
import shutil
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.ckpt.recovery import LoopConfig, ResilientLoop
from repro.configs import ARCHS
from repro.data.pipeline import SyntheticLMSource
from repro.launch.specs import ShapeCell
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = ARCHS["qwen3-32b"].reduced()
    src = SyntheticLMSource(vocab=cfg.vocab, seq_len=128, global_batch=8,
                            correlation=0.85)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3),
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        microbatch=2,
    ))

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in src.batch(i).items()}

    losses = []

    def on_metrics(i, m):
        losses.append(float(m["loss"]))
        if i % 20 == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")

    half = args.steps // 2
    loop = ResilientLoop(step, batch_fn,
                         LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25))
    state = loop.run(init_train_state(cfg, jax.random.PRNGKey(0)), 0, half,
                     on_metrics=on_metrics)
    print(f"--- simulated preemption at step {half}; resuming from latest "
          "checkpoint ---")
    del state

    loop2 = ResilientLoop(step, batch_fn,
                          LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25))
    state, start = loop2.resume_or_init(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
    print(f"resumed at step {start}")
    loop2.run(state, start, args.steps - start, on_metrics=on_metrics)

    print(f"\nloss {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")
    assert losses[-1] < losses[0] - 0.3, "training must make clear progress"
    print("OK")


if __name__ == "__main__":
    main()
