"""repro: ReuseSense on TPU — delta computation-reuse DNN framework in JAX.

Reproduction and TPU-native extension of:
  "ReuseSense: With Great Reuse Comes Greater Efficiency; Effectively
   Employing Computation Reuse on General-Purpose CPUs" (UPC, cs.AR 2023).

Public API surface:
  repro.core      — the reuse engine (delta encode, block-skip matmul, policy)
  repro.models    — composable pure-JAX model zoo (10 assigned architectures)
  repro.configs   — exact public configs per architecture
  repro.launch    — production mesh, multi-pod dry-run, train/serve drivers
  repro.sensor    — measured reuse telemetry & cost accounting
  repro.tune      — trace-driven per-site policy autotuning
"""

__version__ = "0.1.0"
