from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ckpt.recovery import LoopConfig, ResilientLoop

__all__ = [
    "AsyncCheckpointer", "LoopConfig", "ResilientLoop", "gc_checkpoints",
    "latest_step", "restore_checkpoint", "save_checkpoint",
]
