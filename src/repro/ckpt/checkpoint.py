"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000420/
        manifest.json           — tree structure, shapes, dtypes, host shard
                                  map, per-file sha256 (host-0 files)
        host_00000.npz          — this host's param/opt shards (flat leaves)
        host_00000.npz.sha256   — content hash sidecar (every host writes its
                                  own — host 0 can't know remote hashes when
                                  it writes the manifest)
    <dir>/step_000420.COMPLETE   — commit marker (atomic rename)

Design points for 1000+ node deployments:
  * each host writes only its local shards (no cross-host gather);
  * the COMPLETE marker is written only after every host's file exists, so a
    preempted save can never be restored from (torn-write safety);
  * a COMPLETE marker proves the save FINISHED, not that the bytes are still
    good — bitrot, torn page writes behind the marker, or a half-synced
    object-store copy all pass the marker check. `restore_checkpoint`
    therefore verifies each host file against its recorded sha256 and raises
    :class:`CorruptCheckpointError`; `latest_valid_step` walks markers
    newest-first past corrupt/missing steps to the newest restorable one
    (hash verification only — no array loading);
  * `restore` reshards from the manifest — the restoring mesh may have a
    different host count or layout (elastic restart after losing a pod);
  * `AsyncCheckpointer` runs saves on a writer thread so the train loop only
    blocks on device→host transfer, not on disk.

On this single-host container every save has n_hosts=1; the multi-host paths
are exercised by writing/reading synthetic multi-host manifests in tests.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class CorruptCheckpointError(RuntimeError):
    """A checkpoint's bytes don't match their recorded sha256 (or the payload
    is unreadable) even though its COMPLETE marker exists."""


def _sha256_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _ in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        keys.append("/".join(parts))
    return keys, [leaf for _, leaf in flat], treedef


def save_checkpoint(
    directory: str | Path,
    step: int,
    state: Any,
    *,
    host_id: int = 0,
    n_hosts: int = 1,
) -> Path:
    directory = Path(directory)
    step_dir = directory / f"step_{step:06d}"
    tmp_dir = directory / f".tmp_step_{step:06d}_{host_id}"
    tmp_dir.mkdir(parents=True, exist_ok=True)

    keys, leaves, _ = _flatten_with_paths(state)
    arrays = {}
    manifest_leaves = {}
    for key, leaf in zip(keys, leaves):
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype — store as uint16 view + dtype tag
        dtype_tag = str(leaf.dtype)
        if leaf.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest_leaves[key] = {"shape": list(leaf.shape), "dtype": dtype_tag}

    host_file = tmp_dir / f"host_{host_id:05d}.npz"
    np.savez(host_file, **arrays)
    digest = _sha256_file(host_file)
    # every host writes its own sidecar; host 0 additionally records ITS
    # file's hash in the manifest (it cannot know remote hosts' hashes at
    # manifest-write time — verification falls back to sidecars for those)
    (tmp_dir / f"{host_file.name}.sha256").write_text(digest + "\n")
    if host_id == 0:
        (tmp_dir / "manifest.json").write_text(json.dumps({
            "step": step,
            "n_hosts": n_hosts,
            "leaves": manifest_leaves,
            "files": {host_file.name: digest},
            "time": time.time(),
        }, indent=1))

    # atomic publish: rename tmp dir into place, then commit marker
    step_dir.mkdir(parents=True, exist_ok=True)
    for f in tmp_dir.iterdir():
        os.replace(f, step_dir / f.name)
    tmp_dir.rmdir()
    expected = [step_dir / f"host_{h:05d}.npz" for h in range(n_hosts)]
    if all(p.exists() for p in expected):
        marker = directory / f"step_{step:06d}.COMPLETE"
        marker.touch()
    return step_dir


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1].split(".")[0])
        for p in directory.glob("step_*.COMPLETE")
    ]
    return max(steps) if steps else None


def verify_checkpoint(directory: str | Path, step: int) -> None:
    """Integrity-check one checkpoint's bytes without loading any arrays.

    Every host file must exist and match its recorded sha256 — the manifest's
    `files` entry when present (host 0), else the host's own `.sha256`
    sidecar. Raises :class:`CorruptCheckpointError` naming the first bad
    file; pre-integrity checkpoints (no hashes anywhere) pass unverified,
    matching their era's guarantees."""
    step_dir = Path(directory) / f"step_{step:06d}"
    manifest_path = step_dir / "manifest.json"
    try:
        manifest = json.loads(manifest_path.read_text())
    except FileNotFoundError:
        raise CorruptCheckpointError(
            f"{step_dir}: manifest.json missing behind a COMPLETE marker")
    except (json.JSONDecodeError, OSError) as e:
        raise CorruptCheckpointError(
            f"{manifest_path}: unreadable manifest: {e}") from e
    hashes = manifest.get("files", {})
    for h in range(int(manifest.get("n_hosts", 1))):
        name = f"host_{h:05d}.npz"
        host_file = step_dir / name
        if not host_file.exists():
            raise CorruptCheckpointError(
                f"{host_file}: host file missing behind a COMPLETE marker")
        want = hashes.get(name)
        if want is None:
            sidecar = step_dir / f"{name}.sha256"
            if not sidecar.exists():
                continue  # pre-integrity checkpoint: nothing to check against
            want = sidecar.read_text().strip()
        got = _sha256_file(host_file)
        if got != want:
            raise CorruptCheckpointError(
                f"{host_file}: sha256 mismatch (stored {want[:12]}…, "
                f"actual {got[:12]}…) — bytes changed after the save "
                f"committed")


def latest_valid_step(directory: str | Path) -> int | None:
    """Newest step that passes integrity verification.

    Walks COMPLETE markers newest-first and skips any step whose payload is
    corrupt or missing — the recovery path after bitrot or a partially-synced
    restore source, where `latest_step` would hand the loop a checkpoint that
    explodes on restore. Verification is hash-only (no array loading)."""
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(
        (int(p.name.split("_")[1].split(".")[0])
         for p in directory.glob("step_*.COMPLETE")),
        reverse=True,
    )
    for step in steps:
        try:
            verify_checkpoint(directory, step)
        except CorruptCheckpointError:
            continue
        return step
    return None


def restore_checkpoint(
    directory: str | Path,
    step: int,
    state_struct: Any,
    *,
    shardings: Any | None = None,
) -> Any:
    """Elastic restore: loads all host files, reassembles leaves, and places
    them with `shardings` (which may target a different mesh than the save).

    Integrity is verified BEFORE any array is materialized: a hash mismatch,
    missing host file, or unreadable payload raises
    :class:`CorruptCheckpointError` — callers fall back to an older step via
    `latest_valid_step` instead of restoring silently-wrong weights."""
    directory = Path(directory)
    verify_checkpoint(directory, step)
    step_dir = directory / f"step_{step:06d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())

    merged: dict[str, np.ndarray] = {}
    for host_file in sorted(step_dir.glob("host_*.npz")):
        try:
            with np.load(host_file) as z:
                for key in z.files:
                    merged[key] = z[key]
        except Exception as e:  # zip/pickle-layer damage the hash check
            # can't see on pre-integrity checkpoints without sidecars
            raise CorruptCheckpointError(
                f"{host_file}: unreadable payload: {e}") from e

    keys, struct_leaves, treedef = _flatten_with_paths(state_struct)
    out_leaves = []
    for key, struct in zip(keys, struct_leaves):
        arr = merged[key]
        meta = manifest["leaves"][key]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(np.uint16)
            leaf = jnp.asarray(arr).view(jnp.bfloat16).reshape(meta["shape"])
        else:
            leaf = jnp.asarray(arr, dtype=meta["dtype"])
        out_leaves.append(leaf)
    state = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return state


def gc_checkpoints(directory: str | Path, keep: int = 3) -> None:
    directory = Path(directory)
    steps = sorted(
        int(p.name.split("_")[1].split(".")[0])
        for p in directory.glob("step_*.COMPLETE")
    )
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step_{s:06d}", ignore_errors=True)
        (directory / f"step_{s:06d}.COMPLETE").unlink(missing_ok=True)


class AsyncCheckpointer:
    """Writer-thread checkpointing: the step loop hands off host arrays and
    continues; `wait()` joins before exit or before starting a newer save."""

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_state)
                gc_checkpoints(self.directory, keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
