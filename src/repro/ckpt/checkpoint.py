"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000420/
        manifest.json       — tree structure, shapes, dtypes, host shard map
        host_00000.npz      — this host's param/opt shards (flattened leaves)
    <dir>/step_000420.COMPLETE   — commit marker (atomic rename)

Design points for 1000+ node deployments:
  * each host writes only its local shards (no cross-host gather);
  * the COMPLETE marker is written only after every host's file exists, so a
    preempted save can never be restored from (torn-write safety);
  * `restore` reshards from the manifest — the restoring mesh may have a
    different host count or layout (elastic restart after losing a pod);
  * `AsyncCheckpointer` runs saves on a writer thread so the train loop only
    blocks on device→host transfer, not on disk.

On this single-host container every save has n_hosts=1; the multi-host paths
are exercised by writing/reading synthetic multi-host manifests in tests.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _ in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        keys.append("/".join(parts))
    return keys, [leaf for _, leaf in flat], treedef


def save_checkpoint(
    directory: str | Path,
    step: int,
    state: Any,
    *,
    host_id: int = 0,
    n_hosts: int = 1,
) -> Path:
    directory = Path(directory)
    step_dir = directory / f"step_{step:06d}"
    tmp_dir = directory / f".tmp_step_{step:06d}_{host_id}"
    tmp_dir.mkdir(parents=True, exist_ok=True)

    keys, leaves, _ = _flatten_with_paths(state)
    arrays = {}
    manifest_leaves = {}
    for key, leaf in zip(keys, leaves):
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype — store as uint16 view + dtype tag
        dtype_tag = str(leaf.dtype)
        if leaf.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest_leaves[key] = {"shape": list(leaf.shape), "dtype": dtype_tag}

    np.savez(tmp_dir / f"host_{host_id:05d}.npz", **arrays)
    if host_id == 0:
        (tmp_dir / "manifest.json").write_text(json.dumps({
            "step": step,
            "n_hosts": n_hosts,
            "leaves": manifest_leaves,
            "time": time.time(),
        }, indent=1))

    # atomic publish: rename tmp dir into place, then commit marker
    step_dir.mkdir(parents=True, exist_ok=True)
    for f in tmp_dir.iterdir():
        os.replace(f, step_dir / f.name)
    tmp_dir.rmdir()
    expected = [step_dir / f"host_{h:05d}.npz" for h in range(n_hosts)]
    if all(p.exists() for p in expected):
        marker = directory / f"step_{step:06d}.COMPLETE"
        marker.touch()
    return step_dir


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1].split(".")[0])
        for p in directory.glob("step_*.COMPLETE")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | Path,
    step: int,
    state_struct: Any,
    *,
    shardings: Any | None = None,
) -> Any:
    """Elastic restore: loads all host files, reassembles leaves, and places
    them with `shardings` (which may target a different mesh than the save)."""
    directory = Path(directory)
    step_dir = directory / f"step_{step:06d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())

    merged: dict[str, np.ndarray] = {}
    for host_file in sorted(step_dir.glob("host_*.npz")):
        with np.load(host_file) as z:
            for key in z.files:
                merged[key] = z[key]

    keys, struct_leaves, treedef = _flatten_with_paths(state_struct)
    out_leaves = []
    for key, struct in zip(keys, struct_leaves):
        arr = merged[key]
        meta = manifest["leaves"][key]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(np.uint16)
            leaf = jnp.asarray(arr).view(jnp.bfloat16).reshape(meta["shape"])
        else:
            leaf = jnp.asarray(arr, dtype=meta["dtype"])
        out_leaves.append(leaf)
    state = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return state


def gc_checkpoints(directory: str | Path, keep: int = 3) -> None:
    directory = Path(directory)
    steps = sorted(
        int(p.name.split("_")[1].split(".")[0])
        for p in directory.glob("step_*.COMPLETE")
    )
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step_{s:06d}", ignore_errors=True)
        (directory / f"step_{s:06d}.COMPLETE").unlink(missing_ok=True)


class AsyncCheckpointer:
    """Writer-thread checkpointing: the step loop hands off host arrays and
    continues; `wait()` joins before exit or before starting a newer save."""

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_state)
                gc_checkpoints(self.directory, keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
