"""Fault-tolerance harness: restartable training, preemption, stragglers.

`ResilientLoop` wraps a step function with the production failure policy:

  * periodic async checkpoints + resume-from-latest on (re)start;
  * SIGTERM/preemption hook → synchronous final checkpoint before exit
    (cloud TPU preemption semantics);
  * bounded retry on transient step failure (collective timeout, device
    error): re-restore from the last complete VERIFIED checkpoint and replay
    — `latest_valid_step` hash-checks payloads so a corrupt checkpoint
    behind a COMPLETE marker is walked past, and the deterministic data
    pipeline (data/pipeline.py) makes replay exact;
  * straggler watchdog (`repro.guard.watchdog.StragglerWatchdog`, shared with
    the serving plane's quarantine breaker): a step slower than
    `straggler_factor`× the window median is logged with a re-shard
    recommendation. On real fleets this feeds the controller that evicts the
    slow host; here it is exercised by fault-injection tests.
"""

from __future__ import annotations

import dataclasses
import signal
from typing import Any, Callable

from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_valid_step,
    restore_checkpoint,
)
from repro.guard.watchdog import StragglerWatchdog
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 3
    straggler_factor: float = 2.0
    straggler_window: int = 32


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        batch_fn: Callable[[int], Any],
        cfg: LoopConfig,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.watchdog = StragglerWatchdog(
            factor=cfg.straggler_factor, window=cfg.straggler_window)
        self._preempted = False

    # The watchdog owns the raw data; these aliases preserve the loop's
    # historical reporting surface.
    @property
    def step_times(self) -> list[float]:
        return self.watchdog.step_times

    @property
    def straggler_events(self) -> list[dict]:
        return self.watchdog.events

    def _handle_preemption(self, signum, frame):
        self._preempted = True

    def resume_or_init(self, init_state_fn, *, shardings=None):
        last = latest_valid_step(self.cfg.ckpt_dir)
        if last is not None:
            struct = init_state_fn()  # cheap on CPU smoke scale; eval_shape OK too
            state = restore_checkpoint(
                self.cfg.ckpt_dir, last, struct, shardings=shardings
            )
            return state, last + 1
        return init_state_fn(), 0

    def run(
        self,
        state: Any,
        start_step: int,
        num_steps: int,
        *,
        on_metrics: Callable[[int, dict], None] | None = None,
        fail_injector: Callable[[int], None] | None = None,
    ) -> Any:
        old = signal.signal(signal.SIGTERM, self._handle_preemption)
        try:
            step = start_step
            retries = 0
            while step < start_step + num_steps:
                t0 = obs_trace.now()  # perf_counter: immune to clock steps
                try:
                    if fail_injector is not None:
                        fail_injector(step)
                    batch = self.batch_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    retries = 0
                except Exception:
                    retries += 1
                    if retries > self.cfg.max_retries:
                        self.ckpt.wait()
                        raise
                    last = latest_valid_step(self.cfg.ckpt_dir)
                    if last is not None:
                        self.ckpt.wait()
                        state = restore_checkpoint(
                            self.cfg.ckpt_dir, last, state
                        )
                        step = last + 1
                    continue

                self.watchdog.observe(step, obs_trace.now() - t0)
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if step % self.cfg.ckpt_every == 0 or self._preempted:
                    self.ckpt.save(step, state)
                if self._preempted:
                    self.ckpt.wait()
                    break
                step += 1
            self.ckpt.wait()
            return state
        finally:
            signal.signal(signal.SIGTERM, old)
