"""ModelConfig — the single config dataclass all 10 architectures instantiate.

Every knob any assigned architecture needs is a first-class field; configs are
frozen dataclasses so they hash (jit static args) and print reproducibly.
`reduced()` returns the same *family* at smoke-test scale (small width/depth,
few experts, tiny vocab) per the assignment contract.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- attention pattern ---
    attn_kind: str = "full"      # full | swa | local_global | none
    window: int = 4096           # swa / local-layer window
    local_ratio: int = 0         # local_global: N local layers per 1 global
    causal: bool = True          # False => encoder (bidirectional)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: str = "rope"           # rope | mrope | none
    rope_theta: float = 1_000_000.0

    # --- mlp ---
    mlp_kind: str = "swiglu"     # swiglu | gelu | relu2

    # --- moe ---
    n_experts: int = 0
    top_k: int = 1
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # --- ssm / hybrid ---
    ssm_kind: str = "none"       # rwkv6 | mamba2
    ssm_state: int = 64
    ssm_head_dim: int = 64
    hybrid_attn_every: int = 0   # zamba2: one shared attn block per N ssm blocks

    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    max_seq_len: int = 131072
    frontend: str = "none"       # none | audio | vision
    # bf16 params/compute for the TPU target; smoke tests execute in f32
    # because XLA:CPU cannot *execute* bf16xbf16->f32 dots (it compiles fine).
    param_dtype: str = "bfloat16"

    # --- execution knobs (not architecture) ---
    # §Perf levers for decode memory (see EXPERIMENTS.md):
    # duplicate KV heads up to this count so the cache's head dim divides the
    # TP axis and shards 16-way instead of replicating (vLLM-style GQA
    # replication, but for sharding). 0 = off.
    kv_head_pad_to: int = 0
    # store the KV cache as int8 codes with a fixed scale (halves KV bytes;
    # consistent with the paper's int8 inference setting). off by default.
    kv_cache_quant: bool = False
    kv_quant_scale: float = 0.05
    attn_chunk_q: int = 512      # blockwise-attention query chunk
    attn_chunk_kv: int = 1024    # blockwise-attention kv chunk
    loss_chunk: int = 512        # chunked-xent sequence chunk
    remat: bool = True           # remat each block in training
    # "full": recompute everything in backward (min memory, +1 fwd pass of
    # FLOPs AND of TP all-reduces). "dots": save matmul/psum outputs —
    # backward skips both the recompute FLOPs and the re-communication
    # (§Perf iteration 3 for collective-bound training).
    remat_policy: str = "full"
    scan_layers: bool = True     # scan over stacked superblocks

    # ---- derived ----
    @property
    def dtype(self):
        import jax.numpy as jnp

        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    @property
    def d_inner(self) -> int:          # mamba2 expansion
        return 2 * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_kind == "mamba2":
            return self.d_inner // self.ssm_head_dim
        if self.ssm_kind == "rwkv6":
            return self.d_model // self.ssm_head_dim
        return 0

    @property
    def superblock_layers(self) -> int:
        """How many network layers one scanned superblock covers."""
        if self.attn_kind == "local_global" and self.local_ratio:
            return self.local_ratio + 1
        if self.hybrid_attn_every:
            return self.hybrid_attn_every
        return 1

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.superblock_layers == 0, (
            self.n_layers, self.superblock_layers)
        return self.n_layers // self.superblock_layers

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def kv_heads_eff(self) -> int:
        """KV heads as laid out in the cache (after §Perf duplication)."""
        return max(self.n_kv_heads, self.kv_head_pad_to)

    def param_count(self) -> int:
        """Total parameters (used for MODEL_FLOPS = 6·N·D roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mlp_kind == "swiglu":
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        if self.ssm_kind == "rwkv6":
            per_layer = 5 * d * d + d * d + per_mlp  # r,k,v,g,w(+lora approx) + out
            n += self.n_layers * per_layer
        elif self.ssm_kind == "mamba2":
            di = self.d_inner
            per_ssm = d * (2 * di + 2 * self.ssm_state + self.n_ssm_heads) + di * d
            n_ssm_layers = self.n_layers
            n += n_ssm_layers * per_ssm
            if self.hybrid_attn_every:
                # one shared attn+mlp block reused across applications
                n += per_attn + per_mlp
        else:
            per_layer = per_attn + per_mlp
            if self.n_experts:
                per_layer = per_attn + self.n_experts * per_mlp
                per_layer += d * self.n_experts  # router
                if self.shared_expert:
                    per_layer += per_mlp
            n += self.n_layers * per_layer
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: routed top_k + shared)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_mlp = 3 * d * f if self.mlp_kind == "swiglu" else 2 * d * f
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * per_mlp
        return total - inactive

    def reduced(self) -> "ModelConfig":
        """Same family, smoke-test scale. Keeps every structural feature."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2 * self.superblock_layers, self.superblock_layers),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            window=min(self.window, 64),
            max_seq_len=256,
            attn_chunk_q=32,
            attn_chunk_kv=32,
            loss_chunk=32,
            ssm_head_dim=32,
            ssm_state=16,
            param_dtype="float32",
        )
