"""Gemma-3-12B [hf:google/gemma-3-1b-pt family; unverified].

Dense 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144; 5:1
local:global attention (local window 1024), 128k context, head_dim=256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    attn_kind="local_global",
    local_ratio=5,
    window=1024,
    mlp_kind="gelu",
    qk_norm=True,
    rope="rope",
    rope_theta=1000000.0,
    max_seq_len=131072,
)
