"""HuBERT X-Large [arXiv:2106.07447; unverified].

Encoder-only 48L d_model=1280 16H (MHA, kv=16) d_ff=5120 vocab=504
(masked-unit prediction head). Audio frontend is a STUB: input_specs()
provides precomputed frame embeddings at d_model width.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    attn_kind="full",
    causal=False,
    mlp_kind="gelu",
    rope="none",
    frontend="audio",
    tie_embeddings=False,
)
