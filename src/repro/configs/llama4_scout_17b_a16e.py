"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
with a shared expert (early-fusion multimodal family; text backbone here).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    attn_kind="full",
    mlp_kind="swiglu",
    n_experts=16,
    top_k=1,
    shared_expert=True,
    rope="rope",
    rope_theta=500000.0,
)
