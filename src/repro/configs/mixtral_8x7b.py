"""Mixtral-8x7B [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2,
sliding-window attention (W=4096).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    attn_kind="swa",
    window=4096,
    mlp_kind="swiglu",
    n_experts=8,
    top_k=2,
    rope="rope",
    rope_theta=1000000.0,
)
