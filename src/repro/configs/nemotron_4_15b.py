"""Nemotron-4-15B [arXiv:2402.16819; unverified].

Dense 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU
MLP (no gating), untied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    attn_kind="full",
    mlp_kind="relu2",
    tie_embeddings=False,
    rope="rope",
    rope_theta=10000.0,
)
