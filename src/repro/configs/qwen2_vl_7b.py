"""Qwen2-VL-7B [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, M-RoPE, dynamic
resolution. Vision frontend is a STUB (precomputed patch embeddings merge
into the token stream); the LM backbone is what the shapes exercise.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    attn_kind="full",
    mlp_kind="swiglu",
    qkv_bias=True,
    rope="mrope",
    rope_theta=1000000.0,
    frontend="vision",
)
