"""Qwen3-32B [hf:Qwen/Qwen3-8B family; hf].

Dense 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk-norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    attn_kind="full",
    mlp_kind="swiglu",
    qk_norm=True,
    rope="rope",
    rope_theta=1000000.0,
)
