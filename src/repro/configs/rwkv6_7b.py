"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf].

32L d_model=4096, attention-free (WKV6 with data-dependent decay),
channel-mix d_ff=14336 (3.5x), vocab=65536, head_size 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=14336,
    vocab=65536,
    attn_kind="none",
    rope="none",
    ssm_kind="rwkv6",
    ssm_head_dim=64,
    tie_embeddings=False,
)
