"""Zamba2-2.7B [arXiv:2411.15242; hf].

Hybrid: 54 Mamba2 blocks (d_model=2560, ssm_state=64) with a SHARED
attention+MLP block applied every 6 Mamba blocks (9 applications, one set of
weights). Attn 32H kv=32 (MHA, head_dim=80), d_ff=10240, vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    attn_kind="full",
    mlp_kind="gelu",
    rope="rope",
    rope_theta=10000.0,
    ssm_kind="mamba2",
    ssm_state=64,
    ssm_head_dim=64,
    hybrid_attn_every=6,
)
