"""repro.control — online adaptive control plane for reuse serving.

Where `repro.sensor` measures and `repro.tune` fits offline, this package
closes the loop LIVE: a host-side :class:`Controller` runs on a background
cadence inside the serving loop and adapts the reuse policy from the in-cache
counters directly — no JSONL round trip:

* :mod:`controller` — the cadence driver (`Controller.step(engine, cache)`);
* :mod:`retune`     — windowed counter deltas → guardrailed tunables moves,
                      through the SAME harvest model as the offline fitter
                      (`repro.tune.harvest`);
* :mod:`budget`     — `max_active_k` adaptation from the measured
                      `overflow_fallbacks` rate;
* :mod:`admit`      — learned per-session admission predictor
                      (replaces the caller-trusted `Request.predicted_sim`);
* :mod:`report`     — typed decisions + the JSONL decision journal
                      (audit/replay);
* :mod:`replay`     — ``python -m repro.control.replay journal.jsonl``:
                      re-applies a journal to a fresh policy state (and,
                      with ``--arch``, a fresh engine) and asserts the
                      reproduced trajectory matches the recorded one;
* :mod:`restore`    — startup precedence between a checkpointed ctrl block
                      and the tuned-policy table (checkpoint < table < live),
                      journaled as kind="restore" decisions.

Serving entry point: ``python -m repro.launch.serve ... --control-every N``.
"""

from repro.control.admit import AdmissionPredictor
from repro.control.budget import adapt_budget
from repro.control.controller import ControlConfig, Controller
from repro.control.report import (
    CONTROL_JOURNAL_SCHEMA_VERSION,
    ControlReport,
    Decision,
    DecisionJournal,
    load_journal,
)
from repro.control.replay import ReplayResult, replay_rows
from repro.control.restore import resolve_restored_ctrl
from repro.control.retune import (
    bounded_tunables,
    snapshot_entry,
    window_layer_records,
    window_record,
)

__all__ = [
    "CONTROL_JOURNAL_SCHEMA_VERSION",
    "AdmissionPredictor",
    "ControlConfig",
    "ControlReport",
    "Controller",
    "Decision",
    "DecisionJournal",
    "ReplayResult",
    "adapt_budget",
    "bounded_tunables",
    "load_journal",
    "replay_rows",
    "resolve_restored_ctrl",
    "snapshot_entry",
    "window_layer_records",
    "window_record",
]
