"""Learned admission predictor — per-session similarity from retirement data.

PR 2's slot-affinity placement trusted a caller-provided
``Request.predicted_sim`` (a synthetic prior in the demo driver). This
estimator replaces it with a *learned* per-session prediction fit from the
one ground-truth signal the runtime already produces: the per-slot hit-rate
snapshot (`Request.telemetry`) taken at retirement.

State model — three clearly-separated kinds, because they have different
lifetimes:

* **session estimates** (`sessions`) — EMA of retired hit rates keyed by the
  request's session; survive across requests of the same session. A session
  never seen before predicts the population EMA (`global_est`).
* **per-slot occupant state** (the `slot_session` binding) — belongs to the
  CURRENT occupant only; retirement telemetry is attributed through it.
  `reset_slot` (called by the scheduler on slot recycle) clears it: a new
  session must not inherit the previous occupant's similarity estimate, and
  telemetry arriving after a recycle must not be attributed to the departed
  session.
* **lane character** (`lane_character`) — the last RETIRED stream's hit rate
  per slot, used as the lane-side signal for affinity placement (matching
  serve.py's historical lane_sim semantics). Deliberately survives recycling:
  it describes the lane's policy history, not any live session.
"""

from __future__ import annotations

from typing import Any


def _session_key(req: Any) -> Any:
    session = getattr(req, "session", None)
    return session if session is not None else req.rid


class AdmissionPredictor:
    """Per-session stream-similarity estimator fed by retirement telemetry."""

    def __init__(self, *, decay: float = 0.5, prior: float = 0.35,
                 max_sessions: int = 4096):
        self.decay = decay
        self.prior = prior
        self.max_sessions = max_sessions
        self.global_est = prior              # population EMA (cold fallback)
        # least-recently-updated eviction at max_sessions: session-less
        # one-shot requests are keyed by rid (never looked up again), so an
        # unbounded store would grow with total requests served
        self.sessions: dict[Any, float] = {}
        self.slot_session: dict[int, Any] = {}
        self.lane_character: dict[int, float] = {}
        self.observations = 0
        self.rejected_observations = 0  # forged/non-finite telemetry dropped

    # ------------------------------------------------------------- prediction
    def predict(self, req: Any) -> float:
        """Predicted stream similarity for a request — its session's learned
        estimate, else the population estimate. The ContinuousBatcher's
        `predict_sim_fn` hook."""
        return self.sessions.get(_session_key(req), self.global_est)

    def slot_affinity(self, slot: int) -> float:
        """Lane-side affinity signal: the last retired stream's hit rate.
        The ContinuousBatcher's `slot_sim_fn` hook."""
        return self.lane_character.get(slot, 0.0)

    # --------------------------------------------------------------- learning
    def on_placed(self, req: Any) -> None:
        """Bind a slot to its new occupant's session (scheduler `on_place`
        hook, called at admission)."""
        self.slot_session[req.slot] = _session_key(req)

    def observe_retirement(self, req: Any) -> None:
        """Fold one retired request's telemetry into its session estimate.

        Attribution goes through the slot binding when one exists, so
        telemetry can never be credited to a session that already left the
        slot (reset_slot clears the binding on recycle).

        Telemetry is UNTRUSTED input (it crosses the scheduler boundary and
        the guard plane's lying-telemetry scenario forges it): non-finite
        hit rates are dropped entirely — one NaN folded into the EMAs would
        poison every future prediction irreversibly — and finite values are
        clamped to the [0, 1] range a hit rate can actually take. The slot
        binding is still consumed on a dropped observation, so forged
        telemetry can't leave a stale attribution behind."""
        import math

        t = req.telemetry or {}
        if int(t.get("steps", 0)) <= 0:
            return
        hit = float(t.get("hit_rate", 0.0))
        if not math.isfinite(hit):
            self.slot_session.pop(req.slot, None)
            self.rejected_observations += 1
            return
        hit = min(max(hit, 0.0), 1.0)
        key = self.slot_session.pop(req.slot, _session_key(req))
        prev = self.sessions.pop(key, self.global_est)
        while len(self.sessions) >= self.max_sessions:
            del self.sessions[next(iter(self.sessions))]  # oldest update
        self.sessions[key] = (1.0 - self.decay) * prev + self.decay * hit
        self.global_est = (1.0 - self.decay) * self.global_est + self.decay * hit
        self.lane_character[req.slot] = hit
        self.observations += 1

    # ---------------------------------------------------------------- recycle
    def reset_slot(self, slot: int) -> None:
        """Slot recycle: drop the occupant binding so the next stream starts
        from its own session prior and late telemetry can't be attributed to
        the departed session. Lane character is intentionally retained (see
        module docstring)."""
        self.slot_session.pop(slot, None)

    # -------------------------------------------------------------- reporting
    def stats(self) -> dict[str, Any]:
        return {
            "global_est": self.global_est,
            "n_sessions": len(self.sessions),
            "observations": self.observations,
            "rejected_observations": self.rejected_observations,
        }
