"""Budget adapter — max_active_k from the measured overflow-fallback rate.

The compacted execution tiers (ragged grid / gathered compact GEMM) run a
static k-extent budget; an evaluation whose live tile count overflows it
falls back to the full extent (`lax.cond` in kernels/ops.py), which is always
correct but forfeits that step's entire grid-step saving. The sensor's
`overflow_fallbacks` counter measures exactly how often that happens, so the
budget becomes a closed-loop knob:

* **widen** when the windowed fallback rate exceeds `widen_fallback_rate` —
  each overflow costs a full gm·gn·gk walk, so a budget that trips often is
  worse than a looser one;
* **tighten** when a window ran fallback-free AND the measured occupancy
  (plus the policy's standard headroom) sits below the current budget — the
  stream got more similar, and every unused budget block is a grid step the
  kernel still walks. The controller additionally requires a STREAK of
  fallback-free windows (`ControlConfig.tighten_clean_windows`) before
  applying a tighten, and a much longer streak
  (`ControlConfig.tighten_floor_streak`) before re-entering a budget a
  previous widen recorded as overflowed — so a boundary-sitting stream
  can't ping-pong widen/tighten (each move retraces the jitted step).

Both directions move ONE block per interval (bounded step: each move
retraces the jitted step, and the next window re-measures before moving
again).
"""

from __future__ import annotations

from repro.core.policy import ReusePolicy
from repro.tune.trace import SiteTraceRecord


def adapt_budget(
    spec,
    win: SiteTraceRecord,
    *,
    n_layers: int,
    widen_fallback_rate: float,
) -> tuple[int, str] | None:
    """Proposed new max_active_k for one site from its window, or None.

    `n_layers` scales the per-step evaluation count for stacked sites (every
    layer slice's evaluation falls back independently)."""
    if spec.exec_path not in ("ragged", "compact") or spec.max_active_k is None:
        return None
    gk = -(-spec.in_features // spec.block_k)
    if win.block_k != spec.block_k:
        # the window was measured on a different tile grid (the retuner moved
        # block_k this interval); wait for a clean window
        return None
    evals = max(win.steps * max(n_layers, 1), 1)
    rate = win.overflow_fallbacks / evals
    budget = spec.max_active_k
    if rate > widen_fallback_rate and budget < gk:
        return budget + 1, (
            f"overflow_fallbacks {win.overflow_fallbacks}/{evals} evals "
            f"({rate:.0%}) > {widen_fallback_rate:.0%}"
        )
    if win.overflow_fallbacks == 0:
        want = ReusePolicy.ragged_budget(gk, win.tile_skip_rate)
        if want < budget:
            return budget - 1, (
                f"zero fallbacks, measured occupancy wants {want} "
                f"of {gk} blocks"
            )
    return None
