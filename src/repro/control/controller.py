"""Controller — the host-side adaptive control plane for reuse serving.

Closes, on a background cadence INSIDE the serving loop (no JSONL round
trip), the three feedback loops the offline tooling only closed between
runs:

1. **online retuner** — per-site `SiteTunables` refit from windowed deltas of
   the live sensor counters through the same harvest model as
   `repro.tune.fit`, with guardrails (min-samples floor, bounded step per
   interval, the engine's existing mode-flip cooldown) so one noisy window
   can never thrash the policy;
2. **budget adapter** — `max_active_k` widened/tightened from the measured
   `overflow_fallbacks` rate vs grid-step savings;
3. **admission predictor** — the attached :class:`AdmissionPredictor` learns
   per-session similarity from retirement telemetry; the controller journals
   its population estimate so admission drift is auditable.

Stacked sites get a second retune tier: each layer's own windowed counters
feed the same harvest model and land as "site@layer" ctrl-lane rows —
per-layer thresholds inside one scanned stack, journaled per layer, applied
as array writes (never a retrace).

`Controller.step(engine, cache)` returns a :class:`ControlReport`; the caller
rebuilds its jitted step exactly when `report.changed` (the same contract as
`ReuseEngine.refresh_modes`, which the controller invokes last so mode/exec
transitions see the freshly-installed tunables and keep their hysteresis +
cooldown guardrails — and whose per-layer mode flips, being ctrl-array
writes, are journaled but never force a rebuild). Every move lands in the
decision journal.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.control.admit import AdmissionPredictor
from repro.control.budget import adapt_budget
from repro.control.report import ControlReport, Decision, DecisionJournal
from repro.control.retune import (
    bounded_tunables,
    snapshot_entry,
    window_layer_records,
    window_record,
)
from repro.core.reuse_cache import resolve_exec_path
from repro.tune.fit import fit_layer
from repro.tune.harvest import FitConfig, measured_latency_note, solve_site

# SiteTunables fields the retuner may move, journaled field-by-field.
_TUNABLE_FIELDS = (
    "sim_threshold", "min_work_flops", "block_k",
    "hysteresis_margin", "hysteresis_steps", "exec_path", "max_active_k",
)
# The array-resident subset a per-layer ctrl-lane row may move (spec-level
# knobs stay site-granular — they are baked into the traced dispatch).
_LAYER_FIELDS = (
    "sim_threshold", "min_work_flops", "hysteresis_margin", "hysteresis_steps",
)


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    # Guardrail: windows with fewer site evaluations than this are ignored
    # (not enough samples to act on).
    min_window_steps: int = 4
    # Guardrail: sim_threshold moves at most this far per interval.
    max_threshold_step: float = 0.10
    # Guardrail: min_work may only RISE by this factor per interval (lowering
    # — admission — applies immediately; see retune module docstring).
    max_min_work_raise: float = 8.0
    # Budget adapter: windowed overflow-fallback rate above which the
    # compacted-path budget widens by one block.
    widen_fallback_rate: float = 0.10
    # Budget adapter anti-thrash: tightening needs this many CONSECUTIVE
    # fallback-free windows (widening is immediate — every overflow forfeits
    # that step's whole grid saving, while a too-wide budget only walks some
    # extra steps). Prevents the boundary ping-pong where widen/tighten
    # alternate and each move costs a jitted-step retrace.
    tighten_clean_windows: int = 2
    # Re-entering a budget that previously OVERFLOWED (the floor a widen
    # recorded) needs this much longer a clean streak — a boundary stream
    # whose peaks keep tripping the floor resets the streak and never
    # re-tries the known-bad budget, while a genuinely-calmed stream earns
    # the retry after a sustained quiet run.
    tighten_floor_streak: int = 8
    # Journal an "admit" decision when the predictor's population estimate
    # moved by at least this much since the last interval.
    admit_report_eps: float = 0.05
    # Decision-journal JSONL path (None = in-memory only).
    journal_path: str | None = None
    # The shared harvest model's settings (same dataclass the offline fitter
    # takes — one cost model, one config surface). Its `pallas_target` is
    # ignored: the controller derives it from engine.impl each step so pins
    # always match the substrate the engine executes.
    fit: FitConfig = dataclasses.field(default_factory=FitConfig)
    # Measured per-(site, layer, exec_path) latency table to price retunes
    # from (an `obs_latency_table` JSON — serve --obs-dir writes one). Loaded
    # at Controller construction and injected into the harvest model; every
    # decision it influences carries the measured evidence in its reason.
    latency_table_path: str | None = None


class Controller:
    """Online adaptive control plane. One instance per serving engine."""

    def __init__(
        self,
        config: ControlConfig = ControlConfig(),
        *,
        admission: AdmissionPredictor | None = None,
        journal: DecisionJournal | None = None,
        latency=None,
        guard=None,
    ):
        self.config = config
        self.admission = admission
        # Optional repro.guard.QuarantineBreaker: runs FIRST each interval
        # (containment before adaptation — retuning a poisoned window would
        # learn from garbage), its decisions merge into the one journal
        # stream, and sites it froze are skipped by the retuner this interval.
        self.guard = guard
        self.last_guard_report = None
        if journal is None and config.journal_path:
            journal = DecisionJournal(config.journal_path)
        self.journal = journal
        if latency is None and config.latency_table_path:
            from repro.obs.latency import load_latency_table

            latency = load_latency_table(config.latency_table_path)
        self.latency = latency  # obs LatencyTable or None (constant pricing)
        self.reports: list[ControlReport] = []
        self._snaps: dict[str, dict] = {}
        # per-site (skipped_shard, computed_shard) cumulative lanes from the
        # engine's last ctrl snapshot — diffed per interval for the journal's
        # per-shard skip-rate rows (no extra device_get: the lanes ride the
        # snapshot the refresh already pulled)
        self._shard_snaps: dict[str, tuple[np.ndarray, np.ndarray]] = {}
        self._shard_rates: dict[tuple[str, int], float] = {}
        self._clean_windows: dict[str, int] = {}  # per-site fallback-free run
        # per-site budget value observed to overflow (set on widen); units
        # are K-blocks of the block_k the widen happened at
        self._budget_floor: dict[str, int] = {}
        self._interval = 0
        self._last_admit_est: float | None = None

    def step(self, engine, cache: dict[str, Any], *,
             step: int | None = None) -> ControlReport:
        """One control interval: harvest window deltas, retune, adapt
        budgets, refresh modes/exec paths, journal everything."""
        cfg = self.config
        self._interval += 1
        step = self._interval if step is None else step
        decisions: list[Decision] = []
        windows: dict[str, int] = {}
        retrace: dict[str, str] = {}
        # The solver must fit the substrate family the engine actually
        # executes: a Pallas engine compacts onto the ragged grid kernel,
        # jnp onto the gathered GEMM. A config-static pallas_target that
        # mismatched engine.impl would pin the wrong path — and pins
        # override decide_exec_path unconditionally.
        fit_cfg = dataclasses.replace(
            cfg.fit, pallas_target=(engine.impl != "jnp"),
            latency=self.latency if self.latency is not None else
            cfg.fit.latency,
        )

        # -- loop 0: fault containment BEFORE adaptation. The breaker reads
        # the sentinel lanes riding the same ctrl snapshot, pins tripped
        # lanes to basic, scrubs poisoned state, and journals the
        # transitions; retuning a site it froze this interval would fit the
        # harvest model to a poisoned window, so those sites sit out.
        frozen: set[str] = set()
        self.last_guard_report = None
        if self.guard is not None:
            guard_report = self.guard.step(engine, cache, step=step)
            self.last_guard_report = guard_report
            decisions.extend(guard_report.decisions)
            frozen = guard_report.frozen_sites

        shards = getattr(engine, "shards", None) or {}
        stacking = getattr(engine, "stacking", None) or {}
        for name, spec in list(engine.sites.items()):
            cur = snapshot_entry(
                cache[name],
                shard_axis=((1 if stacking.get(name, 0) else 0)
                            if name in shards else None),
            )
            if cur is None:
                continue
            if name in frozen:
                # reset the window baseline: the pre-containment half of the
                # window measured a poisoned site
                self._snaps[name] = cur
                continue
            prev = self._snaps.get(name)
            if prev is None:
                self._snaps[name] = cur  # first sight: window starts now
                continue
            rec = window_record(
                name, spec, engine.site_mode(cache, name),
                resolve_exec_path(spec, engine.impl), prev, cur,
            )
            if rec is None or rec.steps < cfg.min_window_steps:
                # below the min-samples floor: keep the old snapshot so the
                # window keeps ACCUMULATING across intervals instead of
                # being discarded (any cadence eventually clears the floor)
                continue
            self._snaps[name] = cur
            windows[name] = rec.steps

            # -- loop 1: online retune through the shared harvest model.
            # When a measured latency table covers the site, the solve is
            # priced from observed wall-clock and the evidence is appended
            # to every decision it produces.
            current_t = engine.policy.resolve(name)
            target = solve_site(rec, fit_cfg)
            meas_note = measured_latency_note(rec, fit_cfg)
            meas_sfx = f" [{meas_note}]" if meas_note else ""
            bounded, reasons = bounded_tunables(
                current_t, target,
                current_block_k=spec.block_k,
                max_threshold_step=cfg.max_threshold_step,
                max_min_work_raise=cfg.max_min_work_raise,
            )
            if bounded != current_t:
                spec_changed = engine.apply_tunables(name, bounded, cache)
                if spec_changed:
                    retrace[name] = "retune"
                for f in _TUNABLE_FIELDS:
                    b, a = getattr(current_t, f), getattr(bounded, f)
                    if f == "block_k" and b is None:
                        # a table entry's block_k=None defers to the spec:
                        # journal against the EFFECTIVE granularity, not the
                        # sentinel, or every first window logs a phantom move
                        b = spec.block_k
                    if b != a:
                        # a reason's first token is the knob it explains
                        # ("min_work ..." explains min_work_flops); fields
                        # without their own reason (hysteresis, the budget
                        # riding an exec promotion) get the interval blob
                        why = next(
                            (r for r in reasons
                             if f.startswith(r.split(" ", 1)[0])),
                            "; ".join(reasons) or "refit",
                        )
                        decisions.append(Decision(
                            step=step, site=name, kind="retune", field=f,
                            before=b, after=a,
                            reason=f"window {rec.steps} steps, "
                                   f"hit {rec.hit_rate:.2f}, "
                                   f"skip {rec.tile_skip_rate:.2f}: "
                                   f"{why}{meas_sfx}",
                        ))

            # a block_k retune rescales the spec budget (same covered K
            # extent, new units) — journal it or replaying the journal would
            # reconstruct a budget covering half the real extent
            spec_after = engine.sites[name]
            if (spec_after.max_active_k != spec.max_active_k
                    and bounded.max_active_k == current_t.max_active_k):
                decisions.append(Decision(
                    step=step, site=name, kind="retune", field="max_active_k",
                    before=spec.max_active_k, after=spec_after.max_active_k,
                    reason=f"rescaled with block_k {spec.block_k}->"
                           f"{spec_after.block_k} (same covered K extent)",
                ))

            # -- loop 1b: per-layer ctrl-lane retune for stacked sites —
            # each layer's own windowed operating point through the SAME
            # harvest model, bounded exactly like the site move, installed
            # as a "site@layer" row (an array write into the ctrl block, so
            # NO retrace) and journaled per layer.
            layer_recs = window_layer_records(
                name, spec_after, engine.layer_modes(cache, name),
                resolve_exec_path(spec_after, engine.impl), prev, cur,
            )
            layers_moved = False
            for lyr, lrec in sorted(layer_recs.items()):
                if lrec.steps < cfg.min_window_steps:
                    continue
                cur_l = engine.policy.resolve(name, layer=lyr)
                bounded_l, reasons_l = bounded_tunables(
                    cur_l, fit_layer(lrec, fit_cfg),
                    current_block_k=spec_after.block_k,
                    max_threshold_step=cfg.max_threshold_step,
                    max_min_work_raise=cfg.max_min_work_raise,
                )
                moved = {
                    f: (getattr(cur_l, f), getattr(bounded_l, f))
                    for f in _LAYER_FIELDS
                    if getattr(cur_l, f) != getattr(bounded_l, f)
                }
                if not moved:
                    continue
                # cache=None: lane sync deferred to ONE pass after the loop
                # (per-layer sync would rebuild all L lanes per moved layer)
                engine.apply_tunables(name, bounded_l, layer=lyr)
                layers_moved = True
                for f, (b, a) in moved.items():
                    why = next(
                        (r for r in reasons_l
                         if f.startswith(r.split(" ", 1)[0])),
                        "; ".join(reasons_l) or "refit",
                    )
                    note_l = measured_latency_note(lrec, fit_cfg)
                    decisions.append(Decision(
                        step=step, site=name, kind="retune", field=f,
                        before=b, after=a, layer=lyr,
                        reason=f"layer window {lrec.steps} steps, "
                               f"hit {lrec.hit_rate:.2f}, "
                               f"skip {lrec.tile_skip_rate:.2f}: {why}"
                               + (f" [{note_l}]" if note_l else ""),
                    ))
            if layers_moved:
                engine._sync_ctrl(name, cache)

            # -- loop 2: budget adaptation from measured overflow fallbacks
            spec = spec_after  # retune may have replaced it
            if rec.block_k != spec.block_k:
                # floor units are K-blocks of the old granularity: stale
                self._budget_floor.pop(name, None)
            if rec.overflow_fallbacks == 0:
                self._clean_windows[name] = self._clean_windows.get(name, 0) + 1
            else:
                self._clean_windows[name] = 0
            proposal = adapt_budget(
                spec, rec,
                n_layers=engine.stacking.get(name, 0) or 1,
                widen_fallback_rate=cfg.widen_fallback_rate,
            )
            if proposal is not None:
                new_budget, why = proposal
                before = spec.max_active_k
                tightening = before is not None and new_budget < before
                if tightening:
                    # anti-thrash: any tighten needs a clean-window streak,
                    # and re-entering a budget that previously overflowed
                    # (the recorded floor) needs a much longer one — else a
                    # boundary stream ping-pongs widen/tighten, paying a
                    # retrace per move
                    need = cfg.tighten_clean_windows
                    floor = self._budget_floor.get(name)
                    if floor is not None and new_budget <= floor:
                        need = cfg.tighten_floor_streak
                    if self._clean_windows[name] < need:
                        proposal = None
                if proposal is not None and engine.set_budget(name, new_budget):
                    retrace[name] = "budget"
                    if new_budget > (before or 0):
                        self._budget_floor[name] = before or 0
                    decisions.append(Decision(
                        step=step, site=name, kind="budget",
                        field="max_active_k", before=before,
                        after=engine.sites[name].max_active_k, reason=why,
                    ))

        # -- hysteretic mode/exec refresh sees the freshly-installed tunables.
        # Mode flips are per-layer ctrl-array writes (journaled from the
        # engine's event list, NO retrace); only exec-path flips — spec
        # changes — come back in the refresh result and force a rebuild.
        # The refresh also rides every interval where the guard is watching a
        # non-active lane: recovery from quarantine (cooldown drain, mode
        # re-promotion) must not wait for the retuner to accumulate a
        # min-samples window.
        guard_watch = self.guard is not None and any(
            st != "active" for st in self.guard.lane_states().values())
        if windows or guard_watch:
            paths_before = {n: s.exec_path for n, s in engine.sites.items()}
            for name, what in engine.refresh_modes(cache).items():
                retrace[name] = what
                decisions.append(Decision(
                    step=step, site=name, kind="exec", field="exec_path",
                    before=paths_before[name],
                    after=engine.sites[name].exec_path,
                    reason="measured skip rate crossed the compaction "
                           "break-even (refresh_exec_paths)",
                ))
            for ev in engine.last_mode_events:
                decisions.append(Decision(
                    step=step, site=ev["site"], kind="mode", field="mode",
                    before=ev["before"], after=ev["after"], layer=ev["layer"],
                    reason="hysteretic per-layer decide_modes on live "
                           f"sim_ema {ev['sim_ema']:.2f} (ctrl-array write, "
                           "no retrace)",
                ))

        # -- per-shard skip truth from the windowed cross-mesh reduce. The
        # cumulative skipped_shard/computed_shard lanes ([S]) ride the ctrl
        # snapshot the refresh just pulled (engine.last_snapshot), so this
        # costs zero extra transfers; each shard whose windowed rate moved
        # journals ONE kind="shard" observation row — per-shard skip rates
        # alongside the single global knob trajectory, as the mesh design
        # requires. These rows move no knob (replay chains, applies nothing).
        last_snap = getattr(engine, "last_snapshot", None)
        if windows and shards and last_snap:
            for name in sorted(shards):
                if name not in windows:
                    continue
                s = last_snap.get(name, {})
                sk, co = s.get("skipped_shard"), s.get("computed_shard")
                if sk is None or co is None:
                    continue
                sk = np.asarray(sk, np.int64)
                co = np.asarray(co, np.int64)
                prev_lanes = self._shard_snaps.get(name)
                self._shard_snaps[name] = (sk, co)
                if prev_lanes is None:
                    continue  # first sight: window starts now
                d_sk, d_co = sk - prev_lanes[0], co - prev_lanes[1]
                for sh in range(sk.shape[0]):
                    tot = float(d_sk[sh] + d_co[sh])
                    if tot <= 0:
                        continue
                    rate = round(float(d_sk[sh]) / tot, 6)
                    before = self._shard_rates.get((name, sh))
                    if before == rate:
                        continue
                    self._shard_rates[(name, sh)] = rate
                    decisions.append(Decision(
                        step=step, site=name, kind="shard", field="skip_rate",
                        before=before, after=rate, shard=sh,
                        reason=f"windowed cross-mesh reduce: "
                               f"{int(d_sk[sh])}/{int(tot)} owned tiles "
                               f"skipped on shard {sh}",
                    ))

        # -- loop 3: admission predictor drift, journaled
        admission = None
        if self.admission is not None:
            admission = self.admission.stats()
            est = admission["global_est"]
            last = self._last_admit_est
            if last is None or abs(est - last) >= cfg.admit_report_eps:
                if last is not None:
                    decisions.append(Decision(
                        step=step, site="", kind="admit", field="global_est",
                        before=round(last, 4), after=round(est, 4),
                        reason=f"{admission['observations']} retirements "
                               f"across {admission['n_sessions']} sessions",
                    ))
                self._last_admit_est = est

        report = ControlReport(
            step=step, interval=self._interval, window_steps=windows,
            decisions=decisions, retrace=retrace, admission=admission,
        )
        self.reports.append(report)
        if self.journal is not None:
            self.journal.append(report)
        return report
