"""Journal-driven replay — re-apply a decision journal, verify the trajectory.

    python -m repro.control.replay journal.jsonl [--arch qwen3-32b --reduced]

A decision journal (`--control-journal` on the serving CLI) is the complete
causal record of a run's policy moves. Replay re-applies every decision row
IN ORDER to a fresh policy state and asserts the reproduced trajectory
matches the recorded one: each decision's `before` value must equal the state
the preceding decisions left behind (the first sight of a knob seeds it). A
mismatch means the journal is internally inconsistent — rows were lost,
reordered, or produced by something other than the journaled controller —
and replay exits non-zero naming the offending row.

With `--arch`, the decisions are ALSO driven through a real engine
(`build_reuse_engine` on the reduced config): retune rows through
`apply_tunables` (per-layer rows land as "site@layer" ctrl-lane writes),
budget rows through `set_budget`, mode rows through `set_mode` — proving the
journal is a sufficient script to reconstruct the serving run's final policy
on a fresh process, not just a log.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.control.report import load_journal
from repro.core.policy import mode_name  # noqa: F401  (re-export convenience)

# One knob's trajectory is identified per decision KIND as well as field:
# "retune" rows track the policy-table entry while "budget"/"exec" rows track
# the installed spec — two stores that legitimately interleave (set_budget
# syncs the table, pins release), so chains are only verified within a kind.
# Journal v5 adds the shard scope: per-shard observation rows (kind="shard")
# chain independently per shard — a forged/misattributed shard id breaks its
# chain's before/after continuity and surfaces as a mismatch.
_KnobKey = tuple[str, str, str, Any, Any]  # (site, kind, field, layer, shard)

# (kind, field) chains with more than one writer: the budget adapter syncs
# the retuner's table entry between intervals, so the retune-side
# max_active_k chain is applied but not mismatch-checked.
_MULTI_WRITER = {("retune", "max_active_k")}


@dataclasses.dataclass
class ReplayResult:
    n_rows: int
    n_decisions: int
    n_intervals: int
    # final value per knob after re-applying every decision in order
    final_state: dict[_KnobKey, Any]
    # rows whose `before` contradicted the reproduced trajectory
    mismatches: list[dict[str, Any]]
    # per-layer decisions seen (the stacked-site control surface)
    n_layer_scoped: int
    # per-shard observation rows seen (the sharded-mesh control surface)
    n_shard_scoped: int = 0

    @property
    def ok(self) -> bool:
        return not self.mismatches

    def summary_lines(self) -> list[str]:
        lines = [
            f"replayed {self.n_decisions} decisions over "
            f"{self.n_intervals} intervals ({self.n_rows} rows); "
            f"{self.n_layer_scoped} layer-scoped; "
            f"{self.n_shard_scoped} shard-scoped; "
            f"{len(self.mismatches)} trajectory mismatches",
        ]
        for m in self.mismatches:
            lines.append(
                f"  MISMATCH {m['kind']}:{m['site']}.{m['field']}"
                + (f"@{m['layer']}" if m.get("layer") is not None else "")
                + (f"#s{m['shard']}" if m.get("shard") is not None else "")
                + f": journal before={m['before']!r} but replayed "
                f"state={m['replayed']!r} (interval {m['interval']})"
            )
        by_site: dict[str, list[str]] = {}
        for (site, kind, field, layer, shard), val in sorted(
            self.final_state.items(),
            key=lambda kv: tuple(str(p) for p in kv[0]),
        ):
            where = f"@{layer}" if layer is not None else ""
            if shard is not None:
                where = f"{where}#s{shard}"
            by_site.setdefault(site or "<model>", []).append(
                f"{kind}:{field}{where}={val}")
        for site, knobs in sorted(by_site.items()):
            lines.append(f"  final {site:24s} " + " ".join(knobs))
        return lines


def replay_rows(rows: list[dict[str, Any]]) -> ReplayResult:
    """Re-apply journal rows to a fresh knob-state map and verify each
    decision's `before` against the reproduced trajectory."""
    state: dict[_KnobKey, Any] = {}
    mismatches: list[dict[str, Any]] = []
    n_dec = n_int = n_layer = n_shard = 0
    for row in rows:
        kind = row.get("kind")
        if kind == "interval":
            n_int += 1
            continue
        if kind != "decision":
            continue
        n_dec += 1
        layer = row.get("layer")
        if layer is not None:
            n_layer += 1
        shard = row.get("shard")
        if shard is not None:
            n_shard += 1
        kind = row.get("decision_kind", "")
        field = row.get("field", "")
        key = (row.get("site", ""), kind, field, layer, shard)
        if (key in state and state[key] != row.get("before")
                and (kind, field) not in _MULTI_WRITER):
            mismatches.append(dict(
                site=key[0], kind=kind, field=field, layer=layer, shard=shard,
                before=row.get("before"), replayed=state[key],
                interval=row.get("interval"),
            ))
        state[key] = row.get("after")
    return ReplayResult(
        n_rows=len(rows), n_decisions=n_dec, n_intervals=n_int,
        final_state=state, mismatches=mismatches, n_layer_scoped=n_layer,
        n_shard_scoped=n_shard,
    )


def apply_to_engine(rows: list[dict[str, Any]], engine, cache) -> dict[str, Any]:
    """Drive the journal's decisions through a real engine + cache — the
    "fresh engine" half of replay. Returns {site: final spec/ctrl summary}
    for knobs the journal touched. Unknown sites (journal from a different
    arch) are skipped with a note under the "" key."""
    skipped: list[str] = []
    for row in rows:
        if row.get("kind") != "decision":
            continue
        site = row.get("site", "")
        if not site:
            continue  # model-level (admission) rows carry no engine knob
        if site not in engine.sites:
            skipped.append(site)
            continue
        kind, field = row.get("decision_kind"), row.get("field")
        layer = row.get("layer")
        after = row.get("after")
        if kind == "mode":
            engine.set_mode(cache, site, after, layer=layer)
        elif kind == "budget":
            engine.set_budget(site, int(after))
        elif kind in ("retune", "restore"):
            # "restore" rows record the startup checkpoint-vs-table
            # precedence resolution; their `after` is the value that won the
            # lane, so replaying them is the same table write as a retune.
            t = engine.policy.resolve(site, layer=layer)
            if field in {f.name for f in dataclasses.fields(t)}:
                t = dataclasses.replace(t, **{field: after})
                engine.apply_tunables(site, t, cache, layer=layer)
        elif kind == "exec":
            spec = engine.sites[site]
            budget = engine.policy.resolve_max_active_k(site)
            engine.sites[site] = dataclasses.replace(
                spec, exec_path=after, max_active_k=budget,
            )
        elif kind == "shard":
            # per-shard observation rows move no engine knob (skip decisions
            # are shard-LOCAL consequences of the global operating point);
            # replay chains them in replay_rows and applies nothing here
            pass
        elif kind == "quarantine" and field == "state":
            # containment transitions: entering quarantine pins the lane to
            # basic (the breaker's ctrl write); leaving it does NOT force
            # reuse — the hysteretic refresh re-promotes from recovered
            # sim_ema, so replay only reproduces the pin.
            if after == "quarantined":
                engine.set_mode(cache, site, "basic", layer=layer)
    out: dict[str, Any] = {}
    for name, spec in engine.sites.items():
        out[name] = dict(
            exec_path=spec.exec_path, block_k=spec.block_k,
            max_active_k=spec.max_active_k,
            modes=engine.layer_modes(cache, name),
        )
    if skipped:
        out[""] = f"skipped decisions for unknown sites: {sorted(set(skipped))}"
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Re-apply a control decision journal and assert the "
        "reproduced policy trajectory matches the recorded one."
    )
    ap.add_argument("journal", help="decision-journal JSONL path")
    ap.add_argument("--arch", default=None,
                    help="also drive the decisions through a fresh engine "
                    "for this architecture (e.g. qwen3-32b)")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config for --arch")
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)

    rows = load_journal(args.journal)
    result = replay_rows(rows)
    print("\n".join(result.summary_lines()))

    if args.arch:
        from repro.configs import get_config
        from repro.serve.serve_step import build_reuse_engine

        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        engine = build_reuse_engine(cfg)
        cache = engine.init_cache(args.batch)
        summary = apply_to_engine(rows, engine, cache)
        for name, s in sorted(summary.items()):
            print(f"engine {name or '<note>'}: {s}")

    if not result.ok:
        print("REPLAY FAILED: journal trajectory is inconsistent")
        return 1
    print("replay OK: trajectory reproduced")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
