"""Control-plane decisions: typed records, per-interval reports, JSONL journal.

Every knob the controller moves is recorded as a :class:`Decision` — what
changed, from what to what, and the measured evidence it acted on — and every
`Controller.step` emits a :class:`ControlReport` (the interval's windows,
decisions, and the sites whose jitted step must be rebuilt). The
:class:`DecisionJournal` appends both to a JSONL file so an adaptive serving
run can be audited or replayed offline: the journal plus the sensor trace is
the complete causal record of why the policy is where it is.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

#   1 — PR 4 emission
#   2 — decision rows carry `layer` (per-layer ctrl-lane retunes and
#       per-layer kernelMode flips of stacked sites; null = site-granular)
#   3 — rows carry obs correlation ids under "trace" when the obs plane is
#       active (run/window/...; absent = pre-obs emission, byte-identical to
#       v2), and the "restore" decision kind records checkpoint-vs-tuned-table
#       precedence resolutions at startup
#   4 — the "quarantine" decision kind records guard-plane containment
#       transitions (field="state": active→quarantined→probation→active, with
#       the tripped-sentinel evidence in `reason`; field="stall_windows":
#       straggler-watchdog events, site=""), and `load_journal` tolerates
#       exactly one torn final row (crash mid-append) by emitting a
#       kind="torn_tail" marker instead of raising
#   5 — decision rows carry `shard` (model-axis shard the decision is scoped
#       to; null = mesh-global, which every pre-sharding decision is — v1-v4
#       rows load with shard=None) and the "shard" decision kind records
#       per-shard observations from the windowed cross-mesh counter reduce
#       (field="skip_rate": one row per shard whose window moved; the GLOBAL
#       controller trajectory stays shard=None, so a journal shows per-shard
#       skip truth alongside ONE global knob stream)
CONTROL_JOURNAL_SCHEMA_VERSION = 5
LOADABLE_JOURNAL_VERSIONS = (1, 2, 3, 4, 5)

# Decision kinds: which feedback loop acted.
#   "retune"  — online refit of a SiteTunables knob from windowed counters
#               (layer set = a "site@layer" ctrl-lane row, no retrace)
#   "budget"  — max_active_k widened/tightened from the overflow-fallback rate
#   "mode"    — kernelMode flip applied by the hysteretic refresh (an array
#               write into the ctrl block; layer set for stacked sites)
#   "exec"    — execution-substrate flip applied by the hysteretic refresh
#   "admit"   — admission-predictor population estimate moved
#   "restore" — startup precedence resolution between a checkpointed ctrl
#               block and the tuned-policy table (checkpoint < table < live)
#   "quarantine" — guard-plane containment: a tripped sentinel pinned a lane
#               to basic/dense, a lockout drained into probation, or a lane
#               re-admitted after clean windows (field="state"); straggler
#               stalls journal as field="stall_windows" with site=""
#   "shard"   — per-shard observation from the once-per-window cross-mesh
#               counter reduce (field="skip_rate"; `shard` set). Moves no
#               knob — replay chains it for audit but applies nothing.
DECISION_KINDS = (
    "retune", "budget", "mode", "exec", "admit", "restore", "quarantine",
    "shard")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One knob the controller moved, with its evidence."""

    step: int            # serving decode step the interval closed at
    site: str            # "" for model-level (admission) decisions
    kind: str
    field: str           # tunable/spec field that moved (e.g. "sim_threshold")
    before: Any
    after: Any
    reason: str          # measured evidence, human-readable
    # Which layer of a stacked site the decision targets (per-layer ctrl-lane
    # writes: "site@layer" retune rows, per-layer mode flips). None =
    # site-granular (spec-level knobs, unstacked sites).
    layer: int | None = None
    # Which model-axis shard the decision is scoped to. None = mesh-global:
    # every knob the controller moves is global (tunables/modes/budgets write
    # replicated ctrl lanes), so only kind="shard" observation rows set this.
    shard: int | None = None

    def __post_init__(self) -> None:
        if self.kind not in DECISION_KINDS:
            raise ValueError(f"kind {self.kind!r} not in {DECISION_KINDS}")

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ControlReport:
    """What one controller interval saw and did."""

    step: int                       # decode step the interval closed at
    interval: int                   # 1-based controller invocation count
    window_steps: dict[str, int]    # per-site evaluations in this window
    decisions: list[Decision]
    # sites whose spec/mode changed this interval — the jitted serve step
    # must be rebuilt exactly when this is non-empty
    retrace: dict[str, str]
    admission: dict[str, Any] | None = None  # predictor snapshot, if attached

    @property
    def changed(self) -> bool:
        return bool(self.retrace)

    def summary_lines(self) -> list[str]:
        lines = [
            f"ControlReport step={self.step} interval={self.interval} "
            f"windows={len(self.window_steps)} decisions={len(self.decisions)} "
            f"retrace={sorted(self.retrace) or '-'}"
        ]
        for d in self.decisions:
            where = d.site or "<model>"
            if d.layer is not None:
                where = f"{where}@{d.layer}"
            if d.shard is not None:
                where = f"{where}#s{d.shard}"
            lines.append(
                f"  {d.kind:6s} {where:24s} "
                f"{d.field}: {d.before} -> {d.after}  ({d.reason})"
            )
        return lines

    def to_dicts(self) -> list[dict[str, Any]]:
        """JSONL rows: one interval row + one row per decision. Rows are
        stamped with the current obs correlation ids (no-op when the obs
        plane is inactive — the v2 byte layout is preserved exactly)."""
        from repro.obs.events import stamp

        ver = {"schema_version": CONTROL_JOURNAL_SCHEMA_VERSION}
        ts = time.time()
        rows = [dict(
            kind="interval", step=self.step, interval=self.interval,
            window_steps=self.window_steps, n_decisions=len(self.decisions),
            retrace=self.retrace, admission=self.admission, ts=ts, **ver,
        )]
        rows += [dict(d.to_dict(), kind="decision", decision_kind=d.kind,
                      interval=self.interval, ts=ts, **ver)
                 for d in self.decisions]
        return [stamp(row) for row in rows]


class DecisionJournal:
    """Append-only JSONL audit log of controller activity."""

    def __init__(self, path: str):
        self.path = path
        self.rows_written = 0

    def append(self, report: ControlReport) -> None:
        # crash consistency: serialize the whole interval first, then ONE
        # write + flush. A crash can tear at most the final OS-level write —
        # never interleave half an interval with the next process's rows —
        # and load_journal tolerates exactly that one torn tail.
        rows = report.to_dicts()
        payload = "".join(json.dumps(row) + "\n" for row in rows)
        with open(self.path, "a") as f:
            f.write(payload)
            f.flush()
        self.rows_written += len(rows)

    def note(self, **fields: Any) -> None:
        """Append one kind="note" row outside any ControlReport: operational
        facts that belong in the audit stream but move no knob — e.g. an
        interpret-measured latency table fed to a compiled-mode run. Loaders
        keep notes (load_journal accepts any kind); replay ignores them (it
        only chains kind="decision" rows)."""
        from repro.obs.events import stamp

        row = stamp(dict(
            kind="note", ts=time.time(),
            schema_version=CONTROL_JOURNAL_SCHEMA_VERSION, **fields,
        ))
        with open(self.path, "a") as f:
            f.write(json.dumps(row) + "\n")
            f.flush()
        self.rows_written += 1


def load_journal(path: str) -> list[dict[str, Any]]:
    """Parse a decision journal back into rows (audit/replay).

    Loads every journal version this repo has ever emitted
    (`LOADABLE_JOURNAL_VERSIONS`): v1 rows gain `layer=None`, v1/v2 rows
    simply lack the v3 `trace` id sub-dict — consumers treat both as
    optional. Unknown FUTURE versions are rejected loudly.

    Crash tolerance (v4): `DecisionJournal.append` writes whole intervals in
    one flushed write, so the only tear a crash can produce is a truncated
    FINAL line. Exactly that is forgiven — the bad tail is replaced by a
    ``{"kind": "torn_tail", "lineno": ..., "prefix": ...}`` marker row
    (replay-inert: replay only chains kind="decision" rows) so the audit
    stream records that the run died mid-append. Unparseable rows anywhere
    BEFORE the tail are still real corruption and raise."""
    with open(path) as f:
        lines = f.readlines()
    numbered = [(i, ln.strip()) for i, ln in enumerate(lines, start=1)
                if ln.strip()]
    rows: list[dict[str, Any]] = []
    for pos, (lineno, line) in enumerate(numbered):
        try:
            row = json.loads(line)
        except json.JSONDecodeError as e:
            if pos == len(numbered) - 1:
                rows.append({
                    "kind": "torn_tail", "lineno": lineno,
                    "prefix": line[:80],
                    "schema_version": CONTROL_JOURNAL_SCHEMA_VERSION,
                })
                return rows
            raise ValueError(
                f"{path}:{lineno}: unparseable journal row before the tail "
                f"(mid-file corruption, not a torn append): {e}") from e
        ver = row.get("schema_version")
        if ver not in LOADABLE_JOURNAL_VERSIONS:
            raise ValueError(
                f"{path}:{lineno}: journal schema_version {ver!r} not in "
                f"{LOADABLE_JOURNAL_VERSIONS}")
        if row.get("kind") == "decision":
            if "layer" not in row:
                row["layer"] = None  # v1 decisions predate per-layer lanes
            if "shard" not in row:
                row["shard"] = None  # v1-v4 decisions predate the mesh
        rows.append(row)
    return rows
