"""Checkpoint-vs-tuned-table restore precedence for the ctrl block.

A restored serving state carries the array-resident per-layer ctrl block
(mode_id / sim_threshold / min_work / cooldown / occupancy) from the moment
the checkpoint was cut — but the process restoring it may ALSO have been
launched with a tuned-policy table (`--tuned-policy`). Before this module the
two silently raced: whichever write happened last (`_sync_ctrl` from any
retune vs the restored arrays) won, so a checkpointed operating point could
be clobbered back to table values mid-run, or a stale checkpoint could shadow
a freshly fitted table at startup.

The defined order, enforced here once at restore time:

    checkpointed ctrl  <  tuned table  <  live controller state

* Lanes covered by a tuned-table row (site or "site@layer") are re-synced to
  the TABLE — the fitted numbers are newer intent than the checkpoint.
* Lanes with NO table row ADOPT the checkpointed values into the policy
  table, so the next `_sync_ctrl` (every retune runs one) re-derives the
  very same lanes instead of resetting them to defaults.
* The live controller then naturally outranks both: it writes the table and
  the lanes on every interval.
* Dynamic state — mode_id, cooldown, occupancy — is never touched: it is
  measurement, not intent, and only the hysteretic refresh may move it.

Every resolution is journaled as a kind="restore" Decision (journal schema
v3), so the audit trail shows exactly which side won each lane and why.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.control.report import ControlReport, Decision, DecisionJournal
from repro.core.policy import SiteTunables, layer_key

_REL_TOL = 1e-5


def _differs(a: float, b: float) -> bool:
    return not np.isclose(a, b, rtol=_REL_TOL, atol=0.0)


def resolve_restored_ctrl(
    engine,
    cache: dict[str, Any],
    *,
    journal: DecisionJournal | None = None,
    step: int = 0,
) -> list[Decision]:
    """Enforce ctrl-block restore precedence on a just-restored cache.

    Mutates `cache` (re-synced ctrl lanes) and `engine.policy.site_tunables`
    (adopted checkpoint lanes); returns the journaled decisions. Call once,
    after `restore_checkpoint` and before the first serve step."""
    decisions: list[Decision] = []
    table = engine.policy.site_tunables
    for name in engine.sites:
        entry = cache.get(name)
        if entry is None or "ctrl" not in entry:
            continue
        ctrl = entry["ctrl"]
        ck_thr = np.atleast_1d(np.asarray(ctrl["sim_threshold"], np.float64))
        ck_mw = np.atleast_1d(np.asarray(ctrl["min_work"], np.float64))
        stacked = engine.stacking.get(name, 0) > 0
        n_lanes = ck_thr.shape[0]
        for lane in range(n_lanes):
            layer = lane if stacked else None
            row_key = layer_key(name, layer) if layer is not None else name
            covered = row_key in table or name in table
            resolved = engine.policy.resolve(name, layer=layer)
            pairs = (
                ("sim_threshold", float(ck_thr[lane]),
                 float(resolved.sim_threshold)),
                ("min_work_flops", float(ck_mw[lane]),
                 float(resolved.min_work_flops)),
            )
            if covered:
                # table wins: lanes re-sync below; journal real overrides
                for field, ck, tab in pairs:
                    if _differs(ck, tab):
                        decisions.append(Decision(
                            step=step, site=name, kind="restore", field=field,
                            before=ck, after=tab, layer=layer,
                            reason="tuned table overrides checkpointed ctrl "
                                   "lane (precedence: checkpoint < table "
                                   "< live)",
                        ))
            elif any(_differs(ck, tab) for _, ck, tab in pairs):
                # no table row: adopt the checkpointed operating point as a
                # policy row so later _sync_ctrl passes re-derive it instead
                # of resetting the lane to defaults
                adopt_key = layer_key(name, layer) if stacked else name
                table[adopt_key] = dataclasses.replace(
                    resolved,
                    sim_threshold=float(ck_thr[lane]),
                    min_work_flops=float(ck_mw[lane]),
                )
                for field, ck, tab in pairs:
                    if _differs(ck, tab):
                        decisions.append(Decision(
                            step=step, site=name, kind="restore", field=field,
                            before=tab, after=ck, layer=layer,
                            reason="no tuned row for this lane: adopted "
                                   "checkpointed ctrl value into the policy "
                                   "table (survives later ctrl syncs)",
                        ))
        # one sync per site makes the lanes consistent with the final table;
        # mode_id / cooldown / occupancy stay exactly as checkpointed
        engine._sync_ctrl(name, cache)
    if journal is not None and decisions:
        journal.append(ControlReport(
            step=step, interval=0, window_steps={},
            decisions=decisions, retrace={},
        ))
    return decisions
