"""Online retuner — windowed counter deltas → guardrailed SiteTunables moves.

The offline loop (record JSONL → `repro.tune.fit` → reload) and this online
path share ONE harvest model: both build a
:class:`~repro.tune.trace.SiteTraceRecord` describing a measured operating
point and hand it to :func:`repro.tune.harvest.solve_site`. The difference is
purely the guardrails: an offline fit can jump straight to the solved target
(a human reviews the table), while the live retuner moves the installed
tunables a BOUNDED step toward the target each interval, so one noisy window
can never teleport the policy — and the hysteresis/cooldown machinery in
`ReuseEngine.refresh_modes` still owns the actual mode/exec transitions.

Guardrail asymmetry, deliberate: knobs that *restrict* harvesting
(sim_threshold moves, min_work raises) are throttled per interval, because a
wrongly-restricted site stops producing the very measurements that would
correct the mistake. Knobs that *admit* a site whose measured window is
net-positive (min_work lowering) apply immediately — the measurement already
justifies them, and a mis-admission keeps measuring and self-corrects the
next window (throttled back out, with the flip cooldown absorbing the churn).
"""

from __future__ import annotations

import numpy as np

from repro.core.policy import SiteTunables
from repro.tune.harvest import BLOCK_K_CHOICES
from repro.tune.trace import SiteTraceRecord


_COUNTER_KEYS = (
    "skipped_tiles", "computed_tiles", "skipped_macs", "computed_macs",
    "skipped_weight_bytes", "total_weight_bytes", "grid_steps",
    "mode_transitions",
)


def snapshot_entry(entry: dict, shard_axis: int | None = None) -> dict | None:
    """Host-side snapshot of one cache entry's cumulative counters, summed
    over any leading layer dimension (one small device→host transfer).

    For STACKED sites the snapshot additionally keeps the un-summed per-layer
    counter arrays under ``"layers"`` — the per-layer retune loop diffs those
    to give each layer of a stack its own windowed operating point.

    `shard_axis` (model-sharded entries) names the shard axis position; the
    entry is collapsed class-aware first (ownership-partition lanes sum,
    replicated lanes take shard 0 — sensor.aggregate._collapse_shard_entry),
    so everything below keeps reading global per-layer counters and the
    retuner's windowed deltas stay identical to an unsharded run's."""
    sensor = entry.get("sensor")
    if sensor is None:
        return None
    if shard_axis is not None:
        from repro.sensor.aggregate import _collapse_shard_entry

        entry = _collapse_shard_entry(entry, shard_axis)
        sensor = entry["sensor"]

    def total(key: str) -> float:
        return float(np.sum(np.asarray(sensor[key])))

    snap = {k: total(k) for k in _COUNTER_KEYS}
    snap["overflow_fallbacks"] = (
        total("overflow_fallbacks") if "overflow_fallbacks" in sensor else 0.0
    )
    # suppression is a site-level event bumped on every layer slice at once
    snap["suppressed_flips"] = float(np.max(np.asarray(sensor["suppressed_flips"])))
    hit = np.asarray(sensor["slot_hit_sum"], np.float64)
    ss = np.asarray(sensor["slot_steps"], np.float64)
    if hit.ndim > 1:  # stacked site: per-layer arrays kept, lanes summed
        layers: dict[str, np.ndarray] = {
            k: np.asarray(sensor[k], np.float64) for k in _COUNTER_KEYS
        }
        layers["overflow_fallbacks"] = (
            np.asarray(sensor["overflow_fallbacks"], np.float64)
            if "overflow_fallbacks" in sensor
            else np.zeros(hit.shape[0])
        )
        layers["slot_hit_sum"] = hit          # [L, M]
        layers["slot_steps"] = ss             # [L, M]
        layers["steps"] = np.asarray(entry["steps"], np.float64)
        snap["layers"] = layers
        hit = hit.sum(axis=tuple(range(hit.ndim - 1)))
        ss = ss.sum(axis=tuple(range(ss.ndim - 1)))
    snap["slot_hit_sum"] = hit
    snap["slot_steps"] = ss
    snap["steps"] = float(np.max(np.asarray(entry["steps"])))
    return snap


def window_record(
    name: str,
    spec,
    mode: str,
    exec_path: str,
    prev: dict,
    cur: dict,
) -> SiteTraceRecord | None:
    """The window's measured operating point as a solver-ready trace record
    (counter deltas between two snapshots), or None for an empty window.

    Recycled lanes are filtered best-effort: a legitimate lane delta always
    satisfies 0 <= d_hit <= d_steps (each evaluation adds one step and a
    [0, 1] similarity), so lanes whose accumulators went backwards OR
    out-accumulated their step delta (reset_slot zeroed them mid-window and
    a new occupant overran the old sums) drop out of the window's hit rate
    rather than poisoning it with cross-session or >1 values."""
    d = {k: cur[k] - prev[k] for k in cur if isinstance(cur[k], float)}
    steps = int(round(d["steps"]))
    if steps <= 0:
        return None
    hit = _window_hit_rate(
        cur["slot_hit_sum"] - prev["slot_hit_sum"],
        cur["slot_steps"] - prev["slot_steps"],
    )
    return _record_from_deltas(
        name, spec, mode, exec_path, d, hit,
        batch=int(cur["slot_steps"].shape[-1]),
    )


def _window_hit_rate(d_hit: np.ndarray, d_ss: np.ndarray) -> float:
    active = (d_ss > 0) & (d_hit >= 0.0) & (d_hit <= d_ss)
    return float(np.mean(d_hit[active] / d_ss[active])) if active.any() else 0.0


def _record_from_deltas(
    name: str, spec, mode: str, exec_path: str,
    d: dict[str, float], hit: float, *, batch: int, layer: int | None = None,
) -> SiteTraceRecord:
    skipped = d["skipped_tiles"]
    total_tiles = skipped + d["computed_tiles"]
    total_macs = d["skipped_macs"] + d["computed_macs"]
    gn = -(-spec.out_features // spec.block_n)
    dense_grid = total_tiles * gn
    return SiteTraceRecord(
        site=name,
        mode=mode,
        steps=int(round(d["steps"])),
        batch=batch,
        in_features=spec.in_features,
        out_features=spec.out_features,
        block_m=spec.block_m,
        block_k=spec.block_k,
        block_n=spec.block_n,
        tile_skip_rate=skipped / max(total_tiles, 1.0),
        mac_skip_rate=d["skipped_macs"] / max(total_macs, 1e-9),
        weight_byte_skip_rate=(
            d["skipped_weight_bytes"] / max(d["total_weight_bytes"], 1e-9)
        ),
        hit_rate=hit,
        mode_transitions=int(round(d["mode_transitions"])),
        suppressed_flips=int(round(d["suppressed_flips"])),
        total_weight_bytes=d["total_weight_bytes"],
        total_macs=total_macs,
        exec_path=exec_path,
        grid_steps=d["grid_steps"],
        grid_step_skip_rate=max(0.0, 1.0 - d["grid_steps"] / max(dense_grid, 1e-9)),
        overflow_fallbacks=int(round(d["overflow_fallbacks"])),
        layer=layer,
    )


def window_layer_records(
    name: str,
    spec,
    layer_modes: list[str],
    exec_path: str,
    prev: dict,
    cur: dict,
) -> dict[int, SiteTraceRecord]:
    """Per-layer windowed operating points of one STACKED site.

    Diffs the un-summed per-layer counter arrays both snapshots kept under
    ``"layers"`` and yields one solver-ready record per layer with a
    non-empty window — the input of the controller's per-layer retune loop
    (ctrl-lane thresholds, journaled per layer). Empty for unstacked sites
    or snapshots taken before the per-layer capture existed."""
    pl, cl = prev.get("layers"), cur.get("layers")
    if pl is None or cl is None:
        return {}
    n_layers = cl["slot_steps"].shape[0]
    out: dict[int, SiteTraceRecord] = {}
    for layer in range(n_layers):
        d = {k: float(cl[k][layer] - pl[k][layer]) for k in _COUNTER_KEYS}
        d["overflow_fallbacks"] = float(
            cl["overflow_fallbacks"][layer] - pl["overflow_fallbacks"][layer]
        )
        steps_arr = cl["steps"]
        d["steps"] = float(
            (steps_arr[layer] - pl["steps"][layer])
            if np.ndim(steps_arr) else (cur["steps"] - prev["steps"])
        )
        # suppression is site-level; a layer window inherits the site delta
        d["suppressed_flips"] = cur["suppressed_flips"] - prev["suppressed_flips"]
        if int(round(d["steps"])) <= 0:
            continue
        hit = _window_hit_rate(
            cl["slot_hit_sum"][layer] - pl["slot_hit_sum"][layer],
            cl["slot_steps"][layer] - pl["slot_steps"][layer],
        )
        mode = layer_modes[layer] if layer < len(layer_modes) else "auto"
        out[layer] = _record_from_deltas(
            name, spec, mode, exec_path, d, hit,
            batch=int(cl["slot_steps"].shape[-1]), layer=layer,
        )
    return out


def _step_block_k(current: int, target: int) -> int:
    """block_k moves at most one BLOCK_K_CHOICES notch per interval. Each
    move retraces the step, and subsequent tile counts accrue at the new
    granularity — CUMULATIVE tile rates therefore mix units across a move
    (the windowed deltas this retuner feeds the solver stay clean, and exec
    promotion under the controller rides the solver's pin rather than the
    cumulative signal, so only the unpinned `refresh_exec_paths` fallback
    sees the smeared rate)."""
    if target == current:
        return current
    choices = sorted(set(BLOCK_K_CHOICES) | {current, target})
    i = choices.index(current)
    j = choices.index(target)
    return choices[i + 1] if j > i else choices[i - 1]


def bounded_tunables(
    current: SiteTunables,
    target: SiteTunables,
    *,
    current_block_k: int,
    max_threshold_step: float,
    max_min_work_raise: float,
) -> tuple[SiteTunables, list[str]]:
    """Clamp one interval's move from `current` toward the solved `target`.

    Returns the tunables to install plus human-readable reasons for each
    field that moved. `current_block_k` is the spec's resolved granularity
    (the table entry may carry block_k=None)."""
    reasons: list[str] = []

    thr = target.sim_threshold
    lo = current.sim_threshold - max_threshold_step
    hi = current.sim_threshold + max_threshold_step
    thr = min(max(thr, lo), hi)
    if abs(thr - current.sim_threshold) > 1e-9:
        reasons.append(f"sim_threshold {current.sim_threshold:.3f}->{thr:.3f} "
                       f"(target {target.sim_threshold:.3f})")

    mw = target.min_work_flops
    if mw > current.min_work_flops:  # restricting: throttled
        mw = min(mw, current.min_work_flops * max_min_work_raise)
    if abs(mw - current.min_work_flops) > 1e-9:
        reasons.append(f"min_work {current.min_work_flops:.3e}->{mw:.3e}")

    tgt_bk = target.block_k if target.block_k is not None else current_block_k
    bk = _step_block_k(current_block_k, int(tgt_bk))
    if bk != current_block_k:
        reasons.append(f"block_k {current_block_k}->{bk} (target {tgt_bk})")

    # Exec promotion only once the granularity it was solved at is reached —
    # a pinned compacted path at an uncompactable block_k would just thrash.
    # Two deliberate asymmetries: (a) a below-break-even window RELEASES the
    # pin (exec_path=None) rather than pinning a demotion: an un-pinned site
    # falls back to `refresh_exec_paths`, which demotes from CUMULATIVE
    # counters under the flip cooldown — a pin the retuner never released
    # would make that demotion unreachable, since decide_exec_path honors
    # pins unconditionally; (b) the budget of a site already on the target
    # path belongs to the budget adapter (measured fallback rate) —
    # re-solving it every window would fight the adapter's moves (the SPEC
    # keeps its adapted budget across a pin release; only the table clears).
    exec_path = current.exec_path
    mak = current.max_active_k
    if (bk == tgt_bk and target.exec_path is not None
            and target.exec_path != current.exec_path):
        exec_path = target.exec_path
        mak = target.max_active_k
        reasons.append(f"exec_path {current.exec_path}->{exec_path}"
                       + (f"@{mak}" if mak is not None else ""))
    elif target.exec_path is None and current.exec_path is not None:
        exec_path = None
        mak = None
        reasons.append(f"exec_path pin {current.exec_path} released (window "
                       "below compaction break-even); demotion decided by "
                       "the cumulative refresh")

    out = SiteTunables(
        sim_threshold=thr,
        min_work_flops=mw,
        block_k=bk,
        hysteresis_margin=target.hysteresis_margin,
        hysteresis_steps=target.hysteresis_steps,
        exec_path=exec_path,
        max_active_k=mak,
    )
    return out, reasons
