from repro.core.delta import DeltaEncoding, delta_encode, delta_encode_int8
from repro.core.engine import ReuseEngine
from repro.core.policy import (
    MODE_BASIC,
    MODE_REUSE,
    ReusePolicy,
    SiteTunables,
    layer_key,
    mode_name,
    split_layer_key,
)
from repro.core.reuse_cache import (
    ReuseSiteSpec,
    cache_bytes,
    init_reuse_cache,
    init_site_cache,
    init_site_ctrl,
)
from repro.core.reuse_linear import ReuseStats, reuse_linear
from repro.core.similarity import (
    block_zero_mask,
    code_similarity,
    harvestable_similarity,
    row_code_similarity,
    similarity_breakdown,
)

__all__ = [
    "DeltaEncoding",
    "MODE_BASIC",
    "MODE_REUSE",
    "ReuseEngine",
    "ReusePolicy",
    "ReuseSiteSpec",
    "ReuseStats",
    "SiteTunables",
    "block_zero_mask",
    "cache_bytes",
    "code_similarity",
    "delta_encode",
    "delta_encode_int8",
    "harvestable_similarity",
    "init_reuse_cache",
    "init_site_cache",
    "init_site_ctrl",
    "layer_key",
    "mode_name",
    "reuse_linear",
    "row_code_similarity",
    "similarity_breakdown",
    "split_layer_key",
]
