"""Delta encoding (paper Eqns. 2-4) and the overflow-split trick (Sec. IV-B).

    Δ = I_c − I_p            (in the int8 code domain)
    O_c = O_p + Δ · W

Two arithmetic paths:

* **float path** — deltas are dequantized (scale · (q_c − q_p)) and the ΔW GEMM
  runs in bf16 with f32 accumulation. Zero codes ⇒ exactly-zero bf16 deltas, so
  tile skipping is exact. This is the default inside the models.

* **int8 path** — the paper-faithful quantized pipeline. The difference of two
  int8 codes spans [−254, 254]; the paper splits an overflowing delta into two
  in-range components and issues two MACs (measured < 0.01 % of values). We do
  the same: Δ = lo + hi with lo = clip(Δ, −127, 127), hi = Δ − lo (|hi| ≤ 127).
  The hi component is almost entirely zeros, so its GEMM hits the same
  block-skip machinery and costs ~nothing — the overflow handling *is* a reuse
  call.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.similarity import block_zero_mask


class DeltaEncoding(NamedTuple):
    """Delta between consecutive quantized activations of one reuse site."""

    delta: jax.Array       # float (dequantized) delta, [M, K]
    cur_q: jax.Array       # int8 codes of the current input, [M, K]
    block_mask: jax.Array  # int32 [gm, gk]; 1 = tile must be computed
    skip_fraction: jax.Array  # scalar: fraction of skippable tiles


def delta_encode(
    x: jax.Array,
    prev_q: jax.Array,
    scale: jax.Array,
    *,
    block_m: int,
    block_k: int,
    compute_dtype=jnp.bfloat16,
) -> DeltaEncoding:
    """Quantize the current input, form the exact float delta and its tile mask."""
    from repro.quant import quantize_int8

    cur_q = quantize_int8(x, scale)
    dq = cur_q.astype(jnp.int32) - prev_q.astype(jnp.int32)
    delta = (dq.astype(jnp.float32) * scale).astype(compute_dtype)
    mask = block_zero_mask(dq, block_m, block_k)
    skip = 1.0 - jnp.mean(mask.astype(jnp.float32))
    return DeltaEncoding(delta=delta, cur_q=cur_q, block_mask=mask, skip_fraction=skip)


class Int8Delta(NamedTuple):
    lo: jax.Array          # int8 [M, K]
    hi: jax.Array          # int8 [M, K]; nonzero only at overflow positions
    lo_mask: jax.Array     # int32 [gm, gk]
    hi_mask: jax.Array     # int32 [gm, gk] (≈ all zeros ⇒ hi GEMM ≈ free)
    has_overflow: jax.Array  # scalar bool


def delta_encode_int8(
    cur_q: jax.Array, prev_q: jax.Array, *, block_m: int, block_k: int
) -> Int8Delta:
    """Paper-faithful int8 delta with the overflow split (Sec. IV-B)."""
    dq = cur_q.astype(jnp.int32) - prev_q.astype(jnp.int32)
    lo = jnp.clip(dq, -127, 127)
    hi = dq - lo  # |hi| <= 127 because |dq| <= 254
    return Int8Delta(
        lo=lo.astype(jnp.int8),
        hi=hi.astype(jnp.int8),
        lo_mask=block_zero_mask(lo, block_m, block_k),
        hi_mask=block_zero_mask(hi, block_m, block_k),
        has_overflow=jnp.any(hi != 0),
    )


def compact_block_indices(block_mask_row: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Indices of the nonzero K-blocks of one M-row-block, front-compacted.

    Returns (indices [gk], count). indices[i] for i < count are the nonzero
    block ids in order; the tail repeats the last valid id (harmless gathers).
    Used by the compaction GEMM path (beyond-paper, MegaBlocks-style).
    """
    gk = block_mask_row.shape[0]
    nz = block_mask_row != 0
    count = jnp.sum(nz.astype(jnp.int32))
    # Stable front-compaction: position of each nonzero in the compacted order.
    order = jnp.cumsum(nz.astype(jnp.int32)) - 1
    idx = jnp.full((gk,), 0, dtype=jnp.int32)
    idx = idx.at[jnp.where(nz, order, gk - 1)].set(
        jnp.arange(gk, dtype=jnp.int32), mode="drop"
    )
    # Clamp the tail to the last valid entry (or 0 when count == 0).
    last = jnp.maximum(count - 1, 0)
    tail_fill = idx[last]
    idx = jnp.where(jnp.arange(gk) < count, idx, tail_fill)
    return idx, count


def compact_rows(block_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row front-compaction of a [gm, gk] tile mask.

    Returns (idx [gm, gk], counts [gm]): row m's first counts[m] entries are
    its active K-block ids in order, the tail repeats the last valid id. This
    is the scalar-prefetch payload of the ragged compacted-grid kernel
    (kernels/reuse_matmul_ragged.py) and the occupancy signal the accounting
    helpers consume.
    """
    idx, counts = jax.vmap(compact_block_indices)(block_mask)
    return idx, counts
