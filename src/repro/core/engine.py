"""ReuseEngine — site registry + dispatch (the CRS instruction analogue).

The paper's flow: the framework prepares a parameter structure (addresses,
lengths, kernelMode, dataflow) and issues `crs` per layer/tile; ReuseSensor
generates the kernel. Here:

* `register(...)` declares a reuse site (one per unique linear op; sites used
  inside scan-over-layers carry a leading layer dimension in their cache);
* `init_cache(batch)` builds the cache pytree threaded through serve_step;
* `apply(...)` executes one site — the crs call;
* `refresh_modes(cache)` is the host-side policy pass between steps.

The engine itself is static configuration; all mutable state lives in the
cache pytree so steps stay pure and jit/pjit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import ReusePolicy, SiteTunables
from repro.core.reuse_cache import ReuseSiteSpec, init_site_cache
from repro.core.reuse_linear import ReuseStats, reuse_linear
from repro.kernels.ops import clamp_budget


@dataclasses.dataclass
class ReuseEngine:
    policy: ReusePolicy = dataclasses.field(default_factory=ReusePolicy)
    impl: str = "jnp"
    sites: dict[str, ReuseSiteSpec] = dataclasses.field(default_factory=dict)
    # current kernelMode per site; refreshed host-side between steps
    modes: dict[str, str] = dataclasses.field(default_factory=dict)
    # per-site leading layer count (0 = unstacked site)
    stacking: dict[str, int] = dataclasses.field(default_factory=dict)
    # mode-flip cooldown per site: refresh passes left before the next flip
    # is allowed (each flip costs a recompile; see SiteTunables hysteresis)
    cooldown: dict[str, int] = dataclasses.field(default_factory=dict)

    def register(
        self,
        name: str,
        in_features: int,
        out_features: int,
        *,
        n_layers: int = 0,
        block_m: int = 8,
        block_k: int = 256,
        block_n: int = 128,
        mode: str = "auto",
    ) -> ReuseSiteSpec:
        dataflow = self.policy.decide_dataflow(in_features, out_features)
        # The policy's per-site table overrides the caller's tile granularity;
        # the resolved block_k lands in the spec and from there reaches the
        # Pallas kernel dispatch (reuse_linear → ops.reuse_matmul). The same
        # resolution carries the execution substrate: a tuned exec_path /
        # max_active_k selects the compacted tier right at registration.
        block_k = self.policy.resolve_block_k(name, block_k)
        spec = ReuseSiteSpec(
            name=name,
            in_features=in_features,
            out_features=out_features,
            block_m=block_m,
            block_k=block_k,
            block_n=block_n,
            mode=mode,
            dataflow=dataflow,
            exec_path=self.policy.resolve_exec_path(name),
            max_active_k=self.policy.resolve_max_active_k(name),
        )
        self.sites[name] = spec
        self.stacking[name] = n_layers
        # Start optimistic (paper's default is reuse-on); policy may demote.
        self.modes[name] = "reuse" if mode == "auto" else mode
        self.cooldown[name] = 0
        return spec

    def init_cache(self, batch: int) -> dict[str, Any]:
        cache: dict[str, Any] = {}
        for name, spec in self.sites.items():
            entry = init_site_cache(spec, batch)
            n_layers = self.stacking[name]
            if n_layers:
                entry = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_layers, *x.shape)).copy(),
                    entry,
                )
            cache[name] = entry
        return cache

    def apply(
        self,
        name: str,
        x: jax.Array,
        w: jax.Array,
        b: jax.Array | None,
        cache_entry: dict[str, jax.Array],
    ) -> tuple[jax.Array, dict[str, jax.Array], ReuseStats]:
        spec = self.sites[name]
        return reuse_linear(
            x, w, b, cache_entry, spec, mode=self.modes[name], impl=self.impl
        )

    def apply_tunables(self, name: str, t: SiteTunables) -> bool:
        """Install live per-site tunables — the online retuner's write path.

        The policy-table entry is replaced (decide_mode and the refresh
        passes pick the new knobs up immediately); spec fields baked into the
        traced dispatch re-resolve here: block_k, and — for a site already ON
        a compacted path — its k-extent budget. Mode and exec-path
        *transitions* stay with `refresh_modes`, which carries the hysteresis
        margin and the flip cooldown. Returns True when the spec changed, so
        callers rebuild the jitted step."""
        self.policy.site_tunables[name] = t
        spec = self.sites[name]
        new = spec
        if t.block_k is not None and int(t.block_k) != spec.block_k:
            new = dataclasses.replace(new, block_k=int(t.block_k))
            if new.exec_path in ("ragged", "compact") and new.max_active_k:
                # the budget's unit is K-blocks OF block_k: rescale it so the
                # covered K extent survives the granularity change (else a
                # halved block_k silently halves the budgeted extent and
                # every evaluation overflows into the full-extent fallback).
                # The table entry syncs to the rescaled value too, so the
                # next retune interval can't re-install the old-unit number.
                gk = -(-new.in_features // new.block_k)
                scaled = round(new.max_active_k * spec.block_k / new.block_k)
                new = dataclasses.replace(
                    new, max_active_k=clamp_budget(int(scaled), gk)
                )
                self.policy.site_tunables[name] = dataclasses.replace(
                    t, max_active_k=new.max_active_k
                )
        if (
            t.max_active_k is not None
            and new.exec_path in ("ragged", "compact")
            and spec.block_k == new.block_k  # rescale wins on a block_k move
            and int(t.max_active_k) != new.max_active_k
        ):
            gk = -(-new.in_features // new.block_k)
            new = dataclasses.replace(
                new, max_active_k=clamp_budget(int(t.max_active_k), gk)
            )
        if new == spec:
            return False
        self.sites[name] = new
        return True

    def set_budget(self, name: str, budget: int) -> bool:
        """Re-point a compacted site's static k-extent budget — the online
        budget adapter's write path. Keeps the policy table in sync so the
        next exec-path refresh or retune doesn't silently revert the
        adaptation. Returns True when the spec changed (retrace)."""
        spec = self.sites[name]
        if spec.exec_path not in ("ragged", "compact"):
            return False
        gk = -(-spec.in_features // spec.block_k)
        budget = clamp_budget(int(budget), gk)
        if budget == spec.max_active_k:
            return False
        self.sites[name] = dataclasses.replace(spec, max_active_k=budget)
        self.policy.site_tunables[name] = dataclasses.replace(
            self.policy.resolve(name), max_active_k=budget
        )
        return True

    def refresh_modes(self, cache: dict[str, Any]) -> dict[str, str]:
        """Host-side policy pass: read sim_ema out of the cache, re-decide
        kernelMode per site (hysteretically — the policy sees the current
        mode, and a freshly-flipped site is frozen for its tunables'
        `hysteresis_steps` passes so modes can't oscillate reuse↔basic across
        consecutive refreshes). Suppressed flips are counted into the site's
        sensor counters. The same pass re-decides each site's execution
        substrate (`exec_path`) from its measured tile-skip rate — a site
        whose stream turns out highly skippable is promoted onto the ragged/
        compacted tier. Returns the sites whose mode or exec_path changed
        (both cost a retrace, so callers rebuild the jitted step)."""
        changed = {}
        for name, spec in self.sites.items():
            ema = cache[name]["sim_ema"]
            ema_val = float(jnp.mean(ema))  # stacked sites: mean over layers
            cur = self.modes[name]
            new_mode = self.policy.decide_mode(spec, ema_val, current_mode=cur)
            if new_mode == cur:
                self.cooldown[name] = max(0, self.cooldown.get(name, 0) - 1)
                continue
            if self.cooldown.get(name, 0) > 0:
                self.cooldown[name] -= 1
                entry = cache[name]
                if "sensor" in entry:
                    sensor = dict(entry["sensor"])
                    sensor["suppressed_flips"] = sensor["suppressed_flips"] + 1
                    cache[name] = dict(entry, sensor=sensor)
                continue
            self.modes[name] = new_mode
            changed[name] = new_mode
            self.cooldown[name] = self.policy.resolve(name).hysteresis_steps
        changed.update(self.refresh_exec_paths(cache))
        return changed

    def refresh_exec_paths(self, cache: dict[str, Any]) -> dict[str, str]:
        """Promote/demote execution substrates from MEASURED skip rates.

        Cumulative tile counters smooth the signal, and exec flips share the
        mode-flip cooldown (each one retraces the step, so a site frozen
        after any flip stays frozen here too); a site with no measured reuse
        evaluations keeps its current path. Caveat: after a live block_k
        change (apply_tunables) the cumulative rate mixes tile units across
        granularities and converges to the new regime only asymptotically —
        the online controller therefore drives promotion through solver
        pins computed from clean windowed deltas, and this pass is the
        fallback for unpinned sites. Returns {site: "exec:<path>"} for
        sites that moved."""
        from repro.core.reuse_cache import resolve_exec_path

        changed: dict[str, str] = {}
        for name, spec in self.sites.items():
            sensor = cache[name].get("sensor")
            if sensor is None:
                continue
            skipped = float(jnp.sum(sensor["skipped_tiles"]))
            computed = float(jnp.sum(sensor["computed_tiles"]))
            total = skipped + computed
            if total <= 0:
                continue
            new_path = self.policy.decide_exec_path(
                spec, skipped / total, impl=self.impl
            )
            if new_path == resolve_exec_path(spec, self.impl):
                continue
            if self.cooldown.get(name, 0) > 0:
                continue
            gk = -(-spec.in_features // spec.block_k)
            budget = None
            if new_path in ("ragged", "compact"):
                budget = self.policy.resolve_max_active_k(name)
                if budget is None:
                    budget = self.policy.ragged_budget(gk, skipped / total)
            self.sites[name] = dataclasses.replace(
                spec, exec_path=new_path, max_active_k=budget
            )
            changed[name] = f"exec:{new_path}"
            self.cooldown[name] = self.policy.resolve(name).hysteresis_steps
        return changed

    def sensor_report(self, cache: dict[str, Any]):
        """Measured reuse accounting for the whole model — the ReuseSensor's
        bypassed-computation / skipped-weight-load counts, reduced host-side
        from the counters the kernels updated.

        Returns a repro.sensor.aggregate.SensorReport (per-site, per-layer,
        whole-model, JSONL-emittable)."""
        from repro.sensor.aggregate import build_report

        return build_report(self, cache)
