"""ReuseEngine — site registry + dispatch (the CRS instruction analogue).

The paper's flow: the framework prepares a parameter structure (addresses,
lengths, kernelMode, dataflow) and issues `crs` per layer/tile; ReuseSensor
generates the kernel. Here:

* `register(...)` declares a reuse site (one per unique linear op; sites used
  inside scan-over-layers carry a leading layer dimension in their cache);
* `init_cache(batch)` builds the cache pytree threaded through serve_step;
* `apply(...)` executes one site — the crs call;
* `refresh_modes(cache)` is the host-side policy pass between steps.

The engine itself is static configuration; all mutable state lives in the
cache pytree so steps stay pure and jit/pjit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import ReusePolicy
from repro.core.reuse_cache import ReuseSiteSpec, init_site_cache
from repro.core.reuse_linear import ReuseStats, reuse_linear


@dataclasses.dataclass
class ReuseEngine:
    policy: ReusePolicy = dataclasses.field(default_factory=ReusePolicy)
    impl: str = "jnp"
    sites: dict[str, ReuseSiteSpec] = dataclasses.field(default_factory=dict)
    # current kernelMode per site; refreshed host-side between steps
    modes: dict[str, str] = dataclasses.field(default_factory=dict)
    # per-site leading layer count (0 = unstacked site)
    stacking: dict[str, int] = dataclasses.field(default_factory=dict)
    # mode-flip cooldown per site: refresh passes left before the next flip
    # is allowed (each flip costs a recompile; see SiteTunables hysteresis)
    cooldown: dict[str, int] = dataclasses.field(default_factory=dict)

    def register(
        self,
        name: str,
        in_features: int,
        out_features: int,
        *,
        n_layers: int = 0,
        block_m: int = 8,
        block_k: int = 256,
        block_n: int = 128,
        mode: str = "auto",
    ) -> ReuseSiteSpec:
        dataflow = self.policy.decide_dataflow(in_features, out_features)
        # The policy's per-site table overrides the caller's tile granularity;
        # the resolved block_k lands in the spec and from there reaches the
        # Pallas kernel dispatch (reuse_linear → ops.reuse_matmul). The same
        # resolution carries the execution substrate: a tuned exec_path /
        # max_active_k selects the compacted tier right at registration.
        block_k = self.policy.resolve_block_k(name, block_k)
        spec = ReuseSiteSpec(
            name=name,
            in_features=in_features,
            out_features=out_features,
            block_m=block_m,
            block_k=block_k,
            block_n=block_n,
            mode=mode,
            dataflow=dataflow,
            exec_path=self.policy.resolve_exec_path(name),
            max_active_k=self.policy.resolve_max_active_k(name),
        )
        self.sites[name] = spec
        self.stacking[name] = n_layers
        # Start optimistic (paper's default is reuse-on); policy may demote.
        self.modes[name] = "reuse" if mode == "auto" else mode
        self.cooldown[name] = 0
        return spec

    def init_cache(self, batch: int) -> dict[str, Any]:
        cache: dict[str, Any] = {}
        for name, spec in self.sites.items():
            entry = init_site_cache(spec, batch)
            n_layers = self.stacking[name]
            if n_layers:
                entry = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_layers, *x.shape)).copy(),
                    entry,
                )
            cache[name] = entry
        return cache

    def apply(
        self,
        name: str,
        x: jax.Array,
        w: jax.Array,
        b: jax.Array | None,
        cache_entry: dict[str, jax.Array],
    ) -> tuple[jax.Array, dict[str, jax.Array], ReuseStats]:
        spec = self.sites[name]
        return reuse_linear(
            x, w, b, cache_entry, spec, mode=self.modes[name], impl=self.impl
        )

    def refresh_modes(self, cache: dict[str, Any]) -> dict[str, str]:
        """Host-side policy pass: read sim_ema out of the cache, re-decide
        kernelMode per site (hysteretically — the policy sees the current
        mode, and a freshly-flipped site is frozen for its tunables'
        `hysteresis_steps` passes so modes can't oscillate reuse↔basic across
        consecutive refreshes). Suppressed flips are counted into the site's
        sensor counters. The same pass re-decides each site's execution
        substrate (`exec_path`) from its measured tile-skip rate — a site
        whose stream turns out highly skippable is promoted onto the ragged/
        compacted tier. Returns the sites whose mode or exec_path changed
        (both cost a retrace, so callers rebuild the jitted step)."""
        changed = {}
        for name, spec in self.sites.items():
            ema = cache[name]["sim_ema"]
            ema_val = float(jnp.mean(ema))  # stacked sites: mean over layers
            cur = self.modes[name]
            new_mode = self.policy.decide_mode(spec, ema_val, current_mode=cur)
            if new_mode == cur:
                self.cooldown[name] = max(0, self.cooldown.get(name, 0) - 1)
                continue
            if self.cooldown.get(name, 0) > 0:
                self.cooldown[name] -= 1
                entry = cache[name]
                if "sensor" in entry:
                    sensor = dict(entry["sensor"])
                    sensor["suppressed_flips"] = sensor["suppressed_flips"] + 1
                    cache[name] = dict(entry, sensor=sensor)
                continue
            self.modes[name] = new_mode
            changed[name] = new_mode
            self.cooldown[name] = self.policy.resolve(name).hysteresis_steps
        changed.update(self.refresh_exec_paths(cache))
        return changed

    def refresh_exec_paths(self, cache: dict[str, Any]) -> dict[str, str]:
        """Promote/demote execution substrates from MEASURED skip rates.

        Cumulative tile counters smooth the signal, and exec flips share the
        mode-flip cooldown (each one retraces the step, so a site frozen
        after any flip stays frozen here too); a site with no measured reuse
        evaluations keeps its current path. Returns {site: "exec:<path>"}
        for sites that moved."""
        from repro.core.reuse_cache import resolve_exec_path

        changed: dict[str, str] = {}
        for name, spec in self.sites.items():
            sensor = cache[name].get("sensor")
            if sensor is None:
                continue
            skipped = float(jnp.sum(sensor["skipped_tiles"]))
            computed = float(jnp.sum(sensor["computed_tiles"]))
            total = skipped + computed
            if total <= 0:
                continue
            new_path = self.policy.decide_exec_path(
                spec, skipped / total, impl=self.impl
            )
            if new_path == resolve_exec_path(spec, self.impl):
                continue
            if self.cooldown.get(name, 0) > 0:
                continue
            gk = -(-spec.in_features // spec.block_k)
            budget = None
            if new_path in ("ragged", "compact"):
                budget = self.policy.resolve_max_active_k(name)
                if budget is None:
                    budget = self.policy.ragged_budget(gk, skipped / total)
            self.sites[name] = dataclasses.replace(
                spec, exec_path=new_path, max_active_k=budget
            )
            changed[name] = f"exec:{new_path}"
            self.cooldown[name] = self.policy.resolve(name).hysteresis_steps
        return changed

    def sensor_report(self, cache: dict[str, Any]):
        """Measured reuse accounting for the whole model — the ReuseSensor's
        bypassed-computation / skipped-weight-load counts, reduced host-side
        from the counters the kernels updated.

        Returns a repro.sensor.aggregate.SensorReport (per-site, per-layer,
        whole-model, JSONL-emittable)."""
        from repro.sensor.aggregate import build_report

        return build_report(self, cache)
