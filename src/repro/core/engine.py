"""ReuseEngine — site registry + dispatch (the CRS instruction analogue).

The paper's flow: the framework prepares a parameter structure (addresses,
lengths, kernelMode, dataflow) and issues `crs` per layer/tile; ReuseSensor
generates the kernel. Here:

* `register(...)` declares a reuse site (one per unique linear op; sites used
  inside scan-over-layers carry a leading layer dimension in their cache);
* `init_cache(batch)` builds the cache pytree threaded through serve_step;
* `apply(...)` executes one site — the crs call;
* `refresh_modes(cache)` is the host-side policy pass between steps.

The engine itself is static configuration; all mutable state lives in the
cache pytree so steps stay pure and jit/pjit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import ReusePolicy
from repro.core.reuse_cache import ReuseSiteSpec, init_site_cache
from repro.core.reuse_linear import ReuseStats, reuse_linear


@dataclasses.dataclass
class ReuseEngine:
    policy: ReusePolicy = dataclasses.field(default_factory=ReusePolicy)
    impl: str = "jnp"
    sites: dict[str, ReuseSiteSpec] = dataclasses.field(default_factory=dict)
    # current kernelMode per site; refreshed host-side between steps
    modes: dict[str, str] = dataclasses.field(default_factory=dict)
    # per-site leading layer count (0 = unstacked site)
    stacking: dict[str, int] = dataclasses.field(default_factory=dict)

    def register(
        self,
        name: str,
        in_features: int,
        out_features: int,
        *,
        n_layers: int = 0,
        block_m: int = 8,
        block_k: int = 256,
        block_n: int = 128,
        mode: str = "auto",
    ) -> ReuseSiteSpec:
        dataflow = self.policy.decide_dataflow(in_features, out_features)
        spec = ReuseSiteSpec(
            name=name,
            in_features=in_features,
            out_features=out_features,
            block_m=block_m,
            block_k=block_k,
            block_n=block_n,
            mode=mode,
            dataflow=dataflow,
        )
        self.sites[name] = spec
        self.stacking[name] = n_layers
        # Start optimistic (paper's default is reuse-on); policy may demote.
        self.modes[name] = "reuse" if mode == "auto" else mode
        return spec

    def init_cache(self, batch: int) -> dict[str, Any]:
        cache: dict[str, Any] = {}
        for name, spec in self.sites.items():
            entry = init_site_cache(spec, batch)
            n_layers = self.stacking[name]
            if n_layers:
                entry = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_layers, *x.shape)).copy(),
                    entry,
                )
            cache[name] = entry
        return cache

    def apply(
        self,
        name: str,
        x: jax.Array,
        w: jax.Array,
        b: jax.Array | None,
        cache_entry: dict[str, jax.Array],
    ) -> tuple[jax.Array, dict[str, jax.Array], ReuseStats]:
        spec = self.sites[name]
        return reuse_linear(
            x, w, b, cache_entry, spec, mode=self.modes[name], impl=self.impl
        )

    def refresh_modes(self, cache: dict[str, Any]) -> dict[str, str]:
        """Host-side policy pass: read sim_ema out of the cache, re-decide
        kernelMode per site. Returns the sites whose mode changed."""
        changed = {}
        for name, spec in self.sites.items():
            ema = cache[name]["sim_ema"]
            ema_val = float(jnp.mean(ema))  # stacked sites: mean over layers
            new_mode = self.policy.decide_mode(spec, ema_val)
            if new_mode != self.modes[name]:
                self.modes[name] = new_mode
                changed[name] = new_mode
        return changed

    def sensor_report(self, cache: dict[str, Any]):
        """Measured reuse accounting for the whole model — the ReuseSensor's
        bypassed-computation / skipped-weight-load counts, reduced host-side
        from the counters the kernels updated. Supersedes `site_summary`.

        Returns a repro.sensor.aggregate.SensorReport (per-site, per-layer,
        whole-model, JSONL-emittable)."""
        from repro.sensor.aggregate import build_report

        return build_report(self, cache)

    def site_summary(self, cache: dict[str, Any]) -> dict[str, dict[str, float]]:
        """One EMA scalar per site. Superseded by `sensor_report` (measured
        counters); kept for cheap logging and back-compat."""
        out = {}
        for name in self.sites:
            out[name] = {
                "sim_ema": float(jnp.mean(cache[name]["sim_ema"])),
                "mode": self.modes[name],
                "steps": int(jnp.max(cache[name]["steps"])),
            }
        return out
