"""ReuseEngine — site registry + dispatch (the CRS instruction analogue).

The paper's flow: the framework prepares a parameter structure (addresses,
lengths, kernelMode, dataflow) and issues `crs` per layer/tile; ReuseSensor
generates the kernel — and parametrizes kernelMode LAYER BY LAYER. Here:

* `register(...)` declares a reuse site (one per unique linear op; sites used
  inside scan-over-layers carry a leading layer dimension in their cache);
* `init_cache(batch)` builds the cache pytree threaded through serve_step —
  including, per site, the ARRAY-RESIDENT control block (`ctrl`): per-layer
  kernelMode ids, live sim_threshold / min_work operating point, per-layer
  flip cooldown and budget-occupancy EMA;
* `apply(...)` executes one site — the crs call; kernelMode is read from the
  ctrl lane the scan sliced for this layer (lax.cond in reuse_linear), so a
  deep stack runs mixed modes inside ONE trace;
* `refresh_modes(cache)` is the host-side policy pass between steps: a
  vectorized per-layer decide over each site's ctrl block. Mode flips are
  array writes (no retrace); only spec-level changes — exec_path / block_k /
  max_active_k — require rebuilding the jitted step, and only those are
  returned.

The engine itself is static configuration; ALL mutable control state lives in
the cache pytree next to the counters, so steps stay pure and jit/pjit-
friendly and the policy's current operating point checkpoints/donates/shards
with the rest of the serving state.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import (
    MODE_BASIC,
    MODE_REUSE,
    ReusePolicy,
    SiteTunables,
    layer_key,
    mode_name,
)
from repro.core.reuse_cache import ReuseSiteSpec, init_site_cache
from repro.core.reuse_linear import ReuseStats, reuse_linear
from repro.sensor.counters import ShardCtx


def clamp_budget(max_active_k: int | None, gk: int) -> int:
    """kernels.ops.clamp_budget, imported lazily: kernels.ops imports
    repro.core.delta, so a module-level import back into the engine closes
    an import cycle for any consumer that loads repro.kernels first."""
    from repro.kernels.ops import clamp_budget as _clamp

    return _clamp(max_active_k, gk)


def _combine_shard_sentinels(
    lanes: dict[str, jax.Array], count: int
) -> dict[str, jax.Array]:
    """Collapse vmapped sentinel lanes [S, L] → [L], preserving each lane's
    detection semantics: disjoint counts SUM (prev_out columns and the
    counter ownership partition split across shards), replicated health
    flags MAX (a single corrupt shard must still trip), and the ctrl range
    bitmask ORs (max would drop bits when different shards fail different
    range checks)."""
    out: dict[str, jax.Array] = {
        "bad_out": jnp.sum(lanes["bad_out"], axis=0),
        "bad_sim": jnp.max(lanes["bad_sim"], axis=0),
        "steps_l": lanes["steps_l"][0],
    }
    if "ctrl_bad" in lanes:
        out["ctrl_bad"] = functools.reduce(
            jnp.bitwise_or, [lanes["ctrl_bad"][i] for i in range(count)]
        )
        out["quarantine"] = jnp.max(lanes["quarantine"], axis=0)
    if "skipped_l" in lanes:
        out["skipped_l"] = jnp.sum(lanes["skipped_l"], axis=0)
        out["computed_l"] = jnp.sum(lanes["computed_l"], axis=0)
    return out


@functools.partial(jax.jit, static_argnames=("shard_axes",))
def _ctrl_snapshot_device(
    cache: dict[str, Any],
    shard_axes: tuple[tuple[str, int, int], ...] = (),
) -> dict[str, Any]:
    """ONE traced pass over the whole cache pytree gathering everything the
    host-side policy pass reads: per-layer sim_ema means, the ctrl lanes, and
    the sensor tile sums. Before this existed, refresh_modes/refresh_exec_
    paths issued ~7 device→host syncs PER SITE per control interval; now the
    reductions run in one compiled executable and the host pulls one tiny
    pytree (see ReuseEngine.ctrl_snapshot).

    The guard plane's array sentinels (non-finite flags, ctrl-lane range
    bitmasks, per-layer counter lanes — repro.guard.sentinel) ride the same
    traced pass, so fault DETECTION costs zero extra device→host syncs.

    `shard_axes` (static) lists the model-sharded sites as
    (name, shard_axis, n_shards). For those entries the snapshot is ALSO the
    once-per-control-window cross-mesh sensor reduce: the sums below run over
    the shard axis of mesh-placed counter arrays, so SPMD partitioning lowers
    them to the one all-reduce per window the design allows (no hot-path
    collectives), and the host still pulls one tiny replicated pytree.
    Replicated ctrl/sim lanes collapse to shard lane 0; per-shard skip lanes
    (`skipped_shard`/`computed_shard`, [S]) ride along for the controller's
    per-shard journal entries at zero extra transfers."""
    from repro.guard.sentinel import sentinel_lanes

    shard_of = {name: (ax, count) for name, ax, count in shard_axes}
    snap: dict[str, Any] = {}
    for name, entry in cache.items():
        s: dict[str, jax.Array] = {}
        sh = shard_of.get(name)
        ctrl = entry.get("ctrl")
        if ctrl is not None:
            sim = entry["sim_ema"]
            sim_l = sim if sim.ndim == 0 else jnp.mean(sim, axis=-1)
            if sh is not None:  # replicated across shards → lane 0
                ax = sh[0]
                sim_l = jnp.take(sim_l, 0, axis=ax)
                s["sim_l"] = jnp.atleast_1d(sim_l).astype(jnp.float32)
                s["mode_id"] = jnp.atleast_1d(
                    jnp.take(ctrl["mode_id"], 0, axis=ax))
                s["sim_threshold"] = jnp.atleast_1d(
                    jnp.take(ctrl["sim_threshold"], 0, axis=ax))
                s["min_work"] = jnp.atleast_1d(
                    jnp.take(ctrl["min_work"], 0, axis=ax))
                s["cooldown"] = jnp.atleast_1d(
                    jnp.take(ctrl["cooldown"], 0, axis=ax))
            else:
                s["sim_l"] = jnp.atleast_1d(sim_l).astype(jnp.float32)
                s["mode_id"] = jnp.atleast_1d(ctrl["mode_id"])
                s["sim_threshold"] = jnp.atleast_1d(ctrl["sim_threshold"])
                s["min_work"] = jnp.atleast_1d(ctrl["min_work"])
                s["cooldown"] = jnp.atleast_1d(ctrl["cooldown"])
        sensor = entry.get("sensor")
        if sensor is not None:
            # ownership partition ⇒ the plain sum over ALL axes (layers AND
            # shards) IS the global count — this is the mesh reduce.
            s["skipped"] = jnp.sum(sensor["skipped_tiles"])
            s["computed"] = jnp.sum(sensor["computed_tiles"])
            if sh is not None:
                ax = sh[0]
                lane_axes = tuple(
                    i for i in range(sensor["skipped_tiles"].ndim) if i != ax)
                s["skipped_shard"] = jnp.sum(
                    sensor["skipped_tiles"], axis=lane_axes)
                s["computed_shard"] = jnp.sum(
                    sensor["computed_tiles"], axis=lane_axes)
        if ctrl is not None:
            if sh is None:
                s.update(sentinel_lanes(entry))
            else:
                ax, count = sh
                lanes = jax.vmap(sentinel_lanes, in_axes=ax)(entry)
                s.update(_combine_shard_sentinels(lanes, count))
        snap[name] = s
    return snap


@dataclasses.dataclass
class ReuseEngine:
    policy: ReusePolicy = dataclasses.field(default_factory=ReusePolicy)
    impl: str = "jnp"
    sites: dict[str, ReuseSiteSpec] = dataclasses.field(default_factory=dict)
    # per-site leading layer count (0 = unstacked site)
    stacking: dict[str, int] = dataclasses.field(default_factory=dict)
    # exec-path flip cooldown per site: refresh passes left before the next
    # substrate change is allowed (each one retraces the step). kernelMode
    # cooldown is PER LAYER and lives in the cache ctrl block instead.
    exec_cooldown: dict[str, int] = dataclasses.field(default_factory=dict)
    # per-layer mode flips applied by the most recent refresh_modes pass
    # ({site, layer, before, after, sim_ema}; layer None = unstacked) — the
    # controller journals these; they do NOT require a retrace
    last_mode_events: list[dict] = dataclasses.field(default_factory=list)
    # model-axis shard count per site (empty = unsharded engine). Set by
    # shard_sites() BEFORE init_cache; sharded entries carry the shard axis
    # inside the layer axis ([S, ...] unstacked, [L, S, ...] stacked).
    shards: dict[str, int] = dataclasses.field(default_factory=dict)
    # interconnect accounting (bytes, cumulative): the per-window cross-mesh
    # counter reduce riding the ctrl snapshot, and sharded ctrl-lane write
    # fan-out. sensor.cost_model prices these into E_ICI energy.
    ici_reduce_bytes: float = 0.0
    ici_write_bytes: float = 0.0
    # the most recent ctrl_snapshot (host pytree) — the controller reads the
    # per-shard skip lanes from here instead of paying a second device_get
    last_snapshot: dict[str, Any] | None = None

    def register(
        self,
        name: str,
        in_features: int,
        out_features: int,
        *,
        n_layers: int = 0,
        block_m: int = 8,
        block_k: int = 256,
        block_n: int = 128,
        mode: str = "auto",
    ) -> ReuseSiteSpec:
        dataflow = self.policy.decide_dataflow(in_features, out_features)
        # The policy's per-site table overrides the caller's tile granularity;
        # the resolved block_k lands in the spec and from there reaches the
        # Pallas kernel dispatch (reuse_linear → ops.reuse_matmul). The same
        # resolution carries the execution substrate: a tuned exec_path /
        # max_active_k selects the compacted tier right at registration.
        block_k = self.policy.resolve_block_k(name, block_k)
        spec = ReuseSiteSpec(
            name=name,
            in_features=in_features,
            out_features=out_features,
            block_m=block_m,
            block_k=block_k,
            block_n=block_n,
            mode=mode,
            dataflow=dataflow,
            exec_path=self.policy.resolve_exec_path(name),
            max_active_k=self.policy.resolve_max_active_k(name),
        )
        self.sites[name] = spec
        self.stacking[name] = n_layers
        self.exec_cooldown[name] = 0
        return spec

    def shard_sites(self, n_shards: int) -> dict[str, int]:
        """Plan an N-way model-axis split of every registered site — the
        sharded-serving entry point, called BEFORE init_cache. Validates
        divisibility up front (a clear error beats a reshape failure deep in
        the traced step) and records the plan in `self.shards`; init_cache
        then expands every entry with the shard axis, apply() dispatches
        through the vmap-over-shards path, and the ctrl snapshot collapses
        shard lanes back out. n_shards <= 1 clears the plan (unsharded)."""
        from repro.dist.shard import validate_shardable

        if n_shards <= 1:
            self.shards = {}
            return self.shards
        for spec in self.sites.values():
            validate_shardable(spec, n_shards)
        self.shards = {name: n_shards for name in self.sites}
        return self.shards

    def init_cache(self, batch: int) -> dict[str, Any]:
        cache: dict[str, Any] = {}
        for name, spec in self.sites.items():
            n_shards = self.shards.get(name, 0)
            if n_shards:
                from repro.dist.shard import plan_local_spec

                spec = plan_local_spec(spec, n_shards)
            entry = init_site_cache(spec, batch, self.policy.resolve(name))
            if n_shards:
                # shard axis first (innermost), layer axis broadcast below
                # wraps it: [S, ...] unstacked → [L, S, ...] stacked. Initial
                # state is identical across shards (prev_out is zeros at the
                # local N), so a broadcast IS the sharded init.
                entry = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x, (n_shards, *x.shape)).copy(),
                    entry,
                )
            n_layers = self.stacking[name]
            if n_layers:
                entry = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_layers, *x.shape)).copy(),
                    entry,
                )
                # per-layer tunables rows ("site@layer") land in the ctrl
                # lanes here; spec-level knobs stay site-granular
                ts = [self.policy.resolve(name, layer=layer)
                      for layer in range(n_layers)]
                thr = jnp.asarray([t.sim_threshold for t in ts], jnp.float32)
                mw = jnp.asarray([t.min_work_flops for t in ts], jnp.float32)
                if n_shards:  # per-layer lanes replicate across shards
                    thr = jnp.broadcast_to(
                        thr[:, None], (n_layers, n_shards))
                    mw = jnp.broadcast_to(mw[:, None], (n_layers, n_shards))
                entry["ctrl"] = dict(
                    entry["ctrl"], sim_threshold=thr, min_work=mw,
                )
            cache[name] = entry
        return cache

    def apply(
        self,
        name: str,
        x: jax.Array,
        w: jax.Array,
        b: jax.Array | None,
        cache_entry: dict[str, jax.Array],
    ) -> tuple[jax.Array, dict[str, jax.Array], ReuseStats]:
        spec = self.sites[name]
        # Explicitly pinned sites keep the static single-branch dispatch;
        # "auto" sites branch on the ctrl lane the caller's scan sliced.
        mode = spec.mode if spec.mode in ("reuse", "basic") else None
        # named_scope labels the site in device traces/HLO, so a profiler
        # window (serve --profile-dir) attributes device time per reuse site.
        with jax.named_scope(f"reuse_site:{name}"):
            if self.shards.get(name):
                return self._apply_sharded(name, x, w, b, cache_entry, mode)
            return reuse_linear(
                x, w, b, cache_entry, spec, mode=mode, impl=self.impl
            )

    def _apply_sharded(
        self,
        name: str,
        x: jax.Array,
        w: jax.Array,
        b: jax.Array | None,
        entry: dict[str, jax.Array],
        mode: str | None,
    ) -> tuple[jax.Array, dict[str, jax.Array], ReuseStats]:
        """One sharded site call: vmap the shard-local evaluation over the
        entry's shard axis. The weight panel splits column-wise to match
        (`w[:, s·nl:(s+1)·nl]` per shard); x is replicated in closure; every
        cache leaf carries the shard axis uniformly, so `in_axes=0` maps the
        whole entry. NOTHING here crosses shards — no gather, no reduce —
        which is the hot-path invariant the HLO check pins.

        kernelMode dispatch lifts OUTSIDE the vmap: `lax.cond` under vmap
        lowers to a select that executes BOTH branches on every shard, so the
        branch is taken once on the (replicated) layer ctrl lane and each arm
        vmaps a statically-moded evaluation."""
        spec = self.sites[name]
        n_shards = self.shards[name]
        nl = spec.out_features // n_shards
        k = w.shape[0]
        lead = x.shape[:-1]
        local = dataclasses.replace(spec, out_features=nl)
        gn_total = -(-spec.out_features // spec.block_n)
        ws = jnp.moveaxis(w.reshape(k, n_shards, nl), 1, 0)   # [S, K, nl]
        bs = None if b is None else b.reshape(n_shards, nl)
        idx = jnp.arange(n_shards, dtype=jnp.int32)

        def _sharded_eval(static_mode: str):
            def one(i, wl, bl, el):
                shard = ShardCtx(index=i, count=n_shards,
                                 n_total=spec.out_features,
                                 gn_total=gn_total)
                return reuse_linear(
                    x, wl, bl, el, local, mode=static_mode,
                    impl=self.impl, shard=shard,
                )

            axes = (0, 0, None if b is None else 0, 0)
            return lambda: jax.vmap(one, in_axes=axes)(idx, ws, bs, entry)

        if mode is None:
            ctrl = entry.get("ctrl")
            if ctrl is None:
                raise ValueError(
                    f"site {name!r}: sharded mode=None needs a ctrl block "
                    "in the cache entry (engine.init_cache creates it)"
                )
            # the layer's mode lane, replicated across shards → lane 0
            pred = jnp.reshape(ctrl["mode_id"], (-1,))[0] > 0
            out_s, new_entry, stats_s = jax.lax.cond(
                pred, _sharded_eval("reuse"), _sharded_eval("basic")
            )
        else:
            out_s, new_entry, stats_s = _sharded_eval(mode)()
        # [S, *lead, nl] → [*lead, S, nl] → [*lead, N]
        out = jnp.moveaxis(out_s, 0, -2).reshape(*lead, spec.out_features)
        stats = jax.tree.map(lambda a: a[0], stats_s)  # replicated per shard
        return out, new_entry, stats

    # ------------------------------------------------ ctrl-block interrogation

    @staticmethod
    def entry_mode_ids(entry: dict[str, Any]) -> np.ndarray:
        """A site's per-layer mode ids as a 1-d host array ([1] unstacked)."""
        return np.atleast_1d(np.asarray(entry["ctrl"]["mode_id"]))

    def _mode_ids(self, cache: dict[str, Any], name: str) -> np.ndarray:
        """Per-layer mode ids with the shard lane collapsed (mode lanes are
        replicated across model shards, so lane 0 is the site truth)."""
        ids = np.asarray(cache[name]["ctrl"]["mode_id"])
        if self.shards.get(name, 0):
            from repro.dist.shard import shard_axis_of

            ids = np.take(ids, 0, axis=shard_axis_of(
                self.stacking.get(name, 0)))
        return np.atleast_1d(ids)

    def layer_modes(self, cache: dict[str, Any], name: str) -> list[str]:
        return [mode_name(m) for m in self._mode_ids(cache, name)]

    def site_mode(self, cache: dict[str, Any], name: str) -> str:
        """One site's kernelMode summary: "reuse"/"basic" when uniform over
        layers, "mixed" when a stack settled distinct per-layer modes."""
        ids = self._mode_ids(cache, name)
        if np.all(ids == ids[0]):
            return mode_name(ids[0])
        return "mixed"

    def mode_summary(self, cache: dict[str, Any]) -> dict[str, str]:
        return {name: self.site_mode(cache, name) for name in self.sites}

    def set_mode(
        self, cache: dict[str, Any], name: str, mode: str,
        *, layer: int | None = None,
    ) -> None:
        """Force kernelMode for a site (all layers, or one layer's lane) by
        writing the ctrl block — an array write, no retrace."""
        mid = MODE_REUSE if mode == "reuse" else MODE_BASIC
        entry = cache[name]
        cur = entry["ctrl"]["mode_id"]
        new = jnp.full_like(cur, mid) if layer is None else cur.at[layer].set(mid)
        cache[name] = dict(entry, ctrl=dict(entry["ctrl"], mode_id=new))

    # ------------------------------------------------------- live write paths

    def apply_tunables(
        self,
        name: str,
        t: SiteTunables,
        cache: dict[str, Any] | None = None,
        *,
        layer: int | None = None,
    ) -> bool:
        """Install live tunables — the online retuner's write path.

        `layer=None` replaces the site-level policy-table entry; spec fields
        baked into the traced dispatch re-resolve here: block_k, and — for a
        site already ON a compacted path — its k-extent budget. `layer=i`
        installs a per-layer row (`"site@i"` key) instead and touches NO spec
        field (per-layer knobs are array-resident by construction).

        With `cache` given, the affected ctrl lanes (sim_threshold/min_work)
        are re-synced from the updated table in the same pass, so the next
        refresh decides on the new operating point without a separate sync.
        Mode and exec-path *transitions* stay with `refresh_modes`, which
        carries the hysteresis margin and the flip cooldowns. Returns True
        when the SPEC changed, so callers rebuild the jitted step."""
        if layer is not None:
            self.policy.site_tunables[layer_key(name, layer)] = t
            self._sync_ctrl(name, cache)
            return False
        self.policy.site_tunables[name] = t
        spec = self.sites[name]
        new = spec
        if t.block_k is not None and int(t.block_k) != spec.block_k:
            new = dataclasses.replace(new, block_k=int(t.block_k))
            if new.exec_path in ("ragged", "compact") and new.max_active_k:
                # the budget's unit is K-blocks OF block_k: rescale it so the
                # covered K extent survives the granularity change (else a
                # halved block_k silently halves the budgeted extent and
                # every evaluation overflows into the full-extent fallback).
                # The table entry syncs to the rescaled value too, so the
                # next retune interval can't re-install the old-unit number.
                gk = -(-new.in_features // new.block_k)
                scaled = round(new.max_active_k * spec.block_k / new.block_k)
                new = dataclasses.replace(
                    new, max_active_k=clamp_budget(int(scaled), gk)
                )
                self.policy.site_tunables[name] = dataclasses.replace(
                    t, max_active_k=new.max_active_k
                )
        if (
            t.max_active_k is not None
            and new.exec_path in ("ragged", "compact")
            and spec.block_k == new.block_k  # rescale wins on a block_k move
            and int(t.max_active_k) != new.max_active_k
        ):
            gk = -(-new.in_features // new.block_k)
            new = dataclasses.replace(
                new, max_active_k=clamp_budget(int(t.max_active_k), gk)
            )
        self._sync_ctrl(name, cache)
        if new == spec:
            return False
        self.sites[name] = new
        return True

    def _sync_ctrl(self, name: str, cache: dict[str, Any] | None) -> None:
        """Re-derive a site's ctrl sim_threshold/min_work lanes from the
        policy table (per-layer rows win over the site row, as in resolve)."""
        if cache is None:
            return
        entry = cache.get(name)
        if entry is None or "ctrl" not in entry:
            return
        n_layers = self.stacking.get(name, 0)
        if n_layers:
            ts = [self.policy.resolve(name, layer=layer)
                  for layer in range(n_layers)]
            thr = jnp.asarray([t.sim_threshold for t in ts], jnp.float32)
            mw = jnp.asarray([t.min_work_flops for t in ts], jnp.float32)
        else:
            t = self.policy.resolve(name)
            thr = jnp.asarray(t.sim_threshold, jnp.float32)
            mw = jnp.asarray(t.min_work_flops, jnp.float32)
        n_shards = self.shards.get(name, 0)
        if n_shards:  # replicate tunable lanes across the shard axis
            if n_layers:
                thr = jnp.broadcast_to(thr[:, None], (n_layers, n_shards))
                mw = jnp.broadcast_to(mw[:, None], (n_layers, n_shards))
            else:
                thr = jnp.broadcast_to(thr, (n_shards,))
                mw = jnp.broadcast_to(mw, (n_shards,))
            self.ici_write_bytes += float(thr.size + mw.size) * 4
        cache[name] = dict(
            entry, ctrl=dict(entry["ctrl"], sim_threshold=thr, min_work=mw)
        )

    def set_budget(self, name: str, budget: int) -> bool:
        """Re-point a compacted site's static k-extent budget — the online
        budget adapter's write path. The budget is a grid extent baked into
        the traced kernel, so it stays site-granular (per-layer occupancy is
        the MEASUREMENT — ctrl["occupancy"] / the per-layer overflow counters
        — feeding this one knob). Keeps the policy table in sync so the next
        exec-path refresh or retune doesn't silently revert the adaptation.
        Returns True when the spec changed (retrace)."""
        spec = self.sites[name]
        if spec.exec_path not in ("ragged", "compact"):
            return False
        gk = -(-spec.in_features // spec.block_k)
        budget = clamp_budget(int(budget), gk)
        if budget == spec.max_active_k:
            return False
        self.sites[name] = dataclasses.replace(spec, max_active_k=budget)
        self.policy.site_tunables[name] = dataclasses.replace(
            self.policy.resolve(name), max_active_k=budget
        )
        return True

    # -------------------------------------------------- host-side policy pass

    def _shard_axes_static(self) -> tuple[tuple[str, int, int], ...]:
        """Hashable shard layout for the jitted snapshot's static arg."""
        from repro.dist.shard import shard_axis_of

        return tuple(sorted(
            (name, shard_axis_of(self.stacking.get(name, 0)), count)
            for name, count in self.shards.items()
        ))

    def ctrl_snapshot(self, cache: dict[str, Any]) -> dict[str, Any]:
        """Pull the policy pass's inputs for ALL sites in one device round
        trip: the traced `_ctrl_snapshot_device` reduces on device, a single
        `jax.device_get` materializes the result as host numpy.

        On a sharded engine this snapshot IS the once-per-window cross-mesh
        sensor reduce; the payload it moves is metered into
        `ici_reduce_bytes` so the cost model can price it as E_ICI."""
        snap_dev = _ctrl_snapshot_device(
            cache, shard_axes=self._shard_axes_static())
        if self.shards:
            self.ici_reduce_bytes += float(sum(
                leaf.size * leaf.dtype.itemsize
                for name in self.shards
                for leaf in jax.tree.leaves(snap_dev.get(name, {}))
            ))
        snap = jax.device_get(snap_dev)
        self.last_snapshot = snap
        return snap

    def refresh_modes(self, cache: dict[str, Any]) -> dict[str, str]:
        """Host-side policy pass: one BATCHED per-layer decide per site.

        Reads each site's per-layer sim_ema means and its ctrl block
        (mode_id / sim_threshold / min_work / cooldown arrays), re-decides
        kernelMode lane-wise (hysteretically: the signal must leave the
        current mode's band by the layer's margin, and a freshly-flipped lane
        is frozen for its `hysteresis_steps` passes), and writes the new
        mode_id/cooldown arrays back into the cache — an array write, NOT a
        retrace, so distinct layers of one scanned stack settle distinct
        modes at zero recompile cost. A pass where any lane's wanted flip was
        cooldown-vetoed bumps the site's `suppressed_flips` counter once.
        Applied per-layer flips land in `self.last_mode_events` for the
        controller's journal.

        The same pass re-decides each site's execution substrate
        (`exec_path`) from its measured tile-skip rate. Exec flips ARE spec
        changes (the grid geometry is traced), so only they are returned:
        {site: "exec:<path>"} — callers rebuild the jitted step exactly when
        this dict is non-empty."""
        self.last_mode_events = []
        snap = self.ctrl_snapshot(cache)
        for name, spec in self.sites.items():
            entry = cache[name]
            ctrl = entry.get("ctrl")
            if ctrl is None:
                continue
            s = snap[name]
            # [L, M] stacked / [M] unstacked / scalar legacy → per-layer [L]
            sim_l = np.asarray(s["sim_l"], np.float64)
            mode_id = np.asarray(s["mode_id"])
            n_lanes = mode_id.shape[0]
            if sim_l.shape[0] != n_lanes:
                sim_l = np.broadcast_to(sim_l, (n_lanes,))
            thr = np.asarray(s["sim_threshold"], np.float64)
            mw = np.asarray(s["min_work"], np.float64)
            cd = np.asarray(s["cooldown"], np.int64)
            stacked = self.stacking.get(name, 0) > 0
            ts = [
                self.policy.resolve(name, layer=layer if stacked else None)
                for layer in range(n_lanes)
            ]
            margin = np.asarray([t.hysteresis_margin for t in ts])
            hyst = np.asarray([t.hysteresis_steps for t in ts])
            quar = s.get("quarantine")
            want = self.policy.decide_modes(
                spec, sim_l, mode_id, thr, mw, hysteresis_margin=margin,
                quarantine=None if quar is None else np.asarray(quar),
            )
            flip = want != mode_id
            vetoed = flip & (cd > 0)
            applied = flip & ~vetoed
            new_mode = np.where(applied, want, mode_id)
            new_cd = np.where(applied, hyst, np.maximum(cd - 1, 0))
            if vetoed.any() and "sensor" in entry:
                sensor = dict(entry["sensor"])
                sensor["suppressed_flips"] = sensor["suppressed_flips"] + 1
                entry = dict(entry, sensor=sensor)
            for lane in np.nonzero(applied)[0]:
                self.last_mode_events.append({
                    "site": name,
                    "layer": int(lane) if stacked else None,
                    "before": mode_name(mode_id[lane]),
                    "after": mode_name(new_mode[lane]),
                    "sim_ema": float(sim_l[lane]),
                })
            if applied.any():
                # any-flip-freezes-the-site: a mode flip also holds the
                # site's exec substrate still for the cooldown (the exec
                # loop reciprocates by freezing mode lanes) — churn in one
                # control dimension must not compound with the other
                self.exec_cooldown[name] = max(
                    self.exec_cooldown.get(name, 0),
                    int(hyst[applied].max()),
                )
            shape = jnp.shape(ctrl["mode_id"])
            if name in self.shards:
                # decided lanes are per-layer [L]; the ctrl block is
                # [L, S] / [S] — replicate the decision across shards
                # (every shard runs the same layer mode) and meter the
                # sharded write fan-out for the E_ICI rollup
                stacked_w = self.stacking.get(name, 0) > 0
                new_mode_w = np.broadcast_to(
                    new_mode[:, None] if stacked_w else new_mode, shape)
                new_cd_w = np.broadcast_to(
                    new_cd[:, None] if stacked_w else new_cd, shape)
                self.ici_write_bytes += float(np.prod(shape)) * (1 + 4)
            else:
                new_mode_w = new_mode.reshape(shape)
                new_cd_w = new_cd.reshape(shape)
            entry = dict(entry, ctrl=dict(
                ctrl,
                mode_id=jnp.asarray(new_mode_w, jnp.int8),
                cooldown=jnp.asarray(new_cd_w, jnp.int32),
            ))
            cache[name] = entry
        return self.refresh_exec_paths(cache, snapshot=snap)

    def refresh_exec_paths(
        self, cache: dict[str, Any], *, snapshot: dict[str, Any] | None = None,
    ) -> dict[str, str]:
        """Promote/demote execution substrates from MEASURED skip rates.

        Cumulative tile counters smooth the signal; exec flips carry their
        own site-level cooldown (each one retraces the step — unlike mode
        flips, which are ctrl-array writes); a site with no measured reuse
        evaluations keeps its current path. Caveat: after a live block_k
        change (apply_tunables) the cumulative rate mixes tile units across
        granularities and converges to the new regime only asymptotically —
        the online controller therefore drives promotion through solver
        pins computed from clean windowed deltas, and this pass is the
        fallback for unpinned sites. Returns {site: "exec:<path>"} for
        sites that moved."""
        from repro.core.reuse_cache import resolve_exec_path

        if snapshot is None:
            snapshot = self.ctrl_snapshot(cache)
        changed: dict[str, str] = {}
        for name, spec in self.sites.items():
            s = snapshot.get(name, {})
            if "skipped" not in s:
                continue
            skipped = float(s["skipped"])
            computed = float(s["computed"])
            total = skipped + computed
            if total <= 0:
                continue
            new_path = self.policy.decide_exec_path(
                spec, skipped / total, impl=self.impl
            )
            if new_path == resolve_exec_path(spec, self.impl):
                self.exec_cooldown[name] = max(
                    0, self.exec_cooldown.get(name, 0) - 1)
                continue
            if self.exec_cooldown.get(name, 0) > 0:
                self.exec_cooldown[name] -= 1
                continue
            gk = -(-spec.in_features // spec.block_k)
            budget = None
            if new_path in ("ragged", "compact"):
                budget = self.policy.resolve_max_active_k(name)
                if budget is None:
                    budget = self.policy.ragged_budget(gk, skipped / total)
            self.sites[name] = dataclasses.replace(
                spec, exec_path=new_path, max_active_k=budget
            )
            changed[name] = f"exec:{new_path}"
            hyst = self.policy.resolve(name).hysteresis_steps
            self.exec_cooldown[name] = hyst
            # the reciprocal freeze: an exec flip (a retrace) also holds the
            # site's mode lanes still for the cooldown
            entry = cache[name]
            if "ctrl" in entry:
                ctrl = entry["ctrl"]
                cache[name] = dict(entry, ctrl=dict(
                    ctrl,
                    cooldown=jnp.maximum(
                        ctrl["cooldown"], jnp.int32(hyst)),
                ))
        return changed

    def sensor_report(self, cache: dict[str, Any]):
        """Measured reuse accounting for the whole model — the ReuseSensor's
        bypassed-computation / skipped-weight-load counts, reduced host-side
        from the counters the kernels updated.

        Returns a repro.sensor.aggregate.SensorReport (per-site, per-layer,
        whole-model, JSONL-emittable)."""
        from repro.sensor.aggregate import build_report

        return build_report(self, cache)
