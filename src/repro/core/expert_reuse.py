"""Per-(slot, expert) delta reuse for routed MoE — the beyond-paper extension.

DESIGN.md §8 / EXPERIMENTS.md §Perf cell 2: the paper-faithful engine excludes
routed experts (a stream's expert assignment changes between steps, breaking
the consecutive-evaluation premise). But measured router stickiness is high
(0.61–0.98), and the cold-start identity — reuse output == quantized dense on
a lane's first touch — makes expert *switches* numerically safe. So each
decode slot keeps one cache lane PER EXPERT:

    prev_q   [E, B, d]      int8 codes of the last input slot b sent to e
    prev_hi  [E, B, 2f]     wi output for that input (pre-activation)
    prev_act [E, B, f]      activation codes feed the wo site the same way
    prev_out [E, B, d]      wo output

Both expert linears are reuse sites. Exactness chain: if slot b revisits
expert e and its input codes match, Δ = 0 ⇒ hi unchanged ⇒ activation
unchanged ⇒ out unchanged — and partial block matches skip exactly those
weight tiles (same ΔW algebra, batched over experts).

HBM accounting (what the §Perf model charges): weight-tile traffic on wi/wo
scales by (1 − stickiness·harvest); the cache adds E× lanes of activations
(MBs) against GBs of expert weights.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.similarity import block_zero_mask
from repro.models.layers import apply_norm
from repro.quant import quantize_int8


class ExpertReuseStats(NamedTuple):
    sticky_fraction: jax.Array   # P[slot kept its top-1 expert this step]
    wi_skip: jax.Array           # fraction of wi weight tiles skipped
    wo_skip: jax.Array


def init_expert_reuse_cache(cfg: ModelConfig, batch: int) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    n_layers = cfg.n_superblocks
    def stack(x):
        return jnp.broadcast_to(x, (n_layers, *x.shape)).copy()
    return {
        "prev_q": stack(jnp.zeros((e, batch, d), jnp.int8)),
        "prev_hi": stack(jnp.zeros((e, batch, 2 * f), jnp.float32)),
        "prev_act_q": stack(jnp.zeros((e, batch, f), jnp.int8)),
        "prev_out": stack(jnp.zeros((e, batch, d), jnp.float32)),
        "scale": jnp.asarray(0.05, jnp.float32),
        "act_scale": jnp.asarray(0.05, jnp.float32),
    }


def layer_slice(cache: dict, i: int) -> dict:
    """One layer's lane view of the stacked cache (scales pass through)."""
    return {
        k: (v if k in ("scale", "act_scale") else v[i])
        for k, v in cache.items()
    }


def moe_reuse_forward(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,            # [B, 1, d] decode tokens
    cache: dict,             # one layer's slice of init_expert_reuse_cache
    *,
    block_k: int = 128,
) -> tuple[jax.Array, dict, ExpertReuseStats]:
    """Decode-step MoE with per-(slot, expert) delta reuse. Top-1 routing on
    the reuse path (top-k generalizes by running k passes); returns
    (out [B,1,d], new_cache, stats)."""
    b, s, d = x.shape
    assert s == 1, "expert reuse is a decode-step feature"
    e, f = cfg.n_experts, cfg.d_ff
    h = apply_norm(p["norm"], x, cfg.norm_eps).reshape(b, d)

    logits = jnp.einsum("bd,de->be", h.astype(jnp.float32), p["router"])
    top_e = jnp.argmax(logits, axis=-1)                      # [B]
    gate = jax.nn.softmax(logits, axis=-1)[jnp.arange(b), top_e]

    scale = cache["scale"]
    act_scale = cache["act_scale"]

    # ---- wi site: Δ against this (slot, expert) lane ----
    cur_q = quantize_int8(h, scale)                          # [B, d]
    lane_prev_q = cache["prev_q"][top_e, jnp.arange(b)]      # [B, d]
    dq = cur_q.astype(jnp.int32) - lane_prev_q.astype(jnp.int32)
    delta = (dq.astype(jnp.float32) * scale)                 # [B, d]
    wi_mask = block_zero_mask(dq, 1, block_k)                # [B, d/bk]

    wi_b = p["wi"][top_e]                                    # [B, d, 2f]
    hi = cache["prev_hi"][top_e, jnp.arange(b)] + jnp.einsum(
        "bd,bdf->bf", delta, wi_b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )                                                        # [B, 2f]

    gate_h, up = jnp.split(hi, 2, axis=-1)
    act = jax.nn.silu(gate_h) * up                           # [B, f]

    # ---- wo site: Δ of the activation codes, same lanes ----
    act_q = quantize_int8(act, act_scale)
    lane_prev_act = cache["prev_act_q"][top_e, jnp.arange(b)]
    dq2 = act_q.astype(jnp.int32) - lane_prev_act.astype(jnp.int32)
    delta2 = dq2.astype(jnp.float32) * act_scale
    wo_mask = block_zero_mask(dq2, 1, block_k)

    wo_b = p["wo"][top_e]                                    # [B, f, d]
    out = cache["prev_out"][top_e, jnp.arange(b)] + jnp.einsum(
        "bf,bfd->bd", delta2, wo_b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )                                                        # [B, d]

    # ---- cache update: only the visited (expert, slot) lanes ----
    idx = (top_e, jnp.arange(b))
    new_cache = dict(
        cache,
        prev_q=cache["prev_q"].at[idx].set(cur_q),
        prev_hi=cache["prev_hi"].at[idx].set(hi),
        prev_act_q=cache["prev_act_q"].at[idx].set(act_q),
        prev_out=cache["prev_out"].at[idx].set(out),
    )

    # stickiness measured against the lane actually used last step: a lane
    # whose codes fully match implies the stream revisited "warm" state
    sticky = jnp.mean((jnp.sum(wi_mask, axis=-1) == 0).astype(jnp.float32))
    stats = ExpertReuseStats(
        sticky_fraction=sticky,
        wi_skip=1.0 - jnp.mean(wi_mask.astype(jnp.float32)),
        wo_skip=1.0 - jnp.mean(wo_mask.astype(jnp.float32)),
    )
    final = (out * gate[:, None]).reshape(b, 1, d).astype(x.dtype)
    return final, new_cache, stats
