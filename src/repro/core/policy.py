"""ReusePolicy — the kernelMode decision logic (paper Sec. IV + Fig. 12).

Fig. 12 shows reuse can *regress* for layers with low input similarity or
small sizes (delta/cache bookkeeping isn't amortized). The paper exposes a
per-call `kernelMode` flag and leaves mode selection to the framework. We make
the selection explicit: a site runs in reuse mode iff

    sim_ema >= threshold   and   M·K·N work >= min_work

Mode decisions are taken *between* jitted steps (host-side, from the sim_ema
carried in the cache pytree), so a mode flip recompiles rather than bloating
the step HLO with both branches — the analogue of the paper re-invoking CRS
with a different parameter block.

The paper's constants are one global operating point, but the measured data
(its own Fig. 12, our sensor traces) shows the profitable threshold and tile
granularity differ per layer. `SiteTunables` is the per-site override record:
the policy resolves a site name to its tunables (falling back to the global
defaults), and `repro.tune` fits tables of them from recorded sensor traces.
Because a mode flip costs a recompile, the tunables also carry hysteresis: a
similarity band (`hysteresis_margin`) the signal must cross before leaving
the current mode, and a cooldown (`hysteresis_steps`, in refresh passes)
during which `ReuseEngine.refresh_modes` suppresses flip-backs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.core.reuse_cache import ReuseSiteSpec

DEFAULT_SIM_THRESHOLD = 0.20
DEFAULT_MIN_WORK_FLOPS = float(2**24)
DEFAULT_HYSTERESIS_MARGIN = 0.05
DEFAULT_HYSTERESIS_STEPS = 1


@dataclasses.dataclass(frozen=True)
class SiteTunables:
    """Per-site policy knobs — the learned replacements for the paper's
    global constants. `block_k=None` keeps the registration-time default."""

    # Below ~20 % similarity the paper's own data shows little or negative
    # gain (Fig. 12 layers A-C); tiles need even more headroom.
    sim_threshold: float = DEFAULT_SIM_THRESHOLD
    # Small sites aren't worth the bookkeeping (paper: "even if the input
    # similarity is high for small layers, we see little gains").
    min_work_flops: float = DEFAULT_MIN_WORK_FLOPS
    # Delta-tile K granularity reaching the kernel dispatch; None = default.
    block_k: int | None = None
    # Mode-flip hysteresis: similarity must leave the current mode's band by
    # this margin before a flip, and after a flip the site is frozen for
    # `hysteresis_steps` refresh passes (each flip costs a recompile).
    hysteresis_margin: float = DEFAULT_HYSTERESIS_MARGIN
    hysteresis_steps: int = DEFAULT_HYSTERESIS_STEPS

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SiteTunables":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class ReusePolicy:
    # Global defaults (the paper's single operating point) ...
    sim_threshold: float = DEFAULT_SIM_THRESHOLD
    min_work_flops: float = DEFAULT_MIN_WORK_FLOPS
    dataflow_output_bias: float = 1.0  # >1 prefers output-stationary
    hysteresis_margin: float = DEFAULT_HYSTERESIS_MARGIN
    hysteresis_steps: int = DEFAULT_HYSTERESIS_STEPS
    # ... plus the per-site table that overrides them (fitted by repro.tune).
    site_tunables: dict[str, SiteTunables] = dataclasses.field(
        default_factory=dict
    )

    def resolve(self, site: str) -> SiteTunables:
        """Tunables governing one site: its table entry, else the defaults."""
        t = self.site_tunables.get(site)
        if t is not None:
            return t
        return SiteTunables(
            sim_threshold=self.sim_threshold,
            min_work_flops=self.min_work_flops,
            hysteresis_margin=self.hysteresis_margin,
            hysteresis_steps=self.hysteresis_steps,
        )

    def decide_mode(
        self,
        spec: ReuseSiteSpec,
        sim_ema: float,
        *,
        current_mode: str | None = None,
    ) -> str:
        """kernelMode for one site. With `current_mode` given, the similarity
        comparison is hysteretic: the signal must cross the threshold by
        `hysteresis_margin` before the decision leaves the current mode."""
        if spec.mode in ("reuse", "basic"):
            return spec.mode  # explicit kernelMode wins
        t = self.resolve(spec.name)
        work = 2.0 * spec.in_features * spec.out_features
        if work < t.min_work_flops:
            return "basic"
        threshold = t.sim_threshold
        if current_mode == "reuse":
            threshold -= t.hysteresis_margin
        elif current_mode == "basic":
            threshold += t.hysteresis_margin
        return "reuse" if sim_ema >= threshold else "basic"

    def resolve_block_k(self, site: str, default: int) -> int:
        bk = self.resolve(site).block_k
        return default if bk is None else int(bk)

    def decide_dataflow(self, in_features: int, out_features: int) -> str:
        """Paper Sec. VI-A: 3DUnet's large-input/small-output GEMMs regress
        under input-stationary; prefer output-stationary unless the aspect
        ratio strongly favours holding inputs."""
        if in_features > self.dataflow_output_bias * 4 * out_features:
            return "input"
        return "output"
