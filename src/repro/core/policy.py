"""ReusePolicy — the kernelMode decision logic (paper Sec. IV + Fig. 12).

Fig. 12 shows reuse can *regress* for layers with low input similarity or
small sizes (delta/cache bookkeeping isn't amortized). The paper exposes a
per-call `kernelMode` flag and leaves mode selection to the framework. We make
the selection explicit: a site runs in reuse mode iff

    sim_ema >= threshold   and   M·K·N work >= min_work

Mode decisions are taken *between* jitted steps (host-side, from the sim_ema
carried in the cache pytree), so a mode flip recompiles rather than bloating
the step HLO with both branches — the analogue of the paper re-invoking CRS
with a different parameter block.

The paper's constants are one global operating point, but the measured data
(its own Fig. 12, our sensor traces) shows the profitable threshold and tile
granularity differ per layer. `SiteTunables` is the per-site override record:
the policy resolves a site name to its tunables (falling back to the global
defaults), and `repro.tune` fits tables of them from recorded sensor traces.
The tunables also carry hysteresis: a similarity band (`hysteresis_margin`)
the signal must cross before leaving the current mode, and a cooldown
(`hysteresis_steps`, in refresh passes) during which
`ReuseEngine.refresh_modes` suppresses flip-backs.

kernelMode itself is ARRAY-RESIDENT: a site's per-layer mode ids live in the
ctrl block of its cache entry (int8 [L], `MODE_REUSE`/`MODE_BASIC`), sliced
by the same lax.scan that slices the rest of the cache and branched on with
lax.cond inside the layer body — so a 40-layer stack can run dissimilar early
layers basic and similar late layers in reuse mode simultaneously, and a mode
flip is an array write between steps, not a retrace (only spec-level changes
— block_k / exec_path / max_active_k — rebuild the jitted step). The
host-side decision pass is :meth:`ReusePolicy.decide_modes`, the vectorized
per-layer form of `decide_mode`; per-layer tunables rows use `"site@layer"`
table keys (see :func:`layer_key`).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

import numpy as np

from repro.core.reuse_cache import ReuseSiteSpec, default_exec_path

DEFAULT_SIM_THRESHOLD = 0.20
DEFAULT_MIN_WORK_FLOPS = float(2**24)
DEFAULT_HYSTERESIS_MARGIN = 0.05
DEFAULT_HYSTERESIS_STEPS = 1

# Break-even tile-skip rate above which a compacted execution tier (ragged
# grid on Pallas, gathered GEMM on jnp) beats the masked full-grid walk.
# Model: the compacted grid runs ceil(occupancy · headroom · gk) of gk steps
# but adds the per-row index/count bookkeeping and risks the overflow
# fallback; below ~25 % skip the shrink cannot amortize either. This is the
# MODELED default only: `ReusePolicy.ragged_break_even_skip` carries the live
# gate, and `repro.tune.harvest.derive_break_even_skip` re-derives it from
# the compiled skip-rate sweep in the BENCH_kernels.json trajectory (a value
# > 1.0 means the compacted tier never won — the gate then demotes every
# site to the masked/dense walk).
RAGGED_BREAK_EVEN_SKIP = 0.25
# Budget headroom over the measured occupancy, so mild skip-rate jitter does
# not trip the (full-extent) overflow fallback every few steps.
RAGGED_BUDGET_HEADROOM = 1.25

EXEC_PATHS = ("kernel", "ragged", "compact", "dense")

# kernelMode encoding inside the array-resident control block (the ctrl dict
# that rides in every cache entry): int8 so a whole stacked site's per-layer
# modes are one tiny [L] lane, branched on with lax.cond inside the scanned
# layer body — a flip is an array write, never a retrace.
MODE_BASIC = 0
MODE_REUSE = 1


def mode_name(mode_id: int) -> str:
    return "reuse" if int(mode_id) > 0 else "basic"


def layer_key(site: str, layer: int) -> str:
    """Table key of one layer's tunables row ("site@layer"). Site names never
    contain '@', so layer rows can share the flat {name: SiteTunables} table
    (and its JSON serialization) with the site-level rows."""
    return f"{site}@{layer}"


def split_layer_key(key: str) -> tuple[str, int | None]:
    """Inverse of :func:`layer_key`: ("site", layer) or ("site", None)."""
    site, sep, layer = key.rpartition("@")
    if sep and layer.isdigit():
        return site, int(layer)
    return key, None


@dataclasses.dataclass(frozen=True)
class SiteTunables:
    """Per-site policy knobs — the learned replacements for the paper's
    global constants. `block_k=None` keeps the registration-time default."""

    # Below ~20 % similarity the paper's own data shows little or negative
    # gain (Fig. 12 layers A-C); tiles need even more headroom.
    sim_threshold: float = DEFAULT_SIM_THRESHOLD
    # Small sites aren't worth the bookkeeping (paper: "even if the input
    # similarity is high for small layers, we see little gains").
    min_work_flops: float = DEFAULT_MIN_WORK_FLOPS
    # Delta-tile K granularity reaching the kernel dispatch; None = default.
    block_k: int | None = None
    # Mode-flip hysteresis: similarity must leave the current mode's band by
    # this margin before a flip, and after a flip the site is frozen for
    # `hysteresis_steps` refresh passes (each flip costs a recompile).
    hysteresis_margin: float = DEFAULT_HYSTERESIS_MARGIN
    hysteresis_steps: int = DEFAULT_HYSTERESIS_STEPS
    # Pinned execution substrate for the reuse-mode ΔW GEMM; None lets the
    # policy decide from measured skip rate (see decide_exec_path).
    exec_path: str | None = None
    # Static k-extent budget for the compacted paths, in K-blocks of the
    # site's (possibly tuned) block_k; None = full extent.
    max_active_k: int | None = None

    def __post_init__(self) -> None:
        # Fail at table-load/fit time, not inside the traced serve step.
        if self.exec_path is not None and self.exec_path not in EXEC_PATHS:
            raise ValueError(
                f"exec_path {self.exec_path!r} not in {EXEC_PATHS}"
            )

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SiteTunables":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class ReusePolicy:
    # Global defaults (the paper's single operating point) ...
    sim_threshold: float = DEFAULT_SIM_THRESHOLD
    min_work_flops: float = DEFAULT_MIN_WORK_FLOPS
    dataflow_output_bias: float = 1.0  # >1 prefers output-stationary
    hysteresis_margin: float = DEFAULT_HYSTERESIS_MARGIN
    hysteresis_steps: int = DEFAULT_HYSTERESIS_STEPS
    # The skip-rate gate for promoting a site onto a compacted tier. Defaults
    # to the modeled constant; a measured compiled sweep re-derives it
    # (harvest.derive_break_even_skip) — > 1.0 disables promotion entirely.
    ragged_break_even_skip: float = RAGGED_BREAK_EVEN_SKIP
    # ... plus the per-site table that overrides them (fitted by repro.tune).
    site_tunables: dict[str, SiteTunables] = dataclasses.field(
        default_factory=dict
    )

    def resolve(self, site: str, layer: int | None = None) -> SiteTunables:
        """Tunables governing one site: its table entry, else the defaults.

        With `layer` given, a per-layer row (`"site@layer"` key — the fitter
        emits them from per-layer trace rows, the online retuner from
        per-layer windows) wins over the site-level entry. Layer rows only
        carry the array-resident knobs (sim_threshold / min_work /
        hysteresis); spec-level fields (block_k, exec_path, max_active_k) stay
        site-granular because they are baked into the traced dispatch."""
        if layer is not None:
            t = self.site_tunables.get(layer_key(site, layer))
            if t is not None:
                return t
        t = self.site_tunables.get(site)
        if t is not None:
            return t
        return SiteTunables(
            sim_threshold=self.sim_threshold,
            min_work_flops=self.min_work_flops,
            hysteresis_margin=self.hysteresis_margin,
            hysteresis_steps=self.hysteresis_steps,
        )

    def decide_mode(
        self,
        spec: ReuseSiteSpec,
        sim_ema: float,
        *,
        current_mode: str | None = None,
    ) -> str:
        """kernelMode for one site. With `current_mode` given, the similarity
        comparison is hysteretic: the signal must cross the threshold by
        `hysteresis_margin` before the decision leaves the current mode."""
        if spec.mode in ("reuse", "basic"):
            return spec.mode  # explicit kernelMode wins
        t = self.resolve(spec.name)
        work = 2.0 * spec.in_features * spec.out_features
        if work < t.min_work_flops:
            return "basic"
        threshold = t.sim_threshold
        if current_mode == "reuse":
            threshold -= t.hysteresis_margin
        elif current_mode == "basic":
            threshold += t.hysteresis_margin
        return "reuse" if sim_ema >= threshold else "basic"

    def decide_modes(
        self,
        spec: ReuseSiteSpec,
        sim_ema: np.ndarray,        # [L] per-layer mean similarity
        mode_id: np.ndarray,        # [L] current mode ids (MODE_REUSE/BASIC)
        sim_threshold: np.ndarray,  # [L] live thresholds (ctrl block)
        min_work: np.ndarray,       # [L] live min-work floors (ctrl block)
        *,
        hysteresis_margin: np.ndarray,  # [L]
        quarantine: np.ndarray | None = None,  # [L] guard lockout intervals
    ) -> np.ndarray:
        """Vectorized decide_mode over the layer axis of one site.

        Same semantics as the scalar path, applied lane-wise: a layer runs in
        reuse mode iff its work clears its min_work floor AND its sim_ema
        clears its threshold — hysteretically, the signal must leave the
        current mode's band by the margin. Returns the WANTED mode ids [L];
        the engine's refresh owns cooldown vetoes and the actual write.

        A lane with `quarantine > 0` (the guard plane's circuit breaker
        tripped a sentinel on it) is pinned to MODE_BASIC unconditionally —
        fault containment beats even an explicitly spec-pinned "reuse"."""
        if spec.mode in ("reuse", "basic"):  # explicit kernelMode wins
            pinned = MODE_REUSE if spec.mode == "reuse" else MODE_BASIC
            want = np.full_like(np.asarray(mode_id), pinned)
        else:
            work = 2.0 * spec.in_features * spec.out_features
            thr = np.where(
                mode_id > 0,
                sim_threshold - hysteresis_margin,
                sim_threshold + hysteresis_margin,
            )
            want = np.where(sim_ema >= thr, MODE_REUSE, MODE_BASIC)
            want = np.where(work < min_work, MODE_BASIC, want)
        if quarantine is not None:
            want = np.where(np.asarray(quarantine) > 0, MODE_BASIC, want)
        return np.asarray(want).astype(np.asarray(mode_id).dtype)

    def resolve_block_k(self, site: str, default: int) -> int:
        bk = self.resolve(site).block_k
        return default if bk is None else int(bk)

    def resolve_exec_path(self, site: str, default: str = "auto") -> str:
        p = self.resolve(site).exec_path
        return default if p is None else p

    def resolve_max_active_k(self, site: str) -> int | None:
        mak = self.resolve(site).max_active_k
        return None if mak is None else int(mak)

    def decide_exec_path(
        self, spec: ReuseSiteSpec, skip_rate: float, *, impl: str = "jnp"
    ) -> str:
        """Execution substrate for one site from its MEASURED tile-skip rate.

        A tuned `exec_path` pins the decision. Otherwise: above the break-even
        skip rate the compacted tier wins — "ragged" on the Pallas impls
        (compacted grid: skipped tiles cost zero grid steps), "compact" on
        jnp (gathered GEMM: the CPU-measurable equivalent). Below it, the
        masked full-grid kernel ("kernel" on Pallas, "dense" on jnp) costs
        less than the compaction bookkeeping. Sites whose K extent is a
        single tile have nothing to compact.
        """
        t = self.resolve(spec.name)
        if t.exec_path is not None:
            return t.exec_path
        gk = -(-spec.in_features // spec.block_k)
        if gk >= 2 and skip_rate >= self.ragged_break_even_skip:
            return "ragged" if impl != "jnp" else "compact"
        return default_exec_path(impl)

    @staticmethod
    def ragged_budget(gk: int, skip_rate: float) -> int:
        """Static k-extent budget for a compacted path: measured occupancy
        plus headroom, clamped to [1, gk]."""
        occ = max(0.0, min(1.0, 1.0 - skip_rate))
        want = math.ceil(gk * occ * RAGGED_BUDGET_HEADROOM)
        return max(1, min(gk, want))

    def decide_dataflow(self, in_features: int, out_features: int) -> str:
        """Paper Sec. VI-A: 3DUnet's large-input/small-output GEMMs regress
        under input-stationary; prefer output-stationary unless the aspect
        ratio strongly favours holding inputs."""
        if in_features > self.dataflow_output_bias * 4 * out_features:
            return "input"
        return "output"
