"""ReusePolicy — the kernelMode decision logic (paper Sec. IV + Fig. 12).

Fig. 12 shows reuse can *regress* for layers with low input similarity or
small sizes (delta/cache bookkeeping isn't amortized). The paper exposes a
per-call `kernelMode` flag and leaves mode selection to the framework. We make
the selection explicit: a site runs in reuse mode iff

    sim_ema >= threshold   and   M·K·N work >= min_work

Mode decisions are taken *between* jitted steps (host-side, from the sim_ema
carried in the cache pytree), so a mode flip recompiles rather than bloating
the step HLO with both branches — the analogue of the paper re-invoking CRS
with a different parameter block.
"""

from __future__ import annotations

import dataclasses

from repro.core.reuse_cache import ReuseSiteSpec


@dataclasses.dataclass(frozen=True)
class ReusePolicy:
    # Below ~20 % similarity the paper's own data shows little or negative
    # gain (Fig. 12 layers A-C); tiles need even more headroom.
    sim_threshold: float = 0.20
    # Small sites aren't worth the bookkeeping (paper: "even if the input
    # similarity is high for small layers, we see little gains").
    min_work_flops: float = 2**24
    dataflow_output_bias: float = 1.0  # >1 prefers output-stationary

    def decide_mode(self, spec: ReuseSiteSpec, sim_ema: float) -> str:
        if spec.mode in ("reuse", "basic"):
            return spec.mode  # explicit kernelMode wins
        work = 2.0 * spec.in_features * spec.out_features
        if work < self.min_work_flops:
            return "basic"
        return "reuse" if sim_ema >= self.sim_threshold else "basic"

    def decide_dataflow(self, in_features: int, out_features: int) -> str:
        """Paper Sec. VI-A: 3DUnet's large-input/small-output GEMMs regress
        under input-stationary; prefer output-stationary unless the aspect
        ratio strongly favours holding inputs."""
        if in_features > self.dataflow_output_bias * 4 * out_features:
            return "input"
        return "output"
