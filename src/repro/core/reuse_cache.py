"""ReuseCache — the TPU analogue of ReuseSensor's scratchpad + parameter table.

The paper's hardware caches, per layer: the previous input vector, the previous
outputs, and kernel parameters (addresses, lengths, kernelMode flag, dataflow).
Here each *reuse site* (one linear op in the network) owns a cache entry:

    prev_q   : int8  [M, K]  — previous input, quantized codes
    prev_out : f32   [M, N]  — previous output (pre-activation)
    scale    : f32   scalar  — activation quant scale for this site
    sim_ema  : f32   [M]     — per-slot running code-similarity estimate;
                               the policy reads the mean, the scheduler resets
                               one lane on slot recycle (no cross-stream bleed)
    steps    : i32   scalar  — number of evaluations seen (0 ⇒ cold, run dense)
    sensor   : dict          — measured reuse-accounting counters (see
                               repro.sensor.counters); ride here so they stay
                               jit/donate/shard-friendly with the rest
    ctrl     : dict          — the ARRAY-RESIDENT control block (see
                               init_site_ctrl): per-layer kernelMode id, live
                               sim_threshold / min_work operating point,
                               flip cooldown and budget-occupancy EMA

Caches are a plain pytree threaded through `serve_step` exactly like a KV
cache, so they shard, donate, and checkpoint with the rest of the state. M is
the (fixed) serving batch; per-slot streams are compared against their own
previous evaluation, matching the paper's "consecutive evaluations of a layer".

Sites used inside scan-over-layers get a leading layer dimension on EVERY
leaf (ReuseEngine.init_cache broadcasts), so the scan that slices prev_q/
prev_out for layer l slices that layer's ctrl lane too: the traced layer body
reads its own mode id (a scalar inside the scan) and branches with lax.cond —
per-layer kernelMode with one trace. Unstacked sites are the L=1 degenerate
case: same leaves, no leading axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ReuseSiteSpec:
    """Static description of one reuse site (the CRS parameter-table analogue)."""

    name: str
    in_features: int
    out_features: int
    block_m: int = 8
    block_k: int = 256
    block_n: int = 128  # weight-tile N width (kernel + DMA accounting)
    # kernelMode in the paper: "reuse" | "basic"; "auto" lets the policy decide
    # per call from sim_ema.
    mode: str = "auto"
    # "output" | "input" stationary — kernel grid iteration order.
    dataflow: str = "output"
    # Execution substrate for the reuse-mode ΔW GEMM (see kernels/ops.py):
    # "kernel" (masked full grid) | "ragged" (compacted grid) | "compact"
    # (jnp gather) | "dense" (jnp masked GEMM). "auto" resolves per impl:
    # Pallas impls get "kernel", jnp gets "dense" — the pre-exec_path
    # behaviour. The policy promotes it from measured skip rate.
    exec_path: str = "auto"
    # Static k-extent budget for the ragged/compact paths (in K-blocks);
    # None = full extent. Overflowing steps fall back at runtime.
    max_active_k: int | None = None
    fixed_scale: float = 0.05  # activation scale; sites may recalibrate


def default_exec_path(impl: str) -> str:
    """The substrate an "auto" site runs on: the masked Pallas kernel on the
    Pallas impls, the jnp masked GEMM on jnp — the pre-exec_path behaviour.
    The single source of the impl→path mapping (policy fallthrough, engine
    no-op detection and reuse_linear dispatch all call through here)."""
    return "kernel" if impl != "jnp" else "dense"


def resolve_exec_path(spec: ReuseSiteSpec, impl: str) -> str:
    """The execution substrate a site call will actually run."""
    if spec.exec_path == "auto":
        return default_exec_path(impl)
    return spec.exec_path


def init_site_ctrl(spec: ReuseSiteSpec, tunables=None) -> dict[str, jax.Array]:
    """Fresh control block for one site (one layer's worth; the engine's
    init_cache broadcasts it to [L] for stacked sites and overwrites lanes
    from per-layer tunables rows).

        mode_id       : int8   — kernelMode (MODE_REUSE/MODE_BASIC); the
                                 traced dispatch lax.cond's on it per layer
        sim_threshold : f32    — live admission threshold the refresh reads
        min_work      : f32    — live min-work floor the refresh reads
        cooldown      : int32  — flip-cooldown passes left for this layer
        occupancy     : f32    — EMA of the live (computed) tile fraction per
                                 evaluation — the per-layer budget-occupancy
                                 signal the budget adapter consults
        quarantine    : int32  — guard-plane lockout intervals left for this
                                 layer (repro.guard): while > 0 the mode
                                 decide pins the lane to basic/dense, beating
                                 even a spec-pinned "reuse". Written by the
                                 quarantine breaker on a tripped sentinel,
                                 drained by the breaker's own pass.

    Start optimistic (the paper's default is reuse-on) unless the spec pins
    kernelMode explicitly; the policy may demote per layer.
    """
    # lazy import: policy.py imports this module at load time
    from repro.core.policy import (
        DEFAULT_MIN_WORK_FLOPS,
        DEFAULT_SIM_THRESHOLD,
    )

    mode0 = 0 if spec.mode == "basic" else 1
    thr = (tunables.sim_threshold if tunables is not None
           else DEFAULT_SIM_THRESHOLD)
    mw = (tunables.min_work_flops if tunables is not None
          else DEFAULT_MIN_WORK_FLOPS)
    return {
        "mode_id": jnp.asarray(mode0, dtype=jnp.int8),
        "sim_threshold": jnp.asarray(thr, dtype=jnp.float32),
        "min_work": jnp.asarray(mw, dtype=jnp.float32),
        "cooldown": jnp.zeros((), dtype=jnp.int32),
        "occupancy": jnp.ones((), dtype=jnp.float32),
        "quarantine": jnp.zeros((), dtype=jnp.int32),
    }


def init_site_cache(
    spec: ReuseSiteSpec, batch: int, tunables=None
) -> dict[str, jax.Array]:
    from repro.sensor.counters import init_site_counters

    return {
        "prev_q": jnp.zeros((batch, spec.in_features), dtype=jnp.int8),
        "prev_out": jnp.zeros((batch, spec.out_features), dtype=jnp.float32),
        "scale": jnp.asarray(spec.fixed_scale, dtype=jnp.float32),
        "sim_ema": jnp.zeros((batch,), dtype=jnp.float32),
        "steps": jnp.zeros((), dtype=jnp.int32),
        "sensor": init_site_counters(batch),
        "ctrl": init_site_ctrl(spec, tunables),
    }


def init_reuse_cache(
    specs: dict[str, ReuseSiteSpec], batch: int
) -> dict[str, dict[str, jax.Array]]:
    """Cache pytree for a whole model: {site_name: entry}."""
    return {name: init_site_cache(spec, batch) for name, spec in specs.items()}


def cache_bytes(cache: Any) -> int:
    """Total HBM footprint of a reuse cache (reported in benchmarks)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
