"""reuse_linear — one reuse site: O_c = O_p + Δ·W (paper Eqns. 2-4).

Cold-start needs no branch: caches initialize to prev_q = 0, prev_out = 0, so
the first evaluation degenerates to O = dequant(quantize(x))·W — the ordinary
quantized GEMM. Every subsequent evaluation telescopes:

    O_t = Σ_{i<=t} Δ_i · W = dequant(q_t) · W        (exactly, in int32;
                                                      to f32 rounding in float)

so the reuse output always equals the quantized dense output — the central
correctness invariant, property-tested in tests/test_reuse_properties.py.

Reuse is an *inference* feature (the paper's setting): models enable it on
decode-step linear sites, where M = serving batch and the GEMM is deeply
memory-bound — precisely where skipping weight-tile DMAs pays.

kernelMode dispatch is ARRAY-RESIDENT: with `mode=None` (the engine's default)
the call branches with `lax.cond` on the cache entry's per-layer control block
(`cache["ctrl"]["mode_id"]`), so a scanned stack slices a per-layer mode out
of the cache exactly like it slices prev_q — one trace covers both modes for
every layer, and a host-side mode flip is an array write, never a retrace. A
string `mode` ("reuse" | "basic") keeps the static single-branch dispatch for
explicitly pinned sites, tests and benchmarks.

`impl` selects the execution substrate (resolved by kernels/backend.py):
    "jnp"              — pure-jnp semantics (fast on CPU; what the dry-run lowers)
    "pallas_interpret" — the real kernels, interpreted on CPU (EXPLICIT test mode)
    "pallas"           — best compiled substrate: compiled Pallas on TPU, the
                         compiled-XLA tier (kernels/xla_tier.py) on hosts with
                         no Pallas lowering — never silent interpret fallback

`spec.exec_path` selects the reuse-mode GEMM within a substrate (see
kernels/ops.py): "kernel" masked full grid, "ragged" compacted grid,
"compact" jnp gather, "dense" jnp masked GEMM. "auto" preserves the historic
mapping (Pallas impls → "kernel", jnp → "dense"); the policy promotes sites
off it from measured skip rate. On the Pallas impls the quantize → delta →
tile-mask chain runs as ONE fused pass (kernels/delta_quant.py) instead of
the three-op jnp chain, so the delta tensor crosses HBM once.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.delta import DeltaEncoding, delta_encode
from repro.core.reuse_cache import ReuseSiteSpec, resolve_exec_path
from repro.core.similarity import ema_update, row_code_similarity
from repro.kernels import ops
from repro.quant import dequantize_int8, quantize_int8
from repro.sensor.counters import (
    ShardCtx,
    owned_panel_count,
    update_on_basic,
    update_on_reuse,
)


class ReuseStats(NamedTuple):
    similarity: jax.Array     # code-level similarity this call
    skip_fraction: jax.Array  # fraction of weight tiles skipped this call


def _interpret_arg(impl: str) -> bool | None:
    """The ONE interpret value threaded into every kernel wrapper call.

    True only for the explicit interpret test mode; None otherwise, which
    `kernels.backend.resolve` turns into the best compiled substrate for this
    process (compiled Pallas on TPU, compiled-XLA elsewhere).
    """
    return True if impl == "pallas_interpret" else None


def _encode(
    xm: jax.Array, cache: dict[str, jax.Array], spec: ReuseSiteSpec,
    w_dtype, impl: str,
) -> DeltaEncoding:
    """Quantize + delta + tile mask: fused single pass on the Pallas impls,
    the jnp three-op chain otherwise."""
    if impl == "jnp":
        return delta_encode(
            xm, cache["prev_q"], cache["scale"],
            block_m=spec.block_m, block_k=spec.block_k,
            compute_dtype=w_dtype,
        )
    cur_q, delta, mask = ops.delta_quant_fused(
        xm, cache["prev_q"], cache["scale"],
        block_m=spec.block_m, block_k=spec.block_k,
        delta_dtype=w_dtype, interpret=_interpret_arg(impl),
    )
    skip = 1.0 - jnp.mean(mask.astype(jnp.float32))
    return DeltaEncoding(delta=delta, cur_q=cur_q, block_mask=mask,
                         skip_fraction=skip)


def _basic_eval(
    xm: jax.Array, w: jax.Array, cache: dict[str, jax.Array],
    spec: ReuseSiteSpec, ema_decay: float,
    shard: ShardCtx | None = None,
):
    """ReuseSensor+ReuseOFF: the generated basic kernel (Fig. 7-A) — plain
    quantized GEMM, no delta/cache bookkeeping beyond refreshing state."""
    m, k = xm.shape
    n = w.shape[-1]
    cur_q = quantize_int8(xm, cache["scale"])
    out = jnp.dot(
        dequantize_int8(cur_q, cache["scale"], dtype=xm.dtype),
        w,
        preferred_element_type=jnp.float32,
    )
    row_sim = row_code_similarity(cur_q, cache["prev_q"])
    sim = jnp.mean(row_sim)
    new_cache = dict(
        cache,
        prev_q=cur_q,
        prev_out=out,
        sim_ema=ema_update(cache["sim_ema"], row_sim, ema_decay),
        steps=cache["steps"] + 1,
    )
    if "sensor" in cache:
        new_cache["sensor"] = update_on_basic(
            cache["sensor"], row_sim=row_sim, m=m, k=k, n=n,
            gn=-(-n // spec.block_n),
            block_m=spec.block_m, block_k=spec.block_k,
            w_itemsize=w.dtype.itemsize,
            shard=shard,
        )
    stats = ReuseStats(similarity=sim,
                       skip_fraction=jnp.zeros((), jnp.float32))
    return out, new_cache, stats


def _reuse_eval(
    xm: jax.Array, w: jax.Array, cache: dict[str, jax.Array],
    spec: ReuseSiteSpec, impl: str, ema_decay: float,
    shard: ShardCtx | None = None,
):
    """ReuseSensor+ReuseON: delta-encode against the previous evaluation and
    run the ΔW GEMM on the spec's execution substrate.

    With `shard` set the GEMM itself is untouched (w/prev_out are already the
    shard-local [K, N/S] slices) — only the dma/grid accounting changes:
    every per-panel formula is linear in the n-panel count, so it is
    evaluated at gn=1 and scaled by the shard's owned GLOBAL panel count
    (counters.py ownership partition; the sum over shards is bitwise the
    unsharded value)."""
    n = w.shape[-1]
    enc = _encode(xm, cache, spec, w.dtype, impl)
    path = resolve_exec_path(spec, impl)
    gm, gk = enc.block_mask.shape
    gn = -(-n // spec.block_n)
    gn_own = None if shard is None else owned_panel_count(shard)
    interpret = _interpret_arg(impl)
    sel = None
    dma_issued = None
    grid_steps = None
    overflow = None
    if path == "dense":
        out = ops.reuse_matmul_ref(
            enc.delta, w, cache["prev_out"], enc.block_mask,
            spec.block_m, spec.block_k,
        )
    elif path == "compact":
        k_mask = jnp.max(enc.block_mask, axis=0)
        out = ops.reuse_matmul_compact(
            enc.delta, w, cache["prev_out"], k_mask,
            block_k=spec.block_k, max_blocks=spec.max_active_k,
        )
        # The gather streams each live K-block's weight panel once,
        # shared across all rows.
        if shard is None:
            dma_issued = jnp.sum(k_mask).astype(jnp.int32) * gn
            grid_steps = ops.ragged_grid_steps(
                jnp.broadcast_to(jnp.sum(k_mask), (gm,)),
                gm=gm, gn=gn, gk=gk, max_active_k=spec.max_active_k,
            )
        else:
            dma_issued = jnp.sum(k_mask).astype(jnp.int32) * gn_own
            grid_steps = ops.ragged_grid_steps(
                jnp.broadcast_to(jnp.sum(k_mask), (gm,)),
                gm=gm, gn=1, gk=gk, max_active_k=spec.max_active_k,
            ) * gn_own.astype(jnp.float32)
        overflow = ops.budget_overflow(
            jnp.sum(k_mask), gk=gk, max_active_k=spec.max_active_k
        )
    elif path == "ragged":
        idx, counts = ops.compact_rows(enc.block_mask)
        out = ops.reuse_matmul_ragged(
            enc.delta, w, cache["prev_out"], enc.block_mask,
            block_m=spec.block_m, block_n=spec.block_n,
            block_k=spec.block_k, max_active_k=spec.max_active_k,
            interpret=interpret, compacted=(idx, counts),
        )
        if shard is None:
            dma_issued = ops.ragged_dma_tiles(counts, gn=gn)
            grid_steps = ops.ragged_grid_steps(
                counts, gm=gm, gn=gn, gk=gk, max_active_k=spec.max_active_k,
            )
        else:
            dma_issued = ops.ragged_dma_tiles(counts, gn=1) * gn_own
            grid_steps = ops.ragged_grid_steps(
                counts, gm=gm, gn=1, gk=gk, max_active_k=spec.max_active_k,
            ) * gn_own.astype(jnp.float32)
        overflow = ops.budget_overflow(
            counts, gk=gk, max_active_k=spec.max_active_k
        )
    elif path == "kernel":
        sel = ops.skip_sel(enc.block_mask)
        out = ops.reuse_matmul(
            enc.delta, w, cache["prev_out"], enc.block_mask,
            block_m=spec.block_m, block_n=spec.block_n,
            block_k=spec.block_k,
            dataflow=spec.dataflow,
            interpret=interpret, sel=sel,
        )
    else:
        raise ValueError(
            f"unknown exec_path {path!r} for site {spec.name!r}"
        )
    row_sim = row_code_similarity(enc.cur_q, cache["prev_q"])
    sim = jnp.mean(row_sim)
    new_cache = dict(
        cache,
        prev_q=enc.cur_q,
        prev_out=out,
        sim_ema=ema_update(cache["sim_ema"], row_sim, ema_decay),
        steps=cache["steps"] + 1,
    )
    if "ctrl" in cache:
        # Per-layer budget occupancy: EMA of the live-tile fraction this
        # evaluation — the signal the budget adapter reads per layer.
        live = jnp.mean(enc.block_mask.astype(jnp.float32))
        new_cache["ctrl"] = dict(
            cache["ctrl"],
            occupancy=ema_update(cache["ctrl"]["occupancy"], live, ema_decay),
        )
    if "sensor" in cache:
        if dma_issued is None:  # kernel/dense: masked full-grid semantics
            if shard is None:
                dma_issued = ops.weight_dma_tiles(
                    enc.block_mask, gn=gn, dataflow=spec.dataflow, sel=sel,
                )
            else:
                dma_issued = ops.weight_dma_tiles(
                    enc.block_mask, gn=1, dataflow=spec.dataflow, sel=sel,
                ) * gn_own
        if grid_steps is None and shard is not None:
            # masked full-grid walk over the shard's owned global panels
            grid_steps = (jnp.int32(gm * gk) * gn_own).astype(jnp.float32)
        new_cache["sensor"] = update_on_reuse(
            cache["sensor"], block_mask=enc.block_mask, row_sim=row_sim,
            block_m=spec.block_m, block_k=spec.block_k, n=n, gn=gn,
            w_itemsize=w.dtype.itemsize,
            dma_issued=dma_issued,
            grid_steps=grid_steps,
            overflow=overflow,
            shard=shard,
        )
    stats = ReuseStats(
        similarity=sim,
        skip_fraction=enc.skip_fraction.astype(jnp.float32),
    )
    return out, new_cache, stats


def reuse_linear(
    x: jax.Array,                       # [..., K]
    w: jax.Array,                       # [K, N]
    b: jax.Array | None,
    cache: dict[str, jax.Array],
    spec: ReuseSiteSpec,
    *,
    mode: str | None = "reuse",         # "reuse" | "basic" | None (= ctrl)
    impl: str = "jnp",
    ema_decay: float = 0.9,
    shard: ShardCtx | None = None,      # model-axis shard accounting context
) -> tuple[jax.Array, dict[str, jax.Array], ReuseStats]:
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    xm = x.reshape(-1, k)
    m = xm.shape[0]
    assert cache["prev_q"].shape == (m, k), (cache["prev_q"].shape, (m, k))

    if mode == "basic":
        out, new_cache, stats = _basic_eval(xm, w, cache, spec, ema_decay,
                                            shard)
    elif mode == "reuse":
        out, new_cache, stats = _reuse_eval(xm, w, cache, spec, impl,
                                            ema_decay, shard)
    elif mode is None:
        # Array-resident kernelMode: branch on this layer's ctrl lane. Both
        # branches trace once (identical cache/stats structure); at runtime
        # the HLO conditional executes exactly one — so a host-side per-layer
        # flip between steps changes which branch runs without retracing.
        ctrl = cache.get("ctrl")
        if ctrl is None:
            raise ValueError(
                f"site {spec.name!r}: mode=None needs a ctrl block in the "
                "cache entry (engine.init_cache creates it)"
            )
        out, new_cache, stats = jax.lax.cond(
            ctrl["mode_id"] > 0,
            lambda: _reuse_eval(xm, w, cache, spec, impl, ema_decay, shard),
            lambda: _basic_eval(xm, w, cache, spec, ema_decay, shard),
        )
    else:
        raise ValueError(f"unknown mode {mode!r}")

    if b is not None:
        out = out + b.astype(out.dtype)
    return out.astype(x.dtype).reshape(*lead, n), new_cache, stats
