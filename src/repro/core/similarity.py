"""Input-similarity measurement (paper Sec. II-B / III-A, Figs. 3 & 4).

Similarity between two consecutive evaluations of a layer is the fraction of
*identical* values at matching positions, measured in the quantized (int8 code)
domain. Fig. 4 of the paper further splits similarity into positions where both
codes are zero vs. identical-nonzero; squared-ReLU / ReLU archs are dominated by
the zero component, GLU archs by the nonzero component.

We additionally implement the *granularity* analysis: the paper shows the SVE
`sdot` instruction can only skip when a whole 4-element sub-vector of deltas is
zero (only 13.9 % of ResNet's raw similarity is harvestable at that
granularity), motivating the per-scalar `mla8`. On TPU the skip granularity is
a (block_m × block_k) tile, so `harvestable_similarity` reports the fraction of
tiles that are entirely unchanged — the TPU analogue of that study.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def code_similarity(cur_q: jax.Array, prev_q: jax.Array) -> jax.Array:
    """Fraction of positions whose int8 codes are identical. Scalar in [0, 1]."""
    return jnp.mean((cur_q == prev_q).astype(jnp.float32))


def row_code_similarity(cur_q: jax.Array, prev_q: jax.Array) -> jax.Array:
    """Per-row code-match fraction, [M] — one similarity per serving slot.

    Feeds the per-slot sim_ema lanes and the sensor hit-rate counters; the
    scalar `code_similarity` is its mean."""
    return jnp.mean((cur_q == prev_q).astype(jnp.float32), axis=-1)


def similarity_breakdown(cur_q: jax.Array, prev_q: jax.Array) -> dict[str, jax.Array]:
    """Fig.-4 split: identical-and-zero vs identical-and-nonzero fractions."""
    same = cur_q == prev_q
    zero = same & (cur_q == 0)
    nonzero = same & (cur_q != 0)
    n = cur_q.size
    return {
        "similarity": jnp.sum(same) / n,
        "zero_similarity": jnp.sum(zero) / n,
        "nonzero_similarity": jnp.sum(nonzero) / n,
    }


def block_zero_mask(
    delta: jax.Array, block_m: int, block_k: int
) -> jax.Array:
    """Per-tile "any element changed" mask for a [M, K] delta tensor.

    Returns int32 [ceil(M/bm), ceil(K/bk)] — 1 where the tile has ANY nonzero
    delta (must be computed), 0 where the whole tile is unchanged (skippable).
    M/K are padded virtually; padding positions count as unchanged.
    """
    m, k = delta.shape
    pm = (-m) % block_m
    pk = (-k) % block_k
    if pm or pk:
        delta = jnp.pad(delta, ((0, pm), (0, pk)))
    gm, gk = delta.shape[0] // block_m, delta.shape[1] // block_k
    tiles = delta.reshape(gm, block_m, gk, block_k)
    any_nz = jnp.any(tiles != 0, axis=(1, 3))
    return any_nz.astype(jnp.int32)


def harvestable_similarity(
    cur_q: jax.Array, prev_q: jax.Array, block_m: int, block_k: int
) -> jax.Array:
    """Fraction of (bm × bk) tiles fully unchanged — similarity usable at tile
    granularity (paper: 'all deltas in the sub-vector must be zero')."""
    delta = cur_q.astype(jnp.int32) - prev_q.astype(jnp.int32)
    mask = block_zero_mask(delta, block_m, block_k)
    return 1.0 - jnp.mean(mask.astype(jnp.float32))


def ema_update(stat: jax.Array, obs: jax.Array, decay: float) -> jax.Array:
    """Running similarity estimate used by the reuse policy (engine state)."""
    return decay * stat + (1.0 - decay) * obs
