from repro.data.pipeline import (
    SyntheticAudioSource,
    SyntheticLMSource,
    make_source,
)

__all__ = ["SyntheticAudioSource", "SyntheticLMSource", "make_source"]
