"""Deterministic synthetic data pipeline with sharded, resumable iteration.

Real deployments swap `SyntheticLMSource` for a tokenized corpus reader; the
contract the trainer depends on is:

  * determinism — batch(step) is a pure function of (seed, step), so restart
    from a checkpoint replays the exact stream (fault tolerance requirement);
  * host sharding — each host materializes only its slice of the global batch
    (`host_slice`), matching the DP sharding of the train step;
  * correlated streams — `correlation` controls how similar consecutive
    samples are, which is what drives the input-similarity experiments
    (paper Figs. 3/4: sequence workloads are correlated, ResNet-style
    workloads are not, yet both exhibit code-level similarity after int8).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMSource:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # fraction of token positions copied from the previous sample (input
    # similarity in the *token* domain; activation-level similarity is higher)
    correlation: float = 0.0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Consecutive-sample similarity ~= `correlation`, built statelessly:
        every sample mixes a FIXED anchor sequence (kept w.p. sqrt(c), so two
        consecutive samples agree w.p. c at anchor positions) with fresh
        noise. Stateless => random access (exact replay from checkpoints)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        b, s = self.host_batch, self.seq_len
        tokens = rng.integers(0, self.vocab, size=(b, s), dtype=np.int32)
        if self.correlation > 0.0:
            anchor_rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 10**9, self.host_id])
            )
            anchor = anchor_rng.integers(0, self.vocab, size=(b, s),
                                         dtype=np.int32)
            keep = rng.random((b, s)) < np.sqrt(self.correlation)
            tokens = np.where(keep, anchor, tokens)
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1  # masked
        return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class SyntheticAudioSource:
    """Frame-embedding source for the hubert stub frontend."""

    d_model: int
    seq_len: int
    global_batch: int
    vocab: int = 504
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        self.host_batch = self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        b, s = self.host_batch, self.seq_len
        # smooth frames: audio-like temporal correlation (high similarity regime)
        drift = rng.normal(size=(b, s, self.d_model)).astype(np.float32)
        embeds = np.cumsum(drift, axis=1) * 0.05
        labels = rng.integers(0, self.vocab, size=(b, s), dtype=np.int32)
        return {"embeds": embeds, "labels": labels}


def make_source(cfg, cell, *, seed=0, correlation=0.0, n_hosts=1, host_id=0):
    if cfg.frontend == "audio":
        return SyntheticAudioSource(
            d_model=cfg.d_model, seq_len=cell.seq_len,
            global_batch=cell.global_batch, vocab=cfg.vocab, seed=seed,
            n_hosts=n_hosts, host_id=host_id,
        )
    return SyntheticLMSource(
        vocab=cfg.vocab, seq_len=cell.seq_len, global_batch=cell.global_batch,
        seed=seed, correlation=correlation, n_hosts=n_hosts, host_id=host_id,
    )
