"""repro.dist — model-axis sharding of the reuse state.

`repro.dist.shard` plans shard-local site specs, builds NamedShardings that
pin each sharded cache leaf's shard axis to the mesh "model" axis, and
exposes the HLO shape signatures the no-gather assertion matches against.

(`repro.dist.sharding` — full per-arch weight partition specs — is a
separate, still-open roadmap item; tests/test_sharding.py skips until it
lands.)
"""

from repro.dist.shard import (  # noqa: F401
    cache_shape_signatures,
    cache_shardings,
    plan_local_spec,
    shard_axis_of,
    validate_shardable,
)
