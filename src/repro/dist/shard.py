"""Model-axis sharding of the reuse cache — plan, placement, HLO evidence.

The sharding rule is the Proximu$ one (PAPERS.md): reuse state lives WITH the
weights it shadows. A weight-stationary linear site [K, N] splits N-ways on
the mesh "model" axis, so shard s owns the weight columns `[s·N/S, (s+1)·N/S)`
and, with them, the only cache leaf that is N-shaped: `prev_out`. Everything
M/K-shaped — `prev_q`, `scale`, `sim_ema`, `steps`, the ctrl lanes, the
sensor counters — is replicated per shard (the quantize→delta→mask compare
path needs the full K row and therefore runs identically on every shard:
shard-LOCAL, zero collectives). The shard axis sits INSIDE the layer axis:
unstacked entries carry leading [S, ...], stacked entries [L, S, ...], so
`lax.scan` over layers still slices its leading axis and the layer body sees
a clean [S, ...] shard block for `vmap`.

Counter accounting under replication is the ownership partition documented in
`repro.sensor.counters`: per-shard counter lanes are DISJOINT slices of the
dense-baseline accounting, so their plain sum reproduces the unsharded
counters bitwise — the invariant the shard-parity tests pin.

This module carries the pieces that are about *placement*, not execution:
local-spec planning with divisibility validation, `NamedSharding` assignment
for a sharded cache pytree, and the cache-buffer shape signatures the HLO
no-gather assertion (`roofline.hlo_parse.cache_collective_violations`)
matches collective operands against.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.reuse_cache import ReuseSiteSpec
from repro.sensor.counters import (  # noqa: F401  (re-exported: one import site)
    COUNTER_SHARD_REDUCE,
    ShardCtx,
    owned_k_mask,
    owned_panel_count,
)


def validate_shardable(spec: ReuseSiteSpec, n_shards: int) -> None:
    """Raise with an actionable message when a site can't split N-ways."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if spec.out_features % n_shards:
        raise ValueError(
            f"site {spec.name!r}: out_features={spec.out_features} is not "
            f"divisible by {n_shards} model shards — pick a mesh whose model "
            f"axis divides every reuse site's N"
        )


def plan_local_spec(spec: ReuseSiteSpec, n_shards: int) -> ReuseSiteSpec:
    """The shard-local site spec: same site, N/S output columns.

    Only `out_features` changes — block geometry, dataflow, exec_path and the
    k-extent budget are N-independent (K is never split), so the shard-local
    evaluation is the same traced program at a narrower weight panel.
    """
    validate_shardable(spec, n_shards)
    return dataclasses.replace(
        spec, out_features=spec.out_features // n_shards
    )


def shard_axis_of(n_layers: int) -> int:
    """Position of the shard axis in a site's cache leaves: inside the layer
    axis ([L, S, ...] stacked, [S, ...] unstacked)."""
    return 1 if n_layers else 0


def cache_shardings(engine, mesh, cache: dict[str, Any]) -> dict[str, Any]:
    """NamedSharding pytree for `jax.device_put`: each sharded site's shard
    axis pins to the mesh "model" axis, every other leaf (and every unsharded
    site) replicates. Shapes are already shard-expanded by
    `ReuseEngine.init_cache`, so placement is pure axis naming — no resplit.
    """
    model_size = int(mesh.shape["model"])
    out: dict[str, Any] = {}
    replicated = NamedSharding(mesh, P())
    for name, entry in cache.items():
        n_shards = engine.shards.get(name)
        if not n_shards:
            out[name] = jax.tree.map(lambda _: replicated, entry)
            continue
        if n_shards != model_size:
            raise ValueError(
                f"site {name!r} is planned for {n_shards} shards but the "
                f"mesh model axis is {model_size} wide"
            )
        ax = shard_axis_of(engine.stacking.get(name, 0))

        def _leaf_sharding(leaf, ax=ax):
            parts: list = [None] * np.ndim(leaf)
            parts[ax] = "model"
            return NamedSharding(mesh, P(*parts))

        out[name] = jax.tree.map(_leaf_sharding, entry)
    return out


# numpy dtype name → HLO shape-prefix dtype token (hlo_parse._OP_RE groups).
_DTYPE_HLO = {
    "int8": "s8",
    "int32": "s32",
    "int64": "s64",
    "uint32": "u32",
    "float32": "f32",
    "float64": "f64",
    "bfloat16": "bf16",
    "bool": "pred",
}


def cache_shape_signatures(cache: dict[str, Any]) -> set[tuple[str, tuple]]:
    """(hlo_dtype, dims) signatures of every cache leaf — global shape AND
    (for placed arrays) the per-device shard shape, since SPMD-partitioned
    HLO names buffers by their local shapes. The no-gather assertion flags
    any all-gather/all-to-all whose operands match one of these."""
    sigs: set[tuple[str, tuple]] = set()
    for leaf in jax.tree.leaves(cache):
        dt = _DTYPE_HLO.get(np.dtype(leaf.dtype).name)
        if dt is None:
            continue
        sigs.add((dt, tuple(int(d) for d in leaf.shape)))
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            try:
                sigs.add((dt, tuple(
                    int(d) for d in sharding.shard_shape(leaf.shape))))
            except Exception:
                pass
    return sigs
