"""repro.guard — serving-plane fault containment.

ReuseSense's bet is that STALE STATE (cached products of a previous input)
can stand in for fresh computation, which makes the serving loop uniquely
exposed to state corruption: one poisoned prev_q/prev_out slot or a garbage
ctrl lane silently wrongs every output until the slot recycles. This package
is the containment plane:

* :mod:`repro.guard.inject`     — deterministic, seeded fault injector with
  hooks at the real seams (cache post-update, ctrl block, retirement
  telemetry, journal writer, checkpoint dir, step clock). Each fault is a
  named scenario usable from tests and ``serve --inject <scenario>``.
* :mod:`repro.guard.sentinel`   — cheap invariant checks that ride the jitted
  control snapshot as array ops (non-finite flags, ctrl-lane range
  validation, counter conservation) plus a periodic dense shadow spot-check
  against the bitwise oracle.
* :mod:`repro.guard.quarantine` — the per-(site, layer) circuit breaker:
  tripped sentinel → lane pinned to basic/dense via a ctrl array write (no
  retrace), poisoned state scrubbed, replayable ``kind="quarantine"``
  journal decision; probation with exponential backoff re-admits.
* :mod:`repro.guard.watchdog`   — the median-based straggler watchdog shared
  by the training loop (`ckpt.recovery.ResilientLoop`) and the serve step
  clock, feeding the same breaker.
"""

from repro.guard.inject import SCENARIOS, FaultInjector
from repro.guard.quarantine import GuardConfig, GuardReport, QuarantineBreaker
from repro.guard.sentinel import evaluate_snapshot, sentinel_lanes, shadow_check
from repro.guard.watchdog import StragglerWatchdog

__all__ = [
    "SCENARIOS",
    "FaultInjector",
    "GuardConfig",
    "GuardReport",
    "QuarantineBreaker",
    "StragglerWatchdog",
    "evaluate_snapshot",
    "sentinel_lanes",
    "shadow_check",
]
