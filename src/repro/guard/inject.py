"""Deterministic fault injector — named, replayable failure scenarios.

Chaos with a seed: every scenario is a pure function of (scenario params,
seed, step), so a failing CI run replays locally with the same flags and the
same fault lands at the same step. The injector hooks the REAL seams the
serving plane exposes — the post-update reuse cache, the ctrl block, the
retirement telemetry callback, the decision-journal file, the checkpoint
directory, and the step clock — rather than monkeypatching internals, so a
passing chaos test certifies the production wiring, not a test double.

Scenarios (see SCENARIOS for tunable parameters):

    poison-nan       NaN written into a prev_out cache lane (stale-product
                     corruption — the exact hazard computation reuse adds)
    poison-sim       NaN written into a sim_ema lane (drives mode decisions)
    ctrl-garbage     out-of-range ctrl lanes: mode_id=7, cooldown=-3
    poison-counters  skipped_tiles bumped without work — breaks the
                     skipped+computed == steps·gm·gk conservation invariant
    lying-telemetry  retirement telemetry reports a non-finite / out-of-range
                     hit_rate (attacks the admission predictor's EMA)
    torn-journal     the decision journal's final row is half-written
                     (simulated crash mid-append)
    corrupt-ckpt     bytes flipped mid-file in the newest checkpoint's host
                     payload (bitrot / torn write behind a COMPLETE marker)
    stall            the step clock stalls for `seconds` (straggler host)

Usage::

    inj = FaultInjector.from_spec("poison-nan:at_step=12,site=mlp_up")
    cache = inj.on_cache_update(cache, step)     # serve loop, post-decode
    t = inj.on_telemetry(t, step)                # retirement path
    inj.maybe_stall(step)                        # inside the timed region
    inj.tear_journal(path); inj.corrupt_checkpoint(ckpt_dir)   # at exit

Every fault that actually fired is appended to `.fired` for assertions.
"""

from __future__ import annotations

import time
from typing import Any

import jax.numpy as jnp
import numpy as np

SCENARIOS: dict[str, dict[str, Any]] = {
    "poison-nan": {
        "at_step": 12,
        "desc": "NaN into a prev_out cache lane (stale-product corruption)",
    },
    "poison-sim": {
        "at_step": 12,
        "desc": "NaN into a sim_ema lane (poisons mode decisions)",
    },
    "ctrl-garbage": {
        "at_step": 12,
        "desc": "out-of-range ctrl lanes (mode_id=7, cooldown=-3)",
    },
    "poison-counters": {
        "at_step": 12,
        "bump": 7,
        "desc": "skipped_tiles bumped without work (breaks conservation)",
    },
    "lying-telemetry": {
        "at_step": 0,
        "value": float("nan"),
        "desc": "retirement telemetry reports a bogus hit_rate",
    },
    "torn-journal": {
        "desc": "decision journal's final row half-written (crash mid-append)",
    },
    "corrupt-ckpt": {
        "desc": "bytes flipped mid-file in the newest checkpoint host payload",
    },
    "stall": {
        "at_step": 12,
        "seconds": 0.25,
        "desc": "step clock stalls (straggler host)",
    },
}

_CACHE_SCENARIOS = {
    "poison-nan", "poison-sim", "ctrl-garbage", "poison-counters",
}


def _coerce(raw: str) -> Any:
    for cast in (int, float):
        try:
            return cast(raw)
        except ValueError:
            continue
    return raw


class FaultInjector:
    """One named scenario, armed with concrete parameters. Hooks that don't
    belong to the scenario are no-ops, so serve can wire every hook
    unconditionally."""

    def __init__(self, scenario: str, *, site: str | None = None,
                 layer: int | None = None, seed: int = 0, **params: Any):
        if scenario not in SCENARIOS:
            raise ValueError(
                f"unknown fault scenario {scenario!r}; "
                f"have {sorted(SCENARIOS)}")
        defaults = {k: v for k, v in SCENARIOS[scenario].items()
                    if k != "desc"}
        unknown = set(params) - set(defaults)
        if unknown:
            raise ValueError(
                f"scenario {scenario!r} takes {sorted(defaults)}, "
                f"got unknown {sorted(unknown)}")
        self.scenario = scenario
        self.site = site
        self.layer = layer
        self.seed = seed
        self.params = {**defaults, **params}
        self.fired: list[dict[str, Any]] = []

    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Parse ``name`` or ``name:key=val,key=val`` (the --inject flag)."""
        name, _, rest = spec.partition(":")
        kwargs: dict[str, Any] = {}
        if rest:
            for part in rest.split(","):
                key, _, raw = part.partition("=")
                if not _ or not key:
                    raise ValueError(
                        f"bad injector spec segment {part!r} in {spec!r}")
                kwargs[key.strip()] = _coerce(raw.strip())
        site = kwargs.pop("site", None)
        layer = kwargs.pop("layer", None)
        seed = kwargs.pop("seed", 0)
        return cls(name.strip(), site=site, layer=layer, seed=seed, **kwargs)

    # ------------------------------------------------------------------ hooks
    def _pick(self, cache: dict[str, Any]) -> tuple[str, int | None]:
        site = self.site if self.site is not None else sorted(cache)[0]
        if site not in cache:
            raise KeyError(f"injector target site {site!r} not in cache")
        stacked = cache[site]["prev_q"].ndim == 3
        layer = self.layer
        if stacked and layer is None:
            layer = 0
        if not stacked:
            layer = None
        return site, layer

    def _lane(self, layer: int | None) -> tuple:
        return () if layer is None else (layer,)

    def on_cache_update(self, cache: dict[str, Any], step: int,
                        ) -> dict[str, Any]:
        """Post-decode cache hook: mutates one lane at `at_step`."""
        if self.scenario not in _CACHE_SCENARIOS:
            return cache
        if step != self.params["at_step"]:
            return cache
        site, layer = self._pick(cache)
        lane = self._lane(layer)
        entry = dict(cache[site])
        if self.scenario == "poison-nan":
            out = entry["prev_out"]
            entry["prev_out"] = out.at[lane + (0, 0)].set(jnp.nan)
            detail = "prev_out[...,0,0] = NaN"
        elif self.scenario == "poison-sim":
            sim = entry["sim_ema"]
            entry["sim_ema"] = sim.at[lane + (0,)].set(jnp.nan)
            detail = "sim_ema[...,0] = NaN"
        elif self.scenario == "ctrl-garbage":
            ctrl = dict(entry["ctrl"])
            ctrl["mode_id"] = ctrl["mode_id"].at[lane].set(7)
            ctrl["cooldown"] = ctrl["cooldown"].at[lane].set(-3)
            entry["ctrl"] = ctrl
            detail = "ctrl mode_id=7, cooldown=-3"
        else:  # poison-counters
            sensor = dict(entry["sensor"])
            bump = int(self.params["bump"])
            sensor["skipped_tiles"] = (
                sensor["skipped_tiles"].at[lane].add(bump))
            entry["sensor"] = sensor
            detail = f"skipped_tiles += {bump} without work"
        cache = dict(cache)
        cache[site] = entry
        self.fired.append({"scenario": self.scenario, "step": step,
                           "site": site, "layer": layer, "detail": detail})
        return cache

    def on_telemetry(self, telemetry: dict[str, Any], step: int,
                     ) -> dict[str, Any]:
        """Retirement-telemetry hook: first retirement at/after `at_step`
        reports a bogus hit_rate."""
        if self.scenario != "lying-telemetry" or self.fired:
            return telemetry
        if step < self.params["at_step"]:
            return telemetry
        value = float(self.params["value"])
        self.fired.append({"scenario": self.scenario, "step": step,
                           "detail": f"hit_rate -> {value}"})
        return dict(telemetry, hit_rate=value)

    def maybe_stall(self, step: int) -> None:
        """Step-clock hook: call inside the timed region of the decode step."""
        if self.scenario != "stall" or step != self.params["at_step"]:
            return
        seconds = float(self.params["seconds"])
        time.sleep(seconds)
        self.fired.append({"scenario": self.scenario, "step": step,
                           "detail": f"slept {seconds}s"})

    # -------------------------------------------------------- at-rest targets
    def tear_journal(self, path) -> None:
        """Truncate the journal mid-way through its final row (simulated
        crash between write and flush)."""
        if self.scenario != "torn-journal":
            return
        import os
        data = open(path, "rb").read()
        body = data.rstrip(b"\n")
        last_nl = body.rfind(b"\n")
        last_len = len(body) - (last_nl + 1)
        if last_len < 2:
            return
        cut = len(body) - last_len // 2
        with open(path, "wb") as f:
            f.write(data[:cut])
            f.flush()
            os.fsync(f.fileno())
        self.fired.append({
            "scenario": self.scenario, "step": -1,
            "detail": f"truncated {path} to {cut}/{len(data)} bytes "
                      f"(final row torn)"})

    def corrupt_checkpoint(self, directory) -> None:
        """Flip bytes mid-file in the newest COMPLETE checkpoint's first host
        payload — bitrot behind a COMPLETE marker."""
        if self.scenario != "corrupt-ckpt":
            return
        from pathlib import Path
        root = Path(directory)
        markers = sorted(root.glob("step_*.COMPLETE"), reverse=True)
        if not markers:
            return
        step_dir = root / markers[0].name[: -len(".COMPLETE")]
        hosts = sorted(step_dir.glob("host_*.npz"))
        if not hosts:
            return
        target = hosts[0]
        data = bytearray(target.read_bytes())
        rng = np.random.default_rng(self.seed)
        mid = len(data) // 2
        span = min(64, max(1, len(data) - mid))
        data[mid:mid + span] = rng.integers(
            0, 256, size=span, dtype=np.uint8).tobytes()
        target.write_bytes(bytes(data))
        self.fired.append({
            "scenario": self.scenario, "step": -1,
            "detail": f"flipped {span} bytes mid-file in {target}"})
