"""Quarantine breaker — per-(site, layer) fault containment state machine.

A tripped sentinel must not keep wronging outputs until the slot recycles, so
the breaker flips the offending lane to basic/dense THE SAME control interval
the evidence lands: `set_mode` + a `quarantine` ctrl-lane write (both array
writes into the PR 5 control block — no retrace), the poisoned state is
scrubbed (prev_q/prev_out/sim_ema lanes zeroed, corrupt ctrl lanes rebuilt
from the policy table; the cold-start property — reuse == quantized dense on
the first step after a zeroed lane — makes the scrub exact, the same
guarantee slot recycling leans on), and a replayable `kind="quarantine"`
decision with the sentinel evidence lands in the decision journal.

Lifecycle per lane::

    active ──trip──▶ quarantined ──lockout drains──▶ probation ──K clean──▶ active
                        ▲                                │
                        └────────── re-offense ──────────┘   (lockout doubles)

The lockout is `quarantine_intervals` control intervals, doubling on every
re-offense up to `max_quarantine` (exponential backoff: a lane that keeps
tripping converges to permanently-dense). Cross-freeze: a quarantine bumps
the lane's mode cooldown AND the site's exec cooldown, so neither the
hysteretic refresh nor the retuner can thrash against the breaker — and the
controller skips retuning a site the breaker froze this interval. A stalled
interval (the straggler watchdog fired) never counts as "clean" for
probation: a replica limping on latency has not proven itself healthy.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.control.report import Decision
from repro.guard.sentinel import Trip, evaluate_snapshot, shadow_check


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    # Initial lockout length, in control intervals, for a first offense.
    quarantine_intervals: int = 2
    # Lockout growth on re-offense (doubles) is capped here.
    max_quarantine: int = 64
    # Clean (trip-free, stall-free) probation intervals before re-admission.
    probation_windows: int = 2
    # Run the dense shadow spot-check every N intervals (0 = disabled). One
    # site per eligible interval, round-robin — the check costs two real site
    # evaluations, so it must not run per site per interval.
    shadow_every: int = 0
    shadow_batch: int = 2
    shadow_seed: int = 0


@dataclasses.dataclass
class _Lane:
    state: str = "active"        # active | quarantined | probation
    lockout: int = 2             # current lockout length (doubles on re-offense)
    remaining: int = 0           # lockout intervals left while quarantined
    clean: int = 0               # clean probation intervals so far
    offenses: int = 0


@dataclasses.dataclass
class GuardReport:
    """What one breaker pass saw and did."""

    step: int
    interval: int
    trips: list[Trip]
    decisions: list[Decision]
    # sites the breaker acted on this interval — the retuner must skip them
    frozen_sites: set[str]
    stalled: bool
    shadow: tuple[str, bool, str] | None = None  # (site, ok, detail)
    quarantined_lanes: int = 0  # live count after this pass

    @property
    def tripped(self) -> bool:
        return bool(self.trips)


class QuarantineBreaker:
    """Host-side circuit breaker fed by the array sentinels. One instance per
    serving engine; invoke `step(engine, cache, step=...)` once per control
    interval (the Controller does this first, before retuning)."""

    def __init__(self, config: GuardConfig = GuardConfig()):
        self.config = config
        self._lanes: dict[tuple[str, int | None], _Lane] = {}
        # previous interval's counter lanes + geometry, for the windowed
        # conservation check (a block_k move invalidates one window)
        self._prev_lanes: dict[str, dict[str, np.ndarray]] = {}
        self._prev_block_k: dict[str, int] = {}
        self._pending_stalls: list[dict] = []
        self.stall_windows = 0
        self._interval = 0
        self._shadow_idx = 0
        self.total_trips = 0

    # ------------------------------------------------------------ stall input
    def note_stall(self, event: dict) -> None:
        """Feed a straggler-watchdog event (serve times each decode step);
        journaled and counted against probation on the next `step`."""
        self._pending_stalls.append(event)

    # ------------------------------------------------------------- inspection
    def lane_states(self) -> dict[tuple[str, int | None], str]:
        return {k: v.state for k, v in self._lanes.items()}

    def quarantined_lanes(self) -> int:
        return sum(1 for v in self._lanes.values() if v.state == "quarantined")

    # ------------------------------------------------------------------- pass
    def step(self, engine, cache: dict[str, Any], *, step: int,
             snapshot: dict[str, Any] | None = None) -> GuardReport:
        cfg = self.config
        self._interval += 1
        snap = snapshot if snapshot is not None else engine.ctrl_snapshot(cache)
        decisions: list[Decision] = []
        trips: list[Trip] = []
        frozen: set[str] = set()

        # -- stall accounting first: a stalled interval voids probation credit
        stalled = bool(self._pending_stalls)
        for ev in self._pending_stalls:
            decisions.append(Decision(
                step=step, site="", kind="quarantine", field="stall_windows",
                before=self.stall_windows, after=self.stall_windows + 1,
                reason=f"straggler watchdog: step {ev['step']} took "
                       f"{ev['seconds']:.4f}s vs median {ev['median']:.4f}s "
                       f"({ev['action']})",
            ))
            self.stall_windows += 1
        self._pending_stalls = []

        # -- array sentinels per site (lanes already ride the one snapshot)
        for name, spec in engine.sites.items():
            s = snap.get(name, {})
            if "bad_out" not in s:
                continue  # entry predates the guard lanes
            stacked = engine.stacking.get(name, 0) > 0
            batch = cache[name]["prev_q"].shape[-2]
            gm = -(-batch // spec.block_m)
            gk = -(-spec.in_features // spec.block_k)
            prev = self._prev_lanes.get(name)
            tiles = gm * gk
            if self._prev_block_k.get(name) != spec.block_k:
                tiles = None  # geometry moved: this window's delta mixes units
            trips += evaluate_snapshot(
                name, s, stacked=stacked, tiles_per_eval=tiles, prev=prev,
            )
            self._prev_lanes[name] = {
                k: np.asarray(s[k])
                for k in ("skipped_l", "computed_l", "steps_l") if k in s
            }
            self._prev_block_k[name] = spec.block_k

        # -- periodic dense shadow spot-check, one site round-robin
        shadow = None
        if cfg.shadow_every > 0 and self._interval % cfg.shadow_every == 0:
            sites = sorted(engine.sites)
            if sites:
                site = sites[self._shadow_idx % len(sites)]
                self._shadow_idx += 1
                ok, detail = shadow_check(
                    engine, site, batch=cfg.shadow_batch,
                    seed=cfg.shadow_seed + self._interval,
                )
                shadow = (site, ok, detail)
                if not ok:
                    trips.append(Trip(site=site, layer=None, check="shadow",
                                      evidence=detail))

        # -- breaker: trips → quarantine writes + journal decisions
        by_lane: dict[tuple[str, int | None], list[Trip]] = {}
        for t in trips:
            by_lane.setdefault((t.site, t.layer), []).append(t)
        for (site, layer), lane_trips in sorted(
                by_lane.items(), key=lambda kv: (kv[0][0], kv[0][1] or 0)):
            lane = self._lanes.setdefault(
                (site, layer), _Lane(lockout=cfg.quarantine_intervals))
            before = lane.state
            if lane.offenses > 0:
                # any re-offense — out of probation, while locked, or after a
                # full re-admission — doubles the lockout (backoff)
                lane.lockout = min(lane.lockout * 2, cfg.max_quarantine)
            lane.state = "quarantined"
            lane.remaining = lane.lockout
            lane.clean = 0
            lane.offenses += 1
            self.total_trips += len(lane_trips)
            self._apply_quarantine(engine, cache, site, layer, lane.lockout)
            decisions.append(Decision(
                step=step, site=site, kind="quarantine", field="state",
                before=before, after="quarantined", layer=layer,
                reason="; ".join(f"{t.check}: {t.evidence}"
                                 for t in lane_trips)
                       + f" [lockout {lane.lockout} intervals, "
                         f"offense #{lane.offenses}]",
            ))
            frozen.add(site)

        # -- drain lockouts / advance probation for lanes NOT tripped now
        for (site, layer), lane in sorted(
                self._lanes.items(), key=lambda kv: (kv[0][0], kv[0][1] or 0)):
            if (site, layer) in by_lane:
                continue
            if lane.state == "quarantined":
                frozen.add(site)  # still locked: retuner keeps hands off
                lane.remaining -= 1
                self._write_ctrl_lane(
                    cache, site, layer, quarantine=max(lane.remaining, 0))
                if lane.remaining <= 0:
                    lane.state = "probation"
                    lane.clean = 0
                    decisions.append(Decision(
                        step=step, site=site, kind="quarantine",
                        field="state", before="quarantined",
                        after="probation", layer=layer,
                        reason=f"lockout drained after {lane.lockout} "
                               f"intervals; needs {cfg.probation_windows} "
                               f"clean windows to re-admit",
                    ))
            elif lane.state == "probation":
                if stalled:
                    lane.clean = 0  # a limping interval proves nothing
                    continue
                lane.clean += 1
                if lane.clean >= cfg.probation_windows:
                    lane.state = "active"
                    decisions.append(Decision(
                        step=step, site=site, kind="quarantine",
                        field="state", before="probation", after="active",
                        layer=layer,
                        reason=f"re-admitted after {lane.clean} clean "
                               f"windows; next offense locks out "
                               f"{min(lane.lockout * 2, cfg.max_quarantine)} "
                               f"intervals",
                    ))

        return GuardReport(
            step=step, interval=self._interval, trips=trips,
            decisions=decisions, frozen_sites=frozen, stalled=stalled,
            shadow=shadow, quarantined_lanes=self.quarantined_lanes(),
        )

    # ------------------------------------------------------------ lane writes
    def _apply_quarantine(
        self, engine, cache: dict[str, Any], site: str, layer: int | None,
        lockout: int,
    ) -> None:
        """Contain one lane: pin basic, scrub poisoned state, rebuild ctrl
        lanes from the policy table, cross-freeze mode/exec cooldowns, bump
        the sentinel-trip counter. All array writes — no retrace."""
        engine.set_mode(cache, site, "basic", layer=layer)
        entry = cache[site]

        def scrub(arr):
            if layer is None:
                return jnp.zeros_like(arr)
            return arr.at[layer].set(0)

        entry = dict(
            entry,
            prev_q=scrub(entry["prev_q"]),
            prev_out=scrub(entry["prev_out"]),
            sim_ema=scrub(entry["sim_ema"]),
        )
        if "sensor" in entry and "sentinel_trips" in entry["sensor"]:
            sensor = dict(entry["sensor"])
            st = sensor["sentinel_trips"]
            if layer is None or st.ndim == 0:
                st = st + 1
            else:
                st = st.at[layer].add(1)
            sensor["sentinel_trips"] = st
            entry = dict(entry, sensor=sensor)
        cache[site] = entry
        # rebuild the lane's ctrl operating point from the policy table (a
        # ctrl_range trip means these very lanes may be garbage)
        stacked = engine.stacking.get(site, 0) > 0
        t = engine.policy.resolve(site, layer=layer if stacked else None)
        self._write_ctrl_lane(
            cache, site, layer,
            sim_threshold=t.sim_threshold,
            min_work=t.min_work_flops,
            occupancy=1.0,
            cooldown=lockout,
            quarantine=lockout,
        )
        # the reciprocal freeze the mode/exec refreshes already practice:
        # containment must not thrash against the retuner's exec decisions
        engine.exec_cooldown[site] = max(
            engine.exec_cooldown.get(site, 0), lockout)

    @staticmethod
    def _write_ctrl_lane(
        cache: dict[str, Any], site: str, layer: int | None, **values: Any,
    ) -> None:
        entry = cache[site]
        ctrl = dict(entry["ctrl"])
        for key, val in values.items():
            arr = ctrl.get(key)
            if arr is None:
                continue  # legacy ctrl block without the lane
            if layer is None:
                ctrl[key] = jnp.full_like(arr, val)
            else:
                ctrl[key] = arr.at[layer].set(val)
        cache[site] = dict(entry, ctrl=ctrl)
