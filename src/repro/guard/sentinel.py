"""Invariant sentinels — cheap array-resident health checks on the reuse state.

Two tiers, matching the cost they're allowed to spend:

1. **Array sentinels** (`sentinel_lanes`): a handful of reductions over one
   cache entry — non-finite flags on prev_out, sim_ema range validation,
   ctrl-lane range bitmasks, per-layer counter sums for conservation. They
   run INSIDE the engine's jitted control snapshot (`_ctrl_snapshot_device`),
   so detection rides the one device→host transfer the control plane already
   pays per interval (the Proximu$ lesson: move the checking to where the
   state lives). `evaluate_snapshot` is the host half: it turns the pulled
   lanes plus windowed counter deltas into named trip records.

2. **Dense shadow spot-check** (`shadow_check`): every N control windows one
   (site, layer) is re-proven against the bitwise oracle — a deterministic
   synthetic probe built from integer-valued operands (every f32 accumulation
   exact regardless of order, the tests/test_backend.py methodology) runs the
   site's CURRENT spec (exec_path / block_k / max_active_k) down the reuse
   path and down a dense-oracle spec, and the outputs must be bitwise equal.
   This proves the *substrate under the current operating point* still honors
   the telescoping invariant; live-state poisoning is the array sentinels'
   job (the probe deliberately uses fresh synthetic state so a poisoned live
   cache can't mask a substrate bug, and vice versa).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

# ctrl-lane corruption bitmask (per layer) — which range check failed.
CTRL_BAD_MODE = 1        # mode_id outside {MODE_BASIC, MODE_REUSE}
CTRL_BAD_COOLDOWN = 2    # cooldown < 0
CTRL_BAD_THRESHOLD = 4   # sim_threshold non-finite or far outside [0, 1]
CTRL_BAD_MIN_WORK = 8    # min_work negative or non-finite
CTRL_BAD_OCCUPANCY = 16  # occupancy non-finite
CTRL_BAD_QUARANTINE = 32  # quarantine < 0

# sim_ema is an EMA of per-row code-match fractions in [0, 1]; allow float
# rounding slack before calling a value corrupt.
_SIM_EPS = 1e-5
# sim_threshold is retuner-moved; anything inside this generous band is a
# legitimate operating point, outside it is corruption.
_THR_LO, _THR_HI = -0.5, 1.5


def sentinel_lanes(entry: dict[str, Any]) -> dict[str, Any]:
    """Array-sentinel reductions for one cache entry (traced; jit-safe).

    Returns per-layer lanes (leading [L]; unstacked entries get [1]):

        bad_out       int32 [L] — non-finite prev_out element count
        bad_sim       int32 [L] — sim_ema values non-finite or outside
                                  [-eps, 1+eps]
        ctrl_bad      int32 [L] — CTRL_BAD_* bitmask of range violations
        quarantine    int32 [L] — the guard lockout lane (0 on pre-guard
                                  ctrl blocks)
        skipped_l     int32 [L] — per-layer skipped-tile counter
        computed_l    int32 [L] — per-layer computed-tile counter
        steps_l       int32 [L] — per-layer evaluation counter
    """
    out: dict[str, Any] = {}
    prev_out = entry["prev_out"]
    # [L, M, N] stacked / [M, N] unstacked → reduce the trailing two axes
    nonfin = (~jnp.isfinite(prev_out)).astype(jnp.int32)
    out["bad_out"] = jnp.atleast_1d(jnp.sum(nonfin, axis=(-2, -1)))

    sim = entry["sim_ema"]
    sim_bad = (~jnp.isfinite(sim)) | (sim < -_SIM_EPS) | (sim > 1.0 + _SIM_EPS)
    sim_bad = sim_bad.astype(jnp.int32)
    if sim.ndim >= 1:  # [L, M] / [M] → per-layer count
        sim_bad = jnp.sum(sim_bad, axis=-1)
    out["bad_sim"] = jnp.atleast_1d(sim_bad)

    ctrl = entry.get("ctrl")
    if ctrl is not None:
        mode_id = jnp.atleast_1d(ctrl["mode_id"]).astype(jnp.int32)
        cd = jnp.atleast_1d(ctrl["cooldown"])
        thr = jnp.atleast_1d(ctrl["sim_threshold"])
        mw = jnp.atleast_1d(ctrl["min_work"])
        occ = jnp.atleast_1d(ctrl["occupancy"])
        quar = jnp.atleast_1d(
            ctrl.get("quarantine", jnp.zeros_like(ctrl["cooldown"]))
        )
        bad = jnp.where((mode_id < 0) | (mode_id > 1), CTRL_BAD_MODE, 0)
        bad = bad | jnp.where(cd < 0, CTRL_BAD_COOLDOWN, 0)
        bad = bad | jnp.where(
            ~jnp.isfinite(thr) | (thr < _THR_LO) | (thr > _THR_HI),
            CTRL_BAD_THRESHOLD, 0)
        bad = bad | jnp.where(~jnp.isfinite(mw) | (mw < 0),
                              CTRL_BAD_MIN_WORK, 0)
        bad = bad | jnp.where(~jnp.isfinite(occ), CTRL_BAD_OCCUPANCY, 0)
        bad = bad | jnp.where(quar < 0, CTRL_BAD_QUARANTINE, 0)
        out["ctrl_bad"] = bad.astype(jnp.int32)
        out["quarantine"] = quar.astype(jnp.int32)

    sensor = entry.get("sensor")
    if sensor is not None:
        out["skipped_l"] = jnp.atleast_1d(
            sensor["skipped_tiles"]).astype(jnp.int32)
        out["computed_l"] = jnp.atleast_1d(
            sensor["computed_tiles"]).astype(jnp.int32)
    out["steps_l"] = jnp.atleast_1d(entry["steps"]).astype(jnp.int32)
    return out


_CTRL_BAD_NAMES = {
    CTRL_BAD_MODE: "mode_id",
    CTRL_BAD_COOLDOWN: "cooldown",
    CTRL_BAD_THRESHOLD: "sim_threshold",
    CTRL_BAD_MIN_WORK: "min_work",
    CTRL_BAD_OCCUPANCY: "occupancy",
    CTRL_BAD_QUARANTINE: "quarantine",
}


def _bad_lanes(mask: int) -> str:
    names = [n for bit, n in _CTRL_BAD_NAMES.items() if mask & bit]
    return "+".join(names) or "none"


@dataclasses.dataclass(frozen=True)
class Trip:
    """One tripped sentinel: which check, where, and the measured evidence."""

    site: str
    layer: int | None   # None = unstacked site
    check: str          # "nonfinite_out" | "sim_range" | "ctrl_range" |
    #                     "conservation" | "shadow"
    evidence: str


def evaluate_snapshot(
    name: str,
    lanes: dict[str, Any],
    *,
    stacked: bool,
    tiles_per_eval: int | None = None,
    prev: dict[str, np.ndarray] | None = None,
) -> list[Trip]:
    """Host half of the array sentinels: lanes (already device_get numpy)
    → named per-layer trip records.

    `tiles_per_eval` (gm·gk of the site's CURRENT geometry) enables the
    counter-conservation check over the window since `prev` (the previous
    interval's lanes): Δskipped + Δcomputed must equal Δsteps · gm · gk. The
    caller passes `tiles_per_eval=None` for windows where block_k changed —
    the delta would mix tile units across granularities and trip falsely.
    """
    trips: list[Trip] = []
    bad_out = np.asarray(lanes["bad_out"])
    n_lanes = bad_out.shape[0]

    def _layer(i: int) -> int | None:
        return i if stacked else None

    for i in range(n_lanes):
        if bad_out[i] > 0:
            trips.append(Trip(
                site=name, layer=_layer(i), check="nonfinite_out",
                evidence=f"{int(bad_out[i])} non-finite prev_out elements",
            ))
    bad_sim = np.asarray(lanes["bad_sim"])
    for i in range(bad_sim.shape[0]):
        if bad_sim[i] > 0:
            trips.append(Trip(
                site=name, layer=_layer(i), check="sim_range",
                evidence=f"{int(bad_sim[i])} sim_ema values non-finite or "
                         f"outside [0, 1]",
            ))
    ctrl_bad = np.asarray(lanes.get("ctrl_bad", np.zeros(0, np.int32)))
    for i in range(ctrl_bad.shape[0]):
        if ctrl_bad[i]:
            trips.append(Trip(
                site=name, layer=_layer(i), check="ctrl_range",
                evidence=f"ctrl lanes out of range: "
                         f"{_bad_lanes(int(ctrl_bad[i]))}",
            ))
    if (tiles_per_eval is not None and prev is not None
            and "skipped_l" in lanes and "skipped_l" in prev):
        d_skip = np.asarray(lanes["skipped_l"]) - np.asarray(prev["skipped_l"])
        d_comp = (np.asarray(lanes["computed_l"])
                  - np.asarray(prev["computed_l"]))
        d_steps = np.asarray(lanes["steps_l"]) - np.asarray(prev["steps_l"])
        for i in range(d_skip.shape[0]):
            expect = int(d_steps[i]) * tiles_per_eval
            got = int(d_skip[i]) + int(d_comp[i])
            if got != expect:
                trips.append(Trip(
                    site=name, layer=_layer(i), check="conservation",
                    evidence=f"Δskipped+Δcomputed={got} != "
                             f"Δsteps·gm·gk={expect} "
                             f"(Δsteps={int(d_steps[i])}, "
                             f"tiles/eval={tiles_per_eval})",
                ))
    return trips


# --------------------------------------------------------------- shadow check


def _probe_operands(spec, batch: int, seed: int):
    """Deterministic integer-valued probe operands for one site: every f32
    accumulation is exact regardless of order, so reuse-vs-dense compares
    BITWISE (the tests/test_backend.py parity methodology)."""
    rng = np.random.default_rng(seed)
    k, n = spec.in_features, spec.out_features
    # two consecutive integer activations with ~half the codes shared, so the
    # probe exercises a mixed tile mask (skip + compute + telescoping)
    x0 = rng.integers(-3, 4, size=(batch, k)).astype(np.float32)
    x1 = np.where(rng.random((batch, k)) < 0.5, x0,
                  rng.integers(-3, 4, size=(batch, k))).astype(np.float32)
    w = rng.integers(-2, 3, size=(k, n)).astype(np.float32)
    return x0, x1, w


def shadow_check(
    engine, site: str, *, batch: int = 2, seed: int = 0,
) -> tuple[bool, str]:
    """Dense shadow spot-check of one site's CURRENT operating point.

    Builds a fresh synthetic cache entry for the site's live spec, feeds two
    consecutive integer-valued probe activations down the reuse path AND down
    a dense-oracle replica of the spec (exec_path="dense", no budget), and
    asserts the second outputs are bitwise equal — the telescoping invariant
    under the exact exec_path / block_k / max_active_k the serve loop is
    running. Returns (ok, detail).
    """
    from repro.core.reuse_cache import init_site_cache
    from repro.core.reuse_linear import reuse_linear

    spec = engine.sites[site]
    # integer probe codes must survive quantization exactly: scale=1 int8
    # quantization of small integers is the identity
    probe_spec = dataclasses.replace(spec, fixed_scale=1.0)
    oracle_spec = dataclasses.replace(
        probe_spec, exec_path="dense", max_active_k=None)
    x0, x1, w = _probe_operands(spec, batch, seed)

    def _run(sp):
        cache = init_site_cache(sp, batch)
        y = None
        for x in (x0, x1):
            y, cache, _ = reuse_linear(
                jnp.asarray(x), jnp.asarray(w), None, cache, sp,
                mode="reuse", impl=engine.impl,
            )
        return np.asarray(y)

    got = _run(probe_spec)
    want = _run(oracle_spec)
    if np.array_equal(got, want):
        return True, (f"bitwise-exact vs dense oracle "
                      f"(exec={spec.exec_path}, block_k={spec.block_k}, "
                      f"budget={spec.max_active_k})")
    diff = int(np.sum(got != want))
    return False, (f"{diff}/{got.size} output elements diverge from the "
                   f"dense oracle (exec={spec.exec_path}, "
                   f"block_k={spec.block_k}, budget={spec.max_active_k})")
