"""Straggler watchdog — median-based step-latency anomaly detection.

One implementation shared by the two loops that need it: the training
harness (`repro.ckpt.recovery.ResilientLoop`, which historically carried this
logic inline) and the serving step clock (`repro.launch.serve` times each
decode step and feeds the guard plane's circuit breaker). A step slower than
`factor`× the median of the recent window is an event; on real fleets this
feeds the controller that evicts the slow host, here it feeds the quarantine
breaker's stall accounting (a stalled interval never counts as "clean" for
probation) and the ResilientLoop's re-shard recommendation.

Median, not EMA, on purpose: one straggler must not drag the baseline it is
judged against (an EMA poisoned by the outlier stops flagging the next one).
"""

from __future__ import annotations

import statistics


class StragglerWatchdog:
    """Per-step wall-time monitor. `observe(step, dt)` returns an event dict
    when the step breached `factor`× the window median, else None. All events
    accumulate in `.events` for end-of-run reporting."""

    def __init__(
        self,
        *,
        factor: float = 2.0,
        window: int = 32,
        min_samples: int = 8,
        action: str = "recommend re-shard / evict host",
    ):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.action = action
        self.step_times: list[float] = []
        self.events: list[dict] = []

    def observe(self, step: int, dt: float) -> dict | None:
        self.step_times.append(dt)
        recent = self.step_times[-self.window:]
        if len(recent) < self.min_samples:
            return None
        med = statistics.median(recent)
        if dt > self.factor * med:
            event = {
                "step": step, "seconds": dt, "median": med,
                "action": self.action,
            }
            self.events.append(event)
            return event
        return None
