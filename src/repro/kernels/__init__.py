"""Pallas TPU kernels for the paper's compute hot-spot: the ΔW reuse GEMM.

  reuse_matmul.py      — block-skip ΔW GEMM (ReuseSensor analogue; skips the
                         HBM→VMEM weight-tile DMA and the MXU op per zero tile)
  reuse_matmul_ragged.py — compacted-grid ΔW GEMM: the k-extent is the
                         measured-occupancy budget, so skipped tiles cost
                         zero grid steps (the wall-clock tier)
  reuse_matmul_int8.py — int8×int8→int32 variant (the mla8 analogue)
  delta_quant.py       — fused quantize + delta + tile-mask pass
  wkv6_decode.py       — fused RWKV6 decode step (one state pass instead of
                         four; the rwkv6 batched-decode hot-spot)
  ops.py               — jit'd public wrappers (padding, path dispatch)
  ref.py               — pure-jnp oracles
"""
