"""Compiled execution plane: resolve the best kernel substrate ONCE per process.

Before this module every kernel wrapper took `interpret: bool` with divergent
defaults (`ops.py` said True, the kernel modules said False), so "what actually
runs" depended on which layer you entered through — and on CPU everything
silently fell back to interpret-mode Pallas, pricing the policy's break-even
constants against a cost model that is 20-80x off compiled reality.

Now there is one resolution, cached per process:

    "pallas"     — compiled Pallas (TPU devices present)
    "pallas_cpu" — compiled CPU Pallas lowering (probed; jaxlib-dependent)
    "xla"        — semantics-identical compiled-XLA tier (kernels/xla_tier.py)
    "interpret"  — interpret-mode Pallas, EXPLICIT test mode only

`ops.py` wrappers call `resolve(interpret=...)`: `None` (the default) picks the
best compiled substrate; `True` is the explicit interpret test mode; `False`
forces the best compiled Pallas variant (raises where none exists — no silent
interpret fallback ever again). `reuse_linear` maps its `impl` string through
`for_impl` so "pallas" on a CPU-only host degrades to the compiled-XLA tier
instead of crashing or interpreting.

`tag()` returns the provenance dict ({backend, interpret, jax, jaxlib}) that
every BENCH_kernels.json row and latency_table.json entry now carries, so
compiled and interpret measurements can never again be conflated in a
trajectory.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

__all__ = [
    "Substrate",
    "best",
    "for_impl",
    "resolve",
    "tag",
    "describe",
    "PALLAS",
    "PALLAS_CPU",
    "XLA",
    "INTERPRET",
]


@dataclasses.dataclass(frozen=True)
class Substrate:
    """One resolved execution substrate for the reuse kernels.

    use_pallas — route through the Pallas kernels (compiled or interpret);
                 False routes through the compiled-XLA tier (xla_tier.py).
    interpret  — Pallas interpret mode (only meaningful with use_pallas).
    compiled   — the numbers this substrate produces are compiled-mode truth;
                 False marks the explicit interpret test mode.
    """

    name: str
    use_pallas: bool
    interpret: bool
    compiled: bool


PALLAS = Substrate("pallas", use_pallas=True, interpret=False, compiled=True)
PALLAS_CPU = Substrate(
    "pallas_cpu", use_pallas=True, interpret=False, compiled=True
)
XLA = Substrate("xla", use_pallas=False, interpret=False, compiled=True)
INTERPRET = Substrate(
    "interpret", use_pallas=True, interpret=True, compiled=False
)


def _probe_compiled_pallas_cpu() -> bool:
    """Can this jaxlib compile a Pallas kernel for the CPU backend?

    Current jaxlib CPU lowering raises "Only interpret mode is supported on
    CPU backend" — but that is a jaxlib property, not a law; probe instead of
    assuming so a capable jaxlib is picked up automatically.

    The probe forces an explicit lower+compile rather than an eager call: the
    first `resolve(None)` may happen INSIDE a trace (a kernel wrapper under
    lax.cond/vmap), where an eager pallas_call would merely be traced — no
    lowering runs, no error fires, and an incapable jaxlib would be mistaken
    for a capable one and cached for the process.
    """
    try:
        from jax.experimental import pallas as pl

        def _k(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        x = jnp.ones((8, 128), jnp.float32)

        def _call(v):
            return pl.pallas_call(
                _k, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(v)

        jax.jit(_call).lower(x).compile()
        return True
    except Exception:
        return False


@functools.lru_cache(maxsize=1)
def best() -> Substrate:
    """The best compiled substrate on this process's default backend.

    TPU → compiled Pallas; CPU with a Pallas-capable jaxlib → compiled CPU
    Pallas; otherwise the compiled-XLA tier. Never resolves to interpret —
    interpret survives only as an explicit request.
    """
    platform = jax.default_backend()
    if platform == "tpu":
        return PALLAS
    if _probe_compiled_pallas_cpu():
        return PALLAS_CPU
    return XLA


def for_impl(impl: str) -> Substrate:
    """Map reuse_linear's `impl` string to a substrate.

    "jnp"              → compiled-XLA tier (pure-jnp semantics, as before)
    "pallas_interpret" → interpret-mode Pallas (EXPLICIT test mode)
    "pallas"           → best compiled substrate for this process — compiled
                         Pallas on TPU, compiled-XLA on a CPU-only host
                         (previously this silently interpreted).
    """
    if impl == "jnp":
        return XLA
    if impl == "pallas_interpret":
        return INTERPRET
    if impl == "pallas":
        return best()
    raise ValueError(f"unknown impl {impl!r}")


def resolve(interpret: bool | None) -> Substrate:
    """Resolve a kernel wrapper's `interpret` argument to a substrate.

    None  → best compiled substrate (the only default anywhere now)
    True  → interpret-mode Pallas (explicit test mode)
    False → best compiled Pallas; raises on a host with none rather than
            silently interpreting (the bug class this module deletes).
    """
    if interpret is None:
        return best()
    if interpret:
        return INTERPRET
    sub = best()
    if not sub.use_pallas:
        raise ValueError(
            "interpret=False requested but no compiled Pallas lowering exists "
            f"on backend {jax.default_backend()!r}; pass interpret=None to "
            "use the compiled-XLA tier or interpret=True for the explicit "
            "interpret test mode"
        )
    return sub


def tag(sub: Substrate | None = None) -> dict:
    """Provenance stamp for benchmark rows and latency-table entries."""
    if sub is None:
        sub = best()
    return {
        "backend": sub.name,
        "interpret": sub.interpret,
        "jax_version": jax.__version__,
        "jaxlib_version": _jaxlib_version(),
    }


def _jaxlib_version() -> str:
    try:
        import jaxlib

        return jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        return "unknown"


def describe() -> str:
    """One-line human summary (serve/bench startup logs)."""
    sub = best()
    return (
        f"backend={sub.name} interpret={sub.interpret} "
        f"platform={jax.default_backend()} jax={jax.__version__}"
    )
