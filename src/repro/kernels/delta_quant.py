"""Fused quantize + delta + tile-mask kernel (the delta-value-register analogue).

The paper's ReuseSensor computes deltas with generated `sub` instructions and
copies the result into an in-unit delta-value register that the generation
logic consults. On TPU the equivalent hot loop is a single memory-bound pass:

    read x (current activations, bf16/f32) and prev_q (int8 codes)
    -> cur_q = quantize(x)            (int8 codes, written back to the cache)
    -> delta = scale * (cur_q - prev_q)   (exact-zero where codes match)
    -> mask[m, k] = any(delta_tile != 0)  (one bit per (block_m × block_k) tile)

Fusing the three avoids two extra HBM round-trips of the activation tensor —
this is a beyond-paper optimization (the paper's engine gets it for free in
hardware; we must claim it explicitly).

The mask output is written as one int32 per grid step into a [gm, gk] array in
SMEM-addressable layout (block shape (1, 1)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(scale_ref, x_ref, prev_q_ref, q_ref, delta_ref, mask_ref):
    scale = scale_ref[0]
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / scale), -127, 127)
    dq = q.astype(jnp.int32) - prev_q_ref[...].astype(jnp.int32)
    q_ref[...] = q.astype(jnp.int8)
    delta_ref[...] = (dq.astype(jnp.float32) * scale).astype(delta_ref.dtype)
    mask_ref[0, 0] = jnp.any(dq != 0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "delta_dtype", "interpret")
)
def delta_quant(
    x: jax.Array,        # [M, K] float
    prev_q: jax.Array,   # [M, K] int8
    scale: jax.Array,    # scalar f32
    *,
    block_m: int = 128,
    block_k: int = 256,
    delta_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (cur_q int8 [M,K], delta [M,K] in delta_dtype, mask int32
    [gm,gk]). `delta_dtype` follows the weight dtype of the consuming GEMM:
    f32 weights need an f32 delta to keep the telescoping invariant exact."""
    m, k = x.shape
    assert m % block_m == 0 and k % block_k == 0, (x.shape, block_m, block_k)
    gm, gk = m // block_m, k // block_k
    scale_arr = jnp.reshape(scale.astype(jnp.float32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # scale
        grid=(gm, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ki, s: (mi, ki)),
            pl.BlockSpec((block_m, block_k), lambda mi, ki, s: (mi, ki)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ki, s: (mi, ki)),
            pl.BlockSpec((block_m, block_k), lambda mi, ki, s: (mi, ki)),
            pl.BlockSpec(
                (1, 1), lambda mi, ki, s: (mi, ki), memory_space=pltpu.SMEM
            ),
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, k), delta_dtype),
            jax.ShapeDtypeStruct((gm, gk), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
    )(scale_arr, x, prev_q)
