"""Public kernel API: padding, batch flattening, path dispatch.

Execution paths (per DESIGN.md §2; `ReuseSiteSpec.exec_path` selects one):
  "kernel"  — block-skip GEMM on the FULL (gm, gn, gk) grid: skipped tiles
              suppress the weight DMA and the MXU op but still cost a grid
              step. Compiled Pallas on TPU; the compiled-XLA masked lowering
              (kernels/xla_tier.py) where no Pallas lowering exists.
  "ragged"  — compacted-grid GEMM: the grid k-extent is a static budget
              `max_active_k` < gk; front-compacted indices walk only the
              ACTIVE tiles, so skipped tiles cost zero grid steps. Compiled
              Pallas scalar-prefetch on TPU; a `jnp.take` gather GEMM on the
              compiled-XLA tier. Runtime falls back to the full extent when a
              row's live count overflows the budget (correctness never
              depends on the policy's guess).
  "compact" — gather the nonzero K-blocks of Δ and the matching W row-blocks,
              dense GEMM on the compacted operands (MegaBlocks-style;
              beyond-paper). Pure jnp, shardable under pjit, and the path the
              CPU wall-clock benchmarks measure. With a static `max_blocks`
              budget the GEMM shape shrinks (same overflow fallback).
  "masked"  — branchless jnp.where software reuse (the paper's Sec.-III
              negative result: costs MORE than dense — kept as a benchmark).
  "dense"   — O_p-free ordinary GEMM (the "basic kernel" / reuse-OFF mode).
  "ref"     — oracle (tests only).

Substrate resolution (kernels/backend.py): every wrapper's `interpret`
parameter defaults to None = "best compiled substrate for this process",
resolved ONCE per process. `interpret=True` is the EXPLICIT interpret-mode
test path; `interpret=False` demands compiled Pallas and raises where none
exists. The old divergent defaults (ops.py said True, the kernel modules said
False) are gone — callers thread one explicit value or accept the resolved
compiled default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import compact_block_indices, compact_rows
from repro.kernels import backend as _backend
from repro.kernels import ref as _ref
from repro.kernels import xla_tier as _xla
from repro.kernels.delta_quant import delta_quant as delta_quant_kernel
from repro.kernels.reuse_matmul import reuse_matmul as _reuse_matmul_kernel
from repro.kernels.reuse_matmul import skip_sel, weight_dma_tiles
from repro.kernels.reuse_matmul_int8 import reuse_matmul_int8 as _reuse_matmul_int8
from repro.kernels.reuse_matmul_ragged import (
    reuse_matmul_ragged as _reuse_matmul_ragged_kernel,
)

__all__ = [
    "reuse_matmul",
    "reuse_matmul_ragged",
    "reuse_matmul_compact",
    "reuse_matmul_masked",
    "delta_quant_fused",
    "reuse_matmul_int8",
    "weight_dma_tiles",
    "ragged_dma_tiles",
    "ragged_grid_steps",
    "budget_overflow",
    "clamp_budget",
    "skip_sel",
    "compact_rows",
]


def clamp_budget(max_active_k: int | None, gk: int) -> int:
    """Static k-extent budget, clamped to [1, gk]. ONE definition shared by
    the executing wrappers and the grid-step accounting — the sensor's
    grid_steps counter is only honest while both see the same extent."""
    if max_active_k is None:
        return gk
    return max(1, min(int(max_active_k), gk))


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def reuse_matmul(
    delta: jax.Array,
    w: jax.Array,
    prev_out: jax.Array,
    block_mask: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    dataflow: str = "output",
    interpret: bool | None = None,
    sel: jax.Array | None = None,
) -> jax.Array:
    """Padded/validated entry to the block-skip GEMM (masked full grid)."""
    sub = _backend.resolve(interpret)
    m, n = prev_out.shape
    dp = _pad_to(delta, block_m, block_k)
    wp = _pad_to(w, block_k, block_n)
    pp = _pad_to(prev_out.astype(jnp.float32), block_m, block_n)
    gm, gk = dp.shape[0] // block_m, dp.shape[1] // block_k
    assert block_mask.shape == (gm, gk), (block_mask.shape, (gm, gk))
    if sub.use_pallas:
        out = _reuse_matmul_kernel(
            dp, wp, pp, block_mask,
            block_m=block_m, block_n=block_n, block_k=block_k,
            dataflow=dataflow, interpret=sub.interpret, sel=sel,
        )
    else:
        out = _xla.reuse_matmul_xla(
            dp, wp, pp, block_mask, block_m=block_m, block_k=block_k,
        )
    return out[:m, :n]


def reuse_matmul_int8(
    delta_q: jax.Array,
    w_q: jax.Array,
    prev_acc: jax.Array,
    block_mask: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    sub = _backend.resolve(interpret)
    m, n = prev_acc.shape
    dp = _pad_to(delta_q, block_m, block_k)
    wp = _pad_to(w_q, block_k, block_n)
    pp = _pad_to(prev_acc, block_m, block_n)
    if sub.use_pallas:
        out = _reuse_matmul_int8(
            dp, wp, pp, block_mask,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=sub.interpret,
        )
    else:
        out = _xla.reuse_matmul_int8_xla(
            dp, wp, pp, block_mask, block_m=block_m, block_k=block_k,
        )
    return out[:m, :n]


def reuse_matmul_ragged(
    delta: jax.Array,       # [M, K]
    w: jax.Array,           # [K, N]
    prev_out: jax.Array,    # [M, N]
    block_mask: jax.Array,  # [gm, gk] int32; 1 = compute tile
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    max_active_k: int | None = None,
    interpret: bool | None = None,
    compacted: tuple[jax.Array, jax.Array] | None = None,  # (idx, counts)
) -> jax.Array:
    """Padded entry to the ragged compacted-grid GEMM.

    `max_active_k` is the static k-extent budget (None = gk, i.e. no grid
    shrink but still compaction-ordered). When any row's live tile count
    overflows the budget, a `lax.cond` falls back to the full-extent grid —
    the budget is a performance hint from the policy, never a correctness
    contract. `compacted` lets the caller thread a precomputed
    `compact_rows(block_mask)` (reuse_linear shares it with the accounting).
    On the compiled-XLA substrate the compacted walk runs as the gather GEMM
    (xla_tier.reuse_matmul_ragged_xla) with the same budget/fallback shape.
    """
    sub = _backend.resolve(interpret)
    m, n = prev_out.shape
    dp = _pad_to(delta, block_m, block_k)
    wp = _pad_to(w, block_k, block_n)
    pp = _pad_to(prev_out.astype(jnp.float32), block_m, block_n)
    gm, gk = dp.shape[0] // block_m, dp.shape[1] // block_k
    assert block_mask.shape == (gm, gk), (block_mask.shape, (gm, gk))
    if compacted is None:
        idx, counts = compact_rows(block_mask)
    else:
        idx, counts = compacted
    kb = clamp_budget(max_active_k, gk)

    def run(n_k: int) -> jax.Array:
        if sub.use_pallas:
            return _reuse_matmul_ragged_kernel(
                dp, wp, pp, counts, idx[:, :n_k],
                block_m=block_m, block_n=block_n, block_k=block_k,
                interpret=sub.interpret,
            )
        return _xla.reuse_matmul_ragged_xla(
            dp, wp, pp, counts, idx[:, :n_k],
            block_m=block_m, block_n=block_n, block_k=block_k,
        )

    if kb >= gk:
        out = run(gk)
    else:
        out = jax.lax.cond(
            jnp.any(counts > kb), lambda: run(gk), lambda: run(kb)
        )
    return out[:m, :n]


def ragged_dma_tiles(counts: jax.Array, *, gn: int) -> jax.Array:
    """Measured weight-tile DMA count under the ragged kernel's semantics.

    Per (m, n) output panel the weight index walks the row's `count` active
    blocks (the compacted tail repeats the last id — no new copy); a
    fully-skipped row still holds one resident tile. Same (block_k × block_n)
    tile units as `weight_dma_tiles`.
    """
    return (jnp.sum(jnp.maximum(counts, 1)) * gn).astype(jnp.int32)


def ragged_grid_steps(
    counts: jax.Array, *, gm: int, gn: int, gk: int, max_active_k: int | None
) -> jax.Array:
    """Grid steps the ragged path actually executes (fallback-aware).

    The compacted grid runs gm·gn·kb steps; when any row overflows the budget
    the wrapper re-runs the full gm·gn·gk extent, and the accounting must say
    so — saved steps are counted like saved DMAs: only when truly elided.
    """
    kb = clamp_budget(max_active_k, gk)
    if kb >= gk:
        return jnp.asarray(gm * gn * gk, jnp.float32)
    return jnp.where(
        jnp.any(counts > kb), float(gm * gn * gk), float(gm * gn * kb)
    )


def budget_overflow(
    counts: jax.Array, *, gk: int, max_active_k: int | None
) -> jax.Array:
    """1 when an evaluation's live tile counts overflow the static budget —
    i.e. the compacted wrappers' `lax.cond` took the full-extent fallback —
    else 0. `counts` is the ragged per-row count vector or the compact path's
    scalar live-block count. Shares `clamp_budget` with the executing
    wrappers, so the sensor's `overflow_fallbacks` counter can only disagree
    with the branch actually taken if the wrappers themselves change."""
    kb = clamp_budget(max_active_k, gk)
    if kb >= gk:
        return jnp.zeros((), jnp.int32)
    return jnp.any(counts > kb).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_k", "max_blocks"))
def _compact_gemm(
    delta: jax.Array,
    w: jax.Array,
    prev_out: jax.Array,
    k_block_mask: jax.Array,
    *,
    block_k: int,
    max_blocks: int,
) -> jax.Array:
    mrows, k = delta.shape
    gk = k // block_k
    idx, count = compact_block_indices(k_block_mask)
    nb = max_blocks
    idx = idx[:nb]
    # Zero-weight blocks beyond `count` so the tail contributes nothing even
    # when it aliases a real block.
    valid = (jnp.arange(nb) < count).astype(delta.dtype)
    d_blocks = delta.reshape(mrows, gk, block_k).transpose(1, 0, 2)[idx]
    d_blocks = d_blocks * valid[:, None, None]
    w_blocks = w.reshape(gk, block_k, -1)[idx]
    # [nb, M, bk] × [nb, bk, N] — contract over (blocks, bk) at once.
    upd = jnp.einsum(
        "gmk,gkn->mn", d_blocks, w_blocks,
        preferred_element_type=jnp.float32,
    )
    return prev_out + upd


def reuse_matmul_compact(
    delta: jax.Array,       # [M, K]
    w: jax.Array,           # [K, N]
    prev_out: jax.Array,    # [M, N]
    k_block_mask: jax.Array,  # [gk] int32 — per-K-block "any row changed"
    *,
    block_k: int = 256,
    max_blocks: int | None = None,
) -> jax.Array:
    """Compaction path: gather nonzero K-blocks of Δ and W, dense GEMM.

    Shared-K masking (one mask bit per K-block across all rows) keeps the
    gather a clean 2-D slice gather that GSPMD shards on the N axis. With
    `max_blocks` static (< gk) the GEMM shape shrinks — the policy's
    compacted budget on CPU serving; a `lax.cond` falls back to the full
    extent whenever the live block count overflows the budget. K is padded
    to a block_k multiple (padding blocks carry zero deltas and an inactive
    mask bit, so they are never gathered).
    """
    kp = (-delta.shape[1]) % block_k
    if kp:
        # The caller's mask is already on the ceil(K/block_k) grid
        # (block_zero_mask pads virtually); only the operands need real pads.
        delta = jnp.pad(delta, ((0, 0), (0, kp)))
        w = jnp.pad(w, ((0, kp), (0, 0)))
    gk = delta.shape[1] // block_k
    assert k_block_mask.shape == (gk,), (k_block_mask.shape, gk)
    prev_out = prev_out.astype(jnp.float32)
    nb = clamp_budget(max_blocks, gk)

    def run(n_blocks: int) -> jax.Array:
        return _compact_gemm(delta, w, prev_out, k_block_mask,
                             block_k=block_k, max_blocks=n_blocks)

    if nb >= gk:
        return run(gk)
    count = jnp.sum((k_block_mask != 0).astype(jnp.int32))
    return jax.lax.cond(count > nb, lambda: run(gk), lambda: run(nb))


def reuse_matmul_masked(
    delta: jax.Array, w: jax.Array, prev_out: jax.Array
) -> jax.Array:
    """Software reuse, branchless: the Sec.-III negative result on TPU.

    Masks deltas with `where` but still issues the full GEMM — all the delta
    bookkeeping, none of the skipping. Benchmarked to show it is *slower*
    than the dense baseline, reproducing the paper's motivation.
    """
    d = jnp.where(delta != 0, delta, jnp.zeros_like(delta))
    return prev_out + jnp.dot(d, w, preferred_element_type=jnp.float32)


def delta_quant_fused(
    x: jax.Array,
    prev_q: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_k: int = 256,
    delta_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Padded entry to the fused delta/quant/mask pass."""
    sub = _backend.resolve(interpret)
    m, k = x.shape
    xp = _pad_to(x, block_m, block_k)
    pq = _pad_to(prev_q, block_m, block_k)
    if sub.use_pallas:
        q, delta, mask = delta_quant_kernel(
            xp, pq, scale, block_m=block_m, block_k=block_k,
            delta_dtype=delta_dtype, interpret=sub.interpret,
        )
    else:
        q, delta, mask = _xla.delta_quant_xla(
            xp, pq, scale, block_m=block_m, block_k=block_k,
            delta_dtype=delta_dtype,
        )
    return q[:m, :k], delta[:m, :k], mask


# Re-exported oracles so tests import one module.
reuse_matmul_ref = _ref.reuse_matmul_ref
reuse_matmul_int8_ref = _ref.reuse_matmul_int8_ref
delta_quant_ref = _ref.delta_quant_ref
