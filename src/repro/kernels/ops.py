"""Public kernel API: padding, batch flattening, path dispatch.

Paths (per DESIGN.md §2):
  "kernel"  — Pallas block-skip GEMM (structural skipping; TPU target,
              interpret=True on CPU).
  "compact" — gather the nonzero K-blocks of Δ and the matching W row-blocks,
              dense GEMM on the compacted operands (MegaBlocks-style;
              beyond-paper). Pure jnp, shardable under pjit, and the path the
              CPU wall-clock benchmarks measure.
  "masked"  — branchless jnp.where software reuse (the paper's Sec.-III
              negative result: costs MORE than dense — kept as a benchmark).
  "dense"   — O_p-free ordinary GEMM (the "basic kernel" / reuse-OFF mode).
  "ref"     — oracle (tests only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import compact_block_indices
from repro.kernels import ref as _ref
from repro.kernels.delta_quant import delta_quant as delta_quant_kernel
from repro.kernels.reuse_matmul import reuse_matmul as _reuse_matmul_kernel
from repro.kernels.reuse_matmul import weight_dma_tiles
from repro.kernels.reuse_matmul_int8 import reuse_matmul_int8 as _reuse_matmul_int8

__all__ = [
    "reuse_matmul",
    "reuse_matmul_compact",
    "reuse_matmul_masked",
    "delta_quant_fused",
    "reuse_matmul_int8",
    "weight_dma_tiles",
]


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def reuse_matmul(
    delta: jax.Array,
    w: jax.Array,
    prev_out: jax.Array,
    block_mask: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    dataflow: str = "output",
    interpret: bool = True,
) -> jax.Array:
    """Padded/validated entry to the Pallas block-skip kernel."""
    m, n = prev_out.shape
    dp = _pad_to(delta, block_m, block_k)
    wp = _pad_to(w, block_k, block_n)
    pp = _pad_to(prev_out.astype(jnp.float32), block_m, block_n)
    gm, gk = dp.shape[0] // block_m, dp.shape[1] // block_k
    assert block_mask.shape == (gm, gk), (block_mask.shape, (gm, gk))
    out = _reuse_matmul_kernel(
        dp, wp, pp, block_mask,
        block_m=block_m, block_n=block_n, block_k=block_k,
        dataflow=dataflow, interpret=interpret,
    )
    return out[:m, :n]


def reuse_matmul_int8(
    delta_q: jax.Array,
    w_q: jax.Array,
    prev_acc: jax.Array,
    block_mask: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    m, n = prev_acc.shape
    dp = _pad_to(delta_q, block_m, block_k)
    wp = _pad_to(w_q, block_k, block_n)
    pp = _pad_to(prev_acc, block_m, block_n)
    out = _reuse_matmul_int8(
        dp, wp, pp, block_mask,
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block_k", "max_blocks"))
def reuse_matmul_compact(
    delta: jax.Array,       # [M, K]
    w: jax.Array,           # [K, N]
    prev_out: jax.Array,    # [M, N]
    k_block_mask: jax.Array,  # [gk] int32 — per-K-block "any row changed"
    *,
    block_k: int = 256,
    max_blocks: int | None = None,
) -> jax.Array:
    """Compaction path: gather nonzero K-blocks of Δ and W, dense GEMM.

    Shared-K masking (one mask bit per K-block across all rows) keeps the
    gather a clean 2-D slice gather that GSPMD shards on the N axis. With
    `max_blocks` static (< gk) the GEMM shape shrinks — the static-shape
    budget mode used for the roofline study; by default all gk blocks are
    gathered (shape-stable, value-exact, savings appear as skipped DMAs only
    on real hardware via the kernel path).
    """
    mrows, k = delta.shape
    gk = k // block_k
    assert k % block_k == 0
    idx, count = compact_block_indices(k_block_mask)
    nb = max_blocks if max_blocks is not None else gk
    idx = idx[:nb]
    # Zero-weight blocks beyond `count` so the tail contributes nothing even
    # when it aliases a real block.
    valid = (jnp.arange(nb) < count).astype(delta.dtype)
    d_blocks = delta.reshape(mrows, gk, block_k).transpose(1, 0, 2)[idx]
    d_blocks = d_blocks * valid[:, None, None]
    w_blocks = w.reshape(gk, block_k, -1)[idx]
    # [nb, M, bk] × [nb, bk, N] — contract over (blocks, bk) at once.
    upd = jnp.einsum(
        "gmk,gkn->mn", d_blocks, w_blocks,
        preferred_element_type=jnp.float32,
    )
    return prev_out + upd


def reuse_matmul_masked(
    delta: jax.Array, w: jax.Array, prev_out: jax.Array
) -> jax.Array:
    """Software reuse, branchless: the Sec.-III negative result on TPU.

    Masks deltas with `where` but still issues the full GEMM — all the delta
    bookkeeping, none of the skipping. Benchmarked to show it is *slower*
    than the dense baseline, reproducing the paper's motivation.
    """
    d = jnp.where(delta != 0, delta, jnp.zeros_like(delta))
    return prev_out + jnp.dot(d, w, preferred_element_type=jnp.float32)


def delta_quant_fused(
    x: jax.Array,
    prev_q: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Padded entry to the fused delta/quant/mask kernel."""
    m, k = x.shape
    xp = _pad_to(x, block_m, block_k)
    pq = _pad_to(prev_q, block_m, block_k)
    q, delta, mask = delta_quant_kernel(
        xp, pq, scale, block_m=block_m, block_k=block_k, interpret=interpret
    )
    return q[:m, :k], delta[:m, :k], mask


# Re-exported oracles so tests import one module.
reuse_matmul_ref = _ref.reuse_matmul_ref
reuse_matmul_int8_ref = _ref.reuse_matmul_int8_ref
delta_quant_ref = _ref.delta_quant_ref
