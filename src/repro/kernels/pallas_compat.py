"""Version compatibility shims for `jax.experimental.pallas.tpu`.

`TPUCompilerParams` was renamed to `CompilerParams` upstream; support both so
the kernels import under the jax pinned in this image and under newer ones.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
