"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests).

The oracle for the block-skip ΔW GEMM applies the *mask semantics* explicitly:
tiles whose mask bit is 0 contribute nothing (the kernel never loads them).
When the mask is derived from the delta (its only legitimate producer), masked
tiles are all-zero anyway, so the oracle equals `prev_out + delta @ w` — the
property tests assert both facts independently.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expand_block_mask(
    block_mask: jax.Array, m: int, k: int, block_m: int, block_k: int
) -> jax.Array:
    """[gm, gk] tile mask -> [M, K] elementwise {0,1} float mask."""
    em = jnp.repeat(block_mask, block_m, axis=0)[:m]
    return jnp.repeat(em, block_k, axis=1)[:, :k].astype(jnp.float32)


def reuse_matmul_ref(
    delta: jax.Array,       # [M, K] float
    w: jax.Array,           # [K, N] float
    prev_out: jax.Array,    # [M, N] f32
    block_mask: jax.Array,  # [gm, gk] int32; 1 = compute tile
    block_m: int,
    block_k: int,
) -> jax.Array:
    """O_c = O_p + (Δ ⊙ mask) @ W with f32 accumulation."""
    m, k = delta.shape
    emask = expand_block_mask(block_mask, m, k, block_m, block_k)
    d = delta.astype(jnp.float32) * emask
    return prev_out + jax.lax.dot(d, w.astype(jnp.float32),
                                  precision=jax.lax.Precision.HIGHEST)


def reuse_matmul_int8_ref(
    delta_q: jax.Array,     # [M, K] int8
    w_q: jax.Array,         # [K, N] int8
    prev_acc: jax.Array,    # [M, N] int32
    block_mask: jax.Array,  # [gm, gk] int32
    block_m: int,
    block_k: int,
) -> jax.Array:
    """Int8 × int8 → int32 accumulate variant (the mla8 analogue)."""
    m, k = delta_q.shape
    emask = expand_block_mask(block_mask, m, k, block_m, block_k).astype(jnp.int32)
    d = delta_q.astype(jnp.int32) * emask
    return prev_acc + jax.lax.dot(d, w_q.astype(jnp.int32),
                                  preferred_element_type=jnp.int32)


def delta_quant_ref(
    x: jax.Array,        # [M, K] float
    prev_q: jax.Array,   # [M, K] int8
    scale: jax.Array,    # scalar f32
    block_m: int,
    block_k: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused quantize + delta + tile mask. Returns (cur_q, delta_bf16, mask)."""
    from repro.core.similarity import block_zero_mask

    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    cur_q = q.astype(jnp.int8)
    dq = cur_q.astype(jnp.int32) - prev_q.astype(jnp.int32)
    delta = (dq.astype(jnp.float32) * scale).astype(jnp.bfloat16)
    mask = block_zero_mask(dq, block_m, block_k)
    return cur_q, delta, mask
