"""Block-skip ΔW GEMM — the TPU translation of the ReuseSensor (paper Sec. IV).

The paper's hardware unit walks the kernel of Fig. 7-B and, when a delta is
zero, *does not emit* the weight load or the `mla8` op. On a TPU the analogous
levers are (a) the HBM→VMEM DMA of a weight tile and (b) the MXU issue for that
tile. This kernel skips both:

* a scalar-prefetched `sel` table drives the weight/delta `BlockSpec`
  index_maps: for a skipped (m, k) tile, `sel[m, k]` repeats the previously
  loaded block index, so the Pallas pipeline emits **no new copy** — the DMA
  that would have streamed that weight tile simply never happens (the paper's
  "skipping weight loads");
* `@pl.when(mask[m, k] != 0)` suppresses the MXU dot for that tile (the
  paper's "bypassing computations").

Grid/dataflow:

* `output` stationary (default; what ARMNN's sdot kernels use, Fig. 5): grid
  (gm, gn, gk), k innermost; a VMEM scratch accumulator is initialized from
  `prev_out` at k = 0 and written back at k = gk − 1. Skipped k-steps touch
  neither HBM nor the MXU.
* `input` stationary (the paper's 3DUnet analysis): grid (gm, gk, gn), the
  delta tile is resident while n sweeps; the output block is read-modified-
  written via input/output aliasing. More output traffic when N is large —
  exactly the regression the paper reports for 3DUnet — measured in
  benchmarks/dataflow.py.

Tile sizes default to MXU-aligned (block_k, block_n multiples of 128; block_m
multiples of 8). Correctness is validated in interpret mode against
`ref.reuse_matmul_ref` over shape/dtype/mask sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _skip_sel(block_mask: jax.Array) -> jax.Array:
    """sel[m, k] = index of the newest non-skipped k'-block with k' <= k.

    Repeating the previous index across skipped steps is what suppresses the
    DMA (Pallas only issues a copy when the block index changes). Cold prefix
    (no nonzero block yet) clamps to 0 — harmless: the compute is @pl.when-ed
    off, the tile is merely resident.
    """
    gm, gk = block_mask.shape
    ks = jnp.arange(gk, dtype=jnp.int32)[None, :]
    marked = jnp.where(block_mask != 0, ks, -1)
    sel = jax.lax.cummax(marked, axis=1)
    return jnp.maximum(sel, 0).astype(jnp.int32)


# Public alias: reuse_linear builds the table once per call and threads it
# into both the kernel launch and the DMA accounting.
skip_sel = _skip_sel


def weight_dma_tiles(
    block_mask: jax.Array,
    *,
    gn: int,
    dataflow: str = "output",
    sel: jax.Array | None = None,
) -> jax.Array:
    """Measured weight-tile DMA count under this kernel's sel semantics.

    The sensor subsystem's ground truth for "weight loads actually issued":
    Pallas emits a copy only when a BlockSpec index changes between grid
    steps, so the issue count is a property of the sel table, not of the
    mask alone (the cold prefix clamps to tile 0, which still costs one
    resident load per (m, n) panel).

    * output-stationary, grid (gm, gn, gk): per (m, n) panel the w index is
      (sel[m, k], n) — one load at k = 0 plus one per sel transition;
    * input-stationary, grid (gm, gk, gn): a computed (m, k) tile sweeps gn
      weight tiles; masked steps pin both coordinates (no copy issued).

    Cheap trace-side math on the [gm, gk] mask — used for accounting, never
    on the kernel's own critical path. When the caller already built the sel
    table for the kernel launch, pass it as `sel` to avoid recomputing it.
    """
    if sel is None:
        sel = _skip_sel(block_mask)
    if dataflow == "output":
        transitions = jnp.sum((sel[:, 1:] != sel[:, :-1]).astype(jnp.int32))
        rows = block_mask.shape[0]
        return (transitions + rows) * gn
    return jnp.sum((block_mask != 0).astype(jnp.int32)) * gn


def _kernel_output_stationary(
    mask_ref, sel_ref, delta_ref, w_ref, prev_ref, out_ref, acc_ref, *, n_k: int
):
    m = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = prev_ref[...].astype(jnp.float32)

    @pl.when(mask_ref[m, k] != 0)
    def _compute():
        acc_ref[...] += jnp.dot(
            delta_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _kernel_input_stationary(
    mask_ref, sel_ref, delta_ref, w_ref, prev_ref, out_ref, acc_ref,
    *, n_k: int, block_n: int,
):
    """Delta tile resident; the full output row-panel lives in VMEM scratch.

    Grid is (gm, gk, gn) — n innermost, so one delta tile serves gn weight
    tiles before moving on (input stationary). Output panel is initialized
    from prev_out during the k == 0 sweep and flushed on the last k sweep.
    """
    m = pl.program_id(0)
    k = pl.program_id(1)
    n = pl.program_id(2)
    nslice = pl.ds(n * block_n, block_n)

    @pl.when(k == 0)
    def _init():
        acc_ref[:, nslice] = prev_ref[...].astype(jnp.float32)

    @pl.when(mask_ref[m, k] != 0)
    def _compute():
        acc_ref[:, nslice] += jnp.dot(
            delta_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[:, nslice].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "dataflow", "interpret"),
)
def reuse_matmul(
    delta: jax.Array,       # [M, K] bf16/f32 — zero wherever codes matched
    w: jax.Array,           # [K, N]
    prev_out: jax.Array,    # [M, N] f32
    block_mask: jax.Array,  # [gm, gk] int32 (gm = M/block_m, gk = K/block_k)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    dataflow: str = "output",
    interpret: bool = False,
    sel: jax.Array | None = None,  # precomputed _skip_sel(block_mask)
) -> jax.Array:
    """O_c = O_p + Δ·W, skipping weight-tile DMAs and MXU ops for zero tiles."""
    m, k = delta.shape
    k2, n = w.shape
    assert k == k2, (delta.shape, w.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        "caller (ops.reuse_linear_kernel) pads to tile multiples",
        (m, k, n),
        (block_m, block_k, block_n),
    )
    gm, gk, gn = m // block_m, k // block_k, n // block_n
    assert block_mask.shape == (gm, gk), (block_mask.shape, (gm, gk))

    if sel is None:
        sel = _skip_sel(block_mask)

    if dataflow == "output":
        grid = (gm, gn, gk)

        def delta_map(mi, ni, ki, mask, sel):
            return (mi, sel[mi, ki])

        def w_map(mi, ni, ki, mask, sel):
            return (sel[mi, ki], ni)

        def prev_map(mi, ni, ki, mask, sel):
            return (mi, ni)

        def out_map(mi, ni, ki, mask, sel):
            return (mi, ni)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), delta_map),
                pl.BlockSpec((block_k, block_n), w_map),
                pl.BlockSpec((block_m, block_n), prev_map),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), out_map),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        )
        kernel = functools.partial(_kernel_output_stationary, n_k=gk)
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), prev_out.dtype),
            interpret=interpret,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
        )(block_mask, sel, delta, w, prev_out)

    elif dataflow == "input":
        grid = (gm, gk, gn)

        def delta_map(mi, ki, ni, mask, sel):
            return (mi, sel[mi, ki])

        def w_map(mi, ki, ni, mask, sel):
            # Freeze BOTH coordinates across a fully-masked k sweep so no
            # weight DMA is issued for skipped tiles (n pinned to the last
            # block fetched before entering the masked region).
            return (sel[mi, ki], jnp.where(mask[mi, ki] != 0, ni, gn - 1))

        def prev_map(mi, ki, ni, mask, sel):
            # prev_out is only consumed during the k == 0 sweep; freeze after.
            return (mi, jnp.where(ki == 0, ni, gn - 1))

        def out_map(mi, ki, ni, mask, sel):
            return (mi, ni)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), delta_map),
                pl.BlockSpec((block_k, block_n), w_map),
                pl.BlockSpec((block_m, block_n), prev_map),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), out_map),
            scratch_shapes=[pltpu.VMEM((block_m, n), jnp.float32)],
        )
        kernel = functools.partial(
            _kernel_input_stationary, n_k=gk, block_n=block_n
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), prev_out.dtype),
            interpret=interpret,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary"),
            ),
        )(block_mask, sel, delta, w, prev_out)

    raise ValueError(f"unknown dataflow {dataflow!r}")
