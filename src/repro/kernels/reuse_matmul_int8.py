"""Int8 block-skip ΔW GEMM — the `mla8` analogue (paper Sec. IV-A).

The paper extends ARM SVE `mla` to `mla8`: 8-bit multiplies accumulated into
32-bit destinations so quantized DNNs can exploit per-element skipping without
overflow. The MXU equivalent is an int8 × int8 → int32 matmul tile; overflow
of the *delta itself* (|q_c − q_p| > 127) is handled by the caller via the
paper's split trick (core.delta.delta_encode_int8) — the `hi` component is
routed through this same kernel and its near-empty mask makes it nearly free.

Structure mirrors reuse_matmul.py (output-stationary): scalar-prefetched `sel`
suppresses weight-tile DMAs, @pl.when suppresses MXU ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

from repro.kernels.reuse_matmul import _skip_sel


def _kernel(mask_ref, sel_ref, delta_ref, w_ref, prev_ref, out_ref, acc_ref, *, n_k: int):
    m = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = prev_ref[...]

    @pl.when(mask_ref[m, k] != 0)
    def _compute():
        acc_ref[...] += jnp.dot(
            delta_ref[...].astype(jnp.int32),
            w_ref[...].astype(jnp.int32),
            preferred_element_type=jnp.int32,
        )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def reuse_matmul_int8(
    delta_q: jax.Array,     # [M, K] int8 (lo or hi component)
    w_q: jax.Array,         # [K, N] int8
    prev_acc: jax.Array,    # [M, N] int32
    block_mask: jax.Array,  # [gm, gk] int32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, k = delta_q.shape
    _, n = w_q.shape
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    gm, gk, gn = m // block_m, k // block_k, n // block_n
    sel = _skip_sel(block_mask)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki, msk, sl: (mi, sl[mi, ki])),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki, msk, sl: (sl[mi, ki], ni)),
            pl.BlockSpec((block_m, block_n), lambda mi, ni, ki, msk, sl: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki, msk, sl: (mi, ni)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_k=gk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(block_mask, sel, delta_q, w_q, prev_acc)
