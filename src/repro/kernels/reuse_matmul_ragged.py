"""Ragged compacted-grid ΔW GEMM — skipped tiles cost ZERO grid steps.

The masked kernel (reuse_matmul.py) suppresses the weight DMA and the MXU op
for a skipped (m, k) tile, but the grid still *visits* the tile: every skipped
step burns a full pipeline slot walking `sel`/`mask`. At an 83 % skip rate the
sensor shows almost none of that as step time — the paper's unit wins because
skipped dot products never issue at all.

This kernel makes the grid itself ragged: the k-extent is a static budget
`max_active_k` (chosen by the policy from the measured skip rate) instead of
`gk`. Per m-row-block, scalar-prefetched front-compacted block indices
(`compact_block_indices`) and a per-row active count drive the delta/weight
index_maps, so grid step k touches the k-th *active* block:

    delta block  -> (m, idx[m, k])
    weight block -> (idx[m, k], n)
    @pl.when(k < count[m]) guards the tail (idx repeats the last valid id
    there, so the resident tiles are never re-fetched and never computed).

A row with count == 0 passes prev_out straight through. Rows can have
*different* counts — the grid is sized for the budget, the guard trims each
row to its own raggedness. Correctness for counts that overflow the budget is
handled by the `ops.reuse_matmul_ragged` wrapper (runtime fallback to the
full-extent grid), not here: this kernel assumes count[m] <= n_k or accepts
that overflowing rows compute only their first n_k active blocks.

Output-stationary only (grid (gm, gn, kb), k innermost): the compaction is
per m-row, which is exactly the output-stationary iteration; an
input-stationary sweep would re-gather per n and win nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(count_ref, idx_ref, delta_ref, w_ref, prev_ref, out_ref, acc_ref,
            *, n_k: int):
    m = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = prev_ref[...].astype(jnp.float32)

    @pl.when(k < count_ref[m])
    def _compute():
        acc_ref[...] += jnp.dot(
            delta_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def reuse_matmul_ragged(
    delta: jax.Array,       # [M, K] bf16/f32 — zero wherever codes matched
    w: jax.Array,           # [K, N]
    prev_out: jax.Array,    # [M, N] f32
    counts: jax.Array,      # [gm] int32 — active K-blocks per m-row-block
    idx: jax.Array,         # [gm, kb] int32 — front-compacted block indices
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """O_c = O_p + Δ·W over a compacted k-grid of extent kb = idx.shape[1]."""
    m, k = delta.shape
    k2, n = w.shape
    assert k == k2, (delta.shape, w.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        "caller (ops.reuse_matmul_ragged) pads to tile multiples",
        (m, k, n),
        (block_m, block_k, block_n),
    )
    gm, gn = m // block_m, n // block_n
    kb = idx.shape[1]
    assert 1 <= kb <= k // block_k, (kb, k // block_k)
    assert counts.shape == (gm,) and idx.shape == (gm, kb), (
        counts.shape, idx.shape, (gm, kb),
    )

    grid = (gm, gn, kb)

    def delta_map(mi, ni, ki, count, idx):
        return (mi, idx[mi, ki])

    def w_map(mi, ni, ki, count, idx):
        return (idx[mi, ki], ni)

    def prev_map(mi, ni, ki, count, idx):
        return (mi, ni)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), delta_map),
            pl.BlockSpec((block_k, block_n), w_map),
            pl.BlockSpec((block_m, block_n), prev_map),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), prev_map),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    kernel = functools.partial(_kernel, n_k=kb)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), prev_out.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(counts.astype(jnp.int32), idx.astype(jnp.int32), delta, w, prev_out)
