"""Fused WKV6 decode-step kernel — the rwkv6 long_500k serving hot-spot.

One autoregressive RWKV6 step per head is four elementwise passes over the
[dk, dv] state in naive jnp (outer product, bonus-add, readout, decay-update)
— memory-bound on the state, which at 4 reads+writes dominates the rwkv6
long-decode memory term. This kernel fuses the whole step into ONE
HBM→VMEM→HBM pass over the state:

    kv   = kᵀ v                       (outer product, in VMEM)
    out  = r · (diag(u)·kv + S)       (readout)
    S'   = diag(w)·S + kv             (decay update, written in place)

Grid: one program per (batch·head); the [dk, dv] state tile lives in VMEM.
Validated in interpret mode against the pure-jnp oracle (= the step body of
models/ssm.rwkv6_time_mix).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref, out_ref, s_new_ref):
    r = r_ref[0].astype(jnp.float32)        # [dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)        # [dv]
    w = w_ref[0].astype(jnp.float32)        # [dk]
    u = u_ref[0].astype(jnp.float32)        # [dk]
    s = s_ref[0].astype(jnp.float32)        # [dk, dv]

    kv = k[:, None] * v[None, :]            # [dk, dv]
    out = jnp.sum(r[:, None] * (u[:, None] * kv + s), axis=0)   # [dv]
    out_ref[0] = out.astype(out_ref.dtype)
    s_new_ref[0] = (w[:, None] * s + kv).astype(s_new_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_decode(
    r: jax.Array,   # [B, H, dk]
    k: jax.Array,   # [B, H, dk]
    v: jax.Array,   # [B, H, dv]
    w: jax.Array,   # [B, H, dk]   per-channel decay in (0, 1)
    u: jax.Array,   # [H, dk]      bonus
    state: jax.Array,  # [B, H, dk, dv] f32
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [B, H, dv], new_state [B, H, dk, dv])."""
    b, h, dk = r.shape
    dv = v.shape[-1]
    bh = b * h

    rf = r.reshape(bh, dk)
    kf = k.reshape(bh, dk)
    vf = v.reshape(bh, dv)
    wf = w.reshape(bh, dk)
    uf = jnp.broadcast_to(u[None], (b, h, dk)).reshape(bh, dk)
    sf = state.reshape(bh, dk, dv)

    vec = pl.BlockSpec((1, dk), lambda i: (i, 0))
    vecv = pl.BlockSpec((1, dv), lambda i: (i, 0))
    mat = pl.BlockSpec((1, dk, dv), lambda i: (i, 0, 0))

    out, s_new = pl.pallas_call(
        _kernel,
        grid=(bh,),
        in_specs=[vec, vec, vecv, vec, vec, mat],
        out_specs=[vecv, mat],
        out_shape=[
            jax.ShapeDtypeStruct((bh, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
    )(rf, kf, vf, wf, uf, sf)
    return out.reshape(b, h, dv), s_new.reshape(b, h, dk, dv)


def wkv6_decode_ref(r, k, v, w, u, state):
    """Pure-jnp oracle (identical math to models/ssm.rwkv6_time_mix's step)."""
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", rf,
                     u[None, :, :, None].astype(jnp.float32) * kv + state)
    s_new = wf[..., :, None] * state + kv
    return out, s_new
