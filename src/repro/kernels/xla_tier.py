"""Compiled-XLA tier: semantics-identical jnp lowerings of the reuse kernels.

On hosts whose jaxlib has no compiled Pallas lowering (today: every CPU-only
host — the CPU backend raises "Only interpret mode is supported"), interpret
mode was the silent fallback and ran 20-80x slower than a plain XLA GEMM,
poisoning every measured latency the policy consumed. This module lowers each
kernel's *algorithm* (not merely its answer) to jnp so XLA compiles it:

  reuse_matmul_xla        — masked full-grid semantics: skipped (m, k) tiles
                            contribute exactly zero (mask expanded and applied
                            to Δ before one dense f32 GEMM).
  reuse_matmul_ragged_xla — the scalar-prefetch compacted walk as a gather
                            GEMM: `jnp.take`/`take_along_axis` gather the
                            active Δ-blocks and their matching W row-blocks
                            per m-row (the DMA the Pallas index_maps express),
                            tail guarded by the same `j < count[m]` predicate
                            the kernel's @pl.when applies.
  reuse_matmul_int8_xla   — int8 × int8 → int32 masked accumulate.
  delta_quant_xla         — bitwise-identical quantize/delta/tile-mask math
                            (same clip/round/int32-subtract chain as the
                            Pallas kernel body).

Outputs are bitwise-exact vs the interpret-mode Pallas kernels whenever f32
accumulation order cannot matter (integer-valued operands — the parity suite
in tests/test_backend.py pins this) and allclose otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "reuse_matmul_xla",
    "reuse_matmul_ragged_xla",
    "reuse_matmul_int8_xla",
    "delta_quant_xla",
]


def _expand_mask(block_mask, m, k, block_m, block_k):
    em = jnp.repeat(block_mask, block_m, axis=0)[:m]
    return jnp.repeat(em, block_k, axis=1)[:, :k]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def reuse_matmul_xla(
    delta: jax.Array,       # [M, K] float, tile-multiple padded
    w: jax.Array,           # [K, N]
    prev_out: jax.Array,    # [M, N] f32
    block_mask: jax.Array,  # [gm, gk] int32; 1 = compute tile
    *,
    block_m: int,
    block_k: int,
) -> jax.Array:
    """Masked full-grid semantics: O_c = O_p + (Δ ⊙ mask) @ W, f32 accum."""
    m, k = delta.shape
    emask = _expand_mask(block_mask, m, k, block_m, block_k)
    d = delta.astype(jnp.float32) * emask.astype(jnp.float32)
    return prev_out + jax.lax.dot(
        d, w.astype(jnp.float32), precision=jax.lax.Precision.HIGHEST
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k")
)
def reuse_matmul_ragged_xla(
    delta: jax.Array,     # [M, K] float, tile-multiple padded
    w: jax.Array,         # [K, N]
    prev_out: jax.Array,  # [M, N] f32
    counts: jax.Array,    # [gm] int32 — active K-blocks per m-row-block
    idx: jax.Array,       # [gm, kb] int32 — front-compacted block indices
    *,
    block_m: int,
    block_n: int,
    block_k: int,
) -> jax.Array:
    """The ragged kernel's compacted walk as a compiled gather GEMM.

    Grid step (m, j) of the Pallas kernel reads Δ-block (m, idx[m, j]) and
    W-block (idx[m, j], n) under the guard j < count[m]; here the same gather
    is two vectorized takes and the guard is a validity mask on the gathered
    Δ, contracted in one einsum over (active block, block_k).
    """
    m, k = delta.shape
    n = w.shape[1]
    gm = m // block_m
    gk = k // block_k
    kb = idx.shape[1]
    assert counts.shape == (gm,) and idx.shape == (gm, kb), (
        counts.shape, idx.shape, (gm, kb),
    )
    # [gm, gk, bm, bk]: Δ as a grid of tiles, m-major like the kernel's grid.
    d_blk = delta.astype(jnp.float32).reshape(
        gm, block_m, gk, block_k
    ).transpose(0, 2, 1, 3)
    # Gather each row's active blocks: d_g[g, j] = d_blk[g, idx[g, j]].
    d_g = jnp.take_along_axis(d_blk, idx[:, :, None, None], axis=1)
    # Matching weight row-blocks: w_g[g, j] = W-block idx[g, j], shared N.
    w_g = jnp.take(w.astype(jnp.float32).reshape(gk, block_k, n), idx, axis=0)
    # @pl.when(j < count[m]): tail blocks (idx repeats the last valid id
    # there) must contribute nothing.
    valid = (jnp.arange(kb)[None, :] < counts[:, None]).astype(jnp.float32)
    d_g = d_g * valid[:, :, None, None]
    upd = jnp.einsum(
        "gjab,gjbn->gan", d_g, w_g,
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )
    out = prev_out.astype(jnp.float32).reshape(gm, block_m, n) + upd
    return out.reshape(m, n).astype(prev_out.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def reuse_matmul_int8_xla(
    delta_q: jax.Array,     # [M, K] int8
    w_q: jax.Array,         # [K, N] int8
    prev_acc: jax.Array,    # [M, N] int32
    block_mask: jax.Array,  # [gm, gk] int32
    *,
    block_m: int,
    block_k: int,
) -> jax.Array:
    """Int8 × int8 → int32 masked accumulate (exact in int32)."""
    m, k = delta_q.shape
    emask = _expand_mask(block_mask, m, k, block_m, block_k).astype(jnp.int32)
    d = delta_q.astype(jnp.int32) * emask
    return prev_acc + jax.lax.dot(
        d, w_q.astype(jnp.int32), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "delta_dtype")
)
def delta_quant_xla(
    x: jax.Array,        # [M, K] float, tile-multiple padded
    prev_q: jax.Array,   # [M, K] int8
    scale: jax.Array,    # scalar f32
    *,
    block_m: int,
    block_k: int,
    delta_dtype=jnp.bfloat16,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Same elementwise chain as the Pallas kernel body — bitwise identical.

    Returns (cur_q int8 [M,K], delta [M,K] delta_dtype, mask int32 [gm,gk]).
    """
    m, k = x.shape
    assert m % block_m == 0 and k % block_k == 0, (x.shape, block_m, block_k)
    gm, gk = m // block_m, k // block_k
    s = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    dq = q.astype(jnp.int32) - prev_q.astype(jnp.int32)
    cur_q = q.astype(jnp.int8)
    delta = (dq.astype(jnp.float32) * s).astype(delta_dtype)
    tiles = dq.reshape(gm, block_m, gk, block_k)
    mask = jnp.any(tiles != 0, axis=(1, 3)).astype(jnp.int32)
    return cur_q, delta, mask
