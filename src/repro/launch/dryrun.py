import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without hardware:
``jax.jit(step, in_shardings, out_shardings).lower(specs).compile()`` must
succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for every
runnable cell; ``memory_analysis()`` proves it fits, ``cost_analysis()`` +
the HLO collective parse feed §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh pod --out experiments/dryrun
Cells already present in --out are skipped (resumable).
"""

import argparse
import dataclasses
import json
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.dist import sharding
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.launch.specs import (
    SHAPES,
    cell_runnable,
    input_specs,
    state_specs_struct,
)
from repro.obs import trace as obs_trace
from repro.roofline.hlo_parse import parse_collective_bytes, summarize_cost


def _eval_shape_tree(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


def build_cell(arch: str, shape: str, mesh, *, reuse: bool = False,
               sharding_mode: str = "tp", remat_policy: str = "full",
               cfg_overrides: dict | None = None):
    """Returns (jitted_fn, arg_structs, in_shardings) for one cell."""
    from jax.sharding import PartitionSpec as P

    cfg = get_config(arch)
    if remat_policy != "full":
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = SHAPES[shape]
    ax = mesh_axes(mesh)
    dp = ax["dp_axes"]
    key = jax.random.PRNGKey(0)

    inputs = input_specs(cfg, cell)
    in_specs_batch = sharding.sanitize_specs(
        sharding.batch_specs(cfg, inputs, dp_axes=dp), inputs, mesh
    )

    if cell.kind == "train":
        from repro.optim.adamw import AdamWConfig
        from repro.train.train_step import init_train_state, make_train_step

        state = _eval_shape_tree(
            lambda: init_train_state(cfg, key)
        )
        fsdp_size = mesh.devices.size // (2 if "pod" in mesh.axis_names else 1)
        pspecs = sharding.sanitize_specs(
            sharding.param_specs(
                cfg, state["params"], model_size=ax["model_size"],
                mode=sharding_mode, fsdp_size=fsdp_size,
            ),
            state["params"], mesh,
        )
        state_specs = {
            "params": pspecs,
            "opt": sharding.opt_state_specs(pspecs),
        }
        step = make_train_step(cfg, AdamWConfig())
        fn = step
        args = (state, inputs)
        in_shardings = (state_specs, in_specs_batch)
        out_shardings = (state_specs, None)
    elif cell.kind == "prefill":
        from repro.models import init_params
        from repro.serve.serve_step import init_serve_state, prefill_step

        params = _eval_shape_tree(lambda: init_params(cfg, key))
        dstate = _eval_shape_tree(
            lambda: init_serve_state(cfg, cell.global_batch, cell.seq_len)
        )
        pspecs = sharding.sanitize_specs(
            sharding.param_specs(cfg, params, model_size=ax["model_size"]),
            params, mesh,
        )
        sspecs = sharding.sanitize_specs(
            sharding.decode_state_specs(
                cfg, dstate, dp_axes=dp, batch=cell.global_batch,
                data_size=ax["data_size"],
            ),
            dstate, mesh,
        )
        fn = lambda p, i, s: prefill_step(p, cfg, i, s)
        args = (params, inputs, dstate)
        in_shardings = (pspecs, in_specs_batch, sspecs)
        out_shardings = (None, sspecs)
    else:  # decode
        from repro.models import init_params
        from repro.serve.serve_step import (
            build_reuse_engine,
            decode_step,
            init_serve_state,
        )

        params = _eval_shape_tree(lambda: init_params(cfg, key))
        dstate = _eval_shape_tree(
            lambda: init_serve_state(cfg, cell.global_batch, cell.seq_len)
        )
        pspecs = sharding.sanitize_specs(
            sharding.param_specs(cfg, params, model_size=ax["model_size"]),
            params, mesh,
        )
        sspecs = sharding.sanitize_specs(
            sharding.decode_state_specs(
                cfg, dstate, dp_axes=dp, batch=cell.global_batch,
                data_size=ax["data_size"],
            ),
            dstate, mesh,
        )
        # decode begins with a full cache (the assigned decode shapes)
        dstate = dict(dstate)
        if reuse:
            engine = build_reuse_engine(cfg, impl="jnp")
            rcache = _eval_shape_tree(
                lambda: engine.init_cache(cell.global_batch)
            )
            rspecs = sharding.sanitize_specs(
                sharding.reuse_cache_specs(rcache, dp_axes=dp), rcache, mesh
            )
            fn = lambda p, t, s, rc: decode_step(
                p, cfg, t["tokens"], s, engine=engine, reuse_cache=rc
            )
            args = (params, inputs, dstate, rcache)
            in_shardings = (pspecs, in_specs_batch, sspecs, rspecs)
            out_shardings = (None, sspecs, rspecs)
        else:
            fn = lambda p, t, s: decode_step(p, cfg, t["tokens"], s)[:2]
            args = (params, inputs, dstate)
            in_shardings = (pspecs, in_specs_batch, sspecs)
            out_shardings = (None, sspecs)

    return fn, args, in_shardings, out_shardings


def build_pipeline_cell(arch: str, shape: str, mesh):
    """Extra multi-pod demonstration: GPipe over the pod axis composed with
    TP/DP (partial-auto shard_map), lowered as a full loss+grad step."""
    from repro.dist.pipeline import pipeline_train_loss
    from repro.models import init_params

    cfg = get_config(arch)
    cell = SHAPES[shape]
    ax = mesh_axes(mesh)
    key = jax.random.PRNGKey(0)
    inputs = input_specs(cfg, cell)
    params = _eval_shape_tree(lambda: init_params(cfg, key))
    pspecs = sharding.sanitize_specs(
        sharding.param_specs(cfg, params, model_size=ax["model_size"]),
        params, mesh,
    )
    # stage-shard the stacked superblocks on "pod" (dim 0)
    from jax.sharding import PartitionSpec as P

    def stage_spec(spec, leaf):
        rest = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        return P("pod", *rest[1:])

    pspecs = dict(pspecs)
    pspecs["blocks"] = jax.tree.map(
        stage_spec, pspecs["blocks"], params["blocks"],
        is_leaf=lambda x: isinstance(x, P),
    )
    in_specs_batch = sharding.sanitize_specs(
        sharding.batch_specs(cfg, inputs, dp_axes=("data",)), inputs, mesh
    )

    def fn(p, batch):
        return jax.value_and_grad(
            lambda pp: pipeline_train_loss(cfg, pp, batch, n_micro=8, mesh=mesh)
        )(p)

    return fn, (params, inputs), (pspecs, in_specs_batch), None


def run_cell(arch: str, shape: str, mesh_kind: str, *, reuse: bool = False,
             pipeline: bool = False, sharding_mode: str = "tp",
             remat_policy: str = "full",
             cfg_overrides: dict | None = None) -> dict:
    record = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "reuse": reuse,
        "pipeline": pipeline,
        "sharding": sharding_mode,
        "status": "unknown",
    }
    ok, why = cell_runnable(arch, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        return record

    t0 = obs_trace.now()  # perf_counter: lower/compile timings are intervals
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    try:
        if pipeline:
            fn, args, in_sh, out_sh = build_pipeline_cell(arch, shape, mesh)
        else:
            fn, args, in_sh, out_sh = build_cell(
                arch, shape, mesh, reuse=reuse, sharding_mode=sharding_mode,
                remat_policy=remat_policy, cfg_overrides=cfg_overrides)
        with mesh:
            from jax.sharding import NamedSharding

            to_ns = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                tree,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )
            jitted = jax.jit(
                fn,
                in_shardings=to_ns(in_sh),
                out_shardings=(
                    None if out_sh is None
                    else tuple(
                        None if o is None else to_ns(o) for o in out_sh
                    )
                ),
            )
            lowered = jitted.lower(*args)
            t_lower = obs_trace.now() - t0
            compiled = lowered.compile()
            t_compile = obs_trace.now() - t0 - t_lower

            try:
                mem = compiled.memory_analysis()
                record["memory_analysis"] = {
                    k: int(getattr(mem, k))
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes",
                    )
                    if hasattr(mem, k)
                } if mem is not None else None
            except Exception as e:  # CPU backend may not implement it
                record["memory_analysis"] = f"unavailable: {e}"

            try:
                cost = compiled.cost_analysis()
                record["cost_analysis"] = summarize_cost(cost)
            except Exception as e:
                record["cost_analysis"] = f"unavailable: {e}"

            try:
                hlo = compiled.as_text()
                record["collectives"] = parse_collective_bytes(hlo)
                record["hlo_bytes"] = len(hlo)
            except Exception as e:
                record["collectives"] = f"unavailable: {e}"

        record.update(
            status="ok",
            lower_seconds=round(t_lower, 2),
            compile_seconds=round(t_compile, 2),
            n_devices=mesh.devices.size,
        )
    except Exception as e:
        record.update(
            status="failed",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--reuse", action="store_true",
                    help="decode cells: thread the ReuseSense cache (technique mode)")
    ap.add_argument("--pipeline", action="store_true",
                    help="extra cell: GPipe over the pod axis (multipod only)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--kv-pad", type=int, default=0,
                    help="kv_head_pad_to override (§Perf: shard KV heads)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache override (§Perf)")
    ap.add_argument("--tag", default="",
                    help="suffix for perf-iteration records (§Perf)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out) / args.mesh
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        tag = (f"{arch}__{shape}" + ("__reuse" if args.reuse else "")
               + ("__pipeline" if args.pipeline else "")
               + (f"__{args.tag}" if args.tag else ""))
        path = outdir / f"{tag}.json"
        if path.exists() and not args.force:
            print(f"[skip-existing] {tag}")
            continue
        print(f"[run] {tag} on {args.mesh} ...", flush=True)
        overrides = {}
        if args.kv_pad:
            overrides["kv_head_pad_to"] = args.kv_pad
        if args.kv_quant:
            overrides["kv_cache_quant"] = True
        rec = run_cell(arch, shape, args.mesh, reuse=args.reuse,
                       pipeline=args.pipeline, sharding_mode=args.sharding,
                       remat_policy=args.remat,
                       cfg_overrides=overrides or None)
        path.write_text(json.dumps(rec, indent=2))
        print(
            f"[done] {tag}: {rec['status']} "
            f"(compile {rec.get('compile_seconds', '-')}s)",
            flush=True,
        )


if __name__ == "__main__":
    main()
