"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod prepends a 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int, model_size: int | None = None):
    """Small mocked mesh over host devices (tests/CI): ("data", "model") with
    the model axis `model_size` wide (default: every device on the model
    axis — the sharded-serving test shape).

    Host devices come from `XLA_FLAGS=--xla_force_host_platform_device_count=N`
    (set BEFORE jax initializes); validate up front with actionable errors
    instead of letting jax.make_mesh fail on an opaque reshape.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if model_size is None:
        model_size = n_devices
    if model_size < 1 or n_devices % model_size:
        raise ValueError(
            f"model_size={model_size} must divide n_devices={n_devices} "
            f"(mesh shape is (data={n_devices}//{model_size}, "
            f"model={model_size}))"
        )
    avail = jax.device_count()
    if avail < n_devices:
        raise RuntimeError(
            f"mesh wants {n_devices} devices but only {avail} are visible — "
            f"mock host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices} "
            f"(must be set before jax initializes)"
        )
    return jax.make_mesh(
        (n_devices // model_size, model_size), ("data", "model")
    )


def parse_mesh_spec(spec: str):
    """Mesh from a CLI spec string.

    "host:N"    — N mocked host devices, all on the model axis
    "host:N@S"  — N host devices, model axis S wide (data axis N/S)
    "prod"      — the fixed 16x16 production pod
    "prod-pod"  — 2x16x16 multi-pod
    """
    s = spec.strip().lower()
    if s == "prod":
        return make_production_mesh()
    if s in ("prod-pod", "prod:pod"):
        return make_production_mesh(multi_pod=True)
    if s.startswith("host:"):
        body = s[len("host:"):]
        model: int | None = None
        if "@" in body:
            body, model_s = body.split("@", 1)
            try:
                model = int(model_s)
            except ValueError:
                raise ValueError(
                    f"bad mesh spec {spec!r}: model size {model_s!r} is not "
                    "an integer") from None
        try:
            n = int(body)
        except ValueError:
            raise ValueError(
                f"bad mesh spec {spec!r}: device count {body!r} is not an "
                "integer") from None
        return make_host_mesh(n, model)
    raise ValueError(
        f"unknown mesh spec {spec!r} — expected 'host:N', 'host:N@S', "
        "'prod', or 'prod-pod'"
    )


def mesh_axes(mesh) -> dict:
    """Role map for the sharding rules."""
    names = mesh.axis_names
    dp_axes = tuple(a for a in names if a in ("pod", "data"))
    return {
        "dp_axes": dp_axes,
        "data_size": math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1,
        "model_axis": "model",
        "model_size": mesh.shape["model"],
    }
