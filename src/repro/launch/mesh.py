"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod prepends a 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> dict:
    """Role map for the sharding rules."""
    names = mesh.axis_names
    dp_axes = tuple(a for a in names if a in ("pod", "data"))
    return {
        "dp_axes": dp_axes,
        "data_size": math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1,
        "model_axis": "model",
        "model_size": mesh.shape["model"],
    }
