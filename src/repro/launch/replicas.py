"""In-process N-replica serving harness — the fleet plane's test substrate.

    PYTHONPATH=src python -m repro.launch.replicas --arch qwen3-32b --reduced \
        --replicas 2 --out /tmp/fleet --inject poison-sim:at_step=24

Runs N *independent* serving replicas in one process: each replica owns its
engine, reuse cache, serving state, continuous batcher, control plane
(controller + admission predictor + quarantine breaker), decision journal,
metrics registry, and obs dir — exactly the per-process state a real fleet
member owns — while sharing the (read-only) model parameters. The driver
interleaves them round-robin via `ContinuousBatcher.step_once`, wrapping
every replica turn in `events.context(run=..., replica=...)` so each row in
each stream carries its (run, replica) join keys, and drains the span buffer
after each turn so span attribution follows the same boundary.

Each replica gets a DISTINCT session mix (replica i cycles `2 + i` session
identities), so admission predictors learn different traffic and the fleet
view has real variance to show. `--inject` arms one replica (default: the
last) with a deterministic fault from `repro.guard.inject` — the chaos case
the SLO watcher must attribute to THAT replica and no other.

While the replicas run, a `FleetAggregator` tails all the obs dirs live
(the same code path an out-of-process aggregator would use) and an
`SLOWatcher` evaluates after every poll. Outputs under `--out`:

    replica-<id>/{sensor,journal,spans,metrics}.jsonl + metrics.prom
    fleet_report.json    per-replica + fleet rollup (obs.fleet schema)
    alerts.jsonl         SLO alert rows (journal-style)
    fleet.prom           fleet_* gauges + fleet_alerts_total counters

This harness is the scaffold the PR-10 router will place sessions onto: the
`ReplicaHealth` it surfaces per replica is the placement signal set the
ROADMAP assigns the router.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import events, trace as obs_trace
from repro.obs.metrics import (
    MetricsRegistry,
    observe_control_report,
    observe_guard_report,
    observe_sensor_report,
    observe_spans,
)

from repro.configs import get_config
from repro.serve.scheduler import ContinuousBatcher, Request, reset_slot
from repro.serve.serve_step import (
    build_reuse_engine,
    decode_step,
    greedy_sample,
    init_serve_state,
    prefill_step,
)
from repro.models import init_params


class Replica:
    """One serving replica's full per-process state, obs dir included."""

    def __init__(self, name: str, cfg, params, args, fleet_dir: str, *,
                 injector=None, seed: int = 0):
        from repro.control import AdmissionPredictor, ControlConfig, Controller
        from repro.control.report import DecisionJournal
        from repro.guard import QuarantineBreaker

        self.name = name
        self.cfg = cfg
        self.params = params
        self.injector = injector
        self.run = events.new_run_id()
        self.obs_dir = os.path.join(fleet_dir, f"replica-{name}")
        os.makedirs(self.obs_dir, exist_ok=True)
        self.sensor_path = os.path.join(self.obs_dir, "sensor.jsonl")
        self.spans_path = os.path.join(self.obs_dir, "spans.jsonl")
        self.metrics_path = os.path.join(self.obs_dir, "metrics.jsonl")

        self.engine = build_reuse_engine(cfg, impl="jnp")
        self.registry = MetricsRegistry()
        self.journal = DecisionJournal(
            os.path.join(self.obs_dir, "journal.jsonl"))
        self.predictor = AdmissionPredictor()
        self.breaker = QuarantineBreaker()
        self.controller = Controller(
            ControlConfig(), admission=self.predictor, journal=self.journal,
            guard=self.breaker)
        self.sstate = {
            "state": init_serve_state(cfg, args.batch_slots, args.cache_len),
            "rcache": self.engine.init_cache(args.batch_slots),
        }
        self.all_spans: list[dict[str, Any]] = []
        self._decode_variants: dict[tuple, Any] = {}
        self._decode_jit = self._jit_decode_factory()
        self._control_every = args.control_every
        # repeat traffic: every stream in this replica loops one token (a
        # distinct one per replica), so consecutive decode steps feed
        # near-identical activations — the paper's sticky-session reuse case,
        # and the steady skip baseline the SLO watcher judges collapses
        # against. random traffic exercises the no-reuse extreme instead.
        self.sticky_token = 7 + 4 * seed if args.traffic == "repeat" else None
        self.batcher = self._build_batcher(args)
        rng = np.random.default_rng(seed)
        for i in range(args.requests):
            if self.sticky_token is not None:
                prompt = np.full((args.prompt_len,), self.sticky_token,
                                 dtype=np.int32)
            else:
                prompt = rng.integers(0, cfg.vocab, size=(args.prompt_len,),
                                      dtype=np.int32)
            self.batcher.submit(Request(
                rid=i,
                prompt=prompt,
                max_new_tokens=args.max_new,
                # distinct session mix per replica: replica i cycles 2+i
                # session identities, so admission predictors diverge
                session=f"sess-{i % self._n_sessions}",
            ))

    @property
    def _n_sessions(self) -> int:
        return 2 + int(self.name.lstrip("r") or 0) \
            if self.name.startswith("r") else 2

    # ------------------------------------------------------------ jit plumbing
    def _spec_signature(self) -> tuple:
        return tuple(sorted(self.engine.sites.items()))

    def _jit_decode_factory(self):
        # same variant memoisation + donation as launch/serve.py: compiled
        # executables are keyed by the sites' full spec signature, and the
        # serving state + reuse cache are donated through the step
        key = self._spec_signature()
        fn = self._decode_variants.get(key)
        if fn is None:
            engine, cfg = self.engine, self.cfg

            @functools.partial(jax.jit, donate_argnums=(2, 3))
            def _step(p, toks, st, rc):
                return decode_step(p, cfg, toks, st, engine=engine,
                                   reuse_cache=rc)
            self._decode_variants[key] = fn = _step
        return fn

    # --------------------------------------------------------- batcher wiring
    def _build_batcher(self, args) -> ContinuousBatcher:
        from repro.sensor.aggregate import slot_telemetry

        cfg, params = self.cfg, self.params

        @jax.jit
        def jit_prefill(p, toks, st):
            return prefill_step(p, cfg, toks, st)

        def prefill_fn(prompt, slot):
            full = jnp.zeros((args.batch_slots, prompt.shape[1]), jnp.int32)
            full = full.at[slot].set(jnp.asarray(prompt[0]))
            logits, new_state = jit_prefill(
                params, full, self.sstate["state"])
            self.sstate["state"] = new_state
            self.sstate["rcache"] = reset_slot(self.sstate["rcache"], slot)
            return int(greedy_sample(logits[slot: slot + 1, -1:])[0, 0])

        def decode_fn(tokens):
            if self.injector is not None:
                self.injector.maybe_stall(self.batcher.stats["steps"] + 1)
            logits, new_state, new_rcache = self._decode_jit(
                params, jnp.asarray(tokens), self.sstate["state"],
                self.sstate["rcache"])
            self.sstate["state"] = new_state
            self.sstate["rcache"] = new_rcache
            out = np.asarray(greedy_sample(logits[:, -1:]))[:, :, 0] \
                if logits.ndim == 4 else np.asarray(greedy_sample(logits))
            if self.sticky_token is not None:
                # teacher-force the loop token: full decode compute ran (and
                # synced — `out` forced the device round trip), only the
                # emitted token is pinned so the stream keeps repeating
                out = np.full_like(out, self.sticky_token)
            return out

        def telemetry_fn(slot):
            t = slot_telemetry(self.engine, self.sstate["rcache"], slot)
            if self.injector is not None:
                t = self.injector.on_telemetry(
                    t, self.batcher.stats["steps"])
            return t

        def on_retire(req):
            self.predictor.observe_retirement(req)
            self.sstate["rcache"] = reset_slot(
                self.sstate["rcache"], req.slot, admission=self.predictor)

        def on_step(step_idx):
            if self.injector is not None:
                n_fired = len(self.injector.fired)
                self.sstate["rcache"] = self.injector.on_cache_update(
                    self.sstate["rcache"], step_idx)
                if len(self.injector.fired) > n_fired:
                    print(f"[{self.name}] inject @step {step_idx}: "
                          f"{self.injector.fired[-1]['detail']}")
            if step_idx % self._control_every == 0:
                with events.context(window=step_idx):
                    rep = self.controller.step(
                        self.engine, self.sstate["rcache"], step=step_idx)
                    observe_control_report(self.registry, rep)
                    if self.controller.last_guard_report is not None:
                        observe_guard_report(
                            self.registry, self.controller.last_guard_report)
                    # one cumulative sensor snapshot per control window —
                    # the fleet plane's windowed-skip stream
                    self.engine.sensor_report(
                        self.sstate["rcache"]).write_jsonl(self.sensor_path)
                if rep.changed:
                    self._decode_jit = self._jit_decode_factory()

        return ContinuousBatcher(
            batch_slots=args.batch_slots,
            prefill_fn=prefill_fn,
            decode_fn=decode_fn,
            max_steps=args.requests * args.max_new + 8,
            telemetry_fn=telemetry_fn,
            on_retire=on_retire,
            slot_sim_fn=self.predictor.slot_affinity,
            on_step=on_step,
            predict_sim_fn=self.predictor.predict,
            on_place=self.predictor.on_placed,
        )

    # ---------------------------------------------------------------- driving
    def turn(self) -> bool:
        """One interleaved scheduling turn, correlation-scoped to this
        replica; spans close inside the turn, so draining the (module-global)
        buffer here attributes them to the right replica."""
        if not self.batcher.pending:
            return False
        with events.context(run=self.run, replica=self.name):
            alive = self.batcher.step_once()
        drained = obs_trace.drain_spans()
        if drained:
            self.all_spans.extend(drained)
            with open(self.spans_path, "a") as f:
                for row in drained:
                    f.write(json.dumps(row) + "\n")
        return alive

    def finalize(self) -> None:
        """End-of-run emission, stamped with this replica's identity."""
        from repro.obs.export import write_jsonl, write_prometheus

        with events.context(run=self.run, replica=self.name):
            report = self.engine.sensor_report(self.sstate["rcache"])
            report.write_jsonl(self.sensor_path)
            observe_sensor_report(self.registry, report)
            observe_spans(self.registry, self.all_spans)
            write_prometheus(
                os.path.join(self.obs_dir, "metrics.prom"), self.registry)
            write_jsonl(self.metrics_path, self.registry)
        print(f"[{self.name}] run={self.run} "
              f"served={len(self.batcher.completed)} "
              f"steps={self.batcher.stats['steps']} "
              f"trips={self.breaker.total_trips} "
              f"quarantined={self.breaker.quarantined_lanes()}")


def main() -> None:
    from repro.obs.fleet import (
        FleetAggregator,
        export_fleet_metrics,
    )
    from repro.obs.slo import SLOConfig, SLOWatcher
    from repro.obs.stream import ReplicaStream

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6,
                    help="requests submitted PER replica")
    ap.add_argument("--batch-slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--control-every", type=int, default=6,
                    help="control-plane (and sensor-window) cadence in "
                    "decode steps, per replica")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--traffic", choices=("repeat", "random"),
                    default="repeat",
                    help="repeat: sticky streams looping one token per "
                    "replica (steady reuse, the skip baseline SLO collapse "
                    "is judged against); random: uncorrelated tokens "
                    "(the no-reuse extreme)")
    ap.add_argument("--out", required=True,
                    help="fleet dir: replica obs subdirs + fleet artifacts")
    ap.add_argument("--inject", default=None, metavar="SCENARIO[:k=v,...]",
                    help="arm a repro.guard.inject scenario on ONE replica "
                    "(see --inject-replica)")
    ap.add_argument("--inject-replica", type=int, default=None,
                    help="replica index to arm --inject on (default: last)")
    ap.add_argument("--slo-collapse-frac", type=float, default=0.6)
    ap.add_argument("--slo-consecutive", type=int, default=2)
    ap.add_argument("--slo-min-baseline", type=float, default=0.05)
    ap.add_argument("--slo-p95-target", type=float, default=None)
    ap.add_argument("--baseline-windows", type=int, default=3)
    args = ap.parse_args()

    if args.inject_replica is not None and not args.inject:
        ap.error("--inject-replica requires --inject")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.family != "audio", "encoder archs have no decode path"

    obs_trace.enable()
    os.makedirs(args.out, exist_ok=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    inject_idx = None
    if args.inject:
        inject_idx = (args.replicas - 1 if args.inject_replica is None
                      else args.inject_replica)
        if not 0 <= inject_idx < args.replicas:
            ap.error(f"--inject-replica {inject_idx} out of range "
                     f"for --replicas {args.replicas}")

    replicas: list[Replica] = []
    for i in range(args.replicas):
        injector = None
        if inject_idx == i:
            from repro.guard import FaultInjector

            injector = FaultInjector.from_spec(args.inject)
            print(f"[r{i}] fault injection armed: {injector.scenario} "
                  f"{injector.params}")
        replicas.append(Replica(
            f"r{i}", cfg, params, args, args.out,
            injector=injector, seed=args.seed + i))
    print(f"fleet: {args.replicas} replicas, "
          + ", ".join(f"{r.name}=run:{r.run}" for r in replicas))

    # live fleet plane: tail the obs dirs the replicas are writing, exactly
    # as an out-of-process aggregator would
    fleet_registry = MetricsRegistry()
    agg = FleetAggregator(
        [ReplicaStream(r.obs_dir, replica=r.name) for r in replicas],
        baseline_windows=args.baseline_windows)
    watcher = SLOWatcher(
        agg,
        SLOConfig(
            collapse_frac=args.slo_collapse_frac,
            collapse_consecutive=args.slo_consecutive,
            min_baseline_skip=args.slo_min_baseline,
            p95_target_s=args.slo_p95_target,
        ),
        registry=fleet_registry,
        alerts_path=os.path.join(args.out, "alerts.jsonl"),
    )

    t0 = obs_trace.now()
    max_turns = args.requests * args.max_new + 16
    for turn in range(max_turns):
        alive = False
        for rep in replicas:
            alive = rep.turn() or alive
        if turn % args.control_every == 0 or not alive:
            agg.poll()
            for alert in watcher.evaluate():
                print(f"SLO alert: {alert['alert_kind']} "
                      f"replica={alert['replica']} site={alert['site'] or '-'}"
                      f" {alert['detail']}")
        if not alive:
            break
    dt = obs_trace.now() - t0

    for rep in replicas:
        rep.finalize()

    # final drain: pick up the end-of-run sensor/metrics rows just written
    agg.poll(final=True)
    for alert in watcher.evaluate():
        print(f"SLO alert: {alert['alert_kind']} replica={alert['replica']} "
              f"site={alert['site'] or '-'} {alert['detail']}")
    export_fleet_metrics(fleet_registry, agg)

    from repro.obs.export import write_prometheus

    report = agg.fleet_report()
    report_path = os.path.join(args.out, "fleet_report.json")
    with open(report_path, "w") as f:
        json.dump(report, f, indent=2)
    n_prom = write_prometheus(
        os.path.join(args.out, "fleet.prom"), fleet_registry)
    print("\n".join(agg.summary_lines()))
    print(f"fleet artifacts -> {args.out} (fleet_report.json, alerts.jsonl "
          f"{len(watcher.alerts)} alerts, fleet.prom {n_prom} lines) "
          f"in {dt:.2f}s")


if __name__ == "__main__":
    main()
