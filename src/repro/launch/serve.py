"""Serving driver CLI: continuous batching + ReuseSense decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --requests 8 --batch-slots 4 --max-new 24 --reuse

Runs the full serving stack at reduced scale: prefill into slot lanes, shared
decode step with the reuse engine threaded, per-site similarity stats printed
at the end (the live analogue of paper Fig. 12's per-layer similarity).

Observability (`repro.obs`): `--obs` turns on span tracing + metrics for the
run; `--obs-dir OUT` additionally exports `metrics.prom` (Prometheus
textfile), `metrics.jsonl` (snapshots for `python -m repro.obs.top`),
`spans.jsonl`, and `latency_table.json` — the measured per-(site, layer,
exec_path) dispatch latencies, probed at the run's measured skip rates. Feed
that table back with `--latency-table` (or to `repro.tune.fit
--latency-table`) and break-even/exec decisions are priced from measured
wall-clock instead of cost-model constants. `--profile-dir` opens a
`jax.profiler` device-trace window around the serve loop; the obs spans'
TraceAnnotations line up host spans with device slices.

Fault containment (`repro.guard`): with `--control-every` the controller
carries a QuarantineBreaker — array sentinels ride the ctrl snapshot, tripped
lanes are pinned to basic/dense and scrubbed, transitions land in the
decision journal as `kind="quarantine"` rows. `--inject <scenario[:k=v,...]>`
arms a deterministic fault (see `repro.guard.inject.SCENARIOS`: poison-nan,
poison-sim, ctrl-garbage, poison-counters, lying-telemetry, torn-journal,
corrupt-ckpt, stall) at the real seams, so a chaos run exercises the exact
production wiring. Each decode step is timed; the straggler watchdog feeds
stall events into the same breaker.
"""

from __future__ import annotations

import argparse
import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import events, trace as obs_trace

from repro.configs import get_config
from repro.core.reuse_cache import cache_bytes
from repro.serve.scheduler import ContinuousBatcher, Request, reset_slot
from repro.serve.serve_step import (
    build_reuse_engine,
    decode_step,
    greedy_sample,
    init_serve_state,
    prefill_step,
)
from repro.models import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reuse", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sensor-jsonl", default=None,
                    help="append the final SensorReport rows to this JSONL file")
    ap.add_argument("--tuned-policy", default=None,
                    help="tuned-table JSON (python -m repro.tune.fit output); "
                    "replaces the global-constant policy with per-site "
                    "tunables and reports tuned-vs-default mode deltas")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="re-run the host-side mode policy every N decode "
                    "steps (0 = keep registration-time modes); superseded "
                    "by --control-every, which runs the full adaptive "
                    "control plane at that cadence instead")
    ap.add_argument("--affinity", action="store_true",
                    help="place requests on slots by predicted stream "
                    "similarity (per-slot sim_ema affinity) instead of "
                    "first-free")
    ap.add_argument("--control-every", type=int, default=0,
                    help="run the online control plane (repro.control) every "
                    "N decode steps: live per-site retuning, overflow-driven "
                    "max_active_k budget adaptation, and learned per-session "
                    "admission (replaces the synthetic predicted_sim). "
                    "Subsumes --refresh-every (the controller invokes the "
                    "mode refresh itself).")
    ap.add_argument("--control-journal", default=None,
                    help="append the controller's decision journal (JSONL) "
                    "to this path for audit/replay")
    ap.add_argument("--obs", action="store_true",
                    help="enable the observability plane: perf_counter spans "
                    "around serve steps/prefills, correlation ids stamped on "
                    "sensor/journal rows, metrics aggregation")
    ap.add_argument("--replica-id", default=None,
                    help="fleet replica identity: stamp every emitted row's "
                    "trace block with replica=ID so a fleet aggregator "
                    "(repro.obs.fleet) can join this replica's streams; "
                    "unset, emission is byte-identical to before")
    ap.add_argument("--obs-dir", default=None,
                    help="export observability artifacts here (implies "
                    "--obs): metrics.prom, metrics.jsonl, spans.jsonl, and "
                    "latency_table.json (measured per-site/path dispatch "
                    "latencies, probed at the run's measured skip rates)")
    ap.add_argument("--profile-dir", default=None,
                    help="open a jax.profiler trace window around the serve "
                    "loop, writing the device trace here")
    ap.add_argument("--latency-table", default=None,
                    help="measured latency table (a previous run's "
                    "--obs-dir/latency_table.json) for the online controller "
                    "— break-even/exec retunes are priced from measured "
                    "wall-clock; requires --control-every")
    ap.add_argument("--cache-ckpt", default=None,
                    help="reuse-cache checkpoint directory: restore the "
                    "latest step at start (ctrl-block precedence: checkpoint "
                    "< tuned table < live controller, resolutions journaled) "
                    "and save the final cache at exit; requires --reuse")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="shard the reuse serve across a device mesh "
                    "(repro.launch.mesh specs: 'host:N' puts N mocked host "
                    "devices on the model axis — set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N first — "
                    "'host:N@S' makes the model axis S wide, 'prod' the "
                    "16x16 pod). The reuse cache is sharded along the model "
                    "axis with the weights it shadows; skip decisions stay "
                    "shard-local (compiled step is asserted gather-free on "
                    "cache buffers at startup) and sensor counters cross the "
                    "mesh once per control window; requires --reuse")
    ap.add_argument("--inject", default=None, metavar="SCENARIO[:k=v,...]",
                    help="arm a deterministic fault scenario "
                    "(repro.guard.inject.SCENARIOS) at the production seams "
                    "— e.g. poison-nan:at_step=12,site=mlp_up — for chaos "
                    "runs; requires --reuse")
    args = ap.parse_args()

    for flag in ("sensor_jsonl", "tuned_policy", "refresh_every", "affinity",
                 "control_every", "control_journal", "cache_ckpt", "inject",
                 "mesh"):
        if getattr(args, flag) and not args.reuse:
            ap.error(f"--{flag.replace('_', '-')} requires --reuse")
    if args.control_journal and not args.control_every:
        ap.error("--control-journal requires --control-every")
    if args.latency_table and not args.control_every:
        ap.error("--latency-table requires --control-every")
    if args.control_every and args.refresh_every:
        print("--control-every supersedes --refresh-every "
              "(the controller runs the mode refresh itself)")
        args.refresh_every = 0

    obs_on = args.obs or bool(args.obs_dir)
    registry = None
    if obs_on:
        from repro.obs.metrics import MetricsRegistry

        obs_trace.enable()
        run_id = events.new_run_id()
        events.set_ids(run=run_id)
        registry = MetricsRegistry()
        print(f"obs: tracing enabled, run={run_id}")
    if args.replica_id:
        # works with or without --obs: stamp() fires whenever any id is set,
        # so even a journal/sensor-only run carries its replica identity
        events.set_ids(replica=args.replica_id)
        print(f"obs: replica={args.replica_id}")

    # One shared journal: the restore-precedence pass (below) and the online
    # controller append to the same audit stream.
    journal = None
    if args.control_journal:
        from repro.control.report import DecisionJournal

        journal = DecisionJournal(args.control_journal)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.family != "audio", "encoder archs have no decode path"

    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = init_serve_state(cfg, args.batch_slots, args.cache_len)

    engine = None
    rcache = None
    mesh = None
    if args.reuse:
        policy = None
        if args.tuned_policy:
            from repro.tune.table import load_tuned_policy

            policy = load_tuned_policy(args.tuned_policy)
            print(f"tuned policy: {len(policy.site_tunables)} site entries "
                  f"from {args.tuned_policy}")
        engine = build_reuse_engine(cfg, impl="jnp", policy=policy)
        if args.mesh:
            from repro.launch.mesh import mesh_axes, parse_mesh_spec

            mesh = parse_mesh_spec(args.mesh)
            ax = mesh_axes(mesh)
            planned = engine.shard_sites(ax["model_size"])
            print(f"mesh: {dict(mesh.shape)} — {len(planned)} sites sharded "
                  f"{ax['model_size']}-way on the model axis")
        rcache = engine.init_cache(args.batch_slots)
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.dist.shard import cache_shardings

            # cache shards live WITH the weight columns they shadow; params
            # and decode state replicate (GSPMD partitions the step around
            # the committed input shardings)
            rcache = jax.device_put(
                rcache, cache_shardings(engine, mesh, rcache))
            replicated = NamedSharding(mesh, PartitionSpec())
            params = jax.device_put(params, replicated)
            state = jax.device_put(state, replicated)
        from repro.kernels import backend as kernel_backend

        print(f"kernel substrate: {kernel_backend.describe()}")
        print(f"reuse cache: {cache_bytes(rcache)/1e6:.2f} MB "
              f"({len(engine.sites)} sites)")
        if args.cache_ckpt:
            from repro.ckpt.checkpoint import latest_step, restore_checkpoint
            from repro.control.restore import resolve_restored_ctrl

            ck_step = latest_step(args.cache_ckpt)
            if ck_step is not None:
                rcache = restore_checkpoint(args.cache_ckpt, ck_step, rcache)
                resolutions = resolve_restored_ctrl(
                    engine, rcache, journal=journal, step=0)
                print(f"cache checkpoint: restored step {ck_step} from "
                      f"{args.cache_ckpt}; ctrl precedence resolved "
                      f"{len(resolutions)} lanes "
                      f"(checkpoint < tuned table < live)")
                for d in resolutions:
                    where = d.site + (f"@{d.layer}" if d.layer is not None
                                      else "")
                    print(f"  restore {where} {d.field}: "
                          f"{d.before} -> {d.after}")
        if args.tuned_policy:
            # tuned-vs-default delta: probe each site at full similarity
            # (isolates the min-work admission decision) and report the
            # per-site knobs that moved off the global constants
            from repro.core.policy import ReusePolicy

            default = ReusePolicy()
            for name, spec in engine.sites.items():
                t = engine.policy.resolve(name)
                d_mode = default.decide_mode(spec, 1.0)
                t_mode = engine.policy.decide_mode(spec, 1.0)
                moved = (d_mode != t_mode
                         or abs(t.sim_threshold - default.sim_threshold) > 1e-9
                         or t.block_k is not None
                         or t.exec_path is not None)
                if moved:
                    budget = (f"@{spec.max_active_k}"
                              if spec.max_active_k is not None else "")
                    print(f"  tuned delta {name}: mode@sim=1 {d_mode}->"
                          f"{t_mode} thr={t.sim_threshold:.3f} "
                          f"block_k={spec.block_k} "
                          f"exec={spec.exec_path}{budget}")

    # Batched-prefill simplification: slot prefill re-runs the batch prefill
    # with the slot's prompt in its lane (a production server runs a separate
    # prefill worker; the KV-lane insertion is what matters here).
    pending_prompts = {}

    @jax.jit
    def jit_prefill(p, toks, st):
        return prefill_step(p, cfg, toks, st)

    # Jitted decode-step variants, keyed by the registered sites' full spec
    # signature (exec paths, budgets, tile geometry — everything the closure
    # bakes into the trace). A controller flip to a previously-seen operating
    # point reuses its compiled executable instead of retracing from scratch;
    # mode flips are ctrl-array writes and never change the key. The serving
    # state and the reuse cache are DONATED through the step: the previous
    # step's buffers are dead the moment the call is issued, so XLA writes
    # the new caches in place instead of allocating a copy per token.
    decode_variants: dict[tuple, Any] = {}

    def spec_signature() -> tuple:
        if engine is None:
            return ()
        return tuple(sorted(engine.sites.items()))

    def jit_decode_factory():
        key = spec_signature()
        fn = decode_variants.get(key)
        if fn is None:
            @functools.partial(jax.jit, donate_argnums=(2, 3))
            def _step(p, toks, st, rc):
                return decode_step(p, cfg, toks, st, engine=engine,
                                   reuse_cache=rc)
            decode_variants[key] = fn = _step
        return fn

    decode_jit = jit_decode_factory()

    if mesh is not None:
        # The sharded-serving hot-path invariant, proven on the COMPILED
        # artifact: no all-gather/all-to-all in the donated serve step may
        # touch a reuse-cache buffer (shard-local quantize→delta→mask→skip;
        # the once-per-window counter all-reduce rides the ctrl snapshot,
        # not this step). Checked once at startup against the post-SPMD HLO.
        from repro.dist.shard import cache_shape_signatures
        from repro.roofline.hlo_parse import (
            cache_collective_violations,
            parse_collective_bytes,
        )

        aval = functools.partial(jax.tree.map, lambda a: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=a.sharding))
        tok_aval = jax.ShapeDtypeStruct((args.batch_slots, 1), jnp.int32)
        hlo = decode_jit.lower(
            aval(params), tok_aval, aval(state), aval(rcache)
        ).compile().as_text()
        violations = cache_collective_violations(
            hlo, cache_shape_signatures(rcache))
        if violations:
            raise RuntimeError(
                "sharded serve step gathers reuse-cache state across the "
                f"mesh — hot-path invariant violated: {violations}")
        coll = parse_collective_bytes(hlo)
        print(f"hlo no-gather check: OK — 0 cache-touching gathers "
              f"({coll['count']} collectives, "
              f"{coll['total_bytes']/1e3:.1f} KB/device in compiled step)")

    sstate = {"state": state, "rcache": rcache}

    # Fault plane: the armed injector (chaos runs) plus the step clock the
    # straggler watchdog reads. Armed independently of the control plane — a
    # poisoned run WITHOUT the breaker is the useful negative control.
    injector = None
    watchdog = None
    if args.inject:
        from repro.guard import FaultInjector

        injector = FaultInjector.from_spec(args.inject)
        print(f"fault injection armed: {injector.scenario} "
              f"{injector.params} site={injector.site} "
              f"layer={injector.layer}")
    if engine is not None:
        from repro.guard import StragglerWatchdog

        watchdog = StragglerWatchdog()

    # Learned admission + online control plane (repro.control): the predictor
    # learns per-session similarity from retirement telemetry, the controller
    # retunes the policy / adapts budgets from live counters on a cadence.
    predictor = None
    controller = None
    breaker = None
    if args.control_every > 0:
        from repro.control import AdmissionPredictor, ControlConfig, Controller

        latency = None
        if args.latency_table:
            from repro.obs.latency import load_latency_table, table_provenance

            latency = load_latency_table(args.latency_table)
            print(f"controller pricing from measured latencies: "
                  f"{args.latency_table} ({len(latency)} rows)")
            prov = table_provenance(latency)
            if prov != "compiled":
                print(f"WARNING: latency table {args.latency_table} carries "
                      f"{prov} measurements — interpret-mode numbers run "
                      "20-80x off compiled reality; re-probe with a compiled "
                      "serve run (--obs-dir) before trusting its pricing")
                if journal is not None:
                    journal.note(
                        note="latency_table_provenance",
                        path=args.latency_table, provenance=prov,
                        meta=latency.meta,
                    )
        predictor = AdmissionPredictor()
        # the guard plane rides the controller cadence: sentinels are read
        # from the same ctrl snapshot, containment decisions land in the
        # same journal stream, and the breaker's probation clock ticks in
        # control intervals
        from repro.guard import QuarantineBreaker

        breaker = QuarantineBreaker()
        controller = Controller(
            ControlConfig(),
            admission=predictor,
            journal=journal,
            latency=latency,
            guard=breaker,
        )

    def prefill_fn(prompt, slot):
        nonlocal sstate
        full = jnp.zeros((args.batch_slots, prompt.shape[1]), jnp.int32)
        full = full.at[slot].set(jnp.asarray(prompt[0]))
        logits, new_state = jit_prefill(params, full, sstate["state"])
        # only this slot's lanes changed meaningfully; adopt the new caches.
        # No admission= here: the scheduler's on_place hook has ALREADY bound
        # the slot to the incoming session (admission order: pick slot ->
        # on_place -> prefill), and the retirement-path reset below is where
        # the departing occupant's predictor state gets cleared.
        sstate["state"] = new_state
        sstate["rcache"] = reset_slot(sstate["rcache"], slot)
        return int(greedy_sample(logits[slot: slot + 1, -1:])[0, 0])

    step_clock = {"step": 0}

    def decode_fn(tokens):
        nonlocal sstate
        step_clock["step"] += 1
        t0 = obs_trace.now()
        if injector is not None:
            # the stall scenario lives INSIDE the timed region — exactly
            # where a straggler host's slowness would land
            injector.maybe_stall(step_clock["step"])
        logits, new_state, new_rcache = decode_jit(
            params, jnp.asarray(tokens), sstate["state"], sstate["rcache"]
        )
        sstate["state"] = new_state
        sstate["rcache"] = new_rcache
        out = np.asarray(greedy_sample(logits[:, -1:]))[:, :, 0] \
            if logits.ndim == 4 else np.asarray(greedy_sample(logits))
        # np.asarray above forced the device sync, so dt is real step time
        if watchdog is not None:
            event = watchdog.observe(step_clock["step"], obs_trace.now() - t0)
            if event is not None:
                print(f"straggler: step {event['step']} took "
                      f"{event['seconds']:.3f}s vs median "
                      f"{event['median']:.3f}s")
                if breaker is not None:
                    breaker.note_stall(event)
        return out

    telemetry_fn = None
    on_retire = None
    if engine is not None:
        from repro.sensor.aggregate import slot_telemetry

        def telemetry_fn(slot):
            return slot_telemetry(engine, sstate["rcache"], slot)

        def on_retire(req):
            t = req.telemetry
            if predictor is None:
                # lane store for the synthetic --affinity path only; with
                # the control plane, predictor.lane_character is THE store
                lane_sim[req.slot] = t["hit_rate"]
            else:
                # learn BEFORE the reset clears the slot binding
                predictor.observe_retirement(req)
            print(f"SensorReport rid={req.rid} slot={t['slot']} "
                  f"steps={t['steps']} hit_rate={t['hit_rate']:.3f} "
                  f"sites={t['n_sites']}")
            # Reset the freed lane now (telemetry is already snapshotted):
            # bounds how much idle-slot decode history leaks into the
            # end-of-run report before the next admission resets again.
            sstate["rcache"] = reset_slot(sstate["rcache"], req.slot,
                                          admission=predictor)

    slot_sim_fn = None
    on_step = None
    # Lane similarity history for affinity placement. Freed lanes are reset
    # (their live sim_ema is zero by the time a new request is admitted), so
    # the lane's "character" is the retirement-telemetry hit rate of the last
    # stream that lived there — snapshotted before the reset.
    lane_sim: dict[int, float] = {}
    if engine is not None and args.affinity:
        def slot_sim_fn(slot):
            return lane_sim.get(slot, 0.0)

    if engine is not None and args.refresh_every > 0:
        def on_step(step_idx):
            nonlocal decode_jit
            if step_idx % args.refresh_every == 0:
                changed = engine.refresh_modes(sstate["rcache"])
                if engine.last_mode_events:
                    # per-layer kernelMode flips are ctrl-array writes — the
                    # traced step branches on the cache, so NO rebuild here
                    flips = ", ".join(
                        f"{e['site']}"
                        + (f"@{e['layer']}" if e["layer"] is not None else "")
                        + f"->{e['after']}"
                        for e in engine.last_mode_events)
                    print(f"mode refresh @step {step_idx}: {flips}")
                if changed:
                    # exec-path flips ARE spec changes baked into the traced
                    # step — a fresh trace (the paper's CRS re-invocation)
                    decode_jit = jit_decode_factory()
                    print(f"exec refresh @step {step_idx}: {changed}")

    predict_sim_fn = None
    on_place = None
    if controller is not None:
        # learned admission supplies predictions + lane affinity; per-slot
        # predictor state is cleared on recycle by reset_slot(admission=...)
        predict_sim_fn = predictor.predict
        slot_sim_fn = predictor.slot_affinity
        on_place = predictor.on_placed

        def on_step(step_idx):
            nonlocal decode_jit
            if step_idx % args.control_every == 0:
                # the window id joins this interval's journal rows with the
                # spans and sensor rows emitted while it was open
                with events.context(window=step_idx):
                    rep = controller.step(
                        engine, sstate["rcache"], step=step_idx)
                if registry is not None:
                    from repro.obs.metrics import (
                        observe_control_report,
                        observe_guard_report,
                    )

                    observe_control_report(registry, rep)
                    if controller.last_guard_report is not None:
                        observe_guard_report(
                            registry, controller.last_guard_report)
                if rep.decisions:
                    print("\n".join(rep.summary_lines()))
                if rep.changed:
                    # live spec/mode changes are baked into the traced step
                    decode_jit = jit_decode_factory()

    if injector is not None:
        # chain the injector through the production seams: cache poisoning
        # lands post-decode (before the controller's next look), forged
        # telemetry rides the real retirement path
        base_on_step, base_telemetry = on_step, telemetry_fn

        def on_step(step_idx):
            n_fired = len(injector.fired)
            sstate["rcache"] = injector.on_cache_update(
                sstate["rcache"], step_idx)
            if len(injector.fired) > n_fired:
                print(f"inject @step {step_idx}: "
                      f"{injector.fired[-1]['detail']}")
            if base_on_step is not None:
                base_on_step(step_idx)

        if base_telemetry is not None:
            def telemetry_fn(slot):
                return injector.on_telemetry(
                    base_telemetry(slot), step_clock["step"])

    batcher = ContinuousBatcher(
        batch_slots=args.batch_slots,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        max_steps=args.requests * args.max_new + 8,
        telemetry_fn=telemetry_fn,
        on_retire=on_retire,
        slot_sim_fn=slot_sim_fn,
        on_step=on_step,
        predict_sim_fn=predict_sim_fn,
        on_place=on_place,
    )
    for i in range(args.requests):
        batcher.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=(args.prompt_len,), dtype=np.int32),
            max_new_tokens=args.max_new,
            # Without the control plane, a synthetic stand-in predictor:
            # traffic alternates sticky-looking and one-shot-looking streams.
            # With it, predictions come from the LEARNED per-session
            # estimator (predict_sim_fn) instead of being caller-trusted.
            predicted_sim=(0.8 if i % 2 == 0 else 0.2)
            if (args.affinity and controller is None) else None,
            # two synthetic session classes so the predictor has sessions
            # to learn: even rids are the "sticky" session, odd the one-shot
            session=f"sess-{i % 2}" if controller is not None else None,
        ))

    if args.profile_dir:
        obs_trace.start_profile(args.profile_dir)
    t0 = obs_trace.now()  # perf_counter: monotonic wall-clock discipline
    done = batcher.run()
    dt = obs_trace.now() - t0
    if args.profile_dir:
        prof = obs_trace.stop_profile()
        if prof:
            print(f"device trace written to {prof}")
    print(f"served {len(done)}/{args.requests} requests in {dt:.2f}s; "
          f"{batcher.stats}")
    report = None
    if engine is not None:
        report = engine.sensor_report(sstate["rcache"])
        print("\n".join(report.summary_lines()))
        if engine.shards:
            # per-shard skip rates from one final cross-mesh snapshot (the
            # same [S] lanes the controller journals per window)
            snap = engine.ctrl_snapshot(sstate["rcache"])
            for name in sorted(engine.shards):
                s = snap.get(name, {})
                if "skipped_shard" not in s:
                    continue
                sk = np.asarray(s["skipped_shard"], np.float64)
                cp = np.asarray(s["computed_shard"], np.float64)
                rates = sk / np.maximum(sk + cp, 1e-9)
                print(f"shard skip {name}: " + " ".join(
                    f"s{i}={r:.3f}" for i, r in enumerate(rates)))
            print(f"ici traffic: reduce={engine.ici_reduce_bytes/1e3:.1f} KB "
                  f"ctrl-writes={engine.ici_write_bytes/1e3:.1f} KB "
                  f"(priced at E_ICI in the sensor energy report)")
        if args.sensor_jsonl:
            report.write_jsonl(args.sensor_jsonl)
            print(f"sensor report appended to {args.sensor_jsonl}")
    if controller is not None:
        n_dec = sum(len(r.decisions) for r in controller.reports)
        print(f"control plane: {len(controller.reports)} intervals, "
              f"{n_dec} decisions, admission {predictor.stats()}")
        if controller.journal is not None:
            print(f"decision journal: {controller.journal.rows_written} rows "
                  f"-> {controller.journal.path}")
    if breaker is not None:
        states = breaker.lane_states()
        lanes = ", ".join(
            f"{s}" + (f"@{l}" if l is not None else "") + f"={st}"
            for (s, l), st in sorted(states.items(),
                                     key=lambda kv: (kv[0][0], kv[0][1] or 0)))
        print(f"guard plane: {breaker.total_trips} sentinel trips, "
              f"{breaker.stall_windows} stall windows, "
              f"{breaker.quarantined_lanes()} lanes quarantined"
              + (f" [{lanes}]" if lanes else ""))
    if args.cache_ckpt and engine is not None:
        from repro.ckpt.checkpoint import save_checkpoint

        save_checkpoint(args.cache_ckpt, batcher.stats["steps"],
                        sstate["rcache"])
        print(f"cache checkpoint: saved step {batcher.stats['steps']} "
              f"to {args.cache_ckpt}")
    if injector is not None:
        # at-rest scenarios fire at exit, against the artifacts just written
        if args.control_journal:
            injector.tear_journal(args.control_journal)
        if args.cache_ckpt:
            injector.corrupt_checkpoint(args.cache_ckpt)
        print(f"fault injection: {len(injector.fired)} fault(s) fired")
        for ev in injector.fired:
            print(f"  {ev['scenario']} @step {ev['step']}: {ev['detail']}")
    if args.obs_dir:
        from repro.obs.export import write_jsonl, write_prometheus
        from repro.obs.metrics import observe_sensor_report, observe_spans

        os.makedirs(args.obs_dir, exist_ok=True)
        if engine is not None:
            # Probe measured dispatch latency per (site, exec_path), at the
            # run's MEASURED skip rates — the table --latency-table and
            # `repro.tune.fit --latency-table` consume.
            from repro.obs.latency import probe_latency_table

            skips = {s.site: s.tile_skip_rate for s in report.per_site}
            table = probe_latency_table(
                engine, args.batch_slots, skip_rates=skips)
            lat_path = os.path.join(args.obs_dir, "latency_table.json")
            table.save(lat_path, meta={"arch": args.arch})
            print("\n".join(table.summary_lines()))
            print(f"measured latency table -> {lat_path}")
            observe_sensor_report(registry, report)
        observe_spans(registry, obs_trace.spans())
        n = write_prometheus(
            os.path.join(args.obs_dir, "metrics.prom"), registry)
        write_jsonl(os.path.join(args.obs_dir, "metrics.jsonl"), registry)
        n_spans = obs_trace.write_spans_jsonl(
            os.path.join(args.obs_dir, "spans.jsonl"))
        print(f"obs exports -> {args.obs_dir} (metrics.prom {n} lines, "
              f"metrics.jsonl, spans.jsonl {n_spans} spans)")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
