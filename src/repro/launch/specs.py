"""input_specs: ShapeDtypeStruct stand-ins for every (arch × shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these. `make_dummy_inputs` materializes the same structure with real arrays
for smoke tests at reduced scale.

Assigned shape set (LM family, seq_len × global_batch):
    train_4k      4096 × 256     train_step
    prefill_32k   32768 × 32     serve prefill
    decode_32k    1 new token, KV cache 32768, batch 128    serve decode
    long_500k     1 new token, KV cache 524288, batch 1     serve decode
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# Archs whose attention is quadratic-everywhere: long_500k is skipped
# (DESIGN.md §4 records the skip). Encoder-only archs have no decode at all.
FULL_ATTN_ARCHS = {
    "llama4-scout-17b-a16e", "nemotron-4-15b", "qwen3-32b", "qwen2-72b",
    "qwen2-vl-7b",
}
ENCODER_ARCHS = {"hubert-xlarge"}


def cell_runnable(arch: str, shape: str) -> tuple[bool, str]:
    if shape in ("decode_32k", "long_500k") and arch in ENCODER_ARCHS:
        return False, "encoder-only: no autoregressive decode step exists"
    if shape == "long_500k" and arch in FULL_ATTN_ARCHS:
        return False, "pure full attention: 500k decode KV excluded by assignment"
    return True, ""


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    if cfg.frontend == "audio":
        return {
            "embeds": SDS((b, s, cfg.d_model), jnp.bfloat16),
            "labels": SDS((b, s), jnp.int32),
        }
    return {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    if cfg.frontend == "audio":
        return {"embeds": SDS((b, s, cfg.d_model), jnp.bfloat16)}
    return {"tokens": SDS((b, s), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b = cell.global_batch
    return {"tokens": SDS((b, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    return decode_input_specs(cfg, cell)


def state_specs_struct(tree: Any) -> Any:
    """Decode/train state as ShapeDtypeStructs (no allocation) via eval_shape."""
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def make_dummy_inputs(cfg: ModelConfig, cell: ShapeCell, key=None) -> dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    spec = input_specs(cfg, cell)

    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.zeros(s.shape, s.dtype) + 3
        return jax.random.normal(key, s.shape, jnp.float32).astype(s.dtype)

    return jax.tree.map(mk, spec)
