"""Training driver CLI (runs at reduced scale on CPU; production mesh via pjit).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --resume

Wires together: config -> init/resume -> data pipeline -> pjit'd train_step
-> ResilientLoop (async ckpt, preemption, retry, straggler watchdog).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.recovery import LoopConfig, ResilientLoop
from repro.configs import get_config
from repro.obs import trace as obs_trace
from repro.data.pipeline import make_source
from repro.launch.specs import ShapeCell
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    cell = ShapeCell("cli", "train", args.seq, args.batch)
    source = make_source(cfg, cell, seed=args.seed)

    opt = AdamWConfig(lr=args.lr)
    step_fn = jax.jit(make_train_step(
        cfg, opt,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        microbatch=args.microbatch, compress_grads=args.compress_grads,
    ))

    def batch_fn(step: int):
        b = source.batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    loop = ResilientLoop(
        step_fn, batch_fn,
        LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
    )

    def init_fn():
        return init_train_state(
            cfg, jax.random.PRNGKey(args.seed),
            compress_grads=args.compress_grads,
        )

    if args.resume:
        state, start = loop.resume_or_init(init_fn)
    else:
        state, start = init_fn(), 0

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % args.log_every == 0:
            print(
                f"step {step:5d}  loss {float(m['loss']):.4f}  "
                f"gnorm {float(m['grad_norm']):.3f}  lr {float(m['lr']):.2e}",
                flush=True,
            )

    t0 = obs_trace.now()  # perf_counter: monotonic wall-clock discipline
    state = loop.run(state, start, args.steps, on_metrics=on_metrics)
    dt = obs_trace.now() - t0
    print(
        f"done: {args.steps} steps in {dt:.1f}s "
        f"({args.steps / max(dt, 1e-9):.2f} it/s); "
        f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
        f"stragglers flagged: {len(loop.straggler_events)}"
    )
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
