from repro.models import layers, moe, ssm, transformer
from repro.models.transformer import (
    forward,
    init_decode_state,
    init_params,
    output_logits,
)

__all__ = [
    "forward",
    "init_decode_state",
    "init_params",
    "layers",
    "moe",
    "output_logits",
    "ssm",
    "transformer",
]
