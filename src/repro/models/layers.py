"""Shared layer implementations: norms, RoPE (+M-RoPE), attention, MLP.

Pure-JAX (explicit param pytrees, no framework). Attention uses a *pair-scan*
blockwise formulation: the static list of (q-chunk, kv-chunk) pairs that the
mask admits is enumerated at trace time and scanned with an online-softmax
carry. This gives flash-attention memory behaviour AND exact mask-aware FLOPs
in the lowered HLO (no masked-out upper-triangle waste), which keeps the
roofline analysis honest. Causal, sliding-window and bidirectional patterns
only differ in their pair list.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------- init utils

def _dense_init(key, shape, scale=None, dtype=jnp.bfloat16):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(d: int, kind: str = "rms") -> Params:
    if kind == "rms":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# ----------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    freqs = rope_freqs(x.shape[-1], theta)                     # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                         # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections=(16, 24, 24)
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the D/2 frequency slots are partitioned
    into (temporal, height, width) sections, each rotated by its own position
    stream. positions: [3, ..., S] (for text, all three streams coincide and
    M-RoPE degenerates to RoPE)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                # [D/2]
    assert sum(sections) == d // 2, (sections, d)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )
    # pick, per frequency slot, the position stream of its section
    pos = jnp.take(positions, sec_id, axis=0)                   # [D/2, ..., S]
    pos = jnp.moveaxis(pos, 0, -1)                              # [..., S, D/2]
    angles = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention

def init_attention(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {
        "wqkv": _dense_init(ks[0], (d, cfg.q_dim + 2 * cfg.kv_dim), dtype=cfg.dtype),
        "wo": _dense_init(ks[1], (cfg.q_dim, d), dtype=cfg.dtype),
        "norm": init_norm(d),
    }
    if cfg.qkv_bias:
        p["bqkv"] = jnp.zeros((cfg.q_dim + 2 * cfg.kv_dim,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_norm(cfg.head_dim)
        p["k_norm"] = init_norm(cfg.head_dim)
    return p


def _split_qkv(cfg: ModelConfig, qkv: jax.Array):
    q, k, v = jnp.split(qkv, [cfg.q_dim, cfg.q_dim + cfg.kv_dim], axis=-1)
    b, s = q.shape[:2]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _chunk_pairs(
    n_q: int, n_kv: int, chunk_q: int, chunk_kv: int, *,
    causal: bool, window: int | None, q_offset: int = 0,
) -> list[tuple[int, int]]:
    """Static (q-chunk, kv-chunk) pair list admitted by the mask."""
    pairs = []
    for i in range(n_q):
        q_lo = q_offset + i * chunk_q
        q_hi = q_lo + chunk_q - 1
        for j in range(n_kv):
            k_lo = j * chunk_kv
            k_hi = k_lo + chunk_kv - 1
            if causal and k_lo > q_hi:
                continue  # entirely in the future
            if window is not None and k_hi < q_lo - window + 1:
                continue  # entirely before the window
            pairs.append((i, j))
    return pairs


def blockwise_attention(
    q: jax.Array,   # [B, Sq, H, D]
    k: jax.Array,   # [B, Skv, KV, D]
    v: jax.Array,   # [B, Skv, KV, D]
    *,
    causal: bool,
    window: int | None = None,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Pair-scan flash attention (see module docstring)."""
    b, sq, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = 1.0 / math.sqrt(d)
    chunk_q = min(chunk_q, sq)
    chunk_kv = min(chunk_kv, skv)
    while sq % chunk_q:
        chunk_q -= 1   # largest divisor <= requested (odd smoke shapes)
    while skv % chunk_kv:
        chunk_kv -= 1
    nq, nkv = sq // chunk_q, skv // chunk_kv

    pairs = _chunk_pairs(
        nq, nkv, chunk_q, chunk_kv, causal=causal, window=window, q_offset=q_offset
    )
    qi = jnp.asarray([p[0] for p in pairs], dtype=jnp.int32)
    kj = jnp.asarray([p[1] for p in pairs], dtype=jnp.int32)
    # first/last pair per q chunk (pairs are grouped by i, ascending j)
    first = jnp.asarray(
        [idx == 0 or pairs[idx - 1][0] != p[0] for idx, p in enumerate(pairs)]
    )
    last = jnp.asarray(
        [idx == len(pairs) - 1 or pairs[idx + 1][0] != p[0]
         for idx, p in enumerate(pairs)]
    )

    q_sc = (q.astype(jnp.float32) * scale).astype(q.dtype)

    def body(carry, pair):
        out_buf, out_acc, m, l = carry
        i, j, is_first, is_last = pair
        qc = jax.lax.dynamic_slice_in_dim(q_sc, i * chunk_q, chunk_q, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(k, j * chunk_kv, chunk_kv, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, j * chunk_kv, chunk_kv, axis=1)
        # reset carry at the first pair of each q chunk
        m = jnp.where(is_first, jnp.full_like(m, -jnp.inf), m)
        l = jnp.where(is_first, jnp.zeros_like(l), l)
        acc = jnp.where(is_first, jnp.zeros_like(out_acc), out_acc)

        if rep > 1:
            # grouped GQA: contract against KV without materializing repeats
            qg = qc.reshape(*qc.shape[:2], kv, rep, d)
            s = jnp.einsum(
                "bqgrd,bkgd->bgrqk", qg, kc, preferred_element_type=jnp.float32
            ).reshape(qc.shape[0], h, chunk_q, chunk_kv)
        else:
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qc, kc, preferred_element_type=jnp.float32
            )
        # intra-pair mask (diagonal chunks / window edges)
        qpos = q_offset + i * chunk_q + jnp.arange(chunk_q)
        kpos = j * chunk_kv + jnp.arange(chunk_kv)
        mask = jnp.ones((chunk_q, chunk_kv), dtype=bool)
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, -jnp.inf)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # [B, H, cq]
        # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> use safe sub
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        if rep > 1:
            pg = p.reshape(p.shape[0], kv, rep, chunk_q, chunk_kv)
            upd = jnp.einsum(
                "bgrqk,bkgd->bgrqd", pg, vc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).reshape(p.shape[0], h, chunk_q, d)
        else:
            upd = jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
        acc = acc * alpha[..., None] + upd
        # write the finished q chunk into the output on its last pair
        safe_l = jnp.maximum(l_new, 1e-30)
        finished = (acc / safe_l[..., None]).transpose(0, 2, 1, 3)  # [B,cq,H,D]
        cur = jax.lax.dynamic_slice_in_dim(out_buf, i * chunk_q, chunk_q, 1)
        new = jnp.where(is_last, finished.astype(out_buf.dtype), cur)
        out_buf = jax.lax.dynamic_update_slice_in_dim(out_buf, new, i * chunk_q, 1)
        return (out_buf, acc, m_new, l_new), None

    carry = (
        jnp.zeros((b, sq, h, d), jnp.float32),
        jnp.zeros((b, h, chunk_q, d), jnp.float32),
        jnp.full((b, h, chunk_q), -jnp.inf, jnp.float32),
        jnp.zeros((b, h, chunk_q), jnp.float32),
    )
    body = jax.checkpoint(body, prevent_cse=False)
    (out, _, _, _), _ = jax.lax.scan(body, carry, (qi, kj, first, last))
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, 1, H, D]
    k_cache: jax.Array,  # [B, S, KV, D]  (includes the slot for the new token)
    v_cache: jax.Array,
    length: jax.Array,   # [] current valid length (new token already inserted)
    *,
    grouped: bool = True,
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    Written as plain masked softmax over the cache; under pjit with the cache
    S-axis sharded on "data", GSPMD turns the max/sum reductions into the
    flash-decoding partial-softmax + combine pattern (SP for long_500k).

    grouped=True (default, §Perf iteration 1): GQA via a grouped einsum —
    q reshaped to [B, 1, KV, rep, D] contracts against the cache directly, so
    the rep× repeat of K/V is NEVER materialized. The repeat path (grouped=
    False) is kept as the measured §Perf baseline: its HLO "bytes accessed"
    carries ~8x the KV cache per layer.
    """
    b, s, kv, d = k_cache.shape
    h = q.shape[2]
    rep = h // kv
    scale = 1.0 / math.sqrt(d)
    pos = jnp.arange(s)
    valid = pos < length
    if grouped and rep > 1:
        qg = q.reshape(b, 1, kv, rep, d).astype(jnp.float32) * scale
        s_logits = jnp.einsum(
            "bqgrd,bkgd->bgrqk", qg, k_cache.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )  # [B, KV, rep, 1, S]
        s_logits = jnp.where(valid[None, None, None, None, :], s_logits,
                             -jnp.inf)
        p = jax.nn.softmax(s_logits, axis=-1)
        out = jnp.einsum(
            "bgrqk,bkgd->bqgrd", p, v_cache.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return out.reshape(b, 1, h, d).astype(q.dtype)
    kr = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    vr = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    s_logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kr.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )  # [B, H, 1, S]
    s_logits = jnp.where(valid[None, None, None, :], s_logits, -jnp.inf)
    p = jax.nn.softmax(s_logits, axis=-1)
    out = jnp.einsum(
        "bhqk,bkhd->bqhd", p, vr.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


def _maybe_reuse_matmul(name, x, w, b, reuse_ctx):
    """Route a linear site through the ReuseEngine when serving with reuse."""
    if reuse_ctx is not None:
        engine, cache, stats = reuse_ctx
        if name in cache:
            out, new_entry, st = engine.apply(name, x, w, b, cache[name])
            cache[name] = new_entry
            stats[name] = st
            return out
    out = jnp.einsum("...k,kn->...n", x, w, preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out.astype(x.dtype)


def attention_forward(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                   # [B, S, d]
    *,
    layer_window: int | None,       # None = full; int = sliding window
    positions: jax.Array,           # [B, S] (or [3, B, S] for mrope)
    kv_cache: dict | None = None,   # decode: {"k": [B,Sc,KV,D], "v": ...}
    kv_len: jax.Array | None = None,  # [] valid length before this token
    reuse_ctx=None,
    site_prefix: str = "attn",
) -> tuple[jax.Array, dict | None]:
    b, s, _ = x.shape
    h = apply_norm(p["norm"], x, cfg.norm_eps)
    qkv = _maybe_reuse_matmul(
        f"{site_prefix}_qkv", h, p["wqkv"], p.get("bqkv"), reuse_ctx
    )
    q, k, v = _split_qkv(cfg, qkv)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)

    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, _mrope_sections(cfg))
        k = apply_mrope(k, positions, cfg.rope_theta, _mrope_sections(cfg))

    def to_cache(t):
        """Cache layout transform: duplicate KV heads to kv_heads_eff (so the
        cache head dim shards across TP) and optionally quantize to int8."""
        if cfg.kv_heads_eff != cfg.n_kv_heads:
            assert cfg.kv_heads_eff % cfg.n_kv_heads == 0
            t = jnp.repeat(t, cfg.kv_heads_eff // cfg.n_kv_heads, axis=2)
        if cfg.kv_cache_quant:
            t = jnp.clip(
                jnp.round(t.astype(jnp.float32) / cfg.kv_quant_scale),
                -127, 127,
            ).astype(jnp.int8)
        return t

    def from_cache(t):
        if cfg.kv_cache_quant:
            return (t.astype(jnp.float32) * cfg.kv_quant_scale).astype(x.dtype)
        return t

    new_cache = None
    if kv_cache is None:
        out = blockwise_attention(
            q, k, v,
            causal=cfg.causal,
            window=layer_window,
            chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv,
        )
    elif s > 1:
        # Prefill into a fresh cache: blockwise attention over the new
        # sequence, then write K/V into the cache (rolling layout for windowed
        # layers: token t lives at slot t % cache_len, matching decode).
        cache_len = kv_cache["k"].shape[1]
        out = blockwise_attention(
            q, k, v,
            causal=cfg.causal,
            window=layer_window,
            chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv,
        )
        kq, vq = to_cache(k), to_cache(v)
        rolling = layer_window is not None and layer_window <= cache_len
        if rolling and s >= cache_len:
            slots = jnp.arange(s - cache_len, s) % cache_len
            kc = kv_cache["k"].at[:, slots].set(kq[:, s - cache_len:])
            vc = kv_cache["v"].at[:, slots].set(vq[:, s - cache_len:])
        else:
            n = min(s, cache_len)
            kc = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], kq[:, :n], 0, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], vq[:, :n], 0, 1)
        new_cache = {"k": kc, "v": vc}
    else:
        # Decode: insert the new token. Windowed layers use a rolling cache of
        # size `window` (slot = len % cache_len); since softmax over the valid
        # set is order-independent and RoPE is applied pre-cache with absolute
        # positions, no extra window masking is needed — the cache only ever
        # holds the last `window` tokens.
        cache_len = kv_cache["k"].shape[1]
        length = kv_len
        if layer_window is not None and layer_window <= cache_len:
            slot = length % cache_len
        else:
            slot = jnp.minimum(length, cache_len - 1)
        kc = jax.lax.dynamic_update_index_in_dim(
            kv_cache["k"], to_cache(k)[:, 0], slot, 1)
        vc = jax.lax.dynamic_update_index_in_dim(
            kv_cache["v"], to_cache(v)[:, 0], slot, 1)
        new_cache = {"k": kc, "v": vc}
        out = decode_attention(q, from_cache(kc), from_cache(vc), length + 1)

    out = out.reshape(b, s, cfg.q_dim)
    out = _maybe_reuse_matmul(f"{site_prefix}_out", out, p["wo"], None, reuse_ctx)
    return out.astype(x.dtype), new_cache


def _mrope_sections(cfg: ModelConfig):
    half = cfg.head_dim // 2
    t = half - 2 * (3 * half // 8)
    return (t, 3 * half // 8, 3 * half // 8)


# ------------------------------------------------------------------------ mlp

def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        return {
            "wi": _dense_init(ks[0], (d, 2 * f), dtype=cfg.dtype),  # [gate | up]
            "wo": _dense_init(ks[1], (f, d), dtype=cfg.dtype),
            "norm": init_norm(d),
        }
    return {
        "wi": _dense_init(ks[0], (d, f), dtype=cfg.dtype),
        "wo": _dense_init(ks[1], (f, d), dtype=cfg.dtype),
        "norm": init_norm(d),
    }


def mlp_forward(
    p: Params, cfg: ModelConfig, x: jax.Array, *, reuse_ctx=None,
    site_prefix: str = "mlp",
) -> jax.Array:
    h = apply_norm(p["norm"], x, cfg.norm_eps)
    hi = _maybe_reuse_matmul(f"{site_prefix}_in", h, p["wi"], None, reuse_ctx)
    if cfg.mlp_kind == "swiglu":
        gate, up = jnp.split(hi, 2, axis=-1)
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.mlp_kind == "gelu":
        act = jax.nn.gelu(hi.astype(jnp.float32)).astype(x.dtype)
    elif cfg.mlp_kind == "relu2":
        r = jnp.maximum(hi.astype(jnp.float32), 0.0)
        act = (r * r).astype(x.dtype)
    else:
        raise ValueError(cfg.mlp_kind)
    out = _maybe_reuse_matmul(f"{site_prefix}_out", act, p["wo"], None, reuse_ctx)
    return out.astype(x.dtype)
