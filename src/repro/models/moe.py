"""Mixture-of-Experts: top-k routing with capacity-bounded sorted dispatch.

Dispatch is scatter-based (tokens are ranked within their expert and placed
into an [E, C, d] buffer), NOT all-experts-dense, so the lowered HLO carries
the *active* FLOPs only — 6·N_active·D roofline bookkeeping stays honest.

Sharding (dist/sharding.py):
  EP  — experts on the "model" axis when E % model_size == 0 (llama4: 16/16);
        the token scatter/gather becomes the all-to-all-equivalent collective.
  TP  — d_ff on the "model" axis inside every expert otherwise (mixtral: 8
        experts on a 16-way axis).

Reuse note (DESIGN.md §4): routed-expert GEMMs see a *different* token stream
each step (routing flips), which breaks the "consecutive evaluations of the
same stream" premise of delta reuse, so expert sites default to kernelMode =
basic; attention/shared-expert sites carry the reuse. This is recorded as an
arch-applicability finding, not a limitation of the dispatch.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, apply_norm, init_norm, _dense_init


def init_moe(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * scale),
        "wi": (jax.random.normal(ks[1], (e, d, 2 * f), jnp.float32) * scale
               ).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
               * (1.0 / math.sqrt(f))).astype(cfg.dtype),
        "norm": init_norm(d),
    }
    if cfg.shared_expert:
        p["shared_wi"] = _dense_init(ks[3], (d, 2 * f), dtype=cfg.dtype)
        p["shared_wo"] = _dense_init(ks[4], (f, d), dtype=cfg.dtype)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_forward(
    p: Params, cfg: ModelConfig, x: jax.Array, *, reuse_ctx=None,
    site_prefix: str = "moe",
) -> jax.Array:
    """x: [B, S, d] -> [B, S, d]."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    h = apply_norm(p["norm"], x, cfg.norm_eps).reshape(t, d)

    logits = jnp.einsum(
        "td,de->te", h.astype(jnp.float32), p["router"],
        preferred_element_type=jnp.float32,
    )
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                      # [T, k]
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                   # [T*k]
    flat_g = top_g.reshape(-1)
    cap = _capacity(cfg, t)

    # rank within expert (GShard-style position_in_expert)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)          # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                         # [T*k, E]
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    flat_g = jnp.where(keep, flat_g, 0.0)
    slot = jnp.where(keep, pos_in_e, cap)                        # cap = dropped

    # scatter tokens into the expert buffer [E, C+1, d] (last row = dropped)
    xe = jnp.zeros((e, cap + 1, d), dtype=x.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    xe = xe.at[flat_e, slot].add(h[tok_idx], mode="drop")

    # expert GEMMs (swiglu) — active FLOPs only
    hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"],
                    preferred_element_type=jnp.float32)
    gate, up = jnp.split(hi, 2, axis=-1)
    act = (jax.nn.silu(gate) * up).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", act, p["wo"],
                    preferred_element_type=jnp.float32)          # [E, C+1, d]

    # gather back with combine weights
    yt = ye[flat_e, slot]                                        # [T*k, d]
    out = jnp.zeros((t, d), dtype=jnp.float32)
    out = out.at[tok_idx].add(yt * flat_g[:, None], mode="drop")

    if cfg.shared_expert:
        from repro.models.layers import _maybe_reuse_matmul

        hi_s = _maybe_reuse_matmul(
            f"{site_prefix}_shared_in", h, p["shared_wi"], None, reuse_ctx
        )
        g_s, u_s = jnp.split(hi_s, 2, axis=-1)
        act_s = jax.nn.silu(g_s.astype(jnp.float32)).astype(x.dtype) * u_s
        out = out + _maybe_reuse_matmul(
            f"{site_prefix}_shared_out", act_s, p["shared_wo"], None, reuse_ctx
        ).astype(jnp.float32)

    return out.reshape(b, s, d).astype(x.dtype)


def router_aux_loss(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Switch-style load-balance auxiliary loss (used by train_step for MoE)."""
    b, s, d = x.shape
    h = apply_norm(p["norm"], x, cfg.norm_eps).reshape(-1, d)
    logits = jnp.einsum("td,de->te", h.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(gates, axis=-1)
    density = jnp.mean(jax.nn.one_hot(top_e, cfg.n_experts), axis=0)
    density_proxy = jnp.mean(gates, axis=0)
    return cfg.n_experts * jnp.sum(density * density_proxy)
