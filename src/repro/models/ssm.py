"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both recurrences are evaluated with a two-level chunked scan: an outer
`lax.scan` over time chunks (rematerialized, so only chunk-boundary states are
saved for backward) and an inner `lax.scan` over steps. This bounds training
memory at seq 4k and keeps the lowered HLO small (the dry-run compiles the
body once per level). Decode is the single-step recurrence with the state
carried in the decode-state pytree — O(1) in context length, which is what
makes the long_500k cell runnable for these families.

RWKV6 (arXiv:2404.05892): token-shift with data-dependent (LoRA) mixing,
data-dependent per-channel decay w_t, bonus u, per-head state S ∈ R^{dk×dv}:

    out_t = r_t · (diag(u)·k_tᵀ v_t + S_t);   S_{t+1} = diag(w_t)·S_t + k_tᵀ v_t

Mamba2 (arXiv:2405.21060): scalar-per-head decay a_t = exp(dt_t·A), state
h ∈ R^{heads×headdim×state}:

    h_t = a_t·h_{t-1} + dt_t · x_t ⊗ B_t;     y_t = h_t · C_t + D·x_t
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _dense_init, apply_norm, init_norm, rms_norm


def _chunked_scan(step_fn, state, xs, chunk: int):
    """Outer-remat / inner-step scan over the time axis of every leaf in xs."""
    length = jax.tree.leaves(xs)[0].shape[0]
    while length % chunk:
        chunk -= 1  # largest divisor <= requested (handles odd smoke shapes)
    n_chunks = length // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape(n_chunks, chunk, *a.shape[1:]), xs
    )

    def inner(state, xs_chunk):
        return jax.lax.scan(step_fn, state, xs_chunk)

    inner = jax.checkpoint(inner, prevent_cse=False)
    state, ys = jax.lax.scan(inner, state, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(length, *a.shape[2:]), ys)
    return state, ys


# ------------------------------------------------------------------- RWKV6

RWKV_LORA = 32
RWKV_DECAY_LORA = 64


def init_rwkv6(cfg: ModelConfig, key) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.ssm_head_dim
    n_h = d // hd
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    return {
        "norm1": init_norm(d),
        "norm2": init_norm(d),
        "tmix": {
            "maa_x": jnp.zeros((d,), jnp.float32),
            "maa_wkvrg": jnp.zeros((5, d), jnp.float32),
            "tm_w1": _dense_init(ks[0], (d, 5 * RWKV_LORA), dtype=cfg.dtype),
            "tm_w2": (jax.random.normal(ks[1], (5, RWKV_LORA, d), jnp.float32)
                      * 0.01).astype(cfg.dtype),
            "td_w1": _dense_init(ks[2], (d, RWKV_DECAY_LORA), dtype=cfg.dtype),
            "td_w2": (jax.random.normal(ks[3], (RWKV_DECAY_LORA, d), jnp.float32)
                      * 0.01).astype(cfg.dtype),
            "decay_base": jnp.full((d,), -6.0, jnp.float32),
            "wr": _dense_init(ks[4], (d, d), dtype=cfg.dtype),
            "wk": _dense_init(ks[5], (d, d), dtype=cfg.dtype),
            "wv": _dense_init(ks[6], (d, d), dtype=cfg.dtype),
            "wg": _dense_init(ks[7], (d, d), dtype=cfg.dtype),
            "wo": _dense_init(ks[8], (d, d), dtype=cfg.dtype),
            "bonus": jnp.zeros((n_h, hd), jnp.float32),
            "ln_x": init_norm(d),
        },
        "cmix": {
            "maa_k": jnp.zeros((d,), jnp.float32),
            "maa_r": jnp.zeros((d,), jnp.float32),
            "wk": _dense_init(ks[9], (d, f), dtype=cfg.dtype),
            "wv": _dense_init(ks[10], (f, d), dtype=cfg.dtype),
            "wr": _dense_init(ks[11], (d, d), dtype=cfg.dtype),
        },
    }


def _rwkv_projections(p: Params, cfg: ModelConfig, x, x_shift, reuse_ctx, prefix):
    """Token-shift mixing + r/k/v/g/decay projections. x: [B, S, d]."""
    from repro.models.layers import _maybe_reuse_matmul

    tm = p["tmix"]
    sx = x_shift - x
    xxx = x + sx * tm["maa_x"].astype(x.dtype)
    router = jnp.tanh(
        jnp.einsum("bsd,dl->bsl", xxx, tm["tm_w1"],
                   preferred_element_type=jnp.float32)
    ).reshape(*x.shape[:2], 5, RWKV_LORA)
    mix = jnp.einsum("bsfl,fld->bsfd", router.astype(x.dtype), tm["tm_w2"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    maa = tm["maa_wkvrg"].astype(x.dtype)
    xw, xk, xv, xr, xg = [
        x + sx * (maa[i] + mix[:, :, i]) for i in range(5)
    ]
    r = _maybe_reuse_matmul(f"{prefix}_wr", xr, tm["wr"], None, reuse_ctx)
    k = _maybe_reuse_matmul(f"{prefix}_wk", xk, tm["wk"], None, reuse_ctx)
    v = _maybe_reuse_matmul(f"{prefix}_wv", xv, tm["wv"], None, reuse_ctx)
    g = jax.nn.silu(
        _maybe_reuse_matmul(f"{prefix}_wg", xg, tm["wg"], None, reuse_ctx)
        .astype(jnp.float32)
    ).astype(x.dtype)
    decay_in = jnp.tanh(
        jnp.einsum("bsd,dl->bsl", xw, tm["td_w1"],
                   preferred_element_type=jnp.float32)
    )
    decay = tm["decay_base"] + jnp.einsum(
        "bsl,ld->bsd", decay_in.astype(x.dtype), tm["td_w2"],
        preferred_element_type=jnp.float32,
    )
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32)))  # [B, S, d] in (0, 1)
    return r, k, v, g, w


def rwkv6_time_mix(
    p: Params, cfg: ModelConfig, x: jax.Array, state: dict, *,
    reuse_ctx=None, prefix: str = "rwkv",
) -> tuple[jax.Array, dict]:
    """x: [B, S, d]; state: {"shift": [B, d], "wkv": [B, H, dk, dv]}."""
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    n_h = d // hd
    tm = p["tmix"]

    x_shift = jnp.concatenate([state["shift"][:, None], x[:, :-1]], axis=1)
    r, k, v, g, w = _rwkv_projections(p, cfg, x, x_shift, reuse_ctx, prefix)

    rh = r.reshape(b, s, n_h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, n_h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, n_h, hd).astype(jnp.float32)
    wh = w.reshape(b, s, n_h, hd)
    u = tm["bonus"].astype(jnp.float32)

    def step(wkv, ins):
        r_t, k_t, v_t, w_t = ins          # [B, H, hd]
        kv = k_t[..., :, None] * v_t[..., None, :]     # [B, H, dk, dv]
        out = jnp.einsum("bhk,bhkv->bhv", r_t, u[None, :, :, None] * kv + wkv)
        wkv = w_t[..., :, None] * wkv + kv
        return wkv, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rh, kh, vh, wh))  # [S, B, H, hd]
    wkv, outs = _chunked_scan(step, state["wkv"], xs, chunk=min(s, 256))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)              # [B, S, d]

    out = rms_norm(out.astype(x.dtype), tm["ln_x"]["scale"], cfg.norm_eps) * g
    from repro.models.layers import _maybe_reuse_matmul

    out = _maybe_reuse_matmul(f"{prefix}_wo", out, tm["wo"], None, reuse_ctx)
    new_state = {"shift": x[:, -1], "wkv": wkv}
    return out.astype(x.dtype), new_state


def rwkv6_channel_mix(
    p: Params, cfg: ModelConfig, x: jax.Array, state: dict, *,
    reuse_ctx=None, prefix: str = "rwkv_cmix",
) -> tuple[jax.Array, dict]:
    from repro.models.layers import _maybe_reuse_matmul

    cm = p["cmix"]
    x_shift = jnp.concatenate([state["shift"][:, None], x[:, :-1]], axis=1)
    sx = x_shift - x
    xk = x + sx * cm["maa_k"].astype(x.dtype)
    xr = x + sx * cm["maa_r"].astype(x.dtype)
    k = _maybe_reuse_matmul(f"{prefix}_wk", xk, cm["wk"], None, reuse_ctx)
    k = jnp.square(jnp.maximum(k.astype(jnp.float32), 0.0)).astype(x.dtype)
    kv = _maybe_reuse_matmul(f"{prefix}_wv", k, cm["wv"], None, reuse_ctx)
    r = _maybe_reuse_matmul(f"{prefix}_wr", xr, cm["wr"], None, reuse_ctx)
    out = jax.nn.sigmoid(r.astype(jnp.float32)).astype(x.dtype) * kv
    return out, {"shift": x[:, -1]}


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    n_h = d // hd
    return {
        "tmix": {
            "shift": jnp.zeros((batch, d), cfg.dtype),
            "wkv": jnp.zeros((batch, n_h, hd, hd), jnp.float32),
        },
        "cmix": {"shift": jnp.zeros((batch, d), cfg.dtype)},
    }


# ------------------------------------------------------------------- Mamba2

MAMBA_CONV_K = 4


def init_mamba2(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    st = cfg.ssm_state
    nh = cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * st
    return {
        "norm": init_norm(d),
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * st + nh), dtype=cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (MAMBA_CONV_K, conv_ch), jnp.float32)
                   * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": init_norm(di),
        "out_proj": _dense_init(ks[2], (di, d), dtype=cfg.dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, conv_state: jax.Array):
    """Depthwise causal conv, kernel K. x: [B, S, C]; conv_state: [B, K-1, C]."""
    k = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k)
    )
    out = out + b.astype(x.dtype)
    new_state = xp[:, -(k - 1):] if k > 1 else conv_state
    return out, new_state


def mamba2_forward(
    p: Params, cfg: ModelConfig, x: jax.Array, state: dict, *,
    reuse_ctx=None, prefix: str = "mamba",
) -> tuple[jax.Array, dict]:
    """x: [B, S, d]; state: {"conv": [B, K-1, C], "h": [B, nh, hd, state]}."""
    from repro.models.layers import _maybe_reuse_matmul

    b, s, d = x.shape
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = cfg.ssm_head_dim

    hin = apply_norm(p["norm"], x, cfg.norm_eps)
    zxbcdt = _maybe_reuse_matmul(
        f"{prefix}_in", hin, p["in_proj"], None, reuse_ctx
    )
    z, xc, bc, cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], -1)

    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"], state["conv"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xc, bc, cc = jnp.split(conv_out, [di, di + st], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # [B, S, nh]
    a = -jnp.exp(p["A_log"])                                         # [nh]
    decay = jnp.exp(dt * a)                                          # [B, S, nh]

    xh = xc.reshape(b, s, nh, hd).astype(jnp.float32)
    bf = bc.astype(jnp.float32)
    cf = cc.astype(jnp.float32)

    def step(h, ins):
        x_t, b_t, c_t, dt_t, dec_t = ins  # [B,nh,hd], [B,st], [B,st], [B,nh], [B,nh]
        dx = dt_t[..., None] * x_t                                  # [B, nh, hd]
        h = dec_t[..., None, None] * h + dx[..., :, None] * b_t[:, None, None, :]
        y = jnp.einsum("bhps,bs->bhp", h, c_t)                      # [B, nh, hd]
        return h, y

    xs = (
        jnp.moveaxis(xh, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(decay, 1, 0),
    )
    h, ys = _chunked_scan(step, state["h"], xs, chunk=min(s, 256))
    y = jnp.moveaxis(ys, 0, 1)                                       # [B, S, nh, hd]
    y = y + p["D"][:, None] * xh
    y = y.reshape(b, s, di).astype(x.dtype)

    y = rms_norm(y, p["out_norm"]["scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = _maybe_reuse_matmul(f"{prefix}_out", y, p["out_proj"], None, reuse_ctx)
    return out.astype(x.dtype), {"conv": conv_state, "h": h}


def init_mamba2_state(cfg: ModelConfig, batch: int) -> dict:
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    return {
        "conv": jnp.zeros((batch, MAMBA_CONV_K - 1, di + 2 * st), cfg.dtype),
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, st), jnp.float32),
    }
