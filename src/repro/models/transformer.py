"""Model composition: superblocks, scan-over-layers, train/prefill/decode.

Heterogeneous layer patterns are expressed as *superblocks* — the smallest
repeating group of layers — and the model scans over stacked superblocks:

  dense / moe / vlm   1 superblock = [attn, (mlp | moe)]
  gemma3 (5:1)        1 superblock = 5×[local attn, mlp] + 1×[global attn, mlp]
  rwkv6               1 superblock = [time-mix, channel-mix]
  zamba2 (hybrid)     1 superblock = 6×[mamba2] + 1×[shared attn+mlp block]
                      (shared block params live OUTSIDE the scan — weights are
                      shared across its 9 applications, per the paper)
  hubert (encoder)    1 superblock = [bidirectional attn, mlp], no decode path

Scanning keeps the lowered HLO O(1) in depth (the dry-run compiles one
superblock body), and per-superblock state (KV caches, SSM states, reuse
caches) is sliced by the same scan.

Per-layer reuse control rides that slicing: every reuse-cache entry carries
an array-resident ctrl block (per-layer kernelMode ids, live thresholds,
budget occupancy — see repro.core.reuse_cache), so the scan that hands the
superblock body its layer's prev_q/prev_out hands it that layer's control
lane too. The layer body branches on the sliced mode id with lax.cond inside
reuse_linear — a deep stack runs mixed reuse/basic modes in ONE trace, and a
host-side per-layer mode flip between steps never retraces the scan.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params,
    _dense_init,
    apply_norm,
    attention_forward,
    init_attention,
    init_mlp,
    init_norm,
    mlp_forward,
)

# --------------------------------------------------------------------- params


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_superblocks + 4)
    p: Params = {}

    if cfg.frontend == "audio":
        # stub frontend: precomputed frame embeddings arrive at d_model width
        p["embed_proj"] = _dense_init(
            keys[-1], (cfg.d_model, cfg.d_model), dtype=cfg.dtype
        )
    else:
        p["embed"] = (
            jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model), jnp.float32)
            * 0.01
        ).astype(cfg.dtype)

    def init_superblock(k):
        return _init_superblock(cfg, k)

    if cfg.scan_layers:
        p["blocks"] = jax.vmap(init_superblock)(keys[: cfg.n_superblocks])
    else:
        blocks = [init_superblock(k) for k in keys[: cfg.n_superblocks]]
        p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    if cfg.hybrid_attn_every:
        p["shared_block"] = {
            "attn": init_attention(cfg, keys[-2]),
            "mlp": init_mlp(cfg, keys[-3]),
        }

    p["final_norm"] = init_norm(cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend == "audio":
        p["lm_head"] = _dense_init(keys[-4], (cfg.d_model, cfg.vocab), dtype=cfg.dtype)
    return p


def _init_superblock(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, max(cfg.superblock_layers * 2, 4))
    if cfg.ssm_kind == "rwkv6":
        return {"rwkv": ssm_mod.init_rwkv6(cfg, ks[0])}
    if cfg.ssm_kind == "mamba2":
        inner = [ssm_mod.init_mamba2(cfg, k) for k in ks[: cfg.hybrid_attn_every]]
        return {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *inner)}
    if cfg.attn_kind == "local_global":
        local = [
            {"attn": init_attention(cfg, ks[2 * i]), "mlp": init_mlp(cfg, ks[2 * i + 1])}
            for i in range(cfg.local_ratio)
        ]
        return {
            "local": jax.tree.map(lambda *xs: jnp.stack(xs), *local),
            "global": {
                "attn": init_attention(cfg, ks[-2]),
                "mlp": init_mlp(cfg, ks[-1]),
            },
        }
    block: Params = {"attn": init_attention(cfg, ks[0])}
    if cfg.n_experts:
        block["moe"] = moe_mod.init_moe(cfg, ks[1])
    else:
        block["mlp"] = init_mlp(cfg, ks[1])
    return block


# --------------------------------------------------------------- decode state


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """Per-arch serving state: KV caches (full or rolling), SSM states, pos."""
    nsb = cfg.n_superblocks
    kvd = (cfg.kv_heads_eff, cfg.head_dim)
    kv_dtype = jnp.int8 if cfg.kv_cache_quant else cfg.dtype

    def kv(seq):
        return {
            "k": jnp.zeros((batch, seq, *kvd), kv_dtype),
            "v": jnp.zeros((batch, seq, *kvd), kv_dtype),
        }

    def stack(n, tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n, *x.shape)).copy(), tree)

    state: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.ssm_kind == "rwkv6":
        state["blocks"] = stack(nsb, ssm_mod.init_rwkv6_state(cfg, batch))
    elif cfg.ssm_kind == "mamba2":
        blocks = stack(
            nsb, stack(cfg.hybrid_attn_every, ssm_mod.init_mamba2_state(cfg, batch))
        )
        state["blocks"] = {"mamba": blocks}
        if cfg.hybrid_attn_every:
            state["blocks"]["shared_kv"] = stack(nsb, kv(cache_len))
    elif cfg.attn_kind == "local_global":
        w = min(cfg.window, cache_len)
        state["blocks"] = {
            "local": stack(nsb, stack(cfg.local_ratio, kv(w))),
            "global": stack(nsb, kv(cache_len)),
        }
    elif cfg.attn_kind == "swa":
        state["blocks"] = stack(nsb, kv(min(cfg.window, cache_len)))
    else:
        state["blocks"] = stack(nsb, kv(cache_len))
    return state


# ------------------------------------------------------------------- forward


def _layer_window(cfg: ModelConfig, kind: str) -> int | None:
    if kind == "local":
        return cfg.window
    if kind == "swa":
        return cfg.window
    return None


def _block_forward(
    cfg: ModelConfig,
    bp: Params,
    x: jax.Array,
    bstate: dict | None,
    *,
    positions,
    shared_block: Params | None,
    kv_len=None,
    reuse_ctx=None,
    decode: bool,
):
    """One superblock. Returns (x, new_bstate)."""
    new_state: dict[str, Any] = {}

    if cfg.ssm_kind == "rwkv6":
        st = bstate if bstate is not None else ssm_mod.init_rwkv6_state(
            cfg, x.shape[0]
        )
        h, tstate = ssm_mod.rwkv6_time_mix(
            bp["rwkv"], cfg, apply_norm(bp["rwkv"]["norm1"], x, cfg.norm_eps),
            st["tmix"], reuse_ctx=reuse_ctx,
        )
        x = x + h
        h, cstate = ssm_mod.rwkv6_channel_mix(
            bp["rwkv"], cfg, apply_norm(bp["rwkv"]["norm2"], x, cfg.norm_eps),
            st["cmix"], reuse_ctx=reuse_ctx,
        )
        x = x + h
        return x, {"tmix": tstate, "cmix": cstate}

    if cfg.ssm_kind == "mamba2":
        st = bstate["mamba"] if bstate is not None else None

        def mamba_body(carry, xs):
            xx = carry
            mp, ms = xs
            h, new_ms = ssm_mod.mamba2_forward(mp, cfg, xx, ms, reuse_ctx=None)
            return xx + h, new_ms

        if st is None:
            st = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.hybrid_attn_every, *a.shape)
                ).copy(),
                ssm_mod.init_mamba2_state(cfg, x.shape[0]),
            )
        x, new_ms = jax.lax.scan(mamba_body, x, (bp["mamba"], st))
        new_state["mamba"] = new_ms
        if shared_block is not None:
            kv = bstate.get("shared_kv") if (bstate and decode) else None
            h, new_kv = attention_forward(
                shared_block["attn"], cfg, x,
                layer_window=None, positions=positions,
                kv_cache=kv, kv_len=kv_len, reuse_ctx=reuse_ctx,
                site_prefix="shared_attn",
            )
            x = x + h
            x = x + mlp_forward(
                shared_block["mlp"], cfg, x, reuse_ctx=reuse_ctx,
                site_prefix="shared_mlp",
            )
            if decode:
                new_state["shared_kv"] = new_kv
        return x, new_state

    if cfg.attn_kind == "local_global":
        # Inner local layers run without reuse_ctx: their caches would need a
        # second stacking level; reuse rides on the outer (global) sites.
        def local_body(carry, xs):
            xx = carry
            lp, lkv = xs
            h, new_kv = attention_forward(
                lp["attn"], cfg, xx, layer_window=cfg.window,
                positions=positions, kv_cache=lkv, kv_len=kv_len,
                reuse_ctx=None, site_prefix="attn_local",
            )
            xx = xx + h
            xx = xx + mlp_forward(lp["mlp"], cfg, xx, reuse_ctx=None)
            return xx, new_kv

        if decode:
            x, new_lkv = jax.lax.scan(local_body, x, (bp["local"], bstate["local"]))
            new_state["local"] = new_lkv
        else:
            x, _ = _unstacked_local(cfg, bp, x, positions, reuse_ctx)
        gkv = bstate["global"] if (bstate is not None and decode) else None
        h, new_gkv = attention_forward(
            bp["global"]["attn"], cfg, x, layer_window=None,
            positions=positions, kv_cache=gkv, kv_len=kv_len,
            reuse_ctx=reuse_ctx, site_prefix="attn_global",
        )
        x = x + h
        x = x + mlp_forward(
            bp["global"]["mlp"], cfg, x, reuse_ctx=reuse_ctx,
            site_prefix="mlp_global",
        )
        if decode:
            new_state["global"] = new_gkv
        return x, new_state

    # plain dense / moe / swa / encoder block
    window = cfg.window if cfg.attn_kind == "swa" else None
    kv = bstate if (bstate is not None and decode) else None
    h, new_kv = attention_forward(
        bp["attn"], cfg, x, layer_window=window, positions=positions,
        kv_cache=kv, kv_len=kv_len, reuse_ctx=reuse_ctx,
    )
    x = x + h
    if cfg.n_experts:
        x = x + moe_mod.moe_forward(bp["moe"], cfg, x, reuse_ctx=reuse_ctx)
    else:
        x = x + mlp_forward(bp["mlp"], cfg, x, reuse_ctx=reuse_ctx)
    return x, (new_kv if decode else {})


def _unstacked_local(cfg, bp, x, positions, reuse_ctx):
    """Training/prefill path for local layers (no KV state): scan over the
    stacked local blocks with no per-layer state."""

    def body(carry, lp):
        xx = carry
        h, _ = attention_forward(
            lp["attn"], cfg, xx, layer_window=cfg.window,
            positions=positions, kv_cache=None, reuse_ctx=reuse_ctx,
            site_prefix="attn_local",
        )
        xx = xx + h
        xx = xx + mlp_forward(lp["mlp"], cfg, xx, reuse_ctx=reuse_ctx)
        return xx, None

    x, _ = jax.lax.scan(body, x, bp["local"])
    return x, None


# ------------------------------------------------------------------ embedding


def embed_inputs(params: Params, cfg: ModelConfig, inputs: dict) -> jax.Array:
    if cfg.frontend == "audio":
        x = inputs["embeds"].astype(cfg.dtype)
        return jnp.einsum("bsd,de->bse", x, params["embed_proj"],
                          preferred_element_type=jnp.float32).astype(cfg.dtype)
    x = params["embed"][inputs["tokens"]]
    if "vision_embeds" in inputs and inputs["vision_embeds"] is not None:
        # VLM stub: precomputed patch embeddings overwrite their token slots
        ve = inputs["vision_embeds"].astype(x.dtype)
        vp = inputs["vision_positions"]  # [B, P] int32 positions
        x = jax.vmap(lambda xb, vb, pb: xb.at[pb].set(vb))(x, ve, vp)
    return x


def output_logits(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = apply_norm(params["final_norm"], h, cfg.norm_eps)
    if "lm_head" in params:
        return jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,vd->bsv", h, params["embed"],
                      preferred_element_type=jnp.float32)


# -------------------------------------------------------------------- forward


def forward(
    params: Params,
    cfg: ModelConfig,
    inputs: dict,
    *,
    decode_state: dict | None = None,
    reuse_engine=None,
    reuse_cache: dict | None = None,
):
    """Returns (hidden [B,S,d], new_decode_state, new_reuse_cache, stats)."""
    decode = decode_state is not None
    x = embed_inputs(params, cfg, inputs)
    b, s, _ = x.shape

    if decode:
        pos0 = decode_state["len"]
        positions = (pos0 + jnp.arange(s))[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, b, s))

    shared_block = params.get("shared_block")
    bstates = decode_state["blocks"] if decode else None

    stats: dict[str, Any] = {}

    def body(carry, xs):
        xx = carry
        # rcache is THIS superblock's slice of every reuse site's cache —
        # including the ctrl lane whose mode id the reuse dispatch branches
        # on, so kernelMode is per-layer inside the scan
        bp, bst, rcache = xs
        rctx = None
        if reuse_engine is not None and rcache is not None:
            rctx = (reuse_engine, rcache, {})
        xx, new_bst = _block_forward(
            cfg, bp, xx, bst,
            positions=positions, shared_block=shared_block,
            kv_len=decode_state["len"] if decode else None,
            reuse_ctx=rctx, decode=decode,
        )
        new_rcache = rctx[1] if rctx is not None else rcache
        return xx, (new_bst, new_rcache)

    if cfg.remat and not decode:
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    xs = (params["blocks"], bstates, reuse_cache)
    x, (new_bstates, new_rcache) = jax.lax.scan(body, x, xs)

    new_state = None
    if decode:
        new_state = {"len": decode_state["len"] + s, "blocks": new_bstates}
    return x, new_state, new_rcache, stats
