"""repro.obs — unified tracing, metrics, and measured-latency plane.

Before this package, a serve run's latency story lived in five scattered
``time.time()`` call sites and three disconnected JSONL formats (sensor rows,
control journal, BENCH trajectory) with no way to join them, and every
break-even knob in the control loop was priced by cost-model CONSTANTS. The
obs plane unifies them:

* :mod:`repro.obs.trace`   — low-overhead host-side spans (`perf_counter`
  discipline, optional `block_until_ready` at close, nestable, strict no-op
  when disabled) that also emit `jax.profiler` device-trace markers;
* :mod:`repro.obs.events`  — correlation ids (run / session / request /
  window / site@layer) stamped onto spans, sensor rows, and control-journal
  decisions, so one serve run becomes ONE joinable event stream;
* :mod:`repro.obs.metrics` — counters / gauges / histograms (p50/p95/p99)
  aggregated from sensor counters, controller state and spans;
* :mod:`repro.obs.export`  — Prometheus textfile + JSONL snapshot emission
  (and the parser for round-trip tests);
* :mod:`repro.obs.latency` — the payoff: a per-(site, layer, exec_path)
  MEASURED latency table built from spans, saved/loaded like the tuned-policy
  table and consumed by `repro.tune.fit --latency-table` and the online
  retuner in place of constant cost-model latencies;
* ``python -m repro.obs.top`` — live terminal view of a serve run's metrics
  snapshots (and, with ``--fleet``, per-replica columns + health);
* :mod:`repro.obs.stream`  — tailing JSONL readers that consume a replica's
  obs dir incrementally, forgiving a torn final line like `load_journal`;
* :mod:`repro.obs.fleet`   — :class:`FleetAggregator` merging N replica
  streams into per-(replica, site, layer) and fleet-level rollups, plus the
  typed :class:`ReplicaHealth` router signal;
* :mod:`repro.obs.slo`     — windowed SLO/anomaly watch (skip collapse vs a
  replica's own baseline, p95 burn, quarantine spikes) emitting attributed
  alert rows and `fleet_*` Prometheus series.

Everything here is host-side and dependency-free beyond jax/numpy; with
tracing disabled (the default) every instrumentation point is a shared no-op.
"""

from repro.obs.events import (
    clear_ids,
    context,
    current_ids,
    new_run_id,
    set_ids,
    stamp,
)
from repro.obs.fleet import (
    FleetAggregator,
    ReplicaHealth,
    export_fleet_metrics,
)
from repro.obs.latency import (
    LatencyStat,
    LatencyTable,
    build_from_spans,
    load_latency_table,
    probe_latency_table,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    observe_control_report,
    observe_sensor_report,
)
from repro.obs.slo import (
    SLOConfig,
    SLOWatcher,
    load_alerts,
)
from repro.obs.stream import (
    ReplicaStream,
    TailCursor,
    discover_replica_streams,
    tail_jsonl,
)
from repro.obs.trace import (
    disable,
    drain_spans,
    enable,
    is_enabled,
    now,
    span,
    spans,
    start_profile,
    stop_profile,
)

__all__ = [
    "Counter",
    "FleetAggregator",
    "Gauge",
    "Histogram",
    "LatencyStat",
    "LatencyTable",
    "MetricsRegistry",
    "ReplicaHealth",
    "ReplicaStream",
    "SLOConfig",
    "SLOWatcher",
    "TailCursor",
    "build_from_spans",
    "clear_ids",
    "context",
    "current_ids",
    "disable",
    "discover_replica_streams",
    "drain_spans",
    "enable",
    "export_fleet_metrics",
    "is_enabled",
    "load_alerts",
    "load_latency_table",
    "new_run_id",
    "now",
    "observe_control_report",
    "observe_sensor_report",
    "probe_latency_table",
    "set_ids",
    "spans",
    "span",
    "stamp",
    "start_profile",
    "stop_profile",
    "tail_jsonl",
]
