"""Correlation ids — the join keys of the unified event stream.

A serve run emits three record families: sensor rows (cumulative counters),
control-journal rows (policy decisions), and obs spans (measured wall-clock).
Before this module they were three files with nothing in common; now every
record is stamped with the SAME id set, so a run can be joined offline:

    replica  — fleet identity of the emitting replica (serve --replica-id,
               or the launch/replicas.py harness); the join key the fleet
               aggregator uses to attribute rows across N obs dirs
    run      — one id per process-lifetime observation scope (a serve run)
    session  — the session the active request belongs to (admission identity)
    request  — the request id being prefillled/retired
    window   — the controller interval the record falls in
    site / layer — which reuse site (and ctrl lane) a record concerns

Ids live in module state (the serving loop is single-threaded host Python;
the jitted step never reads them). `stamp(row)` returns the row with a
``"trace"`` sub-dict of the current ids — and returns it UNCHANGED when no
ids are set, so consumers that never touch the obs plane emit byte-identical
rows to the pre-obs builds.
"""

from __future__ import annotations

import contextlib
import uuid
from typing import Any

_IDS: dict[str, Any] = {}


def new_run_id() -> str:
    """A fresh run-scope id (short uuid — unique per serve/bench process)."""
    return uuid.uuid4().hex[:12]


def set_ids(**ids: Any) -> None:
    """Set correlation ids for subsequent stamps. `None` values clear keys."""
    for key, val in ids.items():
        if val is None:
            _IDS.pop(key, None)
        else:
            _IDS[key] = val


def clear_ids(*keys: str) -> None:
    """Clear the named ids, or ALL ids when called with no arguments."""
    if not keys:
        _IDS.clear()
        return
    for key in keys:
        _IDS.pop(key, None)


def current_ids() -> dict[str, Any]:
    return dict(_IDS)


@contextlib.contextmanager
def context(**ids: Any):
    """Scoped ids: set for the block, restore the previous values after —
    nesting-safe (an inner request context restores the outer window id)."""
    saved = {key: _IDS.get(key, _MISSING) for key in ids}
    set_ids(**ids)
    try:
        yield
    finally:
        for key, val in saved.items():
            if val is _MISSING:
                _IDS.pop(key, None)
            else:
                _IDS[key] = val


_MISSING = object()


def stamp(row: dict[str, Any]) -> dict[str, Any]:
    """Return `row` with the current correlation ids under ``"trace"``.

    With no ids set (obs plane never initialised) the row is returned
    UNCHANGED — pre-obs consumers see byte-identical emission."""
    if not _IDS:
        return row
    return dict(row, trace=dict(_IDS))
