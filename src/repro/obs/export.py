"""Metrics export — Prometheus textfile + JSONL snapshots.

Two sinks, one registry:

* `write_prometheus(path, registry)` — the node-exporter textfile-collector
  format: `# TYPE` headers, `name{label="v"} value` samples; histograms emit
  `_count`/`_sum` plus `{quantile="0.5|0.95|0.99"}` summary samples.
* `write_jsonl(path, registry)` — appends one snapshot row per metric,
  stamped with the current correlation ids and a shared `snap` sequence
  number so `repro.obs.top` (and offline joins) can group rows per snapshot.

`parse_prometheus` is the inverse of the textfile writer — the round-trip
contract the exporter tests lock.
"""

from __future__ import annotations

import json
import re
from typing import Any

from repro.obs.events import stamp
from repro.obs.metrics import MetricsRegistry

_SNAP_SEQ = {"n": 0}

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(labels: dict[str, Any], extra: dict[str, Any] | None = None
                 ) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(
        f'{_prom_name(str(k))}="{str(v)}"' for k, v in sorted(merged.items())
    )
    return "{" + inner + "}"


def prometheus_lines(registry: MetricsRegistry) -> list[str]:
    lines: list[str] = []
    seen_types: set[str] = set()
    for row in registry.snapshot():
        name = _prom_name(row["name"])
        kind = row["type"]
        if kind == "histogram":
            # summary-style emission: quantiles + _count/_sum
            if name not in seen_types:
                lines.append(f"# TYPE {name} summary")
                seen_types.add(name)
            for q in (50, 95, 99):
                lines.append(
                    f"{name}{_prom_labels(row['labels'], {'quantile': q / 100})}"
                    f" {row[f'p{q}']:.9g}")
            lines.append(
                f"{name}_count{_prom_labels(row['labels'])} {row['count']}")
            lines.append(
                f"{name}_sum{_prom_labels(row['labels'])} {row['sum']:.9g}")
        else:
            prom_kind = "counter" if kind == "counter" else "gauge"
            if name not in seen_types:
                lines.append(f"# TYPE {name} {prom_kind}")
                seen_types.add(name)
            lines.append(
                f"{name}{_prom_labels(row['labels'])} {row['value']:.9g}")
    return lines


def write_prometheus(path: str, registry: MetricsRegistry) -> int:
    lines = prometheus_lines(registry)
    with open(path, "w") as f:
        f.write("\n".join(lines) + ("\n" if lines else ""))
    return len(lines)


def parse_prometheus(text: str) -> dict[str, dict[str, float]]:
    """Inverse of the textfile writer: {metric_name: {label_string: value}}.
    `# TYPE` lines are validated (they must precede their samples)."""
    out: dict[str, dict[str, float]] = {}
    typed: set[str] = set()
    for lineno, line in enumerate(text.splitlines(), start=1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                typed.add(parts[2])
            continue
        m = re.match(r"^([a-zA-Z0-9_:]+)(\{[^}]*\})?\s+(\S+)$", line)
        if m is None:
            raise ValueError(f"line {lineno}: not a prometheus sample: {line!r}")
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        base = name[:-6] if name.endswith("_count") else (
            name[:-4] if name.endswith("_sum") else name)
        if base not in typed:
            raise ValueError(f"line {lineno}: sample {name!r} precedes its "
                             f"# TYPE header")
        out.setdefault(name, {})[labels] = float(value)
    return out


def write_jsonl(path: str, registry: MetricsRegistry, *,
                extra: dict[str, Any] | None = None) -> int:
    """Append one snapshot (one row per metric) to a JSONL file. Rows share a
    `snap` sequence number and carry the current correlation ids."""
    _SNAP_SEQ["n"] += 1
    snap = _SNAP_SEQ["n"]
    rows = registry.snapshot()
    with open(path, "a") as f:
        for row in rows:
            row = dict(row, snap=snap)
            if extra:
                row.update(extra)
            f.write(json.dumps(stamp(row)) + "\n")
    return len(rows)


def load_snapshots(path: str) -> list[list[dict[str, Any]]]:
    """Parse a metrics JSONL back into snapshots (grouped by `snap`)."""
    by_snap: dict[int, list[dict[str, Any]]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            by_snap.setdefault(int(row.get("snap", 0)), []).append(row)
    return [by_snap[k] for k in sorted(by_snap)]
