"""Metrics export — Prometheus textfile + JSONL snapshots.

Two sinks, one registry:

* `write_prometheus(path, registry)` — the node-exporter textfile-collector
  format: `# TYPE` headers, `name{label="v"} value` samples; histograms emit
  `_count`/`_sum` plus `{quantile="0.5|0.95|0.99"}` summary samples.
* `write_jsonl(path, registry)` — appends one snapshot row per metric,
  stamped with the current correlation ids and a shared `snap` sequence
  number so `repro.obs.top` (and offline joins) can group rows per snapshot.

`parse_prometheus` is the inverse of the textfile writer — the round-trip
contract the exporter tests lock.
"""

from __future__ import annotations

import json
import re
from typing import Any

from repro.obs.events import stamp
from repro.obs.metrics import MetricsRegistry

_SNAP_SEQ = {"n": 0}

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _escape_label_value(value: str) -> str:
    """Exposition-format label escaping: backslash, double-quote, newline.
    Raw interpolation corrupts the textfile — a value containing `"` closes
    the label early and a newline splits the sample across lines."""
    return (value.replace("\\", "\\\\")
                 .replace('"', '\\"')
                 .replace("\n", "\\n"))


def _unescape_label_value(value: str) -> str:
    """Exact inverse of `_escape_label_value`."""
    out: list[str] = []
    i = 0
    while i < len(value):
        ch = value[i]
        if ch == "\\" and i + 1 < len(value):
            nxt = value[i + 1]
            out.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt, ch + nxt))
            i += 2
        else:
            out.append(ch)
            i += 1
    return "".join(out)


def _prom_labels(labels: dict[str, Any], extra: dict[str, Any] | None = None
                 ) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(
        f'{_prom_name(str(k))}="{_escape_label_value(str(v))}"'
        for k, v in sorted(merged.items())
    )
    return "{" + inner + "}"


def prometheus_lines(registry: MetricsRegistry) -> list[str]:
    lines: list[str] = []
    seen_types: set[str] = set()
    for row in registry.snapshot():
        name = _prom_name(row["name"])
        kind = row["type"]
        if kind == "histogram":
            # summary-style emission: quantiles + _count/_sum
            if name not in seen_types:
                lines.append(f"# TYPE {name} summary")
                seen_types.add(name)
            for q in (50, 95, 99):
                lines.append(
                    f"{name}{_prom_labels(row['labels'], {'quantile': q / 100})}"
                    f" {row[f'p{q}']:.9g}")
            lines.append(
                f"{name}_count{_prom_labels(row['labels'])} {row['count']}")
            lines.append(
                f"{name}_sum{_prom_labels(row['labels'])} {row['sum']:.9g}")
        else:
            prom_kind = "counter" if kind == "counter" else "gauge"
            if name not in seen_types:
                lines.append(f"# TYPE {name} {prom_kind}")
                seen_types.add(name)
            lines.append(
                f"{name}{_prom_labels(row['labels'])} {row['value']:.9g}")
    return lines


def write_prometheus(path: str, registry: MetricsRegistry) -> int:
    lines = prometheus_lines(registry)
    with open(path, "w") as f:
        f.write("\n".join(lines) + ("\n" if lines else ""))
    return len(lines)


def parse_labels(body: str) -> dict[str, str]:
    """Parse the inside of a `{...}` label set, exact inverse of
    `_prom_labels`: quote/escape-aware, so values containing `}`, `,`, `"`
    (escaped) or newlines (escaped) round-trip."""
    labels: dict[str, str] = {}
    i, n = 0, len(body)
    while i < n:
        if body[i] == ",":
            i += 1
            continue
        eq = body.find("=", i)
        if eq < 0 or eq + 1 >= n or body[eq + 1] != '"':
            raise ValueError(f"bad label pair at {body[i:]!r}")
        key = body[i:eq].strip()
        j = eq + 2  # scan the quoted value, honouring backslash escapes
        raw: list[str] = []
        while j < n and body[j] != '"':
            if body[j] == "\\" and j + 1 < n:
                raw.append(body[j:j + 2])
                j += 2
            else:
                raw.append(body[j])
                j += 1
        if j >= n:
            raise ValueError(f"unterminated label value in {body!r}")
        labels[key] = _unescape_label_value("".join(raw))
        i = j + 1
    return labels


def _split_sample(line: str) -> tuple[str, str, str]:
    """Split a sample line into (name, label_body, value) with a
    quote-aware scan — a regex that stops at the first `}` mis-parses any
    label value containing `}` or an escaped quote."""
    m = re.match(r"^([a-zA-Z0-9_:]+)", line)
    if m is None:
        raise ValueError(f"not a prometheus sample: {line!r}")
    name = m.group(1)
    rest = line[m.end():]
    body = ""
    if rest.startswith("{"):
        i, n = 1, len(rest)
        while i < n and rest[i] != "}":
            if rest[i] == '"':  # skip the quoted value
                i += 1
                while i < n and rest[i] != '"':
                    i += 2 if rest[i] == "\\" else 1
            i += 1
        if i >= n:
            raise ValueError(f"unterminated label set: {line!r}")
        body = rest[1:i]
        rest = rest[i + 1:]
    value = rest.strip()
    if not value or any(c.isspace() for c in value):
        raise ValueError(f"not a prometheus sample: {line!r}")
    return name, body, value


def parse_prometheus(text: str) -> dict[str, dict[str, float]]:
    """Inverse of the textfile writer: {metric_name: {label_string: value}}.
    Label strings are re-serialised canonically (sorted keys, escaped
    values — `_prom_labels` form), so writer output keys itself. `# TYPE`
    lines are validated (they must precede their samples)."""
    out: dict[str, dict[str, float]] = {}
    typed: set[str] = set()
    for lineno, line in enumerate(text.splitlines(), start=1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                typed.add(parts[2])
            continue
        try:
            name, body, value = _split_sample(line)
            labels = parse_labels(body)
        except ValueError as e:
            raise ValueError(f"line {lineno}: {e}") from e
        base = name[:-6] if name.endswith("_count") else (
            name[:-4] if name.endswith("_sum") else name)
        if base not in typed:
            raise ValueError(f"line {lineno}: sample {name!r} precedes its "
                             f"# TYPE header")
        out.setdefault(name, {})[_prom_labels(labels)] = float(value)
    return out


def write_jsonl(path: str, registry: MetricsRegistry, *,
                extra: dict[str, Any] | None = None) -> int:
    """Append one snapshot (one row per metric) to a JSONL file. Rows share a
    `snap` sequence number and carry the current correlation ids."""
    _SNAP_SEQ["n"] += 1
    snap = _SNAP_SEQ["n"]
    rows = registry.snapshot()
    with open(path, "a") as f:
        for row in rows:
            row = dict(row, snap=snap)
            if extra:
                row.update(extra)
            f.write(json.dumps(stamp(row)) + "\n")
    return len(rows)


def load_snapshots(path: str) -> list[list[dict[str, Any]]]:
    """Parse a metrics JSONL back into snapshots (grouped by `snap`)."""
    by_snap: dict[int, list[dict[str, Any]]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            by_snap.setdefault(int(row.get("snap", 0)), []).append(row)
    return [by_snap[k] for k in sorted(by_snap)]
