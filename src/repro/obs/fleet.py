"""Fleet aggregation — N replica event streams merged into one rollup.

The ROADMAP's fleet-scale item needs two observables no single-process plane
provides: fleet-level skip/energy from sensor aggregation, and per-replica
health (quarantined lanes, stall windows, skip trend) as router placement
signals. :class:`FleetAggregator` provides both by merging N
:class:`~repro.obs.stream.ReplicaStream` tails:

* aggregation stays replica-local (the Proximu$ lesson: compute near the
  data) — each replica reduces its own counters into its own obs dir, and
  only those compact rollup rows cross the process boundary into the fleet
  plane; the aggregator never touches device state;
* per-(replica, site, layer) rollups come straight from the replicas' sensor
  rows; fleet-level rates are recomputed from summed COUNTERS with exactly
  the formulas ``sensor.aggregate.build_report`` uses, so a single-replica
  fleet is bitwise-equal to that replica's own ``SensorReport`` numbers;
* energy is priced through ``sensor.cost_model`` on the same counters;
  latency p50/p95 comes from each replica's ``serve_step`` spans;
* :class:`ReplicaHealth` distills the guard/journal stream into the router
  signals PR 8 made each replica emit: quarantined lanes, sentinel trips,
  stall windows, and the windowed skip trend vs the replica's own trailing
  baseline.

Rows may arrive out of order ACROSS replicas (host clock skew, lagging
tails): the aggregator orders nothing globally — every windowed statistic is
keyed to its own replica's row sequence, so skew cannot corrupt a rollup.
Run ids must be unique fleet-wide; two replicas claiming the same run id is
a wiring bug (copied obs dir, double-started replica) and raises.
"""

from __future__ import annotations

import dataclasses
import json
import math
from collections import deque
from types import SimpleNamespace
from typing import Any

import numpy as np

from repro.obs.stream import ReplicaStream, discover_replica_streams

FLEET_REPORT_SCHEMA_VERSION = 1


@dataclasses.dataclass
class ReplicaHealth:
    """Router-facing health signals for one replica (ROADMAP fleet item:
    drain sticky sessions off a limping replica, not just a dead one)."""

    replica: str
    run: str | None = None
    steps: int = 0
    windows: int = 0              # sensor windows consumed so far
    quarantined_lanes: int = 0    # live count (journal truth, gauge fallback)
    sentinel_trips: int = 0       # cumulative containment actions
    stall_windows: int = 0        # straggler-watchdog events journaled
    torn_lines: int = 0           # stream rows lost to torn appends
    alerts: int = 0               # SLO alerts attributed to this replica
    skip_window: float = 0.0      # mac_skip over the latest sensor window
    skip_baseline: float = 0.0    # trailing-window mean (excluding latest)
    skip_trend: float = 0.0       # skip_window - skip_baseline

    @property
    def status(self) -> str:
        """Coarse placement signal: `quarantined` lanes pin dense (route
        one-shot traffic here), `limping` means latency/stream trouble
        without containment, `ok` is reuse-worthy."""
        if self.quarantined_lanes > 0:
            return "quarantined"
        if self.stall_windows > 0 or self.torn_lines > 0 or self.alerts > 0:
            return "limping"
        return "ok"

    def to_dict(self) -> dict[str, Any]:
        return dict(dataclasses.asdict(self), status=self.status)


class _ReplicaAgg:
    """Mutable per-replica aggregation state, fed row-by-row."""

    def __init__(self, replica: str, baseline_windows: int):
        self.replica = replica
        self.baseline_windows = baseline_windows
        self.runs: list[str] = []
        self.model: dict[str, Any] | None = None     # latest cumulative row
        self.site_rows: dict[tuple[str, int | None], dict[str, Any]] = {}
        self.windows = 0
        # recent windowed mac_skip values; latest is window_skips[-1]
        self.window_skips: deque[float] = deque(maxlen=baseline_windows + 1)
        self.site_window_skips: dict[str, deque[float]] = {}
        self._site_prev: dict[str, tuple[float, float]] = {}
        self._model_prev: tuple[float, float] = (0.0, 0.0)
        self.span_durs: dict[str, list[float]] = {}
        self.lane_state: dict[tuple[str, Any], str] = {}
        self.saw_guard_journal = False
        self.stall_windows = 0
        self.metrics_latest: dict[tuple[str, str], dict[str, Any]] = {}
        self.alerts = 0

    # ------------------------------------------------------------- row intake
    def add_sensor(self, row: dict[str, Any]) -> None:
        kind = row.get("kind")
        if kind == "model":
            self.model = row
            skipped = float(row.get("skipped_macs", 0.0))
            total = skipped + float(row.get("computed_macs", 0.0))
            p_skip, p_total = self._model_prev
            # cumulative counters only grow; a shrinking total means the
            # replica restarted its counters — treat the row as a fresh base
            if total < p_total:
                p_skip, p_total = 0.0, 0.0
            d_total = total - p_total
            if d_total > 0:
                # a row with NO new work (a duplicate end-of-run write) is
                # not a window — a 0/0 "skip" would fake a collapse
                self.windows += 1
                self.window_skips.append((skipped - p_skip) / d_total)
            self._model_prev = (skipped, total)
        elif kind in ("site", "layer"):
            site = row["site"]
            self.site_rows[(site, row.get("layer"))] = row
            if kind == "site":
                skipped = float(row.get("skipped_macs", 0.0))
                total = skipped + float(row.get("computed_macs", 0.0))
                p_skip, p_total = self._site_prev.get(site, (0.0, 0.0))
                if total < p_total:
                    p_skip, p_total = 0.0, 0.0
                d_total = total - p_total
                if d_total > 0:
                    self.site_window_skips.setdefault(
                        site, deque(maxlen=self.baseline_windows + 1)
                    ).append((skipped - p_skip) / d_total)
                self._site_prev[site] = (skipped, total)

    def add_span(self, row: dict[str, Any]) -> None:
        name = row.get("name")
        dur = row.get("dur_s")
        if name is None or dur is None:
            return
        self.span_durs.setdefault(name, []).append(float(dur))

    def add_journal(self, row: dict[str, Any]) -> None:
        if row.get("kind") != "decision" or \
                row.get("decision_kind") != "quarantine":
            return
        self.saw_guard_journal = True
        if row.get("field") == "state":
            self.lane_state[(row.get("site"), row.get("layer"))] = \
                row.get("after")
        elif row.get("field") == "stall_windows":
            self.stall_windows += 1

    def add_metric(self, row: dict[str, Any]) -> None:
        name = row.get("name")
        if name is None:
            return
        key = (name, json.dumps(row.get("labels", {}), sort_keys=True))
        self.metrics_latest[key] = row

    def note_run(self, run: str) -> None:
        if run not in self.runs:
            self.runs.append(run)

    # ------------------------------------------------------------- derived
    def quarantined_lanes(self) -> int:
        if self.saw_guard_journal:
            return sum(1 for s in self.lane_state.values()
                       if s == "quarantined")
        # journal-less stream (plain serve --obs-dir): trust the guard gauge
        row = self.metrics_latest.get(("guard_quarantined_lanes", "{}"))
        return int(row["value"]) if row else 0

    def skip_baseline(self) -> float:
        prior = list(self.window_skips)[:-1]
        return float(np.mean(prior)) if prior else 0.0

    def site_skip_baseline(self, site: str) -> float:
        prior = list(self.site_window_skips.get(site, ()))[:-1]
        return float(np.mean(prior)) if prior else 0.0

    def span_quantile(self, name: str, q: float) -> float:
        durs = self.span_durs.get(name)
        return float(np.quantile(durs, q)) if durs else 0.0


def _energy_from_counters(model_row: dict[str, Any]) -> dict[str, Any]:
    """Price a cumulative counter row through the shared cost model (the
    same path `sensor_energy(report)` takes — bitwise-equal on one replica)."""
    from repro.sensor.cost_model import sensor_energy

    return sensor_energy(SimpleNamespace(model=model_row))


def _dense_grid_steps(site_row: dict[str, Any]) -> float:
    """Mirror of SiteSensor.dense_grid_steps, from an emitted row."""
    block_n = site_row.get("block_n", 0)
    gn = -(-site_row.get("out_features", 0) // block_n) if block_n else 0
    return float(site_row.get("total_tiles", 0) * gn)


class FleetAggregator:
    """Merge N replica streams into per-replica and fleet-level rollups."""

    def __init__(self, streams: list[ReplicaStream] | None = None, *,
                 baseline_windows: int = 3):
        self.baseline_windows = baseline_windows
        self.streams: list[ReplicaStream] = []
        self.replicas: dict[str, _ReplicaAgg] = {}
        self._run_owner: dict[str, str] = {}
        for s in streams or []:
            self.add_stream(s)

    @classmethod
    def from_fleet_dir(cls, fleet_dir: str, **kw: Any) -> "FleetAggregator":
        streams = discover_replica_streams(fleet_dir)
        if not streams:
            raise ValueError(
                f"{fleet_dir}: no replica obs dirs found (expected "
                f"subdirectories holding sensor/spans/journal/metrics JSONL)")
        return cls(streams, **kw)

    def add_stream(self, stream: ReplicaStream) -> None:
        if stream.replica in self.replicas:
            raise ValueError(f"duplicate replica id {stream.replica!r}")
        self.streams.append(stream)
        self.replicas[stream.replica] = _ReplicaAgg(
            stream.replica, self.baseline_windows)

    # ------------------------------------------------------------------ intake
    def poll(self, *, final: bool = False) -> int:
        """Drain every stream's new rows into the rollup state. Returns the
        number of rows consumed this poll."""
        n = 0
        for stream in self.streams:
            agg = self.replicas[stream.replica]
            families = stream.poll(final=final)
            for fam, rows in families.items():
                for row in rows:
                    run = (row.get("trace") or {}).get("run")
                    if run is not None:
                        owner = self._run_owner.setdefault(
                            str(run), stream.replica)
                        if owner != stream.replica:
                            raise ValueError(
                                f"run id {run!r} appears in both replica "
                                f"{owner!r} and replica {stream.replica!r} "
                                f"— run ids must be unique fleet-wide")
                        agg.note_run(str(run))
                    if fam == "sensor":
                        agg.add_sensor(row)
                    elif fam == "spans":
                        agg.add_span(row)
                    elif fam == "journal":
                        agg.add_journal(row)
                    elif fam == "metrics":
                        agg.add_metric(row)
                    n += 1
        return n

    # ----------------------------------------------------------------- health
    def health(self, replica: str) -> ReplicaHealth:
        agg = self.replicas[replica]
        stream = next(s for s in self.streams if s.replica == replica)
        model = agg.model or {}
        skip_window = agg.window_skips[-1] if agg.window_skips else 0.0
        baseline = agg.skip_baseline()
        return ReplicaHealth(
            replica=replica,
            run=agg.runs[-1] if agg.runs else None,
            steps=int(model.get("steps", 0)),
            windows=agg.windows,
            quarantined_lanes=agg.quarantined_lanes(),
            sentinel_trips=int(model.get("sentinel_trips", 0)),
            stall_windows=agg.stall_windows,
            torn_lines=stream.torn_lines,
            alerts=agg.alerts,
            skip_window=float(skip_window),
            skip_baseline=baseline,
            skip_trend=float(skip_window) - baseline,
        )

    def health_by_replica(self) -> dict[str, ReplicaHealth]:
        return {r: self.health(r) for r in sorted(self.replicas)}

    def note_alert(self, replica: str, n: int = 1) -> None:
        """SLO-watcher feedback: alerts count into the replica's health."""
        self.replicas[replica].alerts += n

    # ---------------------------------------------------------------- rollups
    def site_rollups(self) -> list[dict[str, Any]]:
        """Per-(replica, site, layer) view from each replica's latest rows."""
        out = []
        for replica in sorted(self.replicas):
            agg = self.replicas[replica]
            for (site, layer), row in sorted(
                    agg.site_rows.items(),
                    key=lambda kv: (kv[0][0], -1 if kv[0][1] is None
                                    else kv[0][1])):
                site_skips = agg.site_window_skips.get(site)
                out.append({
                    "replica": replica,
                    "site": site,
                    "layer": layer,
                    "mode": row.get("mode"),
                    "exec_path": row.get("exec_path"),
                    "steps": row.get("steps", 0),
                    "mac_skip_rate": row.get("mac_skip_rate", 0.0),
                    "tile_skip_rate": row.get("tile_skip_rate", 0.0),
                    "grid_step_skip_rate": row.get("grid_step_skip_rate", 0.0),
                    "hit_rate": row.get("hit_rate", 0.0),
                    "sentinel_trips": row.get("sentinel_trips", 0),
                    "skip_window": (site_skips[-1]
                                    if layer is None and site_skips else None),
                })
        return out

    def _replica_rollup(self, replica: str) -> dict[str, Any]:
        agg = self.replicas[replica]
        model = agg.model or {}
        health = self.health(replica)
        lat = {
            "serve_step_count": len(agg.span_durs.get("serve_step", ())),
            "serve_step_p50_s": agg.span_quantile("serve_step", 0.5),
            "serve_step_p95_s": agg.span_quantile("serve_step", 0.95),
        }
        return {
            "replica": replica,
            "run": health.run,
            "runs": list(agg.runs),
            "steps": health.steps,
            "windows": agg.windows,
            "n_sites": int(model.get("n_sites", 0)),
            "mac_skip_rate": model.get("mac_skip_rate", 0.0),
            "tile_skip_rate": model.get("tile_skip_rate", 0.0),
            "weight_byte_skip_rate": model.get("weight_byte_skip_rate", 0.0),
            "grid_step_skip_rate": model.get("grid_step_skip_rate", 0.0),
            "hit_rate": model.get("hit_rate", 0.0),
            "energy": (_energy_from_counters(model) if model else None),
            "latency": lat,
            "health": health.to_dict(),
        }

    def fleet_report(self) -> dict[str, Any]:
        """The fleet rollup: per-replica rows + counter-summed fleet rates.

        Fleet rates are recomputed from summed counters with build_report's
        exact formulas (same guards, same order), so a one-replica fleet is
        bitwise-equal to that replica's SensorReport numbers."""
        per_replica = [self._replica_rollup(r) for r in sorted(self.replicas)]
        keys = ("skipped_tiles", "computed_tiles", "skipped_macs",
                "computed_macs", "skipped_weight_bytes", "total_weight_bytes",
                "grid_steps", "sentinel_trips")
        tot = {k: 0.0 for k in keys}
        dense_grid = 0.0
        all_serve: list[float] = []
        for replica in sorted(self.replicas):
            agg = self.replicas[replica]
            model = agg.model or {}
            for k in keys:
                tot[k] += model.get(k, 0)
            dense_grid += sum(
                _dense_grid_steps(row)
                for (site, layer), row in agg.site_rows.items()
                if layer is None)
            all_serve.extend(agg.span_durs.get("serve_step", ()))
        total_tiles = tot["skipped_tiles"] + tot["computed_tiles"]
        total_macs = tot["skipped_macs"] + tot["computed_macs"]
        energies = [r["energy"] for r in per_replica if r["energy"]]
        fleet = dict(
            tot,
            steps=sum(r["steps"] for r in per_replica),
            windows=sum(r["windows"] for r in per_replica),
            total_tiles=total_tiles,
            total_macs=total_macs,
            tile_skip_rate=tot["skipped_tiles"] / max(total_tiles, 1),
            mac_skip_rate=tot["skipped_macs"] / max(total_macs, 1e-9),
            weight_byte_skip_rate=(tot["skipped_weight_bytes"]
                                   / max(tot["total_weight_bytes"], 1e-9)),
            grid_step_skip_rate=max(
                0.0, 1.0 - tot["grid_steps"] / max(dense_grid, 1e-9)),
            hit_rate=(float(np.mean([r["hit_rate"] for r in per_replica]))
                      if per_replica else 0.0),
            energy={
                "baseline_dynamic_j": math.fsum(
                    e["baseline_dynamic_j"] for e in energies),
                "measured_dynamic_j": math.fsum(
                    e["measured_dynamic_j"] for e in energies),
                "saved_dynamic_j": math.fsum(
                    e["saved_dynamic_j"] for e in energies),
            },
            # cross-mesh ICI spend (sharded replicas only; counter rows from
            # unsharded replicas carry no ici keys and contribute 0.0)
            ici_j=math.fsum(e.get("ici_j", 0.0) for e in energies),
            latency={
                "serve_step_count": len(all_serve),
                "serve_step_p50_s": (float(np.quantile(all_serve, 0.5))
                                     if all_serve else 0.0),
                "serve_step_p95_s": (float(np.quantile(all_serve, 0.95))
                                     if all_serve else 0.0),
            },
            quarantined_lanes=sum(
                r["health"]["quarantined_lanes"] for r in per_replica),
            stall_windows=sum(
                r["health"]["stall_windows"] for r in per_replica),
            torn_lines=sum(r["health"]["torn_lines"] for r in per_replica),
            alerts=sum(r["health"]["alerts"] for r in per_replica),
        )
        base = fleet["energy"]["baseline_dynamic_j"]
        fleet["energy"]["dynamic_reduction"] = \
            fleet["energy"]["saved_dynamic_j"] / max(base, 1e-30)
        return {
            "kind": "fleet_report",
            "schema_version": FLEET_REPORT_SCHEMA_VERSION,
            "n_replicas": len(per_replica),
            "per_replica": per_replica,
            "fleet": fleet,
        }

    def summary_lines(self) -> list[str]:
        rep = self.fleet_report()
        f = rep["fleet"]
        lines = [
            f"FleetReport replicas={rep['n_replicas']} "
            f"steps={f['steps']} windows={f['windows']} "
            f"mac_skip={f['mac_skip_rate']:.1%} "
            f"grid_step_skip={f['grid_step_skip_rate']:.1%} "
            f"energy_saved={f['energy']['dynamic_reduction']:.1%} "
            f"serve_p95={f['latency']['serve_step_p95_s'] * 1e3:.2f}ms "
            f"quarantined={f['quarantined_lanes']} alerts={f['alerts']}"
        ]
        for r in rep["per_replica"]:
            h = r["health"]
            lines.append(
                f"  replica {r['replica']:12s} run={str(r['run']):12s} "
                f"steps={r['steps']:4d} mac_skip={r['mac_skip_rate']:6.1%} "
                f"p95={r['latency']['serve_step_p95_s'] * 1e3:7.2f}ms "
                f"quarantined={h['quarantined_lanes']} "
                f"trips={h['sentinel_trips']} stalls={h['stall_windows']} "
                f"trend={h['skip_trend']:+.3f} [{h['status']}]"
            )
        return lines


def export_fleet_metrics(registry, agg: FleetAggregator) -> None:
    """Fleet rollup → `fleet_*` gauges on the shared registry (one labeled
    series per replica + a scope="fleet" rollup series), the Prometheus
    surface the SLO watcher's alert counters share."""
    report = agg.fleet_report()
    for r in report["per_replica"]:
        h = r["health"]
        labels = {"replica": r["replica"]}
        registry.gauge("fleet_mac_skip", **labels).set(r["mac_skip_rate"])
        registry.gauge("fleet_grid_step_skip", **labels).set(
            r["grid_step_skip_rate"])
        registry.gauge("fleet_hit_rate", **labels).set(r["hit_rate"])
        registry.gauge("fleet_steps", **labels).set(r["steps"])
        registry.gauge("fleet_windows", **labels).set(r["windows"])
        registry.gauge("fleet_serve_step_p95_seconds", **labels).set(
            r["latency"]["serve_step_p95_s"])
        registry.gauge("fleet_quarantined_lanes", **labels).set(
            h["quarantined_lanes"])
        registry.gauge("fleet_sentinel_trips", **labels).set(
            h["sentinel_trips"])
        registry.gauge("fleet_stall_windows", **labels).set(
            h["stall_windows"])
        registry.gauge("fleet_torn_lines", **labels).set(h["torn_lines"])
        registry.gauge("fleet_skip_window", **labels).set(h["skip_window"])
        registry.gauge("fleet_skip_baseline", **labels).set(
            h["skip_baseline"])
        if r["energy"]:
            registry.gauge("fleet_energy_saved_joules", **labels).set(
                r["energy"]["saved_dynamic_j"])
    f = report["fleet"]
    registry.gauge("fleet_mac_skip", scope="fleet").set(f["mac_skip_rate"])
    registry.gauge("fleet_grid_step_skip", scope="fleet").set(
        f["grid_step_skip_rate"])
    registry.gauge("fleet_steps", scope="fleet").set(f["steps"])
    registry.gauge("fleet_serve_step_p95_seconds", scope="fleet").set(
        f["latency"]["serve_step_p95_s"])
    registry.gauge("fleet_quarantined_lanes", scope="fleet").set(
        f["quarantined_lanes"])
    registry.gauge("fleet_energy_saved_joules", scope="fleet").set(
        f["energy"]["saved_dynamic_j"])
    registry.gauge("fleet_replicas", scope="fleet").set(
        report["n_replicas"])
