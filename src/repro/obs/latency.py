"""Measured per-(site, layer, exec_path) latency table — the obs payoff.

The ROADMAP's top open item: every break-even knob in the control loop is
calibrated against a cost MODEL (energy constants, `RAGGED_BREAK_EVEN_SKIP`),
not observed wall-clock. This module produces the measured replacement:

* :class:`LatencyTable` — per-(site, layer, exec_path) latency statistics
  (count / mean / p50 / p95 seconds), saved/loaded as versioned JSON exactly
  like the tuned-policy table;
* :func:`build_from_spans` — builds a table from obs spans that carry
  ``site`` / ``exec_path`` tags (the probe emits them; any span source works);
* :func:`probe_latency_table` — measures each registered site's dispatch
  wall-clock per viable execution path (basic-mode dense GEMM as the
  baseline, plus every reuse substrate the impl supports), on a synthetic
  delta stream matched to the site's MEASURED skip rate, with
  `block_until_ready` inside `perf_counter` spans.

`repro.tune.fit --latency-table` and the online retuner
(`repro.control.Controller`) hand the loaded table to the harvest model
(`FitConfig.latency`), which then prices break-even hit rates and exec-path
pins from these measured numbers instead of the energy-model constants.

Stacked sites are probed once at layer=None (every layer shares the dispatch
geometry; per-layer MODE differences are captured by probing both the basic
and reuse paths), and `LatencyTable.stat` falls back layer→None on lookup.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

import numpy as np

LATENCY_TABLE_SCHEMA_VERSION = 1
LATENCY_TABLE_KIND = "obs_latency_table"

# The baseline "execution path" of the basic-mode (ReuseOFF) evaluation —
# not a member of core EXEC_PATHS on purpose: it names the whole dense
# quantized GEMM the reuse paths are priced against.
BASIC_PATH = "basic"


class LatencyTableError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class LatencyStat:
    count: int
    mean_s: float
    p50_s: float
    p95_s: float

    @staticmethod
    def from_samples(samples: Iterable[float]) -> "LatencyStat":
        a = np.asarray(list(samples), np.float64)
        return LatencyStat(
            count=int(a.size),
            mean_s=float(a.mean()) if a.size else 0.0,
            p50_s=float(np.quantile(a, 0.5)) if a.size else 0.0,
            p95_s=float(np.quantile(a, 0.95)) if a.size else 0.0,
        )


_Key = tuple[str, Any, str]  # (site, layer|None, exec_path)

# Provenance fields stamped on every row (and the table meta): which substrate
# produced the measurement. Compiled and interpret-mode numbers differ by
# 20-80x on CPU — conflating them poisons every consumer downstream.
TAG_FIELDS = ("backend", "interpret", "jax_version", "jaxlib_version")


class LatencyTable:
    """Measured dispatch latency per (site, layer, exec_path)."""

    def __init__(self):
        self._samples: dict[_Key, list[float]] = {}
        self._tags: dict[_Key, dict[str, Any]] = {}
        self.meta: dict[str, Any] = {}

    def record(self, site: str, layer: int | None, exec_path: str,
               seconds: float, *, tags: dict[str, Any] | None = None) -> None:
        key = (site, layer, exec_path)
        self._samples.setdefault(key, []).append(float(seconds))
        if tags:
            self._tags[key] = {k: tags[k] for k in TAG_FIELDS if k in tags}

    def stat(self, site: str, exec_path: str, *,
             layer: int | None = None) -> LatencyStat | None:
        """Measured stats for one (site, layer, exec_path); a layer-specific
        lookup falls back to the site-wide (layer=None) row."""
        samples = self._samples.get((site, layer, exec_path))
        if samples is None and layer is not None:
            samples = self._samples.get((site, None, exec_path))
        if not samples:
            return None
        return LatencyStat.from_samples(samples)

    def paths_for(self, site: str, *,
                  layer: int | None = None) -> dict[str, LatencyStat]:
        """{exec_path: stat} of every measured path for one site (layer rows
        preferred, site-wide rows filling the gaps)."""
        out: dict[str, LatencyStat] = {}
        for (s, lyr, path), samples in self._samples.items():
            if s != site or not samples:
                continue
            if lyr is None and path not in out:
                out[path] = LatencyStat.from_samples(samples)
            elif layer is not None and lyr == layer:
                out[path] = LatencyStat.from_samples(samples)
        return out

    def rows(self) -> list[dict[str, Any]]:
        out = []
        for (site, layer, path), samples in sorted(
            self._samples.items(),
            key=lambda kv: (kv[0][0], -1 if kv[0][1] is None else kv[0][1],
                            kv[0][2]),
        ):
            stat = LatencyStat.from_samples(samples)
            out.append({
                "site": site, "layer": layer, "exec_path": path,
                **dataclasses.asdict(stat),
                **self._tags.get((site, layer, path), {}),
            })
        return out

    def __len__(self) -> int:
        return len(self._samples)

    def summary_lines(self) -> list[str]:
        lines = [f"LatencyTable: {len(self)} (site, layer, exec_path) rows"]
        for r in self.rows():
            where = r["site"] + (f"@{r['layer']}" if r["layer"] is not None
                                 else "")
            lines.append(
                f"  {where:24s} {r['exec_path']:8s} n={r['count']:3d} "
                f"mean={r['mean_s'] * 1e6:9.1f}us p50={r['p50_s'] * 1e6:9.1f}us "
                f"p95={r['p95_s'] * 1e6:9.1f}us"
            )
        return lines

    # ------------------------------------------------------------ save/load

    def save(self, path: str, *, meta: dict[str, Any] | None = None) -> None:
        doc = {
            "schema_version": LATENCY_TABLE_SCHEMA_VERSION,
            "kind": LATENCY_TABLE_KIND,
            "meta": {**self.meta, **(meta or {})},
            "rows": self.rows(),
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


def load_latency_table(path: str) -> LatencyTable:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("kind") != LATENCY_TABLE_KIND:
        raise LatencyTableError(f"{path}: not a {LATENCY_TABLE_KIND} document")
    ver = doc.get("schema_version")
    if ver != LATENCY_TABLE_SCHEMA_VERSION:
        raise LatencyTableError(
            f"{path}: schema_version {ver} != supported "
            f"{LATENCY_TABLE_SCHEMA_VERSION}")
    table = LatencyTable()
    table.meta = dict(doc.get("meta", {}))
    for r in doc.get("rows", []):
        # mean-weighted reconstruction: one synthetic sample per recorded
        # stat keeps save→load→stat round trips exact for mean, and p50/p95
        # collapse onto it (percentile detail lives in the saving process)
        key = (r["site"], r.get("layer"), r["exec_path"])
        table._samples[key] = [float(r["mean_s"])] * max(int(r["count"]), 1)
        tags = {k: r[k] for k in TAG_FIELDS if k in r}
        if tags:
            table._tags[key] = tags
    return table


def table_provenance(table: LatencyTable) -> str:
    """Which substrate produced a table's measurements.

    "compiled"  — every row (or the meta) says a compiled backend
    "interpret" — every tagged row says interpret-mode Pallas
    "mixed"     — both kinds of rows in one table
    "unknown"   — no backend tags anywhere (a pre-backend-tag table)

    `fit --latency-table` and `serve --latency-table` warn (and journal) on
    anything but "compiled": interpret numbers price the policy against a
    cost model 20-80x off compiled reality.
    """
    flags: set[bool] = set()
    for key in table._samples:
        tags = table._tags.get(key)
        if tags is not None and "interpret" in tags:
            flags.add(bool(tags["interpret"]))
    if not flags and "interpret" in table.meta:
        flags.add(bool(table.meta["interpret"]))
    if not flags:
        return "unknown"
    if flags == {False}:
        return "compiled"
    if flags == {True}:
        return "interpret"
    return "mixed"


def build_from_spans(span_rows: Iterable[dict[str, Any]]) -> LatencyTable:
    """A LatencyTable from obs spans tagged with site/exec_path (layer
    optional) — the probe's spans, or any instrumented source."""
    table = LatencyTable()
    for row in span_rows:
        site = row.get("site")
        path = row.get("exec_path")
        if site is None or path is None:
            continue
        table.record(site, row.get("layer"), path, row["dur_s"], tags=row)
    return table


# -------------------------------------------------------------- the prober

def _path_tag(impl: str, path: str) -> dict[str, Any]:
    """Substrate provenance for one probed path. The dense/compact/basic
    paths are pure-jnp code on every impl (compiled XLA); kernel/ragged go
    through the ops wrappers, whose substrate `kernels.backend` resolves
    from the impl (compiled Pallas, compiled-XLA tier, or — only for
    impl="pallas_interpret" — the explicit interpret test mode)."""
    from repro.kernels import backend

    if path in (BASIC_PATH, "dense", "compact"):
        return backend.tag(backend.XLA)
    return backend.tag(backend.for_impl(impl))


def _viable_paths(spec, impl: str) -> list[str]:
    """Execution paths measurable for one site on one substrate: the masked
    walk plus — when the K extent compacts (gk >= 2) — the compacted tier."""
    gk = -(-spec.in_features // spec.block_k)
    if impl == "jnp":
        paths = ["dense"]
        if gk >= 2:
            paths.append("compact")
    else:
        paths = ["kernel"]
        if gk >= 2:
            paths.append("ragged")
    return paths


def probe_latency_table(
    engine,
    batch: int,
    *,
    skip_rates: dict[str, float] | None = None,
    iters: int = 5,
    warmup: int = 2,
    seed: int = 0,
) -> LatencyTable:
    """Measure every registered site's dispatch wall-clock per viable path.

    For each site: a synthetic activation pair whose delta skips ~the site's
    measured tile-skip rate (`skip_rates`, e.g. from a live SensorReport;
    default 0.5), probed through a jitted `reuse_linear` per path —
    basic-mode dense GEMM as the baseline (recorded as exec_path "basic"),
    then each reuse substrate. Timing is `perf_counter` around
    `block_until_ready`, emitted as obs spans (`site_probe`), and the table
    is built from those spans — so a probe run joins the event stream like
    any other measurement.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.reuse_cache import init_site_cache
    from repro.core.reuse_linear import reuse_linear
    from repro.obs import trace

    was_enabled = trace.is_enabled()
    if not was_enabled:
        trace.enable()
    probe_spans: list[dict[str, Any]] = []
    rng = np.random.default_rng(seed)
    for name, spec in engine.sites.items():
        skip = float((skip_rates or {}).get(name, 0.5))
        skip = min(max(skip, 0.0), 1.0)
        gk = -(-spec.in_features // spec.block_k)
        # Two activation sets whose mutual delta leaves ~skip of the K-blocks
        # untouched: alternating them gives every timed call the same
        # measured-regime tile occupancy.
        x_a = rng.standard_normal((batch, spec.in_features)).astype(np.float32)
        x_b = x_a.copy()
        live_blocks = [j for j in range(gk) if rng.random() >= skip] or [0]
        for j in live_blocks:
            lo = j * spec.block_k
            hi = min(lo + spec.block_k, spec.in_features)
            x_b[:, lo:hi] += rng.standard_normal(
                (batch, hi - lo)).astype(np.float32)
        w = rng.standard_normal(
            (spec.in_features, spec.out_features)).astype(np.float32) * 0.05
        xs = [jnp.asarray(x_a), jnp.asarray(x_b)]
        w = jnp.asarray(w)

        budget = spec.max_active_k
        if budget is None:
            occupancy = max(len(live_blocks) / gk, 1.0 / gk)
            budget = max(1, min(gk, int(np.ceil(gk * occupancy * 1.25))))

        for path in [BASIC_PATH] + _viable_paths(spec, engine.impl):
            if path == BASIC_PATH:
                pspec, mode = spec, "basic"
            else:
                pspec = dataclasses.replace(
                    spec, exec_path=path,
                    max_active_k=(budget if path in ("ragged", "compact")
                                  else None),
                )
                mode = "reuse"
            cache = init_site_cache(pspec, batch, engine.policy.resolve(name))

            @jax.jit
            def step(x, c, _spec=pspec, _mode=mode):
                out, new_c, _ = reuse_linear(
                    x, w, None, c, _spec, mode=_mode, impl=engine.impl)
                return out, new_c

            for i in range(max(warmup, 1)):
                out, cache = step(xs[i % 2], cache)
            jax.block_until_ready(out)
            n0 = len(trace.spans())
            for i in range(iters):
                with trace.span("site_probe", site=name, layer=None,
                                exec_path=path, skip_rate=skip,
                                **_path_tag(engine.impl, path)) as sp:
                    out, cache = step(xs[i % 2], cache)
                    sp.sync(out)
            probe_spans.extend(trace.spans()[n0:])

    table = build_from_spans(probe_spans)
    from repro.kernels import backend as _backend

    table.meta = {
        "source": "probe_latency_table",
        "impl": engine.impl,
        "batch": batch,
        "iters": iters,
        **_backend.tag(_backend.for_impl(engine.impl)),
    }
    if not was_enabled:
        trace.disable()
    return table
