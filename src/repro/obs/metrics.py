"""Metrics registry — counters, gauges, histograms with p50/p95/p99.

One registry per observation scope (a serve run, a benchmark). Metrics are
keyed by (name, sorted label set), Prometheus-style, so the exporter can emit
them as a textfile and `repro.obs.top` can render them live. Histograms keep
a bounded ring of recent samples (plus exact count/sum/min/max), so long
serve runs get recent-window percentiles at O(1) memory.

Aggregation helpers pull the existing telemetry sources into the registry:
`observe_sensor_report` (sensor counters → gauges), `observe_control_report`
(controller decisions → counters), and `observe_spans` (span durations →
histograms keyed by span name).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable

import numpy as np

_QUANTILES = (0.5, 0.95, 0.99)


def _label_key(labels: dict[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class Counter:
    name: str
    labels: dict[str, Any]
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclasses.dataclass
class Gauge:
    name: str
    labels: dict[str, Any]
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-reservoir histogram: exact count/sum/min/max over the full
    stream, percentiles over the most recent `window` samples."""

    def __init__(self, name: str, labels: dict[str, Any], *,
                 window: int = 4096):
        self.name = name
        self.labels = labels
        self.window = window
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._ring = np.zeros((window,), np.float64)
        self._n_ring = 0
        self._pos = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._ring[self._pos] = v
        self._pos = (self._pos + 1) % self.window
        self._n_ring = min(self._n_ring + 1, self.window)

    def percentile(self, q: float) -> float:
        """q in [0, 1] over the recent-sample window (0.0 when empty)."""
        if self._n_ring == 0:
            return 0.0
        return float(np.quantile(self._ring[: self._n_ring], q))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self) -> dict[str, float]:
        out = {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }
        for q in _QUANTILES:
            out[f"p{int(q * 100)}"] = self.percentile(q)
        return out


class MetricsRegistry:
    def __init__(self):
        self._metrics: dict[tuple, Any] = {}

    def _get(self, cls, name: str, labels: dict[str, Any], **kw):
        key = (cls.__name__, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, labels, **kw)
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(Histogram, name, labels)

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> list[dict[str, Any]]:
        """Plain-dict view of every metric — the exporter/`obs.top` input."""
        rows = []
        for m in self._metrics.values():
            row: dict[str, Any] = {
                "name": m.name,
                "labels": dict(m.labels),
                "type": type(m).__name__.lower(),
            }
            if isinstance(m, Histogram):
                row.update(m.summary())
            else:
                row["value"] = m.value
            rows.append(row)
        return rows


# ------------------------------------------------- telemetry-source adapters

def observe_sensor_report(registry: MetricsRegistry, report) -> None:
    """Sensor counters → gauges (model totals + per-site skip rates)."""
    model = report.model
    for key in ("mac_skip_rate", "tile_skip_rate", "weight_byte_skip_rate",
                "grid_step_skip_rate", "hit_rate"):
        if key in model:
            registry.gauge(f"reuse_{key}", scope="model").set(model[key])
    registry.gauge("reuse_steps", scope="model").set(model.get("steps", 0))
    for s in report.per_site:
        registry.gauge("reuse_site_tile_skip_rate", site=s.site).set(
            s.tile_skip_rate)
        registry.gauge("reuse_site_hit_rate", site=s.site).set(s.hit_rate)
        registry.gauge("reuse_site_overflow_fallbacks", site=s.site).set(
            s.overflow_fallbacks)


def observe_control_report(registry: MetricsRegistry, report) -> None:
    """Controller interval → decision counters by kind, retrace counter."""
    registry.counter("control_intervals").inc()
    for d in report.decisions:
        registry.counter("control_decisions", kind=d.kind).inc()
    if report.retrace:
        registry.counter("control_retraces").inc(len(report.retrace))


def observe_guard_report(registry: MetricsRegistry, report) -> None:
    """Guard-plane breaker pass → sentinel-trip counters by site and check,
    live quarantined-lane gauge, stall counter. The interesting alerting
    signal is `guard_sentinel_trips` staying at zero on healthy runs —
    the chaos CI job asserts the non-zero side."""
    for t in report.trips:
        registry.counter("guard_sentinel_trips",
                         site=t.site, check=t.check).inc()
    if report.stalled:
        registry.counter("guard_stall_windows").inc()
    registry.gauge("guard_quarantined_lanes").set(report.quarantined_lanes)


def observe_spans(registry: MetricsRegistry,
                  span_rows: Iterable[dict[str, Any]]) -> None:
    """Span durations → one histogram per span name (seconds)."""
    for row in span_rows:
        registry.histogram(f"span_{row['name']}_seconds").observe(
            row["dur_s"])
