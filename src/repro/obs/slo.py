"""Windowed SLO / anomaly watch over a :class:`FleetAggregator`.

The router (PR 10) must not learn about a limping replica by routing traffic
into it. This watcher turns the fleet rollup into explicit, attributed alert
rows — journal-style JSONL, same torn-tail tolerance on read — plus
Prometheus counters on the shared registry:

* **skip collapse** — a replica's windowed mac_skip falls below
  ``collapse_frac`` of its *own* trailing baseline for
  ``collapse_consecutive`` consecutive sensor windows. Watched at replica
  level AND per site: one quarantined lane on an 8-lane model only dents
  replica-level skip by ~1/8, but halves its 2-layer site — per-site watch
  is what makes a single-lane containment visible.
* **p95 burn** — measured ``serve_step`` span p95 exceeds the configured
  target (off unless a target is set).
* **quarantine spike** — the replica's quarantined-lane count rose since the
  last evaluation (the guard contained something; the router should know
  before the skip trend shows it).

Alerts fire once per episode (condition must clear before the same key can
alert again), so a sustained collapse is one row, not one per window.
Every alert is attributed to exactly one replica — the acceptance bar is a
clean replica staying alert-free while an injected one is named.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from repro.obs.fleet import FleetAggregator
from repro.obs.stream import TailCursor, tail_jsonl

ALERT_SCHEMA_VERSION = 1


@dataclasses.dataclass
class SLOConfig:
    """Thresholds for the fleet watch plane."""

    collapse_frac: float = 0.6        # window skip < frac * baseline => bad
    collapse_consecutive: int = 2     # bad windows in a row before alerting
    min_baseline_skip: float = 0.05   # don't judge a replica still warming up
    p95_target_s: float | None = None  # serve_step p95 burn target (off=None)
    p95_min_count: int = 5            # spans needed before p95 is judged
    quarantine_spike_lanes: int = 1   # lane-count rise that triggers an alert


class SLOWatcher:
    """Evaluate SLO rules against an aggregator after each poll.

    Call :meth:`evaluate` whenever the aggregator has consumed new rows;
    it returns only the alerts newly raised by that evaluation. Alerts are
    appended to ``alerts_path`` (journal-style JSONL) when given, counted
    into ``registry`` as ``fleet_alerts_total{alert=...,replica=...}``, and
    fed back into the aggregator's per-replica health via ``note_alert``.
    """

    def __init__(self, agg: FleetAggregator,
                 config: SLOConfig | None = None, *,
                 registry=None, alerts_path: str | None = None):
        self.agg = agg
        self.config = config or SLOConfig()
        self.registry = registry
        self.alerts_path = alerts_path
        self.alerts: list[dict[str, Any]] = []
        # episode state, keyed (replica, rule-site key)
        self._streak: dict[tuple[str, str], int] = {}
        self._active: set[tuple[str, str]] = set()
        self._last_window: dict[str, int] = {}
        self._last_lanes: dict[str, int] = {}

    # ------------------------------------------------------------------ emit
    def _emit(self, replica: str, alert_kind: str, *,
              site: str = "", value: float = 0.0, baseline: float = 0.0,
              threshold: float = 0.0, window: int = 0,
              detail: str = "") -> dict[str, Any]:
        row = {
            "kind": "alert",
            "schema_version": ALERT_SCHEMA_VERSION,
            "alert_kind": alert_kind,
            "replica": replica,
            "site": site,
            "window": window,
            "value": value,
            "baseline": baseline,
            "threshold": threshold,
            "detail": detail,
        }
        agg_rep = self.agg.replicas.get(replica)
        if agg_rep and agg_rep.runs:
            row["run"] = agg_rep.runs[-1]
        self.alerts.append(row)
        self.agg.note_alert(replica)
        if self.registry is not None:
            self.registry.counter(
                "fleet_alerts_total", alert=alert_kind,
                replica=replica).inc()
        if self.alerts_path:
            with open(self.alerts_path, "a") as f:
                f.write(json.dumps(row) + "\n")
        return row

    # -------------------------------------------------------------- evaluate
    def evaluate(self) -> list[dict[str, Any]]:
        """Run every rule once; return the alerts raised by this pass."""
        before = len(self.alerts)
        for replica in sorted(self.agg.replicas):
            agg = self.agg.replicas[replica]
            fresh_window = agg.windows > self._last_window.get(replica, 0)
            self._last_window[replica] = agg.windows
            if fresh_window:
                self._check_collapse(
                    replica, "", list(agg.window_skips), agg.windows)
                for site in sorted(agg.site_window_skips):
                    self._check_collapse(
                        replica, site,
                        list(agg.site_window_skips[site]), agg.windows)
            self._check_p95(replica, agg)
            self._check_quarantine(replica, agg)
        return self.alerts[before:]

    def _check_collapse(self, replica: str, site: str,
                        skips: list[float], window: int) -> None:
        cfg = self.config
        key = (replica, site or "<replica>")
        if len(skips) < 2:
            return
        current, prior = skips[-1], skips[:-1]
        baseline = sum(prior) / len(prior)
        if baseline < cfg.min_baseline_skip:
            # still warming up (or a never-skipping lane): no baseline to
            # collapse from, and clearing the streak keeps warm-up noise out
            self._streak[key] = 0
            return
        if current < cfg.collapse_frac * baseline:
            self._streak[key] = self._streak.get(key, 0) + 1
            if self._streak[key] >= cfg.collapse_consecutive and \
                    key not in self._active:
                self._active.add(key)
                self._emit(
                    replica, "skip_collapse", site=site, value=current,
                    baseline=baseline,
                    threshold=cfg.collapse_frac, window=window,
                    detail=(f"windowed mac_skip {current:.3f} < "
                            f"{cfg.collapse_frac:.2f}x trailing baseline "
                            f"{baseline:.3f} for {self._streak[key]} "
                            f"consecutive windows"
                            + (f" at site {site}" if site else "")))
        else:
            self._streak[key] = 0
            self._active.discard(key)

    def _check_p95(self, replica: str, agg) -> None:
        cfg = self.config
        if cfg.p95_target_s is None:
            return
        durs = agg.span_durs.get("serve_step", ())
        if len(durs) < cfg.p95_min_count:
            return
        p95 = agg.span_quantile("serve_step", 0.95)
        key = (replica, "<p95>")
        if p95 > cfg.p95_target_s:
            if key not in self._active:
                self._active.add(key)
                self._emit(
                    replica, "p95_burn", value=p95,
                    threshold=cfg.p95_target_s, window=agg.windows,
                    detail=(f"serve_step p95 {p95 * 1e3:.2f}ms over target "
                            f"{cfg.p95_target_s * 1e3:.2f}ms "
                            f"(n={len(durs)})"))
        else:
            self._active.discard(key)

    def _check_quarantine(self, replica: str, agg) -> None:
        lanes = agg.quarantined_lanes()
        last = self._last_lanes.get(replica, 0)
        self._last_lanes[replica] = lanes
        if lanes - last >= self.config.quarantine_spike_lanes:
            self._emit(
                replica, "quarantine_spike", value=lanes, baseline=last,
                threshold=self.config.quarantine_spike_lanes,
                window=agg.windows,
                detail=(f"quarantined lanes rose {last} -> {lanes}"))


def load_alerts(path: str) -> list[dict[str, Any]]:
    """Read an alert JSONL file, forgiving a torn final line (the watcher
    may have died mid-append) like `load_journal` does."""
    if not os.path.exists(path):
        return []
    return tail_jsonl(path, TailCursor(), final=True)
