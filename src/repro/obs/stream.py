"""Tailing JSONL readers — one replica's obs dir as an incremental stream.

A serving replica emits four JSONL families into its obs dir while it runs
(`sensor.jsonl` cumulative counter rows, `spans.jsonl` measured wall-clock,
`journal.jsonl` control/guard decisions, `metrics.jsonl` registry snapshots).
The fleet plane must consume them *while the replica is still writing*, so
:func:`tail_jsonl` reads incrementally from a byte cursor and holds back an
incomplete final line (a row the replica is mid-append on) instead of failing
on it — the same crash-tolerance contract `repro.control.report.load_journal`
practices at rest:

* a line without a trailing newline is NOT consumed — the next poll retries
  it once the writer finishes (or the final poll counts it as torn);
* on the FINAL poll (`final=True`, the replica is known dead) a leftover
  partial or unparseable last line is forgiven and counted in
  ``TailCursor.torn`` — a replica that died mid-append still aggregates;
* an unparseable line with rows AFTER it is mid-file corruption and raises —
  silently skipping interior rows would corrupt fleet rollups.

:class:`ReplicaStream` bundles one cursor per family for a replica obs dir
(the layout ``serve --obs-dir`` and ``launch/replicas.py`` write) and is the
unit :class:`repro.obs.fleet.FleetAggregator` merges.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

# family name -> file inside a replica obs dir. `sensor` and `journal` match
# the serve flags (--sensor-jsonl / --control-journal) the replica harness
# points into the obs dir; `spans`/`metrics` are the --obs-dir exports.
STREAM_FAMILIES: dict[str, str] = {
    "sensor": "sensor.jsonl",
    "spans": "spans.jsonl",
    "journal": "journal.jsonl",
    "metrics": "metrics.jsonl",
}


@dataclasses.dataclass
class TailCursor:
    """Progress through one JSONL file: consumed bytes + torn-line count."""

    offset: int = 0
    rows: int = 0
    torn: int = 0


def tail_jsonl(path: str, cursor: TailCursor, *,
               final: bool = False) -> list[dict[str, Any]]:
    """Read rows appended to `path` since `cursor.offset`.

    Consumes only newline-terminated lines; a partial final line stays
    unconsumed for the next poll. With `final=True` (the writer is known
    finished) a leftover partial — or an unparseable last line — is counted
    as torn and skipped rather than raised: the one-torn-tail forgiveness of
    `load_journal`, applied to a live tail. Unparseable rows with data after
    them raise `ValueError` (real mid-file corruption)."""
    if not os.path.exists(path):
        return []
    with open(path, "rb") as f:
        f.seek(cursor.offset)
        data = f.read()
    if not data:
        return []
    end = data.rfind(b"\n")
    complete, partial = (b"", data) if end < 0 else (
        data[: end + 1], data[end + 1:])
    rows: list[dict[str, Any]] = []
    lines = complete.splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError as e:
            if final and i == len(lines) - 1 and not partial.strip():
                cursor.torn += 1  # newline-terminated torn tail: forgiven
                continue
            raise ValueError(
                f"{path}: unparseable row before the tail at byte "
                f"{cursor.offset} (mid-file corruption, not a torn append): "
                f"{e}") from e
    cursor.offset += len(complete)
    if final and partial.strip():
        cursor.torn += 1  # writer died mid-append: forgiven, counted
        cursor.offset += len(partial)
    cursor.rows += len(rows)
    return rows


class ReplicaStream:
    """One replica's obs dir as four incrementally-tailed row streams.

    `replica` defaults to the dir basename with a ``replica-`` prefix
    stripped (the `launch/replicas.py` layout). Rows stamped with a
    conflicting ``trace.replica`` id raise — a mislabeled stream must not
    silently pollute another replica's rollups."""

    def __init__(self, obs_dir: str, *, replica: str | None = None):
        self.obs_dir = obs_dir
        base = os.path.basename(os.path.normpath(obs_dir))
        if replica is None:
            replica = base[len("replica-"):] if base.startswith("replica-") \
                else base
        self.replica = replica
        self._cursors = {fam: TailCursor() for fam in STREAM_FAMILIES}

    def __repr__(self) -> str:
        return f"ReplicaStream({self.replica!r}, {self.obs_dir!r})"

    @property
    def torn_lines(self) -> int:
        return sum(c.torn for c in self._cursors.values())

    @property
    def rows_consumed(self) -> int:
        return sum(c.rows for c in self._cursors.values())

    def cursor(self, family: str) -> TailCursor:
        return self._cursors[family]

    def poll(self, *, final: bool = False) -> dict[str, list[dict[str, Any]]]:
        """New rows per family since the last poll. Verifies any stamped
        replica id matches this stream's identity."""
        out: dict[str, list[dict[str, Any]]] = {}
        for fam, fname in STREAM_FAMILIES.items():
            rows = tail_jsonl(
                os.path.join(self.obs_dir, fname), self._cursors[fam],
                final=final)
            for row in rows:
                stamped = (row.get("trace") or {}).get("replica")
                if stamped is not None and str(stamped) != str(self.replica):
                    raise ValueError(
                        f"{self.obs_dir}/{fname}: row stamped "
                        f"replica={stamped!r} inside replica "
                        f"{self.replica!r}'s stream")
            out[fam] = rows
        return out


def discover_replica_streams(fleet_dir: str) -> list[ReplicaStream]:
    """Replica streams under a fleet dir: every subdirectory holding at least
    one known stream family file (`replica-*` naming not required)."""
    streams = []
    for name in sorted(os.listdir(fleet_dir)):
        sub = os.path.join(fleet_dir, name)
        if not os.path.isdir(sub):
            continue
        if any(os.path.exists(os.path.join(sub, f))
               for f in STREAM_FAMILIES.values()):
            streams.append(ReplicaStream(sub))
    return streams
