"""``python -m repro.obs.top`` — live terminal view of a serve run's metrics.

Tails the JSONL snapshot stream written by `repro.obs.export.write_jsonl`
(e.g. `serve --obs-dir OUT` → `OUT/metrics.jsonl`) and renders the latest
snapshot as a compact table: gauges and counters first, then histogram rows
with count / mean / p50 / p95 / p99. ``--once`` renders a single frame and
exits (the CI smoke uses it to assert the stream is renderable).
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Any

from repro.obs.export import load_snapshots


def _fmt_val(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1e4 or abs(v) < 1e-3:
        return f"{v:.3g}"
    return f"{v:.4f}".rstrip("0").rstrip(".")


def _fmt_labels(labels: dict[str, Any]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def render_snapshot(rows: list[dict[str, Any]]) -> list[str]:
    """One terminal frame from one snapshot's rows."""
    trace = next((r.get("trace") for r in rows if r.get("trace")), None)
    header = f"repro.obs.top — snap {rows[0].get('snap', '?')}" if rows else \
        "repro.obs.top — empty stream"
    if trace:
        header += "  run=" + str(trace.get("run", "?"))
        if "window" in trace:
            header += f"  window={trace['window']}"
    lines = [header, "-" * len(header)]
    scalars = [r for r in rows if r["type"] in ("counter", "gauge")]
    hists = [r for r in rows if r["type"] == "histogram"]
    for r in sorted(scalars, key=lambda r: (r["name"], str(r["labels"]))):
        name = r["name"] + _fmt_labels(r["labels"])
        lines.append(f"  {name:48s} {_fmt_val(r['value']):>12s}")
    if hists:
        lines.append("")
        lines.append(f"  {'histogram':48s} {'count':>8s} {'mean':>10s} "
                     f"{'p50':>10s} {'p95':>10s} {'p99':>10s}")
        for r in sorted(hists, key=lambda r: (r["name"], str(r["labels"]))):
            name = r["name"] + _fmt_labels(r["labels"])
            lines.append(
                f"  {name:48s} {int(r['count']):>8d} "
                f"{_fmt_val(r['mean']):>10s} {_fmt_val(r['p50']):>10s} "
                f"{_fmt_val(r['p95']):>10s} {_fmt_val(r['p99']):>10s}")
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top", description=__doc__)
    ap.add_argument("metrics_jsonl", help="metrics snapshot stream "
                    "(e.g. OBS_DIR/metrics.jsonl)")
    ap.add_argument("--once", action="store_true",
                    help="render the latest snapshot once and exit")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="refresh period in seconds (follow mode)")
    args = ap.parse_args(argv)

    last_snap = None
    while True:
        if not os.path.exists(args.metrics_jsonl):
            print(f"waiting for {args.metrics_jsonl} ...")
        else:
            snaps = load_snapshots(args.metrics_jsonl)
            if snaps:
                rows = snaps[-1]
                snap_id = rows[0].get("snap")
                if args.once or snap_id != last_snap:
                    frame = render_snapshot(rows)
                    if not args.once:
                        sys.stdout.write("\x1b[2J\x1b[H")
                    print("\n".join(frame))
                    last_snap = snap_id
            elif args.once:
                print("repro.obs.top — empty stream")
        if args.once:
            return 0
        try:
            time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    raise SystemExit(main())
