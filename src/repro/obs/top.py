"""``python -m repro.obs.top`` — live terminal view of serve-run metrics.

Single-replica mode tails the JSONL snapshot stream written by
`repro.obs.export.write_jsonl` (e.g. `serve --obs-dir OUT` →
`OUT/metrics.jsonl`) and renders the latest snapshot as a compact table:
gauges and counters first, then histogram rows with count / mean / p50 /
p95 / p99. ``--once`` renders a single frame and exits (the CI smoke uses
it to assert the stream is renderable).

``--fleet`` takes a FLEET dir instead (replica obs subdirs, the
`launch/replicas.py` layout) and renders one column block per replica —
skip rates, serve-step latency, and the ReplicaHealth signals the router
reads — by running a `FleetAggregator` over the streams each frame.

Both modes share one snapshot loader (`load_latest_snapshot`) and exit
with a clear one-line error — not a traceback — on missing or empty
inputs.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Any

from repro.obs.export import load_snapshots


class TopError(Exception):
    """A user-facing condition (missing/empty stream) — message, no trace."""


def load_latest_snapshot(metrics_jsonl: str) -> list[dict[str, Any]]:
    """The latest snapshot's rows from a metrics JSONL stream.

    One code path for --once, follow mode, and the fleet view's per-replica
    panes. Raises :class:`TopError` with a clear message when the file is
    missing or holds no snapshots yet."""
    if not os.path.exists(metrics_jsonl):
        raise TopError(f"{metrics_jsonl}: no such metrics stream (expected "
                       f"the metrics.jsonl a --obs-dir run writes)")
    snaps = load_snapshots(metrics_jsonl)
    if not snaps:
        raise TopError(f"{metrics_jsonl}: stream exists but holds no "
                       f"snapshots yet")
    return snaps[-1]


def _fmt_val(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1e4 or abs(v) < 1e-3:
        return f"{v:.3g}"
    return f"{v:.4f}".rstrip("0").rstrip(".")


def _fmt_labels(labels: dict[str, Any]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def render_snapshot(rows: list[dict[str, Any]]) -> list[str]:
    """One terminal frame from one snapshot's rows."""
    trace = next((r.get("trace") for r in rows if r.get("trace")), None)
    header = f"repro.obs.top — snap {rows[0].get('snap', '?')}" if rows else \
        "repro.obs.top — empty stream"
    if trace:
        header += "  run=" + str(trace.get("run", "?"))
        if "replica" in trace:
            header += f"  replica={trace['replica']}"
        if "window" in trace:
            header += f"  window={trace['window']}"
    lines = [header, "-" * len(header)]
    scalars = [r for r in rows if r["type"] in ("counter", "gauge")]
    hists = [r for r in rows if r["type"] == "histogram"]
    for r in sorted(scalars, key=lambda r: (r["name"], str(r["labels"]))):
        name = r["name"] + _fmt_labels(r["labels"])
        lines.append(f"  {name:48s} {_fmt_val(r['value']):>12s}")
    if hists:
        lines.append("")
        lines.append(f"  {'histogram':48s} {'count':>8s} {'mean':>10s} "
                     f"{'p50':>10s} {'p95':>10s} {'p99':>10s}")
        for r in sorted(hists, key=lambda r: (r["name"], str(r["labels"]))):
            name = r["name"] + _fmt_labels(r["labels"])
            lines.append(
                f"  {name:48s} {int(r['count']):>8d} "
                f"{_fmt_val(r['mean']):>10s} {_fmt_val(r['p50']):>10s} "
                f"{_fmt_val(r['p95']):>10s} {_fmt_val(r['p99']):>10s}")
    return lines


def render_fleet(fleet_dir: str) -> list[str]:
    """One fleet frame: per-replica columns + health, from a fresh
    aggregation pass over every replica stream under `fleet_dir`."""
    from repro.obs.fleet import FleetAggregator

    from repro.obs.slo import load_alerts

    try:
        agg = FleetAggregator.from_fleet_dir(fleet_dir)
    except (ValueError, FileNotFoundError) as e:
        raise TopError(str(e)) from e
    agg.poll(final=True)
    # recorded SLO alerts (a fleet-level stream, not per-replica) fold back
    # into the health column they were attributed to
    for alert in load_alerts(os.path.join(fleet_dir, "alerts.jsonl")):
        if alert.get("replica") in agg.replicas:
            agg.note_alert(alert["replica"])
    report = agg.fleet_report()
    per = report["per_replica"]
    if not any(r["windows"] or r["steps"] for r in per):
        raise TopError(f"{fleet_dir}: replica dirs found but no sensor "
                       f"windows consumed yet")
    header = (f"repro.obs.top — fleet {fleet_dir} "
              f"({report['n_replicas']} replicas)")
    lines = [header, "-" * len(header)]
    cols = [("replica", lambda r: r["replica"]),
            ("run", lambda r: str(r["run"])),
            ("steps", lambda r: str(r["steps"])),
            ("windows", lambda r: str(r["windows"])),
            ("mac_skip", lambda r: f"{r['mac_skip_rate']:.1%}"),
            ("grid_skip", lambda r: f"{r['grid_step_skip_rate']:.1%}"),
            ("hit", lambda r: f"{r['hit_rate']:.3f}"),
            ("p95_ms",
             lambda r: f"{r['latency']['serve_step_p95_s'] * 1e3:.2f}"),
            ("quar", lambda r: str(r["health"]["quarantined_lanes"])),
            ("trips", lambda r: str(r["health"]["sentinel_trips"])),
            ("stalls", lambda r: str(r["health"]["stall_windows"])),
            ("torn", lambda r: str(r["health"]["torn_lines"])),
            ("alerts", lambda r: str(r["health"]["alerts"])),
            ("trend", lambda r: f"{r['health']['skip_trend']:+.3f}"),
            ("status", lambda r: r["health"]["status"])]
    widths = [max(len(title), *(len(fn(r)) for r in per)) + 2
              for title, fn in cols]
    lines.append("".join(t.rjust(w) for (t, _), w in zip(cols, widths)))
    for r in per:
        lines.append("".join(fn(r).rjust(w)
                             for (_, fn), w in zip(cols, widths)))
    f = report["fleet"]
    lines.append("")
    lines.append(
        f"  fleet: mac_skip={f['mac_skip_rate']:.1%} "
        f"grid_skip={f['grid_step_skip_rate']:.1%} "
        f"energy_saved={f['energy']['dynamic_reduction']:.1%} "
        f"p95={f['latency']['serve_step_p95_s'] * 1e3:.2f}ms "
        f"quarantined={f['quarantined_lanes']} alerts={f['alerts']}")
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top", description=__doc__)
    ap.add_argument("path", help="metrics snapshot stream "
                    "(OBS_DIR/metrics.jsonl), or with --fleet a fleet dir "
                    "of replica obs subdirs")
    ap.add_argument("--fleet", action="store_true",
                    help="treat PATH as a fleet dir and render per-replica "
                    "columns + health")
    ap.add_argument("--once", action="store_true",
                    help="render the latest frame once and exit")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="refresh period in seconds (follow mode)")
    args = ap.parse_args(argv)

    last_snap = None
    while True:
        try:
            if args.fleet:
                frame = render_fleet(args.path)
                snap_id = object()  # fleet frames re-render every interval
            else:
                rows = load_latest_snapshot(args.path)
                frame = render_snapshot(rows)
                snap_id = rows[0].get("snap")
        except TopError as e:
            if args.once:
                print(f"repro.obs.top: {e}", file=sys.stderr)
                return 1
            print(f"waiting: {e}")
        else:
            if args.once or snap_id != last_snap:
                if not args.once:
                    sys.stdout.write("\x1b[2J\x1b[H")
                print("\n".join(frame))
                last_snap = snap_id
        if args.once:
            return 0
        try:
            time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    raise SystemExit(main())
