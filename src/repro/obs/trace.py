"""Host-side spans — one clock discipline for every wall-clock number.

`span("serve_step", exec_path=...)` measures host wall time with
`time.perf_counter` (monotonic — never `time.time`, which steps under NTP),
optionally blocking on a jax value at close so the measurement covers device
execution, and emits a `jax.profiler.TraceAnnotation` so device traces line
up with host spans when a `--profile-dir` window is open. Spans nest (each
records its parent) and carry the current correlation ids from
:mod:`repro.obs.events`, so they join against sensor rows and journal
decisions.

Disabled (the default), `span()` returns ONE shared no-op context manager and
records nothing — the acceptance bar is < 3 % serve-step overhead with
observability off, so the disabled path is a dict lookup and a constant
return, no allocation.
"""

from __future__ import annotations

import time
from typing import Any

now = time.perf_counter  # THE clock for wall-time measurements, repo-wide

_STATE: dict[str, Any] = {
    "enabled": False,
    "spans": [],          # completed SpanRecord dicts, append order = close order
    "stack": [],          # open span ids (nesting)
    "next_id": 1,
    "max_spans": 262_144,  # hard cap: a runaway loop must not OOM the host
    "dropped": 0,
}


def enable(*, max_spans: int | None = None) -> None:
    _STATE["enabled"] = True
    if max_spans is not None:
        _STATE["max_spans"] = int(max_spans)


def disable() -> None:
    _STATE["enabled"] = False


def is_enabled() -> bool:
    return _STATE["enabled"]


def spans() -> list[dict[str, Any]]:
    """Completed spans so far (the live buffer — do not mutate)."""
    return _STATE["spans"]


def drain_spans() -> list[dict[str, Any]]:
    """Return and clear the completed-span buffer."""
    out, _STATE["spans"] = _STATE["spans"], []
    _STATE["dropped"] = 0
    return out


class _NoopSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def sync(self, value):
        return value

    def tag(self, **tags):
        return self


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "tags", "span_id", "parent_id", "_t0", "_sync",
                 "_annotation")

    def __init__(self, name: str, tags: dict[str, Any]):
        self.name = name
        self.tags = tags
        self.span_id = 0
        self.parent_id = 0
        self._t0 = 0.0
        self._sync = None
        self._annotation = None

    def sync(self, value):
        """Register a jax value to block_until_ready at span close, so the
        span covers device execution, not just dispatch. Returns the value."""
        self._sync = value
        return value

    def tag(self, **tags):
        """Attach tags discovered inside the span (e.g. tokens emitted)."""
        self.tags.update(tags)
        return self

    def __enter__(self):
        state = _STATE
        self.span_id = state["next_id"]
        state["next_id"] += 1
        stack = state["stack"]
        self.parent_id = stack[-1] if stack else 0
        stack.append(self.span_id)
        try:
            import jax

            self._annotation = jax.profiler.TraceAnnotation(self.name)
            self._annotation.__enter__()
        except Exception:  # profiler backends may be absent headless
            self._annotation = None
        self._t0 = now()
        return self

    def __exit__(self, *exc):
        if self._sync is not None:
            import jax

            jax.block_until_ready(self._sync)
        dur = now() - self._t0
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
        state = _STATE
        stack = state["stack"]
        if stack and stack[-1] == self.span_id:
            stack.pop()
        if len(state["spans"]) < state["max_spans"]:
            from repro.obs.events import current_ids

            record = {
                "name": self.name,
                "span_id": self.span_id,
                "parent_id": self.parent_id,
                "dur_s": dur,
                **self.tags,
            }
            ids = current_ids()
            if ids:
                record["trace"] = ids
            state["spans"].append(record)
        else:
            state["dropped"] += 1
        return False


def span(name: str, **tags: Any):
    """Open a measurement span. Usage:

        with span("serve_step", exec_path="compact") as sp:
            out = decode(...)
            sp.sync(out)        # block_until_ready at close

    Disabled → the shared no-op (no allocation, no record)."""
    if not _STATE["enabled"]:
        return _NOOP
    return _Span(name, tags)


# ------------------------------------------------------ device-trace windows

_PROFILE: dict[str, Any] = {"dir": None}


def start_profile(log_dir: str) -> bool:
    """Open a `jax.profiler.trace` window writing to `log_dir`. Host spans
    emitted inside the window line up with the device trace through their
    TraceAnnotations. Returns False when the profiler backend is unavailable
    (the serve run proceeds unprofiled rather than dying)."""
    import jax

    try:
        jax.profiler.start_trace(log_dir)
    except Exception as e:
        print(f"obs: jax profiler unavailable ({e}); continuing unprofiled")
        return False
    _PROFILE["dir"] = log_dir
    return True


def stop_profile() -> str | None:
    """Close the open profiler window, returning its directory (or None)."""
    log_dir, _PROFILE["dir"] = _PROFILE["dir"], None
    if log_dir is None:
        return None
    import jax

    try:
        jax.profiler.stop_trace()
    except Exception as e:
        print(f"obs: stopping jax profiler failed ({e})")
    return log_dir


def write_spans_jsonl(path: str, *, drain: bool = True) -> int:
    """Append the span buffer to a JSONL file (one span per row). Returns the
    number of rows written; with `drain` (default) the buffer is cleared."""
    import json

    rows = drain_spans() if drain else list(spans())
    if not rows:
        return 0
    with open(path, "a") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    return len(rows)
