from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compression import compress_with_feedback, decompress
from repro.optim.schedules import constant, linear_warmup_cosine

__all__ = [
    "AdamWConfig", "adamw_update", "init_opt_state",
    "compress_with_feedback", "decompress",
    "constant", "linear_warmup_cosine",
]
