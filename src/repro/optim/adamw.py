"""AdamW with decoupled weight decay, built from scratch (no optax).

Mixed-precision discipline: master weights and moments are f32 regardless of
the (possibly bf16) param dtype; the update is computed in f32 and cast back.
State is a plain pytree so it shards/checkpoints with the params (the
PartitionSpec tree for the optimizer state mirrors the param tree).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: dict,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay on matrix params only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
