"""Int8 gradient compression with error feedback (distributed-optimization trick).

At 1000+ node scale the gradient all-reduce dominates the collective term for
DP-heavy meshes. Compressing gradients to int8 (per-leaf max-abs scale) before
the reduction cuts DP collective bytes 4x (vs f32) / 2x (vs bf16); the error-
feedback residual keeps the optimizer unbiased in expectation (1-bit Adam /
PowerSGD lineage).

Usage in train_step:
    cgrads, new_residual = compress_with_feedback(grads, residual)
    # psum/all-reduce happens on cgrads.q (int8) + cgrads.scale (f32 scalar)
    grads = decompress(cgrads)

The compiled collective then moves int8 tensors — visible in the dry-run's
collective-byte parse, which is how §Perf measures the win.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedLeaf(NamedTuple):
    q: jax.Array      # int8
    scale: jax.Array  # f32 scalar


def _compress_leaf(g: jax.Array) -> CompressedLeaf:
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return CompressedLeaf(q=q, scale=scale)


def _decompress_leaf(c: CompressedLeaf) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compress_with_feedback(
    grads: Any, residual: Any | None
) -> tuple[Any, Any]:
    """Returns (compressed pytree of CompressedLeaf, new residual pytree)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )
    compressed = jax.tree.map(
        _compress_leaf, corrected, is_leaf=lambda x: isinstance(x, jax.Array)
    )
    new_residual = jax.tree.map(
        lambda c, x: x - _decompress_leaf(c),
        compressed,
        corrected,
        is_leaf=lambda x: isinstance(x, CompressedLeaf),
    )
    return compressed, new_residual


def decompress(compressed: Any) -> Any:
    return jax.tree.map(
        _decompress_leaf,
        compressed,
        is_leaf=lambda x: isinstance(x, CompressedLeaf),
    )
