"""LR schedules (pure functions of the step scalar)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, *, warmup: int, total: int, floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * cos


def constant(step, *, value: float = 1.0):
    del step
    return value
