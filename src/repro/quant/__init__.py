from repro.quant.quantize import (
    QuantSpec,
    calibrate_scale,
    dequantize_int8,
    fake_quantize,
    quantize_int8,
)

__all__ = [
    "QuantSpec",
    "calibrate_scale",
    "dequantize_int8",
    "fake_quantize",
    "quantize_int8",
]
