"""Symmetric int8 quantization (QAsymm8 analogue from the paper's ARMNN setup).

ReuseSense evaluates 8-bit quantized DNNs: input similarity is defined in the
*quantized code domain* (two activations are "identical" iff their int8 codes
match), which is what makes similarity so high in practice (quantization
collapses nearby values; ReLU-family activations collapse to the zero code).

We use symmetric int8 (zero-point 0) with per-tensor or per-channel scales and
int32 accumulation. Symmetric quantization keeps the delta algebra exact:

    dequant(q_c) - dequant(q_p) = scale * (q_c - q_p)

so the delta is exactly zero wherever codes match — the invariant the whole
reuse scheme rests on.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INT8_MIN = -127  # symmetric: reserve -128 so |q| <= 127 and -q is representable
INT8_MAX = 127


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static quantization configuration for one tensor site."""

    bits: int = 8
    per_channel: bool = False
    channel_axis: int = -1
    # Scales are calibrated from data (max-abs) or fixed ahead of time.
    fixed_scale: float | None = None

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def calibrate_scale(x: jax.Array, spec: QuantSpec = QuantSpec()) -> jax.Array:
    """Max-abs scale so that x/scale spans the int range. Shape: scalar or per-channel."""
    if spec.fixed_scale is not None:
        return jnp.asarray(spec.fixed_scale, dtype=jnp.float32)
    if spec.per_channel:
        axes = tuple(a for a in range(x.ndim) if a != spec.channel_axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=False)
    else:
        amax = jnp.max(jnp.abs(x))
    amax = jnp.maximum(amax.astype(jnp.float32), 1e-8)
    return amax / spec.qmax


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """x -> int8 codes. `scale` broadcasts against x."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def fake_quantize(x: jax.Array, spec: QuantSpec = QuantSpec()) -> jax.Array:
    """Quantize+dequantize: the float tensor the quantized model actually sees."""
    scale = calibrate_scale(x, spec)
    return dequantize_int8(quantize_int8(x, scale), scale, dtype=x.dtype)
