"""Parse collective traffic out of compiled (SPMD-partitioned) HLO text.

`cost_analysis()` has no collective-byte counter, so we sum the per-device
result payload of every collective op in the partitioned module. Shapes in
post-SPMD HLO are already per-device, so result bytes ≈ bytes crossing the
ICI per device per op (ring all-reduce moves ~2·(n−1)/n ≈ 2× that; we report
raw payload and apply the ring factor in the roofline term).

Ops counted: all-gather, all-reduce, reduce-scatter, all-to-all,
collective-permute (+ their -start/-done async forms, deduped by id).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-reduce.42 = f32[16,1024]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collective_bytes(hlo_text: str) -> dict:
    """Returns {"total_bytes": int, "by_kind": {kind: bytes}, "count": int}."""
    by_kind: dict[str, int] = defaultdict(int)
    count = 0
    for m in _OP_RE.finditer(hlo_text):
        name, tuple_body, dtype, dims, kind = m.groups()
        if name.endswith(".clone") or "-done" in name:
            continue
        if tuple_body is not None:
            sz = sum(
                _shape_bytes(dt, dm) for dt, dm in _TUPLE_ELEM_RE.findall(tuple_body)
            )
        else:
            sz = _shape_bytes(dtype, dims)
        by_kind[kind] += sz
        count += 1
    return {
        "total_bytes": int(sum(by_kind.values())),
        "by_kind": dict(by_kind),
        "count": count,
    }


# The sharded-serving hot-path invariant (repro.dist): reuse-cache state may
# never be GATHERED across the mesh — the once-per-window counter all-reduce
# is the only allowed cross-shard movement. These are the collective kinds
# that move shard-resident state to other shards wholesale.
_GATHER_KINDS = ("all-gather", "all-to-all")


def iter_collectives(hlo_text: str):
    """Yield (name, kind, [(dtype, dims_tuple), ...]) per collective result.

    Shapes are the RESULT shapes (post-SPMD HLO: per-device locals; an
    all-gather's result is the gathered — global — extent along its axis).
    Async -start/-done pairs dedupe to the -start op.
    """
    for m in _OP_RE.finditer(hlo_text):
        name, tuple_body, dtype, dims, kind = m.groups()
        if name.endswith(".clone") or "-done" in name:
            continue
        if tuple_body is not None:
            shapes = [
                (dt, tuple(int(d) for d in dm.split(",") if d))
                for dt, dm in _TUPLE_ELEM_RE.findall(tuple_body)
            ]
        else:
            shapes = [(dtype, tuple(int(d) for d in dims.split(",") if d))]
        yield name, kind, shapes


def cache_collective_violations(
    hlo_text: str, cache_signatures: set
) -> list[dict]:
    """All-gather/all-to-all ops in compiled HLO whose result shape matches a
    reuse-cache buffer signature — the no-gather hot-path assertion.

    `cache_signatures` is `repro.dist.shard.cache_shape_signatures(cache)`:
    (hlo_dtype, dims) of every cache leaf at both its GLOBAL and per-device
    LOCAL shape. An all-gather materializing a cache leaf's global shape (or
    an all-to-all reshuffling its local shape) is exactly the cross-shard
    cache movement the sharded design forbids; activation collectives (whose
    shapes don't carry the cache's [layer, shard] leading dims) pass through.
    Returns one {op, kind, dtype, dims} per offending op — empty = invariant
    holds.
    """
    violations = []
    for name, kind, shapes in iter_collectives(hlo_text):
        if kind not in _GATHER_KINDS:
            continue
        for dt, dims in shapes:
            if (dt, dims) in cache_signatures:
                violations.append(
                    {"op": name, "kind": kind, "dtype": dt, "dims": dims}
                )
    return violations


def summarize_cost(cost: dict | None) -> dict:
    if not cost:
        return {}
    keep = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        if k in cost:
            keep[k] = float(cost[k])
    # per-memory-space byte counters (bytes accessed0{} etc.)
    for k, v in cost.items():
        if isinstance(v, (int, float)) and k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep
