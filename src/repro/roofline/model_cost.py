"""Analytic per-cell roofline model: FLOPs, HBM bytes, collective bytes.

Why analytic: XLA's ``cost_analysis()`` counts every ``lax.scan`` body ONCE
(calibrated in EXPERIMENTS.md §Dry-run), and this framework scans over
layers, attention chunk-pairs and SSM time chunks — so HLO counters
undercount by the trip counts. The roofline table therefore comes from this
auditable cost model, CROSS-VALIDATED against the compiled HLO on unscanned
single-superblock modules (roofline/validate.py) where the counters are
exact.

All numbers are PER DEVICE. Terms (seconds):
    compute    = flops / PEAK_FLOPS
    memory     = hbm_bytes / HBM_BW
    collective = coll_bytes / ICI_BW          (ring factor folded in)

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig
from repro.launch.specs import SHAPES, ShapeCell, cell_runnable

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    dp: int
    tp: int
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp * self.pods


POD_MESH = MeshSpec(dp=16, tp=16, pods=1)
MULTIPOD_MESH = MeshSpec(dp=16, tp=16, pods=2)


@dataclasses.dataclass
class CellCost:
    flops: float = 0.0          # per device
    hbm_bytes: float = 0.0      # per device
    coll_bytes: float = 0.0     # per device (payload; ring factor included)
    notes: dict = dataclasses.field(default_factory=dict)

    def add(self, flops=0.0, hbm=0.0, coll=0.0, tag=None):
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll
        if tag:
            t = self.notes.setdefault(tag, [0.0, 0.0, 0.0])
            t[0] += flops
            t[1] += hbm
            t[2] += coll

    @property
    def compute_s(self):
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self):
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self):
        # optimistic full-overlap model: bounded by the slowest resource
        return max(self.compute_s, self.memory_s, self.collective_s)


def _avg_attended(cell_s: int, causal: bool, window: int | None) -> float:
    """Average KV positions attended per query (exact FLOPs accounting)."""
    s = cell_s
    if window is None:
        return (s + 1) / 2 if causal else s
    w = min(window, s)
    # sum_i min(i+1, w) / s
    return (w * (w + 1) / 2 + (s - w) * w) / s


def _attn_flops(cfg, tokens: int, kv_len: float) -> float:
    return 4.0 * tokens * kv_len * cfg.n_heads * cfg.head_dim


def _mlp_flops(cfg, tokens: int) -> float:
    mult = 6.0 if cfg.mlp_kind == "swiglu" else 4.0
    return mult * tokens * cfg.d_model * cfg.d_ff


def _layer_param_bytes(cfg: ModelConfig, mesh: MeshSpec) -> dict[str, float]:
    """Per-device parameter bytes by layer component (TP-sharded)."""
    d, f = cfg.d_model, cfg.d_ff
    tp = mesh.tp
    attn = (d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d) * BF16 / tp
    mlp_mult = 3 if cfg.mlp_kind == "swiglu" else 2
    mlp = mlp_mult * d * f * BF16 / tp
    out = {"attn": attn, "mlp": mlp}
    if cfg.n_experts:
        out["experts_all"] = cfg.n_experts * mlp_mult * d * f * BF16 / tp
        out["router"] = d * cfg.n_experts * F32
        if cfg.shared_expert:
            out["shared"] = mlp
    if cfg.ssm_kind == "rwkv6":
        out["rwkv"] = (5 * d * d + d * d + mlp_mult * d * f) * BF16 / tp
    if cfg.ssm_kind == "mamba2":
        di = cfg.d_inner
        out["mamba"] = (d * (2 * di + 2 * cfg.ssm_state + cfg.n_ssm_heads)
                        + di * d) * BF16 / tp
    out["embed"] = cfg.vocab * d * BF16 / tp
    out["head"] = cfg.vocab * d * BF16 / tp if not cfg.tie_embeddings else 0.0
    return out


def _per_layer_forward(cfg: ModelConfig, mesh: MeshSpec, cell_s: int,
                       tokens_loc: int, cost: CellCost, *,
                       kv_len: float | None = None, decode: bool = False):
    """One *average* layer's forward flops/bytes (per device)."""
    tp = mesh.tp
    pb = _layer_param_bytes(cfg, mesh)
    t = tokens_loc
    d = cfg.d_model

    if cfg.ssm_kind == "rwkv6":
        proj_flops = 2 * t * d * (5 * d) / tp          # r,k,v,g,(w lora small)+o
        wkv_flops = 4 * t * d * cfg.ssm_head_dim        # recurrence (VPU)
        cmix_flops = 4 * t * d * cfg.d_ff / tp
        cost.add(flops=proj_flops + wkv_flops + cmix_flops,
                 hbm=pb["rwkv"] + 10 * t * d * BF16, tag="rwkv")
        return

    if cfg.ssm_kind == "mamba2":
        di = cfg.d_inner
        io_flops = 2 * t * d * (2 * di + 2 * cfg.ssm_state + cfg.n_ssm_heads) / tp \
            + 2 * t * di * d / tp
        scan_flops = 5 * t * di * cfg.ssm_state        # recurrence (VPU)
        cost.add(flops=io_flops + scan_flops,
                 hbm=pb["mamba"] + 8 * t * d * BF16, tag="mamba")
        # shared attn block amortized: 1 per hybrid_attn_every layers
        if cfg.hybrid_attn_every:
            frac = 1.0 / cfg.hybrid_attn_every
            _attn_block(cfg, mesh, cell_s, t, cost, kv_len, decode,
                        scale=frac, include_mlp=True)
        return

    # attention + (mlp | moe); local_global averages window sizes
    if cfg.attn_kind == "local_global":
        r = cfg.local_ratio
        _attn_block(cfg, mesh, cell_s, t, cost, kv_len, decode,
                    scale=r / (r + 1), window=cfg.window)
        _attn_block(cfg, mesh, cell_s, t, cost, kv_len, decode,
                    scale=1 / (r + 1), window=None)
    else:
        _attn_block(cfg, mesh, cell_s, t, cost, kv_len, decode,
                    window=cfg.window if cfg.attn_kind == "swa" else None)

    if cfg.n_experts:
        act = cfg.top_k * cfg.capacity_factor
        mult = 6.0 if cfg.mlp_kind == "swiglu" else 4.0
        moe_flops = act * mult * t * d * cfg.d_ff / tp
        moe_flops += 2 * t * d * cfg.n_experts          # router
        # EP/TP: every expert's shard is read once per step (weight traffic
        # is ALL experts / tp, the MoE serving tax)
        hbm = pb["experts_all"] + pb["router"] + 8 * t * d * BF16
        coll = 2 * t * d * BF16  # token all-to-all (dispatch+combine) approx
        if cfg.shared_expert:
            moe_flops += mult * t * d * cfg.d_ff / tp
            hbm += pb["shared"]
        cost.add(flops=moe_flops, hbm=hbm, coll=coll, tag="moe")
    else:
        cost.add(flops=_mlp_flops(cfg, t) / tp,
                 hbm=pb["mlp"] + 6 * t * d * BF16, tag="mlp")


def _attn_block(cfg, mesh, cell_s, t, cost, kv_len, decode,
                *, scale=1.0, window=None, include_mlp=False):
    tp = mesh.tp
    d = cfg.d_model
    pb = _layer_param_bytes(cfg, mesh)
    proj_flops = 2 * t * d * (cfg.q_dim + 2 * cfg.kv_dim) / tp \
        + 2 * t * cfg.q_dim * d / tp
    if decode:
        attended = min(window, kv_len) if window else kv_len
        kv_elt = 1 if cfg.kv_cache_quant else BF16
        kv_heads = cfg.kv_heads_eff
        kv_bytes = 2 * attended * (t) * kv_heads * cfg.head_dim * kv_elt
        # kv heads replicated when < tp (sanitizer) => full kv read per
        # device; kv_head_pad_to makes the head dim divide tp and shard.
        if kv_heads % tp:
            kv_bytes *= 1.0
        else:
            kv_bytes /= tp
        score_flops = _attn_flops(cfg, t, attended) / tp
        cost.add(flops=scale * (proj_flops + score_flops),
                 hbm=scale * (pb["attn"] + kv_bytes + 6 * t * d * BF16),
                 tag="attn")
    else:
        attended = _avg_attended(cell_s, cfg.causal, window)
        score_flops = _attn_flops(cfg, t, attended) / tp
        cost.add(flops=scale * (proj_flops + score_flops),
                 hbm=scale * (pb["attn"] + 8 * t * d * BF16),
                 tag="attn")
    # TP collectives per layer: all-reduce of the block output (row-parallel
    # o/down proj) ~ 2 ops x t x d x 2bytes x ring factor ~2
    cost.add(coll=scale * 2 * 2 * t * d * BF16, tag="attn_tp")
    if include_mlp:
        cost.add(flops=scale * _mlp_flops(cfg, t) / tp,
                 hbm=scale * (pb["mlp"] + 6 * t * d * BF16), tag="shared_mlp")


def cell_cost(cfg: ModelConfig, cell: ShapeCell, mesh: MeshSpec,
              *, reuse_skip_fraction: float = 0.0,
              reuse_covers_experts: bool = False,
              expert_stickiness: float = 0.0) -> CellCost:
    """Per-device roofline terms for one (arch x shape x mesh) cell.

    reuse_skip_fraction > 0 models ReuseSense decode: that fraction of
    weight-tile HBM traffic (and MXU work) on reuse sites is skipped.
    reuse_covers_experts enables the beyond-paper per-(slot, expert) cache
    extension: routed-expert weight streaming also skips, scaled by
    `expert_stickiness` (P[stream keeps its expert across steps], measured
    in benchmarks/moe_stickiness.py) on top of the delta harvest.
    """
    cost = CellCost()
    dp = mesh.dp * mesh.pods
    d = cfg.d_model

    if cell.kind == "train":
        tokens_loc = cell.global_batch * cell.seq_len // dp
        # fwd + bwd(2x) + remat re-fwd (1x) on blocks
        block_cost = CellCost()
        _per_layer_forward(cfg, mesh, cell.seq_len, tokens_loc, block_cost)
        mult = 4.0 if cfg.remat else 3.0
        cost.add(flops=cfg.n_layers * mult * block_cost.flops,
                 hbm=cfg.n_layers * mult * block_cost.hbm_bytes,
                 coll=cfg.n_layers * mult * block_cost.coll_bytes,
                 tag="blocks")
        # embed + lm head (fwd+bwd, no remat)
        head_flops = 3 * 2 * tokens_loc * d * cfg.vocab / mesh.tp
        cost.add(flops=head_flops,
                 hbm=3 * cfg.vocab * d * BF16 / mesh.tp, tag="head")
        # optimizer: read params+mu+nu, write params+mu+nu (f32 moments)
        total_param_bytes = (
            sum(v for k, v in _layer_param_bytes(cfg, mesh).items()
                if k not in ("embed", "head")) * cfg.n_layers
            + _layer_param_bytes(cfg, mesh)["embed"]
            + _layer_param_bytes(cfg, mesh)["head"]
        )
        cost.add(hbm=total_param_bytes * (1 + 2 * 2 + 2 * 2),  # p + mu/nu rw
                 tag="optimizer")
        # DP gradient all-reduce (bf16 grads, ring factor 2)
        cost.add(coll=2 * total_param_bytes, tag="dp_allreduce")
        return cost

    if cell.kind == "prefill":
        tokens_loc = cell.global_batch * cell.seq_len // min(dp, cell.global_batch)
        block_cost = CellCost()
        _per_layer_forward(cfg, mesh, cell.seq_len, tokens_loc, block_cost)
        cost.add(flops=cfg.n_layers * block_cost.flops,
                 hbm=cfg.n_layers * block_cost.hbm_bytes,
                 coll=cfg.n_layers * block_cost.coll_bytes, tag="blocks")
        # KV cache write
        kvw = cfg.n_layers * tokens_loc * 2 * cfg.n_kv_heads * cfg.head_dim * BF16
        cost.add(hbm=kvw, tag="kv_write")
        lb = cell.global_batch // min(dp, cell.global_batch)
        cost.add(flops=2 * lb * d * cfg.vocab / mesh.tp, tag="head")
        return cost

    # decode
    b_loc = max(cell.global_batch // dp, 1)
    block_cost = CellCost()
    _per_layer_forward(cfg, mesh, cell.seq_len, b_loc, block_cost,
                       kv_len=cell.seq_len, decode=True)
    f, h, c = (cfg.n_layers * block_cost.flops,
               cfg.n_layers * block_cost.hbm_bytes,
               cfg.n_layers * block_cost.coll_bytes)
    if reuse_skip_fraction > 0.0:
        # ReuseSense: skip that fraction of weight-tile loads + their MACs on
        # the projection GEMMs; KV/activation traffic and delta/cache upkeep
        # remain. Weight share of decode HBM dominates; approximate weight
        # fraction from the param-byte tags.
        wfrac = _decode_weight_fraction(
            cfg, mesh, cell,
            include_experts=reuse_covers_experts,
            expert_stickiness=expert_stickiness,
        )
        f *= (1 - reuse_skip_fraction * wfrac)
        h *= (1 - reuse_skip_fraction * wfrac)
        # delta/cache upkeep: read prev_q + write cur_q (int8) + prev_out rw
        sites_bytes = _reuse_cache_traffic(cfg, mesh, b_loc)
        h += sites_bytes
    cost.add(flops=f, hbm=h, coll=c, tag="blocks")
    cost.add(flops=2 * b_loc * d * cfg.vocab / mesh.tp,
             hbm=cfg.vocab * d * BF16 / mesh.tp, tag="head")
    return cost


def _decode_weight_fraction(cfg, mesh, cell, *, include_experts=False,
                            expert_stickiness=0.0) -> float:
    """Fraction of decode HBM traffic that is reuse-site weight streaming."""
    pb = _layer_param_bytes(cfg, mesh)
    if cfg.ssm_kind == "rwkv6":
        w = pb["rwkv"]
    elif cfg.ssm_kind == "mamba2":
        w = pb.get("mamba", 0.0) + pb["attn"] / max(cfg.hybrid_attn_every, 1)
    elif cfg.n_experts:
        w = pb["attn"] + pb.get("shared", 0.0)   # routed experts not reused
        if include_experts:
            # per-(slot, expert) extension: an expert's tile skips when the
            # dispatched stream kept that expert AND its delta-block is zero
            w = w + pb["experts_all"] * expert_stickiness
    else:
        w = pb["attn"] + pb["mlp"]
    total = CellCost()
    _per_layer_forward(cfg, mesh, cell.seq_len, 1, total,
                       kv_len=cell.seq_len, decode=True)
    return min(w / max(total.hbm_bytes, 1e-9), 1.0)


def _reuse_cache_traffic(cfg, mesh, b_loc) -> float:
    d = cfg.d_model
    per_site_k = {
        "qkv": d, "out": cfg.q_dim, "in": d, "outm": cfg.d_ff,
    }
    # int8 prev/cur (r+w) + f32 prev_out (r+w), summed over generic 4 sites
    bytes_per_layer = sum(
        b_loc * (2 * k + 0) * 1 for k in per_site_k.values()
    ) + b_loc * 4 * d * F32 * 2
    return cfg.n_layers * bytes_per_layer


# ---------------------------------------------------------------------------
# Kernel-level reuse-GEMM cost model
#
# The cell model above prices whole decode steps; the compiled skip-rate
# sweep (benchmarks/wallclock.py --sweep) needs the same roofline discipline
# ONE level down: for a single reuse site's [M,K]x[K,N] GEMM, how much work
# does each execution substrate actually perform at a given tile-skip rate?
# Time is modeled as balance-weighted work (flops + bytes x PEAK/BW) so the
# prediction is a machine-independent RATIO; validate.validate_kernel_sweep
# compares these ratios against the measured compiled sweep.
# ---------------------------------------------------------------------------

MACHINE_BALANCE = PEAK_FLOPS / HBM_BW  # flops per byte at the roofline knee


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Work one reuse-GEMM substrate performs at a given skip rate."""

    path: str
    flops: float
    bytes: float

    @property
    def work(self) -> float:
        # time ∝ flops/PEAK + bytes/BW ∝ flops + bytes·BALANCE; ratios of
        # `work` are the model's speedup predictions.
        return self.flops + self.bytes * MACHINE_BALANCE


def reuse_kernel_cost(
    m: int, k: int, n: int, *, path: str, skip: float = 0.0,
    block_m: int = 8, block_k: int = 128, max_active_k: int | None = None,
) -> KernelCost:
    """Flops + HBM bytes for one [M,K]x[K,N] reuse GEMM on `path`.

    Paths mirror the compiled execution tiers:
      dense / kernel / masked — full GEMM work (the masked XLA lowering and
        the full-grid kernel walk every tile; masking saves no traffic on
        the compiled-XLA tier).
      compact — shared-K gather GEMM: only the union of active K-blocks is
        gathered; gather MATERIALIZES the selected weight rows (read source
        + write copy), which is exactly why compact loses below break-even.
      ragged — per-M-group budgeted gather: the XLA lowering gathers a
        weight copy PER GROUP (jnp.take over (gm, budget) indices), so its
        weight traffic is gm x budget blocks — the price of per-row raggedness
        on a substrate without scalar-prefetch grids.
    """
    gk = -(-k // block_k)
    gm = -(-m // block_m)
    el = F32
    dense_flops = 2.0 * m * k * n
    dense_bytes = el * (m * k + k * n + 2.0 * m * n)
    if path in ("dense", "dense_gemm", "kernel", "masked", "masked_ref", "ref"):
        return KernelCost(path=path, flops=dense_flops, bytes=dense_bytes)
    occ = min(max(1.0 - skip, 0.0), 1.0)
    if path == "compact":
        ak = occ * gk * block_k  # union of active K-blocks (shared mask)
        flops = 2.0 * m * ak * n
        bytes_ = el * (
            2.0 * ak * n        # gather W rows: read source + materialize
            + 2.0 * m * ak      # gather delta columns: read + materialize
            + 2.0 * m * n       # prev_out read + out write
        )
        return KernelCost(path=path, flops=flops, bytes=bytes_)
    if path in ("ragged", "ragged_xla"):
        if max_active_k is None:
            kb = max(int(math.ceil(occ * gk)), 1)  # budget sized to occupancy
        else:
            kb = int(max_active_k)
        kb = min(max(kb, 1), gk)
        ak = kb * block_k
        flops = 2.0 * m * ak * n  # einsum runs the full budget, masked
        bytes_ = el * (
            2.0 * gm * ak * n   # per-group weight gather: read + materialize
            + 2.0 * m * ak      # per-group delta gather
            + 2.0 * m * n
        )
        return KernelCost(path=path, flops=flops, bytes=bytes_)
    raise ValueError(f"unknown kernel path {path!r}")


def predict_kernel_speedup(
    m: int, k: int, n: int, *, path: str, skip: float,
    block_m: int = 8, block_k: int = 128, max_active_k: int | None = None,
) -> float:
    """Predicted dense_time / path_time ratio (>1 means the path wins)."""
    dense = reuse_kernel_cost(m, k, n, path="dense", block_m=block_m,
                              block_k=block_k)
    pc = reuse_kernel_cost(m, k, n, path=path, skip=skip, block_m=block_m,
                           block_k=block_k, max_active_k=max_active_k)
    return dense.work / max(pc.work, 1e-12)


def predicted_break_even_skip(
    m: int, k: int, n: int, *, path: str = "compact",
    block_m: int = 8, block_k: int = 128, samples: int = 101,
) -> float:
    """Lowest skip rate where `path` matches dense under the work model.

    Same convention as tune.harvest.derive_break_even_skip: 2.0 = the path
    never wins on this shape (gate should demote to dense)."""
    prev_s, prev_m = None, None
    for i in range(samples):
        s = i / (samples - 1)
        margin = predict_kernel_speedup(
            m, k, n, path=path, skip=s, block_m=block_m, block_k=block_k,
        ) - 1.0
        if margin >= 0.0:
            if prev_s is None or margin == prev_m:
                return s
            t = -prev_m / (margin - prev_m)
            return prev_s + t * (s - prev_s)
        prev_s, prev_m = s, margin
    return 2.0


def model_flops_per_step(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS: 6·N·D (dense train) / 6·N_active·D (MoE train); 2·N·D per
    generated/processed token for inference. GLOBAL (all devices)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch


def roofline_row(arch_cfg: ModelConfig, shape: str, mesh_name: str,
                 *, reuse_skip_fraction: float = 0.0) -> dict:
    cell = SHAPES[shape]
    mesh = POD_MESH if mesh_name == "pod" else MULTIPOD_MESH
    ok, why = cell_runnable(arch_cfg.name, shape)
    if not ok:
        return {"arch": arch_cfg.name, "shape": shape, "mesh": mesh_name,
                "skipped": why}
    c = cell_cost(arch_cfg, cell, mesh,
                  reuse_skip_fraction=reuse_skip_fraction)
    mf = model_flops_per_step(arch_cfg, cell)
    hlo_flops_global = c.flops * mesh.n_devices
    return {
        "arch": arch_cfg.name,
        "shape": shape,
        "mesh": mesh_name,
        "compute_s": c.compute_s,
        "memory_s": c.memory_s,
        "collective_s": c.collective_s,
        "dominant": c.dominant,
        "step_s": c.step_s,
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_flops_global, 1e-9),
        "roofline_fraction": (mf / mesh.n_devices / PEAK_FLOPS) / c.step_s,
        "notes": {k: [round(x, 3) for x in v] for k, v in c.notes.items()},
    }
