"""Cross-validate the analytic cost model against compiled-HLO counters.

Calibration findings (EXPERIMENTS.md §Dry-run): cost_analysis is per-device,
and for the real models the layer scan IS trip-count multiplied (verified by
depth-differencing: qwen3 decode at 4 vs 8 layers differs by exactly
4 x per-layer FLOPs). Decode cells are the clean comparison point (no remat,
attention outside any inner scan):

    HLO_flops  ≈  n_layers x analytic_per_layer_flops + head_flops

Ratios near 1 confirm the model; deviations are explained by GQA-padding
(KV heads padded to the TP width by GSPMD) and einsum lowering choices.

    PYTHONPATH=src python -m repro.roofline.validate experiments/dryrun/pod
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

import math

from repro.configs import ARCHS
from repro.launch.specs import SHAPES
from repro.roofline.model_cost import (
    POD_MESH,
    CellCost,
    _per_layer_forward,
    predict_kernel_speedup,
    predicted_break_even_skip,
)


def predicted_decode_hlo_flops(cfg, cell, mesh=POD_MESH) -> float:
    """Per-device FLOPs XLA should report for a decode cell (full layer
    stack + lm head; layer scans are trip-multiplied per calibration)."""
    dp = mesh.dp * mesh.pods
    b_loc = max(cell.global_batch // dp, 1)
    block = CellCost()
    _per_layer_forward(cfg, mesh, cell.seq_len, b_loc, block,
                       kv_len=cell.seq_len, decode=True)
    body = block.flops * cfg.n_layers
    head = 2 * b_loc * cfg.d_model * cfg.vocab / mesh.tp
    return body + head


# Kernel-sweep validation. The work model prices flops + BALANCE-weighted
# bytes and deliberately omits dispatch/gather launch overhead, so on a
# CPU-measured sweep its absolute speedups are optimistic upper bounds and
# its break-even skip is a LOWER bound on the measured crossing. What the
# model does predict on any substrate — and what this validation gates on —
# is the payoff STRUCTURE:
#   rank        per compaction path, measured speedup must be monotone in
#               predicted speedup across the sweep (Spearman rank corr);
#   direction   outside a dead band around parity on BOTH sides, model and
#               measurement must agree on who wins;
#   break-even  one-sided: the measured compaction crossing may sit right
#               of the overhead-free prediction (or never arrive — the gate
#               then demotes to dense) but never LEFT of it: the model must
#               not claim compaction loses where measurement shows a win.
KERNEL_SWEEP_TOLERANCE = {
    # min Spearman rank correlation, predicted vs measured speedup, per
    # compaction path across skip levels
    "rank_corr_min": 0.6,
    # fraction of decided rows where the win/lose verdicts must match
    "direction_agreement_min": 0.7,
    # speedups within this factor of 1.0 (predicted OR measured) are
    # parity-adjacent: direction there is measurement noise, not signal
    "direction_dead_band": 0.15,
    # slack on the one-sided bound: measured_be >= predicted_be - slack
    "break_even_slack": 0.10,
}

# Paths whose work model is identical to dense (masking saves no compiled
# work): excluded from rank (zero predicted variance) and from the
# break-even, which is specifically the COMPACTION crossing.
_PARITY_PATHS = ("kernel", "masked", "masked_ref", "ref")


def _spearman(a: list[float], b: list[float]) -> float | None:
    def ranks(xs):
        order = sorted(range(len(xs)), key=lambda i: xs[i])
        out = [0.0] * len(xs)
        i = 0
        while i < len(order):
            j = i
            while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
                j += 1
            for t in range(i, j + 1):
                out[order[t]] = (i + j) / 2.0
            i = j + 1
        return out

    if len(a) < 3:
        return None
    ra, rb = ranks(a), ranks(b)
    ma, mb = sum(ra) / len(ra), sum(rb) / len(rb)
    va = sum((x - ma) ** 2 for x in ra)
    vb = sum((y - mb) ** 2 for y in rb)
    if va == 0.0 or vb == 0.0:
        return None
    cov = sum((x - ma) * (y - mb) for x, y in zip(ra, rb))
    return cov / math.sqrt(va * vb)


def validate_kernel_sweep(
    sweep_rows: list[dict], *, tolerance: dict | None = None
) -> dict:
    """Measured compiled skip-rate sweep vs the kernel-level work model.

    `sweep_rows`: one dict per (skip, path) measurement with keys
    ``skip, path, us, m, k, n, block_m, block_k`` (``max_active_k`` for the
    budgeted paths); dense rows carry path ``dense_gemm``/``dense``.
    Returns a report with per-row predicted-vs-measured speedups, the three
    structural checks described above, the tolerance it validated against,
    and an overall ``ok``.
    """
    from repro.tune.harvest import derive_break_even_skip

    tol = dict(KERNEL_SWEEP_TOLERANCE)
    if tolerance:
        tol.update(tolerance)
    dead = math.log1p(tol["direction_dead_band"])
    dense_us = {
        float(r["skip"]): float(r["us"])
        for r in sweep_rows if r["path"] in ("dense", "dense_gemm")
    }
    rows, agree, decided = [], 0, 0
    by_path: dict[str, list[tuple[float, float]]] = {}
    best_compaction: dict[float, float] = {}
    for r in sweep_rows:
        if r["path"] in ("dense", "dense_gemm"):
            continue
        skip = float(r["skip"])
        d_us = dense_us.get(skip)
        if d_us is None:
            continue
        measured = d_us / max(float(r["us"]), 1e-9)
        predicted = predict_kernel_speedup(
            int(r["m"]), int(r["k"]), int(r["n"]), path=r["path"], skip=skip,
            block_m=int(r.get("block_m", 8)), block_k=int(r["block_k"]),
            max_active_k=r.get("max_active_k"),
        )
        in_band = (abs(math.log(max(predicted, 1e-9))) < dead
                   or abs(math.log(max(measured, 1e-9))) < dead)
        row = {
            "skip": skip, "path": r["path"],
            "measured_speedup": measured, "predicted_speedup": predicted,
            "log_ratio": math.log(max(measured, 1e-9))
            - math.log(max(predicted, 1e-9)),
            "dead_band": in_band,
        }
        if not in_band:
            decided += 1
            row["direction_agree"] = (measured > 1.0) == (predicted > 1.0)
            agree += row["direction_agree"]
        rows.append(row)
        if r["path"] not in _PARITY_PATHS:
            by_path.setdefault(r["path"], []).append((predicted, measured))
            cur = best_compaction.get(skip)
            if cur is None or float(r["us"]) < cur:
                best_compaction[skip] = float(r["us"])

    rank_corr = {
        p: _spearman([x for x, _ in pts], [y for _, y in pts])
        for p, pts in sorted(by_path.items())
    }
    measured_corrs = [c for c in rank_corr.values() if c is not None]
    rank_ok = all(c >= tol["rank_corr_min"] for c in measured_corrs) \
        if measured_corrs else True

    points = [(s, best_compaction[s], dense_us[s])
              for s in sorted(best_compaction) if s in dense_us]
    measured_be = derive_break_even_skip(points) if points else 2.0
    compaction_rows = [r for r in sweep_rows
                       if r["path"] not in ("dense", "dense_gemm")
                       and r["path"] not in _PARITY_PATHS]
    if compaction_rows:
        ref = compaction_rows[0]
        predicted_be = min(
            predicted_break_even_skip(
                int(ref["m"]), int(ref["k"]), int(ref["n"]), path=p,
                block_m=int(ref.get("block_m", 8)),
                block_k=int(ref["block_k"]),
            )
            for p in {r["path"] for r in compaction_rows}
        )
    else:
        predicted_be = 2.0
    be_ok = measured_be >= predicted_be - tol["break_even_slack"]
    direction = agree / decided if decided else 1.0
    direction_ok = direction >= tol["direction_agreement_min"]
    return {
        "tolerance": tol,
        "rows": rows,
        "rank_correlation": rank_corr,
        "rank_ok": rank_ok,
        "measured_break_even_skip": measured_be,
        "predicted_break_even_skip": predicted_be,
        "break_even_within_tol": be_ok,
        "direction_agreement": direction,
        "direction_ok": direction_ok,
        "ok": rank_ok and be_ok and direction_ok,
    }


def validate(dryrun_dir: str) -> list[dict]:
    rows = []
    for path in sorted(Path(dryrun_dir).glob("*__decode_32k.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok" or not isinstance(
            rec.get("cost_analysis"), dict
        ):
            continue
        arch = rec["arch"]
        cfg = ARCHS[arch]
        pred = predicted_decode_hlo_flops(cfg, SHAPES["decode_32k"])
        hlo = rec["cost_analysis"].get("flops", 0.0)
        rows.append({
            "arch": arch,
            "hlo_flops": hlo,
            "predicted": pred,
            "ratio": hlo / pred if pred else float("nan"),
        })
    return rows


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun/pod"
    rows = validate(d)
    print(f"{'arch':24s} {'HLO flops':>14s} {'predicted':>14s} {'ratio':>7s}")
    for r in rows:
        print(f"{r['arch']:24s} {r['hlo_flops']:14.3e} "
              f"{r['predicted']:14.3e} {r['ratio']:7.2f}")


if __name__ == "__main__":
    main()
