"""Cross-validate the analytic cost model against compiled-HLO counters.

Calibration findings (EXPERIMENTS.md §Dry-run): cost_analysis is per-device,
and for the real models the layer scan IS trip-count multiplied (verified by
depth-differencing: qwen3 decode at 4 vs 8 layers differs by exactly
4 x per-layer FLOPs). Decode cells are the clean comparison point (no remat,
attention outside any inner scan):

    HLO_flops  ≈  n_layers x analytic_per_layer_flops + head_flops

Ratios near 1 confirm the model; deviations are explained by GQA-padding
(KV heads padded to the TP width by GSPMD) and einsum lowering choices.

    PYTHONPATH=src python -m repro.roofline.validate experiments/dryrun/pod
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs import ARCHS
from repro.launch.specs import SHAPES
from repro.roofline.model_cost import (
    POD_MESH,
    CellCost,
    _per_layer_forward,
)


def predicted_decode_hlo_flops(cfg, cell, mesh=POD_MESH) -> float:
    """Per-device FLOPs XLA should report for a decode cell (full layer
    stack + lm head; layer scans are trip-multiplied per calibration)."""
    dp = mesh.dp * mesh.pods
    b_loc = max(cell.global_batch // dp, 1)
    block = CellCost()
    _per_layer_forward(cfg, mesh, cell.seq_len, b_loc, block,
                       kv_len=cell.seq_len, decode=True)
    body = block.flops * cfg.n_layers
    head = 2 * b_loc * cfg.d_model * cfg.vocab / mesh.tp
    return body + head


def validate(dryrun_dir: str) -> list[dict]:
    rows = []
    for path in sorted(Path(dryrun_dir).glob("*__decode_32k.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok" or not isinstance(
            rec.get("cost_analysis"), dict
        ):
            continue
        arch = rec["arch"]
        cfg = ARCHS[arch]
        pred = predicted_decode_hlo_flops(cfg, SHAPES["decode_32k"])
        hlo = rec["cost_analysis"].get("flops", 0.0)
        rows.append({
            "arch": arch,
            "hlo_flops": hlo,
            "predicted": pred,
            "ratio": hlo / pred if pred else float("nan"),
        })
    return rows


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun/pod"
    rows = validate(d)
    print(f"{'arch':24s} {'HLO flops':>14s} {'predicted':>14s} {'ratio':>7s}")
    for r in rows:
        print(f"{r['arch']:24s} {r['hlo_flops']:14.3e} "
              f"{r['predicted']:14.3e} {r['ratio']:7.2f}")


if __name__ == "__main__":
    main()
