"""repro.sensor — measured ReuseSensor telemetry & cost accounting.

The paper's ReuseSensor is also the accounting engine: it knows, per layer,
how many dot-product computations were bypassed and how many weight loads were
skipped — those counts are what produce the headline 8x / 74% figures. This
package is the reproduction's measured analogue:

* ``counters``   — per-site counter pytree riding inside reuse-cache entries
                   (jit/donate/shard-friendly; updated on the hot path);
* ``aggregate``  — host-side reduction across sites/layers/slots into a
                   :class:`SensorReport` with JSONL emission;
* ``cost_model`` — cycles + energy derived from *measured* counters (the
                   ``E_MAC``/``E_HBM``/``E_ICI`` constants live here);
* ``runner``     — drives real decode steps and returns the resulting report
                   (imported lazily as ``repro.sensor.runner`` to avoid a
                   core↔serve import cycle; not re-exported here).
"""

from repro.sensor.aggregate import (
    SENSOR_SCHEMA_VERSION,
    SensorReport,
    SiteSensor,
    build_report,
    slot_telemetry,
)
from repro.sensor.counters import (
    init_site_counters,
    update_on_basic,
    update_on_reuse,
)
from repro.sensor.cost_model import (
    E_HBM,
    E_ICI,
    E_MAC,
    STATIC_W,
    measured_skip_fractions,
    sensor_energy,
    sensor_speedup,
)

__all__ = [
    "E_HBM",
    "E_ICI",
    "E_MAC",
    "SENSOR_SCHEMA_VERSION",
    "STATIC_W",
    "SensorReport",
    "SiteSensor",
    "build_report",
    "init_site_counters",
    "measured_skip_fractions",
    "sensor_energy",
    "sensor_speedup",
    "slot_telemetry",
    "update_on_basic",
    "update_on_reuse",
]
