"""Host-side reduction of sensor counters into a SensorReport.

``build_report(engine, cache)`` pulls the counter pytrees out of a reuse cache
(one device→host transfer per site) and reduces them three ways:

* per (site, layer)   — stacked sites carry a leading layer dimension, so a
                        per-layer row is one slice of the counter leaves;
* per site            — layers summed (the paper's per-layer Fig. 12 view is
                        the per_layer list; this is the site inventory view);
* whole model         — totals + derived skip rates, the numbers the measured
                        benchmarks and the serving telemetry consume.

The report is plain Python (dataclasses of floats/ints), safe to json-dump.
``write_jsonl`` appends one JSON object per row — the serving emission format.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

# Version stamped on every emitted JSONL row; the repro.tune trace loader
# refuses rows it doesn't understand. Bump when row fields change meaning.
#   1 — PR 1 emission (implicit; rows carried no version field)
#   2 — adds schema_version, suppressed_flips, and site geometry
#       (in_features/out_features/block_m/block_k/block_n) on site/layer rows
#   3 — adds grid_steps (measured grid-step counter; dense baseline is
#       total_tiles · gn) and exec_path on site/layer rows
#   4 — adds overflow_fallbacks (evaluations whose live tile count overflowed
#       the compacted-path budget and took the full-extent fallback); v3
#       traces still load with the field defaulted to 0
#   5 — per-layer kernelMode truth: layer rows carry THAT LAYER's mode from
#       the array-resident ctrl block (site rows say "mixed" when a stack
#       settled distinct per-layer modes) plus budget_occupancy (the ctrl
#       block's live-tile-fraction EMA); v2-v4 traces still load
#   6 — adds sentinel_trips (guard-plane containment actions on the lane,
#       bumped host-side by the QuarantineBreaker; layers SUM at site
#       granularity — each lane quarantines independently); v2-v5 traces
#       still load with the field defaulted to 0
SENSOR_SCHEMA_VERSION = 6


@dataclasses.dataclass
class SiteSensor:
    """Measured counters for one reuse site (optionally one layer of it)."""

    site: str
    layer: int | None          # None = summed over layers
    mode: str
    steps: int
    skipped_tiles: int
    computed_tiles: int
    skipped_macs: float
    computed_macs: float
    skipped_weight_bytes: float
    total_weight_bytes: float
    reused_out_elems: float
    dma_issued_tiles: int
    mode_transitions: int
    slot_hit_rates: list[float]
    slot_steps: list[int]      # lanes with 0 steps are excluded from hit_rate
    suppressed_flips: int = 0  # hysteresis-vetoed mode flips (site-level)
    # Measured grid steps (k-tile visits × n panels); the dense baseline is
    # total_tiles · gn. Only the compacted tiers (ragged/compact) shrink it.
    grid_steps: float = 0.0
    # Evaluations whose live tile count overflowed the compacted-path budget
    # (max_active_k) and fell back to the full extent — the online budget
    # adapter's feedback signal.
    overflow_fallbacks: int = 0
    # Execution substrate the site is currently dispatched on.
    exec_path: str = "auto"
    # Live-tile-fraction EMA from the ctrl block (per-layer budget occupancy;
    # 1.0 = every K-block churns every step — nothing for a budget to save).
    budget_occupancy: float = 0.0
    # Guard-plane containment actions that quarantined this lane (host-side
    # bumps by the QuarantineBreaker; summed over layers at site granularity).
    sentinel_trips: int = 0
    # Site geometry — what the tune fitter needs to model bookkeeping cost
    # and pick a block_k without re-deriving the model architecture.
    in_features: int = 0
    out_features: int = 0
    block_m: int = 0
    block_k: int = 0
    block_n: int = 0

    @property
    def total_tiles(self) -> int:
        return self.skipped_tiles + self.computed_tiles

    @property
    def tile_skip_rate(self) -> float:
        return self.skipped_tiles / max(self.total_tiles, 1)

    @property
    def total_macs(self) -> float:
        return self.skipped_macs + self.computed_macs

    @property
    def mac_skip_rate(self) -> float:
        return self.skipped_macs / max(self.total_macs, 1e-9)

    @property
    def weight_byte_skip_rate(self) -> float:
        return self.skipped_weight_bytes / max(self.total_weight_bytes, 1e-9)

    @property
    def dense_grid_steps(self) -> float:
        """Grid steps a dense walk of the same evaluations would have cost."""
        gn = -(-self.out_features // self.block_n) if self.block_n else 0
        return float(self.total_tiles * gn)

    @property
    def grid_step_skip_rate(self) -> float:
        """Fraction of dense grid steps the execution path truly elided —
        zero on the masked kernel (which visits every tile), positive only
        on the compacted tiers (ragged grid / budgeted compact GEMM)."""
        dense = self.dense_grid_steps
        if dense <= 0:
            return 0.0
        return max(0.0, 1.0 - self.grid_steps / dense)

    @property
    def hit_rate(self) -> float:
        """Mean per-slot hit rate over ACTIVE lanes (slot_steps > 0).

        Caveat (slot-batched serving): a freed slot keeps decoding its stale
        token until the next admission resets it, so long idle gaps still
        accumulate lane history; per-request truth is the retirement
        telemetry, which snapshots before the lane goes idle."""
        active = [r for r, s in zip(self.slot_hit_rates, self.slot_steps) if s > 0]
        return float(np.mean(active)) if active else 0.0

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(
            total_tiles=self.total_tiles,
            tile_skip_rate=self.tile_skip_rate,
            total_macs=self.total_macs,
            mac_skip_rate=self.mac_skip_rate,
            weight_byte_skip_rate=self.weight_byte_skip_rate,
            grid_step_skip_rate=self.grid_step_skip_rate,
            hit_rate=self.hit_rate,
        )
        return d


@dataclasses.dataclass
class SensorReport:
    """Measured reuse accounting for a whole model at one point in time."""

    per_site: list[SiteSensor]
    per_layer: list[SiteSensor]
    model: dict[str, Any]

    def summary_lines(self) -> list[str]:
        lines = [
            "SensorReport model: "
            f"steps={self.model['steps']} "
            f"mac_skip={self.model['mac_skip_rate']:.1%} "
            f"weight_byte_skip={self.model['weight_byte_skip_rate']:.1%} "
            f"tile_skip={self.model['tile_skip_rate']:.1%} "
            f"grid_step_skip={self.model.get('grid_step_skip_rate', 0.0):.1%} "
            f"hit_rate={self.model['hit_rate']:.3f}"
        ]
        for s in self.per_site:
            lines.append(
                f"  {s.site:24s} mode={s.mode:5s} exec={s.exec_path:7s} "
                f"steps={s.steps:4d} "
                f"tile_skip={s.tile_skip_rate:6.1%} "
                f"mac_skip={s.mac_skip_rate:6.1%} "
                f"grid_skip={s.grid_step_skip_rate:6.1%} "
                f"hit={s.hit_rate:.3f} transitions={s.mode_transitions} "
                f"suppressed={s.suppressed_flips} ovf={s.overflow_fallbacks}"
            )
        return lines

    def to_dicts(self) -> list[dict[str, Any]]:
        # Rows carry the obs correlation ids under "trace" when the obs plane
        # set any (stamp is the identity otherwise — schema stays v5; the
        # sub-dict is additive and only present on obs-enabled runs).
        from repro.obs.events import stamp

        ver = {"schema_version": SENSOR_SCHEMA_VERSION}
        rows = [dict(self.model, kind="model", **ver)]
        rows += [dict(s.to_dict(), kind="site", **ver) for s in self.per_site]
        rows += [dict(s.to_dict(), kind="layer", **ver) for s in self.per_layer]
        return [stamp(row) for row in rows]

    def write_jsonl(self, path: str, *, mode: str = "a") -> None:
        with open(path, mode) as f:
            for row in self.to_dicts():
                f.write(json.dumps(row) + "\n")


def _entry_rows(name: str, entry: dict, spec=None,
                impl: str = "jnp") -> list[SiteSensor]:
    """One SiteSensor per leading-layer slice of a cache entry's counters.

    Each layer row's kernelMode is THAT LAYER's lane of the array-resident
    ctrl block — a stack that settled mixed modes reports them truthfully,
    not one site-wide compromise string. The emitted exec_path is the
    RESOLVED substrate ("auto" mapped through the impl), so offline trace
    consumers see the path that actually ran."""
    from repro.core.policy import mode_name
    from repro.core.reuse_cache import resolve_exec_path
    sensor = entry["sensor"]
    skipped = np.asarray(sensor["skipped_tiles"])
    stacked = skipped.ndim >= 1
    n_layers = skipped.shape[0] if stacked else 1

    def leaf(key, layer):
        a = np.asarray(sensor[key])
        return a[layer] if stacked else a

    ctrl = entry.get("ctrl")
    if ctrl is not None:
        mode_ids = np.atleast_1d(np.asarray(ctrl["mode_id"]))
        occupancy = np.atleast_1d(np.asarray(ctrl["occupancy"], np.float64))
    else:  # legacy entry without a control block
        mode_ids = np.full((n_layers,), -1)
        occupancy = np.zeros((n_layers,))

    steps = np.asarray(entry["steps"])
    rows = []
    for layer in range(n_layers):
        hit_sum = np.asarray(leaf("slot_hit_sum", layer), np.float64)
        slot_steps = np.asarray(leaf("slot_steps", layer), np.int64)
        rows.append(SiteSensor(
            site=name,
            layer=layer if stacked else None,
            mode=(mode_name(mode_ids[layer])
                  if mode_ids[layer] >= 0 else "auto"),
            steps=int(steps[layer] if stacked and steps.ndim else np.max(steps)),
            skipped_tiles=int(leaf("skipped_tiles", layer)),
            computed_tiles=int(leaf("computed_tiles", layer)),
            skipped_macs=float(leaf("skipped_macs", layer)),
            computed_macs=float(leaf("computed_macs", layer)),
            skipped_weight_bytes=float(leaf("skipped_weight_bytes", layer)),
            total_weight_bytes=float(leaf("total_weight_bytes", layer)),
            reused_out_elems=float(leaf("reused_out_elems", layer)),
            dma_issued_tiles=int(leaf("dma_issued_tiles", layer)),
            mode_transitions=int(leaf("mode_transitions", layer)),
            slot_hit_rates=list(hit_sum / np.maximum(slot_steps, 1)),
            slot_steps=[int(s) for s in slot_steps],
            suppressed_flips=int(leaf("suppressed_flips", layer))
            if "suppressed_flips" in sensor else 0,
            grid_steps=float(leaf("grid_steps", layer))
            if "grid_steps" in sensor else 0.0,
            overflow_fallbacks=int(leaf("overflow_fallbacks", layer))
            if "overflow_fallbacks" in sensor else 0,
            sentinel_trips=int(leaf("sentinel_trips", layer))
            if "sentinel_trips" in sensor else 0,
            exec_path=resolve_exec_path(spec, impl) if spec else "auto",
            budget_occupancy=float(occupancy[layer]),
            in_features=spec.in_features if spec else 0,
            out_features=spec.out_features if spec else 0,
            block_m=spec.block_m if spec else 0,
            block_k=spec.block_k if spec else 0,
            block_n=spec.block_n if spec else 0,
        ))
    return rows


def _collapse_shard_entry(entry: dict, axis: int) -> dict:
    """Collapse a model-sharded entry's shard axis host-side, BEFORE the row
    builder (whose leading-axis heuristics must keep meaning "layers").

    Counter lanes collapse per `COUNTER_SHARD_REDUCE`: the ownership
    partition makes "sum" lanes disjoint slices of the dense baseline (their
    plain sum is the unsharded counter bitwise); replicated lanes take shard
    0. ctrl/steps are replicated across shards by construction — lane 0.
    Returns a minimal host-numpy entry (sensor/ctrl/steps), which is all the
    row builder reads."""
    from repro.sensor.counters import COUNTER_SHARD_REDUCE

    sensor = {}
    for key, arr in entry["sensor"].items():
        a = np.asarray(arr)
        red = COUNTER_SHARD_REDUCE.get(key, "first")
        sensor[key] = a.sum(axis=axis) if red == "sum" \
            else np.take(a, 0, axis=axis)
    out: dict[str, Any] = {
        "sensor": sensor,
        "steps": np.take(np.asarray(entry["steps"]), 0, axis=axis),
    }
    ctrl = entry.get("ctrl")
    if ctrl is not None:
        out["ctrl"] = {
            k: np.take(np.asarray(v), 0, axis=axis) for k, v in ctrl.items()
        }
    return out


def _sum_rows(name: str, rows: list[SiteSensor]) -> SiteSensor:
    hit = np.mean([r.slot_hit_rates for r in rows], axis=0)
    lane_steps = np.max([r.slot_steps for r in rows], axis=0)
    modes = {r.mode for r in rows}
    return SiteSensor(
        site=name,
        layer=None,
        # a stack that settled distinct per-layer modes is "mixed" at site
        # granularity — the per_layer rows carry the lane truth
        mode=modes.pop() if len(modes) == 1 else "mixed",
        steps=max(r.steps for r in rows),
        skipped_tiles=sum(r.skipped_tiles for r in rows),
        computed_tiles=sum(r.computed_tiles for r in rows),
        skipped_macs=sum(r.skipped_macs for r in rows),
        computed_macs=sum(r.computed_macs for r in rows),
        skipped_weight_bytes=sum(r.skipped_weight_bytes for r in rows),
        total_weight_bytes=sum(r.total_weight_bytes for r in rows),
        reused_out_elems=sum(r.reused_out_elems for r in rows),
        dma_issued_tiles=sum(r.dma_issued_tiles for r in rows),
        mode_transitions=sum(r.mode_transitions for r in rows),
        slot_hit_rates=list(np.asarray(hit, np.float64)),
        slot_steps=[int(s) for s in lane_steps],
        # suppression is a site-level event bumped on every layer slice at
        # once, so max (not sum) recovers the event count
        suppressed_flips=max(r.suppressed_flips for r in rows),
        grid_steps=sum(r.grid_steps for r in rows),
        # each layer slice's evaluation falls back independently
        overflow_fallbacks=sum(r.overflow_fallbacks for r in rows),
        # each lane quarantines independently: sum, unlike suppressed_flips
        sentinel_trips=sum(r.sentinel_trips for r in rows),
        exec_path=rows[0].exec_path,
        budget_occupancy=float(np.mean([r.budget_occupancy for r in rows])),
        in_features=rows[0].in_features,
        out_features=rows[0].out_features,
        block_m=rows[0].block_m,
        block_k=rows[0].block_k,
        block_n=rows[0].block_n,
    )


def build_report(engine, cache: dict[str, Any]) -> SensorReport:
    """Reduce a reuse cache's sensor counters. `engine` supplies site specs
    (duck-typed: .sites / .impl); kernelModes come from each entry's
    array-resident ctrl block, per layer."""
    per_site, per_layer = [], []
    impl = getattr(engine, "impl", "jnp")
    shards = getattr(engine, "shards", None) or {}
    stacking = getattr(engine, "stacking", None) or {}
    for name in engine.sites:
        entry = cache[name]
        if "sensor" not in entry:
            continue
        if name in shards:
            entry = _collapse_shard_entry(
                entry, 1 if stacking.get(name, 0) else 0)
        rows = _entry_rows(name, entry, spec=engine.sites[name], impl=impl)
        if rows[0].layer is not None:
            per_layer += rows
        per_site.append(_sum_rows(name, rows))

    tot = {
        k: sum(getattr(s, k) for s in per_site)
        for k in ("skipped_tiles", "computed_tiles", "skipped_macs",
                  "computed_macs", "skipped_weight_bytes", "total_weight_bytes",
                  "reused_out_elems", "mode_transitions", "suppressed_flips",
                  "grid_steps", "overflow_fallbacks", "sentinel_trips")
    }
    total_tiles = tot["skipped_tiles"] + tot["computed_tiles"]
    total_macs = tot["skipped_macs"] + tot["computed_macs"]
    dense_grid = sum(s.dense_grid_steps for s in per_site)
    model = dict(
        tot,
        steps=max((s.steps for s in per_site), default=0),
        n_sites=len(per_site),
        total_tiles=total_tiles,
        tile_skip_rate=tot["skipped_tiles"] / max(total_tiles, 1),
        total_macs=total_macs,
        mac_skip_rate=tot["skipped_macs"] / max(total_macs, 1e-9),
        weight_byte_skip_rate=(
            tot["skipped_weight_bytes"] / max(tot["total_weight_bytes"], 1e-9)
        ),
        grid_step_skip_rate=max(
            0.0, 1.0 - tot["grid_steps"] / max(dense_grid, 1e-9)
        ),
        hit_rate=float(np.mean([s.hit_rate for s in per_site])) if per_site else 0.0,
    )
    if shards:
        # mesh provenance + interconnect payloads for the E_ICI pricing —
        # additive keys, only on sharded runs (unsharded rows are unchanged
        # byte for byte, which the cost-model regression test pins)
        model["mesh_model_shards"] = max(shards.values())
        model["ici_reduce_bytes"] = float(
            getattr(engine, "ici_reduce_bytes", 0.0))
        model["ici_ctrl_write_bytes"] = float(
            getattr(engine, "ici_write_bytes", 0.0))
    return SensorReport(per_site=per_site, per_layer=per_layer, model=model)


def slot_telemetry(engine, cache: dict[str, Any], slot: int) -> dict[str, Any]:
    """Per-request telemetry for one serving slot (read at retirement).

    Reads ONLY the slot's per-site hit-rate lanes (two small [M] transfers per
    site) — cheap enough for the scheduler's retirement path. Tile/MAC skips
    are batch-granular (one tile spans block_m rows), so they live in the
    model-level `build_report`, not here.
    """
    hit_sums, steps = [], 0
    for name in engine.sites:
        sensor = cache[name].get("sensor")
        if sensor is None:
            continue
        hs = np.asarray(sensor["slot_hit_sum"], np.float64)[..., slot]
        ss = np.asarray(sensor["slot_steps"], np.float64)[..., slot]
        hit_sums.append(float(np.sum(hs) / max(float(np.sum(ss)), 1.0))
                        if np.sum(ss) else 0.0)
        steps = max(steps, int(np.max(ss)))
    return {
        "slot": slot,
        "steps": steps,
        "hit_rate": float(np.mean(hit_sums)) if hit_sums else 0.0,
        "n_sites": len(hit_sums),
    }
