"""Cycles + energy derived from MEASURED sensor counters.

This is the accounting half of the paper's evaluation (gem5+McPAT there,
analytic here): given a :class:`~repro.sensor.aggregate.SensorReport` gathered
from real decode steps, derive the dynamic/static energy split and the
roofline-time speedup attributable to the measured skips — no assumed
similarity constant anywhere on this path.

The per-op energy constants previously lived in ``benchmarks/energy.py``;
they move here so both the analytic projection and the measured accounting
draw from one source. Values are public order-of-magnitude figures for a
7nm-class accelerator; the reproduced object is the structure of the paper's
Fig. 13 (dynamic savings from skipped work + static savings from shorter
steps), not absolute joules.
"""

from __future__ import annotations

from typing import Any

from repro.roofline.model_cost import HBM_BW, PEAK_FLOPS

E_MAC = 0.3e-12      # J/FLOP (bf16 MXU, incl. local movement)
E_HBM = 12e-12       # J/byte HBM access
E_ICI = 20e-12       # J/byte off-chip link
STATIC_W = 80.0      # W per chip static/other

FLOPS_PER_MAC = 2.0


def measured_skip_fractions(report) -> dict[str, float]:
    """The harvest actually achieved, straight from counters (feeds the
    roofline model's `reuse_skip_fraction` where an analytic run would have
    used 0.8·PAPER_SIMILARITY)."""
    m = report.model
    return {
        "tile_skip_rate": m["tile_skip_rate"],
        "mac_skip_rate": m["mac_skip_rate"],
        "weight_byte_skip_rate": m["weight_byte_skip_rate"],
        "hit_rate": m["hit_rate"],
    }


def sensor_energy(report) -> dict[str, Any]:
    """Dynamic-energy accounting over the measured window (reuse-site scope).

    baseline  — what the dense kernels would have spent on the instrumented
                sites: every MAC issued, every weight tile streamed;
    measured  — what the reuse kernels actually spent (computed MACs + issued
                weight traffic), PLUS the interconnect cost a model-sharded
                run pays: the once-per-window cross-mesh counter reduce and
                the sharded ctrl-lane write fan-out, metered by the engine
                into the report's ``ici_reduce_bytes``/``ici_ctrl_write_bytes``
                and priced here at E_ICI (an unsharded report carries neither
                key, so its numbers are unchanged bitwise);
    saved     — the skipped component net of that interconnect spend;
                ``reduction`` is saved/baseline.
    Static energy scales with step time, so its reduction follows the cycle
    model (`sensor_speedup`) — reported there, not double-counted here.
    """
    m = report.model
    get = m.get if hasattr(m, "get") else lambda k, d=0.0: getattr(m, k, d)
    base_flops = m["total_macs"] * FLOPS_PER_MAC
    base_bytes = m["total_weight_bytes"]
    saved_flops = m["skipped_macs"] * FLOPS_PER_MAC
    saved_bytes = m["skipped_weight_bytes"]
    ici_bytes = float(get("ici_reduce_bytes", 0.0)) \
        + float(get("ici_ctrl_write_bytes", 0.0))
    ici_j = ici_bytes * E_ICI
    base = base_flops * E_MAC + base_bytes * E_HBM
    saved = saved_flops * E_MAC + saved_bytes * E_HBM
    out = {
        "baseline_dynamic_j": base,
        "measured_dynamic_j": base - saved + ici_j,
        "saved_dynamic_j": saved - ici_j,
        "dynamic_reduction": (saved - ici_j) / max(base, 1e-30),
        "saved_flops": saved_flops,
        "saved_hbm_bytes": saved_bytes,
    }
    if ici_bytes:
        # additive keys, sharded runs only — unsharded output stays
        # key-for-key identical (pinned by the cost-model regression test)
        out["ici_bytes"] = ici_bytes
        out["ici_j"] = ici_j
    return out


def sensor_speedup(report) -> dict[str, Any]:
    """Roofline-time speedup on the instrumented sites from measured skips.

    Site GEMMs at decode shapes are memory-bound, so time ≈ max(flops/peak,
    bytes/bw); the measured variant subtracts the skipped components.
    """
    m = report.model
    base_flops = m["total_macs"] * FLOPS_PER_MAC
    base_bytes = m["total_weight_bytes"]
    live_flops = m["computed_macs"] * FLOPS_PER_MAC
    live_bytes = base_bytes - m["skipped_weight_bytes"]
    t_base = max(base_flops / PEAK_FLOPS, base_bytes / HBM_BW)
    t_meas = max(live_flops / PEAK_FLOPS, live_bytes / HBM_BW)
    return {
        "baseline_site_s": t_base,
        "measured_site_s": t_meas,
        "site_speedup": t_base / max(t_meas, 1e-30),
        "static_energy_reduction": 1.0 - t_meas / max(t_base, 1e-30),
    }
