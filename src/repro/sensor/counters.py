"""Per-site sensor counters — the measured reuse-accounting state.

The counters ride INSIDE each reuse-cache entry (under the ``"sensor"`` key),
so they thread through `jax.lax.scan` over layers, donate, shard and
checkpoint exactly like the rest of the cache pytree. Updates happen on the
traced reuse path and cost a handful of reductions over the (tiny) tile mask
per call — negligible next to the GEMM they account for.

Accounting convention (documented once, used everywhere):

* Tile counters are exact integers on the PADDED tile grid the kernel actually
  executes: a site call with inputs [M, K] and weights [K, N] runs
  ``gm = ceil(M/block_m)`` × ``gk = ceil(K/block_k)`` delta tiles, each worth
  ``block_m · block_k · N`` MACs (the tile is contracted against the full N).
* ``skipped_tiles + computed_tiles == steps · gm · gk`` — counter conservation,
  property-tested in tests/test_sensor.py. Basic-mode calls count every tile
  as computed (the basic kernel skips nothing), so conservation holds across
  mode flips.
* Weight-load accounting is against the dense baseline, which streams the
  site's [K, N] weight panel once per m-row-block per step:
  ``total_weight_bytes = steps · gm · gk · block_k · N · itemsize``.
* MAC/byte accumulators are f32: exact for test-scale counts (< 2^24 per
  increment granularity) and telemetry-grade beyond that.

Per-slot state (``slot_hit_sum``/``slot_steps``, shape [M]) survives inside
the entry so the serving scheduler can reset exactly one lane when a slot is
recycled and read per-request hit rates at retirement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_site_counters(batch: int) -> dict[str, jax.Array]:
    """Fresh counter pytree for one reuse site (one cache entry)."""
    return {
        "skipped_tiles": jnp.zeros((), jnp.int32),
        "computed_tiles": jnp.zeros((), jnp.int32),
        "skipped_macs": jnp.zeros((), jnp.float32),
        "computed_macs": jnp.zeros((), jnp.float32),
        "skipped_weight_bytes": jnp.zeros((), jnp.float32),
        "total_weight_bytes": jnp.zeros((), jnp.float32),
        "reused_out_elems": jnp.zeros((), jnp.float32),
        "dma_issued_tiles": jnp.zeros((), jnp.int32),
        # Grid steps the execution path actually walked, in (k-tile visit ×
        # n-panel) units — dense baseline is gm·gk·gn per evaluation. Only
        # the compacted tiers (ragged grid, budgeted compact GEMM) shrink
        # this — the masked kernel visits every tile; saved steps are
        # accounted like saved DMAs (only when truly elided).
        "grid_steps": jnp.zeros((), jnp.float32),
        # Evaluations whose live tile count overflowed the compacted-path
        # budget (max_active_k) and took the full-extent lax.cond fallback.
        # The online budget adapter widens/tightens max_active_k from the
        # windowed rate of this counter vs the grid-step savings.
        "overflow_fallbacks": jnp.zeros((), jnp.int32),
        # kernelMode tracking: -1 = never evaluated, 0 = basic, 1 = reuse.
        "mode_flag": jnp.full((), -1, jnp.int32),
        "mode_transitions": jnp.zeros((), jnp.int32),
        # flips the policy WANTED but hysteresis vetoed (incremented host-side
        # by ReuseEngine.refresh_modes; a site-level event, so stacked sites
        # see every layer slice bumped together and aggregation takes the max)
        "suppressed_flips": jnp.zeros((), jnp.int32),
        # guard-plane sentinel trips that quarantined this lane (incremented
        # host-side by the QuarantineBreaker per containment action; per-layer
        # on stacked sites — aggregation SUMS lanes, unlike suppressed_flips)
        "sentinel_trips": jnp.zeros((), jnp.int32),
        # per-slot hit-rate accumulators (reset per lane on slot recycle)
        "slot_hit_sum": jnp.zeros((batch,), jnp.float32),
        "slot_steps": jnp.zeros((batch,), jnp.int32),
    }


def _mode_bookkeeping(sensor: dict, flag: int) -> tuple[jax.Array, jax.Array]:
    prev = sensor["mode_flag"]
    flipped = (prev >= 0) & (prev != flag)
    transitions = sensor["mode_transitions"] + flipped.astype(jnp.int32)
    return jnp.full((), flag, jnp.int32), transitions


def update_on_reuse(
    sensor: dict[str, jax.Array],
    *,
    block_mask: jax.Array,    # [gm, gk] int32; 1 = tile computed
    row_sim: jax.Array,       # [M] per-slot code-match fraction this call
    block_m: int,
    block_k: int,
    n: int,
    gn: int,
    w_itemsize: int,
    dma_issued: jax.Array | None = None,  # measured DMA count (kernel semantics)
    grid_steps: jax.Array | None = None,  # measured grid steps (ragged paths)
    overflow: jax.Array | None = None,    # budget-overflow fallback this call
) -> dict[str, jax.Array]:
    """Account one reuse-mode evaluation from its tile mask.

    dma_issued_tiles is in (block_k × block_n) weight-tile units everywhere
    (a dense stream of the site is gm·gk·gn such tiles per step), so the
    counter stays comparable across mode flips. grid_steps defaults to the
    full masked-grid walk gm·gk·gn (the "kernel"/"dense" paths visit every
    tile even when they skip its DMA and MXU op)."""
    gm, gk = block_mask.shape
    computed = jnp.sum(block_mask).astype(jnp.int32)
    total = jnp.int32(gm * gk)
    skipped = total - computed
    macs_per_tile = float(block_m * block_k * n)
    tile_w_bytes = float(block_k * n * w_itemsize)
    # m-row-blocks whose entire k-row of tiles is skipped pass their output
    # through untouched: block_m · N output elements fully reused.
    rows_all_skipped = jnp.sum(jnp.all(block_mask == 0, axis=1)).astype(jnp.float32)
    mode_flag, transitions = _mode_bookkeeping(sensor, 1)
    overflow_fallbacks = sensor.get("overflow_fallbacks")  # legacy caches: absent
    if overflow_fallbacks is not None and overflow is not None:
        overflow_fallbacks = overflow_fallbacks + overflow.astype(jnp.int32)
    extra = (
        {} if overflow_fallbacks is None
        else {"overflow_fallbacks": overflow_fallbacks}
    )
    return dict(
        sensor,
        skipped_tiles=sensor["skipped_tiles"] + skipped,
        computed_tiles=sensor["computed_tiles"] + computed,
        skipped_macs=sensor["skipped_macs"] + skipped.astype(jnp.float32) * macs_per_tile,
        computed_macs=sensor["computed_macs"] + computed.astype(jnp.float32) * macs_per_tile,
        skipped_weight_bytes=sensor["skipped_weight_bytes"]
        + skipped.astype(jnp.float32) * tile_w_bytes,
        total_weight_bytes=sensor["total_weight_bytes"]
        + jnp.float32(gm * gk) * tile_w_bytes,
        reused_out_elems=sensor["reused_out_elems"]
        + rows_all_skipped * float(block_m * n),
        dma_issued_tiles=sensor["dma_issued_tiles"]
        + (dma_issued.astype(jnp.int32) if dma_issued is not None
           else computed * gn),
        grid_steps=sensor["grid_steps"]
        + (grid_steps.astype(jnp.float32) if grid_steps is not None
           else jnp.float32(gm * gk * gn)),
        mode_flag=mode_flag,
        mode_transitions=transitions,
        slot_hit_sum=sensor["slot_hit_sum"] + row_sim.astype(jnp.float32),
        slot_steps=sensor["slot_steps"] + 1,
        **extra,
    )


def update_on_basic(
    sensor: dict[str, jax.Array],
    *,
    row_sim: jax.Array,       # [M]
    m: int,
    k: int,
    n: int,
    gn: int,
    block_m: int,
    block_k: int,
    w_itemsize: int,
) -> dict[str, jax.Array]:
    """Account one basic-mode (reuse-OFF) evaluation: everything computed.
    The dense kernel streams every weight tile: gm·gk·gn DMA units."""
    gm = -(-m // block_m)
    gk = -(-k // block_k)
    total = gm * gk
    macs_per_tile = float(block_m * block_k * n)
    tile_w_bytes = float(block_k * n * w_itemsize)
    mode_flag, transitions = _mode_bookkeeping(sensor, 0)
    return dict(
        sensor,
        computed_tiles=sensor["computed_tiles"] + jnp.int32(total),
        computed_macs=sensor["computed_macs"] + float(total) * macs_per_tile,
        total_weight_bytes=sensor["total_weight_bytes"] + float(total) * tile_w_bytes,
        dma_issued_tiles=sensor["dma_issued_tiles"] + jnp.int32(total * gn),
        grid_steps=sensor["grid_steps"] + jnp.float32(total * gn),
        mode_flag=mode_flag,
        mode_transitions=transitions,
        slot_hit_sum=sensor["slot_hit_sum"] + row_sim.astype(jnp.float32),
        slot_steps=sensor["slot_steps"] + 1,
    )
