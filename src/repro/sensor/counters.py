"""Per-site sensor counters — the measured reuse-accounting state.

The counters ride INSIDE each reuse-cache entry (under the ``"sensor"`` key),
so they thread through `jax.lax.scan` over layers, donate, shard and
checkpoint exactly like the rest of the cache pytree. Updates happen on the
traced reuse path and cost a handful of reductions over the (tiny) tile mask
per call — negligible next to the GEMM they account for.

Accounting convention (documented once, used everywhere):

* Tile counters are exact integers on the PADDED tile grid the kernel actually
  executes: a site call with inputs [M, K] and weights [K, N] runs
  ``gm = ceil(M/block_m)`` × ``gk = ceil(K/block_k)`` delta tiles, each worth
  ``block_m · block_k · N`` MACs (the tile is contracted against the full N).
* ``skipped_tiles + computed_tiles == steps · gm · gk`` — counter conservation,
  property-tested in tests/test_sensor.py. Basic-mode calls count every tile
  as computed (the basic kernel skips nothing), so conservation holds across
  mode flips.
* Weight-load accounting is against the dense baseline, which streams the
  site's [K, N] weight panel once per m-row-block per step:
  ``total_weight_bytes = steps · gm · gk · block_k · N · itemsize``.
* MAC/byte accumulators are f32: exact for test-scale counts (< 2^24 per
  increment granularity) and telemetry-grade beyond that.

Per-slot state (``slot_hit_sum``/``slot_steps``, shape [M]) survives inside
the entry so the serving scheduler can reset exactly one lane when a slot is
recycled and read per-request hit rates at retirement.

Model-axis sharding (ownership partition). When a site's cache is sharded
N-ways along the model axis (`ReuseEngine.shard_sites`), every shard sees the
SAME replicated delta/mask (the compare path is shard-local and K is not
split), so naive per-shard accounting would count each tile/MAC S times. The
convention instead PARTITIONS the dense-baseline accounting by ownership:

* tile/MAC/byte counters — shard s accounts only the k-tile columns with
  ``col % S == s`` (an iota mask over the [gm, gk] grid), priced at the
  GLOBAL N (``n_total``), so the plain sum over shards reproduces the
  unsharded counters BITWISE (per-tile constants are exact f32 integers);
* dma/grid counters — the formulas are linear in the n-panel count, so shard
  s accounts the global panels with ``panel % S == s`` (callers evaluate the
  per-panel formula at gn=1 and scale by `owned_panel_count`);
* `reused_out_elems` — linear in N: each shard prices its LOCAL n columns.

Counters that are NOT partitioned (mode bookkeeping, overflow, slot lanes)
stay replicated across shards; `COUNTER_SHARD_REDUCE` records, per counter,
whether a cross-shard rollup sums lanes or takes any one ("first").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ShardCtx(NamedTuple):
    """Model-axis shard context threaded into one sharded site evaluation.

    `index` is TRACED (the vmap-over-shards lane id); the rest are static
    geometry of the GLOBAL site the shard belongs to."""

    index: jax.Array   # int32 scalar — this shard's position on the model axis
    count: int         # number of shards the site is split into
    n_total: int       # global out_features (the shard computes n_total/count)
    gn_total: int      # global n-panel count: ceil(n_total / block_n)


# How each per-site counter collapses across model-axis shards. "sum": the
# ownership partition above makes lanes disjoint — plain summation is the
# global value, bitwise. "first": the lane is replicated (every shard runs the
# same bookkeeping on the same replicated mask/row_sim) — take any one shard.
COUNTER_SHARD_REDUCE: dict[str, str] = {
    "skipped_tiles": "sum",
    "computed_tiles": "sum",
    "skipped_macs": "sum",
    "computed_macs": "sum",
    "skipped_weight_bytes": "sum",
    "total_weight_bytes": "sum",
    "reused_out_elems": "sum",
    "dma_issued_tiles": "sum",
    "grid_steps": "sum",
    "overflow_fallbacks": "first",
    "mode_flag": "first",
    "mode_transitions": "first",
    "suppressed_flips": "first",
    "sentinel_trips": "first",
    "slot_hit_sum": "first",
    "slot_steps": "first",
}


def owned_k_mask(gk: int, shard: ShardCtx) -> jax.Array:
    """bool [gk]: the k-tile columns shard `index` accounts (col % S == s)."""
    return (jnp.arange(gk, dtype=jnp.int32) % shard.count) == shard.index


def owned_panel_count(shard: ShardCtx) -> jax.Array:
    """int32 scalar: how many GLOBAL n-panels shard `index` accounts."""
    own = (jnp.arange(shard.gn_total, dtype=jnp.int32) % shard.count
           ) == shard.index
    return jnp.sum(own.astype(jnp.int32))


def init_site_counters(batch: int) -> dict[str, jax.Array]:
    """Fresh counter pytree for one reuse site (one cache entry)."""
    return {
        "skipped_tiles": jnp.zeros((), jnp.int32),
        "computed_tiles": jnp.zeros((), jnp.int32),
        "skipped_macs": jnp.zeros((), jnp.float32),
        "computed_macs": jnp.zeros((), jnp.float32),
        "skipped_weight_bytes": jnp.zeros((), jnp.float32),
        "total_weight_bytes": jnp.zeros((), jnp.float32),
        "reused_out_elems": jnp.zeros((), jnp.float32),
        "dma_issued_tiles": jnp.zeros((), jnp.int32),
        # Grid steps the execution path actually walked, in (k-tile visit ×
        # n-panel) units — dense baseline is gm·gk·gn per evaluation. Only
        # the compacted tiers (ragged grid, budgeted compact GEMM) shrink
        # this — the masked kernel visits every tile; saved steps are
        # accounted like saved DMAs (only when truly elided).
        "grid_steps": jnp.zeros((), jnp.float32),
        # Evaluations whose live tile count overflowed the compacted-path
        # budget (max_active_k) and took the full-extent lax.cond fallback.
        # The online budget adapter widens/tightens max_active_k from the
        # windowed rate of this counter vs the grid-step savings.
        "overflow_fallbacks": jnp.zeros((), jnp.int32),
        # kernelMode tracking: -1 = never evaluated, 0 = basic, 1 = reuse.
        "mode_flag": jnp.full((), -1, jnp.int32),
        "mode_transitions": jnp.zeros((), jnp.int32),
        # flips the policy WANTED but hysteresis vetoed (incremented host-side
        # by ReuseEngine.refresh_modes; a site-level event, so stacked sites
        # see every layer slice bumped together and aggregation takes the max)
        "suppressed_flips": jnp.zeros((), jnp.int32),
        # guard-plane sentinel trips that quarantined this lane (incremented
        # host-side by the QuarantineBreaker per containment action; per-layer
        # on stacked sites — aggregation SUMS lanes, unlike suppressed_flips)
        "sentinel_trips": jnp.zeros((), jnp.int32),
        # per-slot hit-rate accumulators (reset per lane on slot recycle)
        "slot_hit_sum": jnp.zeros((batch,), jnp.float32),
        "slot_steps": jnp.zeros((batch,), jnp.int32),
    }


def _mode_bookkeeping(sensor: dict, flag: int) -> tuple[jax.Array, jax.Array]:
    prev = sensor["mode_flag"]
    flipped = (prev >= 0) & (prev != flag)
    transitions = sensor["mode_transitions"] + flipped.astype(jnp.int32)
    return jnp.full((), flag, jnp.int32), transitions


def update_on_reuse(
    sensor: dict[str, jax.Array],
    *,
    block_mask: jax.Array,    # [gm, gk] int32; 1 = tile computed
    row_sim: jax.Array,       # [M] per-slot code-match fraction this call
    block_m: int,
    block_k: int,
    n: int,
    gn: int,
    w_itemsize: int,
    dma_issued: jax.Array | None = None,  # measured DMA count (kernel semantics)
    grid_steps: jax.Array | None = None,  # measured grid steps (ragged paths)
    overflow: jax.Array | None = None,    # budget-overflow fallback this call
    shard: ShardCtx | None = None,        # model-axis ownership partition
) -> dict[str, jax.Array]:
    """Account one reuse-mode evaluation from its tile mask.

    dma_issued_tiles is in (block_k × block_n) weight-tile units everywhere
    (a dense stream of the site is gm·gk·gn such tiles per step), so the
    counter stays comparable across mode flips. grid_steps defaults to the
    full masked-grid walk gm·gk·gn (the "kernel"/"dense" paths visit every
    tile even when they skip its DMA and MXU op).

    With `shard` set, tile/MAC/byte increments cover only the shard's OWNED
    k-tile columns priced at the global N (see module docstring) — callers
    must then pass `dma_issued`/`grid_steps` already ownership-scaled (the
    per-path formulas at gn=1 times `owned_panel_count`)."""
    gm, gk = block_mask.shape
    if shard is None:
        computed = jnp.sum(block_mask).astype(jnp.int32)
        total = jnp.int32(gm * gk)
        n_acct = n
    else:
        assert dma_issued is not None and grid_steps is not None, (
            "sharded accounting needs ownership-scaled dma/grid overrides")
        own = owned_k_mask(gk, shard)
        computed = jnp.sum(
            jnp.where(own[None, :], block_mask, 0)).astype(jnp.int32)
        total = jnp.int32(gm) * jnp.sum(own.astype(jnp.int32))
        n_acct = shard.n_total
    skipped = total - computed
    macs_per_tile = float(block_m * block_k * n_acct)
    tile_w_bytes = float(block_k * n_acct * w_itemsize)
    # m-row-blocks whose entire k-row of tiles is skipped pass their output
    # through untouched: block_m · N output elements fully reused. Under the
    # shard partition each shard prices its LOCAL n columns (linear in N, so
    # the shard sum reproduces rows · block_m · n_total exactly).
    rows_all_skipped = jnp.sum(jnp.all(block_mask == 0, axis=1)).astype(jnp.float32)
    mode_flag, transitions = _mode_bookkeeping(sensor, 1)
    overflow_fallbacks = sensor.get("overflow_fallbacks")  # legacy caches: absent
    if overflow_fallbacks is not None and overflow is not None:
        overflow_fallbacks = overflow_fallbacks + overflow.astype(jnp.int32)
    extra = (
        {} if overflow_fallbacks is None
        else {"overflow_fallbacks": overflow_fallbacks}
    )
    return dict(
        sensor,
        skipped_tiles=sensor["skipped_tiles"] + skipped,
        computed_tiles=sensor["computed_tiles"] + computed,
        skipped_macs=sensor["skipped_macs"] + skipped.astype(jnp.float32) * macs_per_tile,
        computed_macs=sensor["computed_macs"] + computed.astype(jnp.float32) * macs_per_tile,
        skipped_weight_bytes=sensor["skipped_weight_bytes"]
        + skipped.astype(jnp.float32) * tile_w_bytes,
        total_weight_bytes=sensor["total_weight_bytes"]
        + total.astype(jnp.float32) * tile_w_bytes,
        reused_out_elems=sensor["reused_out_elems"]
        + rows_all_skipped * float(block_m * n),
        dma_issued_tiles=sensor["dma_issued_tiles"]
        + (dma_issued.astype(jnp.int32) if dma_issued is not None
           else computed * gn),
        grid_steps=sensor["grid_steps"]
        + (grid_steps.astype(jnp.float32) if grid_steps is not None
           else jnp.float32(gm * gk * gn)),
        mode_flag=mode_flag,
        mode_transitions=transitions,
        slot_hit_sum=sensor["slot_hit_sum"] + row_sim.astype(jnp.float32),
        slot_steps=sensor["slot_steps"] + 1,
        **extra,
    )


def update_on_basic(
    sensor: dict[str, jax.Array],
    *,
    row_sim: jax.Array,       # [M]
    m: int,
    k: int,
    n: int,
    gn: int,
    block_m: int,
    block_k: int,
    w_itemsize: int,
    shard: ShardCtx | None = None,
) -> dict[str, jax.Array]:
    """Account one basic-mode (reuse-OFF) evaluation: everything computed.
    The dense kernel streams every weight tile: gm·gk·gn DMA units. With
    `shard`, the same ownership partition as `update_on_reuse`: owned k-tile
    columns at global N for tiles/MACs/bytes, owned global n-panels for
    dma/grid."""
    gm = -(-m // block_m)
    gk = -(-k // block_k)
    if shard is None:
        total = jnp.int32(gm * gk)
        n_acct = n
        dma = jnp.int32(gm * gk * gn)
        grid = jnp.float32(gm * gk * gn)
    else:
        own = owned_k_mask(gk, shard)
        total = jnp.int32(gm) * jnp.sum(own.astype(jnp.int32))
        n_acct = shard.n_total
        gn_own = owned_panel_count(shard)
        dma = (jnp.int32(gm * gk) * gn_own).astype(jnp.int32)
        grid = (jnp.int32(gm * gk) * gn_own).astype(jnp.float32)
    macs_per_tile = float(block_m * block_k * n_acct)
    tile_w_bytes = float(block_k * n_acct * w_itemsize)
    mode_flag, transitions = _mode_bookkeeping(sensor, 0)
    return dict(
        sensor,
        computed_tiles=sensor["computed_tiles"] + total,
        computed_macs=sensor["computed_macs"]
        + total.astype(jnp.float32) * macs_per_tile,
        total_weight_bytes=sensor["total_weight_bytes"]
        + total.astype(jnp.float32) * tile_w_bytes,
        dma_issued_tiles=sensor["dma_issued_tiles"] + dma,
        grid_steps=sensor["grid_steps"] + grid,
        mode_flag=mode_flag,
        mode_transitions=transitions,
        slot_hit_sum=sensor["slot_hit_sum"] + row_sim.astype(jnp.float32),
        slot_steps=sensor["slot_steps"] + 1,
    )
