"""Measured-decode harness: run real decode steps, return the SensorReport.

This is the shared driver behind ``benchmarks/energy.py --measured``,
``benchmarks/speedup.py --measured`` and ``benchmarks/software_reuse.py
--measured``: a reduced-scale model decodes a correlated token stream with
the reuse engine threaded, and the report comes from the live counters the
kernels' tile masks produced — not from any assumed similarity table.

The correlated stream mirrors benchmarks/similarity.py: with probability
`correlation` the next token re-anchors to a fixed token, otherwise it follows
the model's own greedy output. High correlation ⇒ consecutive activations
quantize to similar codes ⇒ measurable tile skips, which is the operating
regime the paper measures (Table I).

Kept separate from ``repro.sensor.__init__`` on purpose: importing this module
pulls in the serving stack, and ``repro.core.engine`` imports the sensor
package — a cycle if the runner were re-exported there.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params
from repro.serve.serve_step import (
    build_reuse_engine,
    decode_step,
    greedy_sample,
    init_serve_state,
)


# The measured-benchmark operating points: (arch, stream correlation). One
# table so energy/speedup/software_reuse measure the same regime — correlation
# is the stream knob, everything downstream comes from the counters.
MEASURED_OPERATING_POINTS = [
    ("qwen3-32b", 0.95),
    ("mixtral-8x7b", 0.9),
    ("rwkv6-7b", 0.95),
]


@dataclasses.dataclass
class MeasuredDecode:
    arch: str
    steps: int
    batch: int
    engine: object
    cache: dict
    report: object          # SensorReport

    @property
    def skip_fractions(self):
        from repro.sensor.cost_model import measured_skip_fractions

        return measured_skip_fractions(self.report)


def run_measured_decode(
    arch: str,
    *,
    steps: int = 10,
    batch: int = 2,
    cache_len: int = 64,
    correlation: float = 0.9,
    seed: int = 0,
    reduced: bool = True,
    refresh_policy: bool = False,
    policy=None,
    on_step=None,
    burst: tuple[int, int] | None = None,
) -> MeasuredDecode:
    """Decode `steps` tokens on a (reduced) arch and harvest sensor counters.

    refresh_policy=True re-runs the host-side mode policy between steps, so
    low-similarity sites demote to basic mode mid-run (mode_transitions then
    measures real policy churn); False pins the registration-time modes, which
    keeps every site on the reuse path — the right setting when the point is
    to measure skip rates.

    `policy` (a ReusePolicy, e.g. from repro.tune.load_tuned_policy) replaces
    the default global-constant policy — the tuned-vs-default benchmark knob.

    `on_step(step_idx, engine, reuse_cache)` runs host-side after each decode
    step (1-based) — the hook the online control plane (`repro.control`)
    rides in tests and examples; it may mutate the engine's policy/specs and
    the cache's sensor counters in place.

    `burst=(a, b)` feeds uniform-random tokens for steps a..b (1-based,
    inclusive) instead of the correlated stream — a dissimilarity burst that
    spikes tile occupancy, the adversarial input for budget-adaptation tests.
    """
    cfg = ARCHS[arch]
    if reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    engine = build_reuse_engine(cfg, impl="jnp", policy=policy)
    rcache = engine.init_cache(batch)
    state = init_serve_state(cfg, batch, cache_len)

    anchor = rng.integers(0, cfg.vocab, (batch, 1)).astype(np.int32)
    tok = jax.numpy.asarray(anchor)
    if burst is not None and burst[0] <= 1 <= burst[1]:
        # a burst covering step 1 must randomize the pre-loop token too
        tok = jax.numpy.asarray(
            rng.integers(0, cfg.vocab, (batch, 1)).astype(np.int32))
    for i in range(steps):
        logits, state, rcache = decode_step(
            params, cfg, tok, state, engine=engine, reuse_cache=rcache
        )
        if refresh_policy:
            engine.refresh_modes(rcache)
        if on_step is not None:
            on_step(i + 1, engine, rcache)
        nxt = np.asarray(greedy_sample(logits))[:, :1]
        if burst is not None and burst[0] <= i + 2 <= burst[1]:
            # the NEXT step (i+2, 1-based) decodes inside the burst
            tok = jax.numpy.asarray(
                rng.integers(0, cfg.vocab, (batch, 1)).astype(np.int32))
            continue
        keep = rng.random((batch, 1)) < correlation
        tok = jax.numpy.asarray(np.where(keep, anchor, nxt).astype(np.int32))

    return MeasuredDecode(
        arch=arch,
        steps=steps,
        batch=batch,
        engine=engine,
        cache=rcache,
        report=engine.sensor_report(rcache),
    )
