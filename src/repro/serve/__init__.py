from repro.serve.scheduler import ContinuousBatcher, Request, reset_slot
from repro.serve.serve_step import (
    build_reuse_engine,
    decode_step,
    greedy_sample,
    init_serve_state,
    prefill_step,
)

__all__ = [
    "ContinuousBatcher", "Request", "build_reuse_engine", "decode_step",
    "greedy_sample", "init_serve_state", "prefill_step", "reset_slot",
]
