"""Continuous-batching request scheduler for the serving runtime.

Slot-based scheduler in the vLLM lineage, sized to the assigned decode
shapes: a fixed decode batch of B slots; requests queue, claim a free slot,
prefill into that slot's cache lane, then ride the shared decode step until
EOS/limit. The ReuseSense caches are slot-aligned: when a slot is recycled,
its reuse-cache lane is reset (a fresh stream must not delta against the
previous occupant) — `reset_slot` zeroes prev_q/prev_out and the engine's
cold-start property (reuse == quantized dense on first step) makes that safe.

The step loop is host-side Python driving jitted steps — the scheduler is
exercised end-to-end at reduced scale in examples/serve_reuse.py and tests.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import events
from repro.obs.trace import span


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: run to max_new_tokens
    # Predicted stream similarity in [0, 1] (session-level prior: a sticky
    # agent loop predicts high, a one-shot query low). When set — and the
    # batcher has a slot_sim_fn — admission places the request on the free
    # slot whose sim_ema history best matches, instead of first-free. Left
    # None, the batcher's `predict_sim_fn` (the learned admission predictor)
    # supplies the prediction instead of trusting the caller.
    predicted_sim: float | None = None
    # Session identity for the learned admission predictor: requests sharing
    # a session share a similarity estimate. None = per-request (rid) keying.
    session: object = None
    # filled by the scheduler
    output: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    telemetry: dict | None = None  # per-request sensor snapshot at retirement


def reset_slot(
    reuse_cache: dict | None, slot: int, *, admission=None
) -> dict | None:
    """Zero one slot's reuse lane across all sites (stream handoff).

    Beyond prev_q/prev_out, the per-slot policy and sensor lanes reset too:
    sim_ema is per-slot ([M]) so a recycled slot must not inherit the previous
    occupant's similarity history (the policy reads the mean across lanes),
    and the sensor's slot_hit_sum/slot_steps lanes restart so retirement
    telemetry covers exactly one request's residency.

    `admission` (an AdmissionPredictor, or anything with `.reset_slot(slot)`)
    gets its per-slot occupant state cleared in the same pass: a new session
    must not inherit the previous occupant's similarity estimate, and
    telemetry arriving after the recycle must not be attributed to the
    departed session. Cleared even when there is no reuse cache — the
    predictor's slot state is host-side and independent of it."""
    if admission is not None:
        admission.reset_slot(slot)
    if reuse_cache is None:
        return None

    def reset_entry(entry):
        e = dict(entry)
        e["prev_q"] = entry["prev_q"].at[..., slot, :].set(0)
        e["prev_out"] = entry["prev_out"].at[..., slot, :].set(0)
        if entry["sim_ema"].ndim >= 1:  # per-slot lanes (scalar = legacy)
            e["sim_ema"] = entry["sim_ema"].at[..., slot].set(0)
        if "sensor" in entry:
            s = dict(entry["sensor"])
            s["slot_hit_sum"] = s["slot_hit_sum"].at[..., slot].set(0)
            s["slot_steps"] = s["slot_steps"].at[..., slot].set(0)
            e["sensor"] = s
        return e

    return {site: reset_entry(entry) for site, entry in reuse_cache.items()}


class ContinuousBatcher:
    def __init__(
        self,
        *,
        batch_slots: int,
        prefill_fn: Callable,     # (slot_tokens [1, S], slot) -> first token
        decode_fn: Callable,      # (tokens [B, 1]) -> next tokens [B, 1]
        max_steps: int = 512,
        telemetry_fn: Callable | None = None,  # (slot) -> dict, at retirement
        on_retire: Callable | None = None,     # (Request) -> None
        slot_sim_fn: Callable | None = None,   # (slot) -> lane sim_ema score
        on_step: Callable | None = None,       # (step_idx) -> None, post-decode
        predict_sim_fn: Callable | None = None,  # (Request) -> predicted sim
        on_place: Callable | None = None,      # (Request) -> None, post-admit
    ):
        self.batch_slots = batch_slots
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_steps = max_steps
        self.telemetry_fn = telemetry_fn
        self.on_retire = on_retire
        self.slot_sim_fn = slot_sim_fn
        self.on_step = on_step
        self.predict_sim_fn = predict_sim_fn
        self.on_place = on_place
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.free_slots = list(range(batch_slots))
        self.completed: list[Request] = []
        self.stats = {"steps": 0, "prefills": 0, "emitted_tokens": 0,
                      "affinity_placements": 0}
        self._cur: np.ndarray | None = None  # decode-step token buffer

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _pick_slot(self, req: Request) -> int:
        """Slot for an incoming request. Default: first-free. With a
        slot_sim_fn and a request-side similarity prediction, pick the free
        slot whose lane sim_ema history is closest to the prediction: lane
        data is reset on admission, but the mode policy and per-site tunables
        key off per-slot sim_ema, so keeping similarity-alike streams on the
        same lanes stabilises the mean the policy reads and avoids mode-flip
        (recompile) churn when traffic mixes sticky and one-shot streams.

        The similarity prediction is the request's own `predicted_sim` when
        the caller set one; otherwise the batcher's `predict_sim_fn` (the
        learned admission predictor) supplies it."""
        pred = req.predicted_sim
        if pred is None and self.predict_sim_fn is not None:
            pred = float(self.predict_sim_fn(req))
        if (
            pred is None
            or self.slot_sim_fn is None
            or len(self.free_slots) == 1
        ):
            return self.free_slots.pop()
        slot = min(
            self.free_slots,
            key=lambda s: abs(float(self.slot_sim_fn(s)) - pred),
        )
        self.free_slots.remove(slot)
        self.stats["affinity_placements"] += 1
        return slot

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self._pick_slot(req)
            req.slot = slot
            if self.on_place is not None:
                self.on_place(req)
            # The prefill span (and everything the prefill emits) carries the
            # request/session identity — admission is where a slot's stream
            # changes owner, so this is the correlation boundary.
            with events.context(request=req.rid, session=req.session,
                                slot=slot):
                with span("prefill", slot=slot,
                          prompt_len=int(req.prompt.shape[0])) as sp:
                    first = sp.sync(self.prefill_fn(req.prompt[None, :], slot))
            req.output.append(int(first))
            self.active[slot] = req
            self.stats["prefills"] += 1

    def _retire(self, slot: int) -> None:
        req = self.active.pop(slot)
        req.done = True
        # Snapshot per-request reuse telemetry BEFORE the slot is freed (the
        # next occupant's prefill resets the slot's sensor lanes). Retirement
        # work is stamped with the departing request's identity.
        with events.context(request=req.rid, session=req.session, slot=slot):
            if self.telemetry_fn is not None:
                req.telemetry = self.telemetry_fn(slot)
            self.completed.append(req)
            self.free_slots.append(slot)
            if self.on_retire is not None:
                self.on_retire(req)

    @property
    def pending(self) -> bool:
        """Work remains: requests queued or slots actively decoding."""
        return bool(self.active or self.queue)

    def step_once(self) -> bool:
        """Admit waiting requests and run ONE shared decode step.

        Returns False when there is nothing left to do. Factored out of
        `run` so an external driver (the N-replica harness, later the
        router) can interleave several batchers step-by-step in one
        process instead of letting each run to completion."""
        if self._cur is None:
            self._cur = np.zeros((self.batch_slots, 1), np.int32)
        self._admit()
        if not self.active and not self.queue:
            return False
        for slot, req in self.active.items():
            self._cur[slot, 0] = req.output[-1]
        # THE serve-step measurement: host dispatch + device execution
        # (sync), one span per decode step, batch-occupancy tagged.
        with span("serve_step", active=len(self.active)) as sp:
            nxt = np.asarray(sp.sync(self.decode_fn(self._cur)))
        self.stats["steps"] += 1
        if self.on_step is not None:
            self.on_step(self.stats["steps"])
        for slot in list(self.active):
            req = self.active[slot]
            tok = int(nxt[slot, 0])
            req.output.append(tok)
            self.stats["emitted_tokens"] += 1
            if (req.eos_id >= 0 and tok == req.eos_id) or (
                len(req.output) >= req.max_new_tokens
            ):
                self._retire(slot)
        return True

    def run(self) -> list[Request]:
        for _ in range(self.max_steps):
            if not self.step_once():
                break
        return self.completed
