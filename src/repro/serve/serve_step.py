"""Serving steps: prefill (S > 1 into fresh caches) and decode (S = 1).

`decode_step` is where ReuseSense lives (the paper's setting: repeated
evaluations of the same layer on consecutive inputs). The reuse cache pytree
threads through the step beside the KV cache; the engine's per-site kernelMode
has already been decided host-side (policy), so the step stays branch-free.

These are the functions the dry-run lowers for prefill_32k / decode_32k /
long_500k cells.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ReuseEngine
from repro.core.policy import ReusePolicy
from repro.models import forward, init_decode_state, output_logits


def build_reuse_engine(
    cfg: ModelConfig,
    *,
    impl: str = "jnp",
    block_m: int = 8,
    block_k: int = 256,
    policy: ReusePolicy | None = None,
) -> ReuseEngine:
    """Register the decode-time reuse sites for an architecture.

    Site inventory mirrors DESIGN.md §4: attention projections + dense MLP +
    shared-expert everywhere they exist; routed experts and nested-inner sites
    are excluded (documented arch-applicability scoping).

    `policy` carries per-site tunables (see repro.tune): registration resolves
    each site's block_k, exec_path and max_active_k through it, so a tuned
    table changes both the tile granularity AND the execution substrate
    (masked kernel vs ragged compacted grid vs gathered compact GEMM) the
    site is dispatched on — and the host-side `refresh_modes` pass keeps
    promoting sites onto the compacted tier as their measured skip rate
    develops.
    """
    eng = ReuseEngine(impl=impl, policy=policy or ReusePolicy())
    nsb = cfg.n_superblocks
    d = cfg.d_model

    def reg(name, fi, fo, mode="auto"):
        eng.register(
            name, fi, fo, n_layers=nsb, block_m=block_m, block_k=block_k,
            mode=mode,
        )

    if cfg.ssm_kind == "rwkv6":
        for nm in ("wr", "wk", "wv", "wg"):
            reg(f"rwkv_{nm}", d, d)
        reg("rwkv_wo", d, d)
        reg("rwkv_cmix_wk", d, cfg.d_ff)
        reg("rwkv_cmix_wv", cfg.d_ff, d)
        reg("rwkv_cmix_wr", d, d)
        return eng
    if cfg.ssm_kind == "mamba2":
        # inner mamba sites are nested (excluded); the shared block carries reuse
        if cfg.hybrid_attn_every:
            reg("shared_attn_qkv", d, cfg.q_dim + 2 * cfg.kv_dim)
            reg("shared_attn_out", cfg.q_dim, d)
            fi = 2 * cfg.d_ff if cfg.mlp_kind == "swiglu" else cfg.d_ff
            reg("shared_mlp_in", d, fi)
            reg("shared_mlp_out", cfg.d_ff, d)
        return eng

    if cfg.attn_kind == "local_global":
        reg("attn_global_qkv", d, cfg.q_dim + 2 * cfg.kv_dim)
        reg("attn_global_out", cfg.q_dim, d)
        fi = 2 * cfg.d_ff if cfg.mlp_kind == "swiglu" else cfg.d_ff
        reg("mlp_global_in", d, fi)
        reg("mlp_global_out", cfg.d_ff, d)
        return eng

    reg("attn_qkv", d, cfg.q_dim + 2 * cfg.kv_dim)
    reg("attn_out", cfg.q_dim, d)
    if cfg.n_experts:
        if cfg.shared_expert:
            reg("moe_shared_in", d, 2 * cfg.d_ff)
            reg("moe_shared_out", cfg.d_ff, d)
    else:
        fi = 2 * cfg.d_ff if cfg.mlp_kind == "swiglu" else cfg.d_ff
        reg("mlp_in", d, fi)
        reg("mlp_out", cfg.d_ff, d)
    return eng


def prefill_step(
    params: Any, cfg: ModelConfig, tokens_or_inputs, state: dict
) -> tuple[jax.Array, dict]:
    """Process a prompt into fresh caches. Returns (last-token logits, state)."""
    inputs = (
        tokens_or_inputs
        if isinstance(tokens_or_inputs, dict)
        else {"tokens": tokens_or_inputs}
    )
    h, new_state, _, _ = forward(params, cfg, inputs, decode_state=state)
    logits = output_logits(params, cfg, h[:, -1:])
    return logits, new_state


def decode_step(
    params: Any,
    cfg: ModelConfig,
    token: jax.Array,        # [B, 1] int32
    state: dict,
    *,
    engine: ReuseEngine | None = None,
    reuse_cache: dict | None = None,
) -> tuple[jax.Array, dict, dict | None]:
    """One autoregressive step. Returns (logits [B,1,V], state, reuse_cache)."""
    h, new_state, new_rcache, _ = forward(
        params, cfg, {"tokens": token}, decode_state=state,
        reuse_engine=engine, reuse_cache=reuse_cache,
    )
    logits = output_logits(params, cfg, h)
    return logits, new_state, new_rcache


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def init_serve_state(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return init_decode_state(cfg, batch, cache_len)
