from repro.train.train_step import (
    chunked_xent_loss,
    init_train_state,
    loss_fn,
    make_train_step,
)

__all__ = ["chunked_xent_loss", "init_train_state", "loss_fn", "make_train_step"]
