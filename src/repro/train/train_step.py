"""Training step: chunked-vocab cross-entropy, grad accumulation, AdamW.

The loss scans over sequence chunks so the [B, chunk, V] logits tensor — not
[B, S, V] — is the peak intermediate (vocab reaches 262k on gemma3; a full
logits tensor would be tens of GB per device). The chunk body is
rematerialized, so backward recomputes chunk logits instead of storing them.

`train_step` is the function the dry-run lowers for the train_4k cells.
Gradient accumulation (microbatching) is a scan over microbatch slices with
an f32 grad accumulator — at 1000+ nodes this is what keeps the per-device
activation footprint constant while the global batch scales.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward, transformer
from repro.models.layers import apply_norm
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.compression import compress_with_feedback, decompress
from repro.optim.schedules import linear_warmup_cosine


def _head_weight(params):
    if "lm_head" in params:
        return params["lm_head"]
    return params["embed"].T


def chunked_xent_loss(
    params: Any, cfg: ModelConfig, h: jax.Array, labels: jax.Array
) -> jax.Array:
    """Mean token cross-entropy with [B, chunk, V] peak logits."""
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk, s)
    while s % chunk:
        chunk -= 1
    n_chunks = s // chunk
    h = apply_norm(params["final_norm"], h, cfg.norm_eps)
    w = _head_weight(params)

    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(total, xs):
        hx, lx = xs
        logits = jnp.einsum(
            "bsd,dv->bsv", hx, w, preferred_element_type=jnp.float32
        )
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        valid = (lx >= 0).astype(jnp.float32)
        nll = (logz - gold) * valid
        return total + jnp.sum(nll), None

    body = jax.checkpoint(body, prevent_cse=False)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    n_valid = jnp.maximum(jnp.sum((labels >= 0).astype(jnp.float32)), 1.0)
    return total / n_valid


def loss_fn(params: Any, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    h, _, _, _ = forward(params, cfg, batch)
    loss = chunked_xent_loss(params, cfg, h, batch["labels"])
    metrics = {"loss": loss}
    return loss, metrics


def make_train_step(
    cfg: ModelConfig,
    opt: AdamWConfig,
    *,
    total_steps: int = 100_000,
    warmup_steps: int = 1_000,
    microbatch: int = 0,          # 0 = no accumulation
    compress_grads: bool = False,  # int8 all-reduce with error feedback
):
    """Builds train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "residual"?}. Pure function of its inputs;
    pjit-ready (the caller attaches in/out shardings).
    """

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        return grads, metrics

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]

        if microbatch and microbatch > 1:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_body(carry, i):
                acc = carry
                mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                g, m = compute_grads(params, mb_batch)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / microbatch, acc, g
                )
                return acc, m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, ms = jax.lax.scan(
                acc_body, zero, jnp.arange(microbatch)
            )
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        else:
            grads, metrics = compute_grads(params, batch)

        residual = state.get("residual")
        if compress_grads:
            compressed, residual = compress_with_feedback(grads, residual)
            grads = decompress(compressed)

        lr_scale = linear_warmup_cosine(
            state["opt"]["step"] + 1, warmup=warmup_steps, total=total_steps
        )
        new_params, new_opt, opt_metrics = adamw_update(
            opt, params, grads, state["opt"], lr_scale
        )
        metrics = dict(metrics, **opt_metrics)
        new_state = {"params": new_params, "opt": new_opt}
        if compress_grads:
            new_state["residual"] = residual
        return new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key, *, compress_grads: bool = False) -> dict:
    from repro.optim.adamw import init_opt_state

    params = transformer.init_params(cfg, key)
    state = {"params": params, "opt": init_opt_state(params)}
    if compress_grads:
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state
