"""repro.tune — trace-driven autotuning of the reuse policy.

Closes the loop the sensor subsystem opened: serving runs record measured
per-site harvest (`--sensor-jsonl`), the fitter turns those traces into
per-site :class:`~repro.core.policy.SiteTunables` (threshold / block_k /
min-work / hysteresis, solved against `repro.sensor.cost_model`), and the
serialized table feeds back into serving via ``--tuned-policy``:

    serve --reuse --sensor-jsonl trace.jsonl      # record
    python -m repro.tune.fit --trace trace.jsonl --out tuned.json
    serve --reuse --tuned-policy tuned.json       # exploit

* ``trace``   — schema-validated loader for sensor JSONL output;
* ``harvest`` — the break-even/harvest solver SHARED with the online retuner
  (`repro.control.retune`), so offline and live fits use one cost model;
* ``fit``     — the offline fitter front door (``python -m repro.tune.fit``);
* ``table``   — tuned-table JSON serialization + policy construction.
"""

from repro.tune.fit import FitConfig, fit_layer, fit_site, fit_trace
from repro.tune.harvest import record_from_sensor, solve_site
from repro.tune.table import (
    TUNED_TABLE_SCHEMA_VERSION,
    TableSchemaError,
    load_table,
    load_tuned_policy,
    save_table,
)
from repro.tune.trace import SiteTraceRecord, Trace, TraceSchemaError, load_trace

__all__ = [
    "FitConfig",
    "SiteTraceRecord",
    "TUNED_TABLE_SCHEMA_VERSION",
    "TableSchemaError",
    "Trace",
    "TraceSchemaError",
    "fit_layer",
    "fit_site",
    "fit_trace",
    "load_table",
    "load_trace",
    "load_tuned_policy",
    "record_from_sensor",
    "save_table",
    "solve_site",
]
