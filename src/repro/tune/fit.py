"""Harvest-model fitter: measured sensor trace → per-site SiteTunables.

The paper runs every layer at one global operating point; its own Fig. 12
shows that leaves gains on the table (and regresses small / low-similarity
layers). This fitter closes the loop PR 1 opened: it reads the measured
per-site skip rates out of a sensor trace and solves, per site, for the knobs
`ReusePolicy` consults.

The solve itself lives in :mod:`repro.tune.harvest` — ONE break-even/harvest
model shared with the online retuner (`repro.control.retune`), so the offline
record→fit→reload loop and the live controller can never disagree on
cost-model units. This module is the offline front door: trace in, tuned
table out (`python -m repro.tune.fit`).
"""

from __future__ import annotations

import dataclasses

from repro.core.policy import SiteTunables, layer_key
from repro.tune.harvest import (
    BLOCK_K_CHOICES,
    BOOKKEEP_BYTES_PER_MN,
    BOOKKEEP_BYTES_PER_XK,
    FitConfig,
    solve_site,
)
from repro.tune.trace import SiteTraceRecord, Trace

__all__ = [
    "BLOCK_K_CHOICES",
    "BOOKKEEP_BYTES_PER_MN",
    "BOOKKEEP_BYTES_PER_XK",
    "FitConfig",
    "fit_layer",
    "fit_site",
    "fit_trace",
    "summary_lines",
]


def fit_site(rec: SiteTraceRecord, cfg: FitConfig = FitConfig()) -> SiteTunables:
    """Solve one site's tunables from its measured operating point (thin
    offline wrapper over the shared harvest model)."""
    return solve_site(rec, cfg)


def fit_layer(rec: SiteTraceRecord, cfg: FitConfig = FitConfig()) -> SiteTunables:
    """Solve ONE LAYER's tunables row from its per-layer trace slice.

    Same harvest model as the site fit, but spec-level knobs (block_k /
    exec_path / max_active_k) are stripped: those are baked into the traced
    dispatch at SITE granularity, while a layer row only drives the
    array-resident ctrl lanes (sim_threshold / min_work / hysteresis) the
    engine writes per layer without a retrace."""
    return dataclasses.replace(
        solve_site(rec, cfg),
        block_k=None, exec_path=None, max_active_k=None,
    )


def fit_trace(
    trace: Trace, cfg: FitConfig = FitConfig(), *, per_layer: bool = True
) -> dict[str, SiteTunables]:
    """Per-site tunables from a trace; with `per_layer` (default), stacked
    sites' layer rows additionally fit "site@layer" keyed rows, so a 40-layer
    stack whose early layers are dissimilar and late layers sticky gets
    per-layer thresholds instead of one compromise."""
    table = {
        name: fit_site(rec, cfg) for name, rec in sorted(trace.sites.items())
    }
    if per_layer:
        for name, by_layer in sorted(trace.layers.items()):
            if len(by_layer) < 2:
                continue  # a 1-layer "stack" has nothing layer-specific
            for layer, rec in sorted(by_layer.items()):
                table[layer_key(name, layer)] = fit_layer(rec, cfg)
    return table


def summary_lines(
    trace: Trace, tunables: dict[str, SiteTunables]
) -> list[str]:
    default = SiteTunables()
    n_layer_rows = sum(name not in trace.sites for name in tunables)
    lines = [
        f"fitted {len(tunables) - n_layer_rows} sites "
        f"(+{n_layer_rows} per-layer rows) from {trace.n_rows} rows "
        f"({trace.path})",
        f"{'site':24s} {'thr':>6s} {'blk_k':>6s} {'exec':>8s} {'min_work':>10s} "
        f"{'hit':>5s} {'eff':>5s}  vs default",
    ]
    for name, t in tunables.items():
        if name not in trace.sites:
            continue  # "site@layer" rows: summarized by the count above
        rec = trace.sites[name]
        diffs = []
        if abs(t.sim_threshold - default.sim_threshold) > 1e-9:
            diffs.append(f"thr {default.sim_threshold:.2f}->{t.sim_threshold:.2f}")
        if t.block_k != rec.block_k:
            diffs.append(f"block_k {rec.block_k}->{t.block_k}")
        if t.exec_path is not None:
            budget = f"@{t.max_active_k}" if t.max_active_k is not None else ""
            diffs.append(f"exec {rec.exec_path}->{t.exec_path}{budget}")
        if t.min_work_flops != default.min_work_flops:
            diffs.append(f"min_work {default.min_work_flops:.2e}->"
                         f"{t.min_work_flops:.2e}")
        lines.append(
            f"{name:24s} {t.sim_threshold:6.3f} {t.block_k!s:>6s} "
            f"{t.exec_path or 'auto':>8s} "
            f"{t.min_work_flops:10.3e} {rec.hit_rate:5.2f} "
            f"{rec.harvest_efficiency:5.2f}  {'; '.join(diffs) or 'unchanged'}"
        )
    return lines


def main() -> None:
    import argparse

    from repro.tune.table import save_table
    from repro.tune.trace import load_trace

    ap = argparse.ArgumentParser(
        description="Fit per-site ReusePolicy tunables from a sensor trace "
        "(serve with --sensor-jsonl, fit, serve with --tuned-policy)."
    )
    ap.add_argument("--trace", required=True, help="sensor JSONL trace path")
    ap.add_argument("--out", required=True, help="tuned-table JSON output path")
    ap.add_argument("--safety-margin", type=float,
                    default=FitConfig.safety_margin)
    ap.add_argument("--prior-efficiency", type=float,
                    default=FitConfig.prior_efficiency)
    ap.add_argument("--latency-table", default=None,
                    help="measured per-(site, layer, exec_path) latency "
                    "table (serve --obs-dir writes one); when given, "
                    "break-even / admission / exec pins are priced from "
                    "measured wall-clock instead of energy-model constants")
    ap.add_argument("--pallas-target", action="store_true",
                    help="fit the Pallas compacted-grid path (exec_path="
                    "'ragged') for high-skip sites instead of the jnp "
                    "gather path ('compact', the CPU serving default)")
    ap.add_argument("--site-only", action="store_true",
                    help="fit site-granular rows only; by default stacked "
                    "sites' per-layer trace rows also fit 'site@layer' "
                    "tunables rows (per-layer ctrl-lane thresholds)")
    args = ap.parse_args()

    latency = None
    if args.latency_table:
        from repro.obs.latency import load_latency_table, table_provenance

        latency = load_latency_table(args.latency_table)
        print(f"pricing from measured latencies: {args.latency_table} "
              f"({len(latency)} rows)")
        prov = table_provenance(latency)
        if prov != "compiled":
            print(f"WARNING: latency table {args.latency_table} carries "
                  f"{prov} measurements — interpret-mode numbers price the "
                  "fit 20-80x off compiled reality; re-probe with a compiled "
                  "serve run (--obs-dir) before trusting the fitted table")
    cfg = FitConfig(safety_margin=args.safety_margin,
                    prior_efficiency=args.prior_efficiency,
                    pallas_target=args.pallas_target,
                    latency=latency)
    trace = load_trace(args.trace)
    tunables = fit_trace(trace, cfg, per_layer=not args.site_only)
    print("\n".join(summary_lines(trace, tunables)))
    save_table(args.out, tunables,
               meta={"trace": args.trace, "n_rows": trace.n_rows,
                     **({"latency_table": args.latency_table}
                        if args.latency_table else {})})
    print(f"tuned table written to {args.out}")


if __name__ == "__main__":
    main()
