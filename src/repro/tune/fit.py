"""Harvest-model fitter: measured sensor trace → per-site SiteTunables.

The paper runs every layer at one global operating point; its own Fig. 12
shows that leaves gains on the table (and regresses small / low-similarity
layers). This fitter closes the loop PR 1 opened: it reads the measured
per-site skip rates out of a sensor trace and solves, per site, for the knobs
`ReusePolicy` consults — using the same `repro.sensor.cost_model` constants
the measured benchmarks report with, so "profitable" here means profitable in
the units the benchmarks measure.

Per-step harvest model for one site (batch M, weights [K, N]):

    saved(r)  = g · r · (W_bytes · E_HBM  +  MACs · 2 · E_MAC)
    book      = (M·K·(x + prev_q + cur_q + delta)  +  M·N·(read + write O_p))
                · E_HBM

where r is the stream's code-hit rate, and g is the site's measured *harvest
efficiency* — the fraction of similarity the current tile granularity turns
into actually-skipped weight traffic (weight_byte_skip_rate / hit_rate).
The break-even hit rate r* solves saved(r*) = book; the fitted sim_threshold
is r* padded by a safety margin. Sites whose measured operating point is
net-positive get min_work_flops lowered to admit them; net-negative sites get
it raised to pin them basic. block_k steps down when g shows the granularity
is wasting similarity (tiles too coarse) and up when the harvest is already
saturated; churny sites (high mode_transitions/steps) get stiffer hysteresis.
"""

from __future__ import annotations

import dataclasses

from repro.core.policy import (
    DEFAULT_MIN_WORK_FLOPS,
    RAGGED_BREAK_EVEN_SKIP,
    ReusePolicy,
    SiteTunables,
)
from repro.sensor.cost_model import E_HBM, E_MAC, FLOPS_PER_MAC
from repro.tune.trace import SiteTraceRecord, Trace

# Bookkeeping bytes per element, charged at HBM rates (conservative — much of
# this traffic stays on-chip): read x f32 + prev_q int8, write cur_q int8 +
# delta f32 per [M, K] element; read + write the f32 [M, N] prev_out panel.
BOOKKEEP_BYTES_PER_XK = 4.0 + 1.0 + 1.0 + 4.0
BOOKKEEP_BYTES_PER_MN = 4.0 + 4.0

BLOCK_K_CHOICES = (64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class FitConfig:
    safety_margin: float = 1.25     # threshold = margin × break-even hit rate
    min_threshold: float = 0.05
    max_threshold: float = 0.95
    # harvest-efficiency prior for sites with no measured reuse steps
    # (granularity.py measures 0.7-0.9 at block_k=256; stay conservative)
    prior_efficiency: float = 0.7
    low_efficiency: float = 0.5     # below: halve block_k (tiles too coarse)
    high_efficiency: float = 0.9    # above: double block_k (harvest saturated)
    churn_flip_rate: float = 0.10   # transitions/step above this = churny
    min_work_admit_factor: float = 0.5
    min_work_reject_factor: float = 2.0
    # Measured tile-skip rate above which the compacted execution tier
    # (ragged grid / gathered GEMM) is fitted instead of the masked walk.
    ragged_min_skip: float = RAGGED_BREAK_EVEN_SKIP
    # True fits "ragged" (Pallas compacted-grid kernel — the TPU target);
    # False fits "compact" (jnp gather — what CPU serving actually runs).
    pallas_target: bool = False


def _per_step_costs(rec: SiteTraceRecord) -> tuple[float, float, float]:
    """(dense weight bytes, dense MACs, bookkeeping joules) per evaluation."""
    steps = max(rec.steps, 1)
    gm = -(-rec.batch // rec.block_m)
    gk = -(-rec.in_features // rec.block_k)
    if rec.total_weight_bytes > 0:
        w_bytes = rec.total_weight_bytes / steps
    else:  # trace without byte totals: assume f32 weights on the padded grid
        w_bytes = gm * gk * rec.block_k * rec.out_features * 4.0
    if rec.total_macs > 0:
        macs = rec.total_macs / steps
    else:
        macs = gm * gk * rec.block_m * rec.block_k * rec.out_features
    book_j = (
        rec.batch * rec.in_features * BOOKKEEP_BYTES_PER_XK
        + rec.batch * rec.out_features * BOOKKEEP_BYTES_PER_MN
    ) * E_HBM
    return w_bytes, macs, book_j


def _saved_per_step_j(w_bytes: float, macs: float, g: float, r: float) -> float:
    return g * r * (w_bytes * E_HBM + macs * FLOPS_PER_MAC * E_MAC)


def _pick_block_k(rec: SiteTraceRecord, g: float, cfg: FitConfig) -> int:
    # Cap at the largest choice that doesn't exceed the (padded) K extent —
    # a block_k beyond K degenerates to all-or-nothing skipping.
    viable = [c for c in BLOCK_K_CHOICES if c <= rec.in_features]
    if not viable:
        return BLOCK_K_CHOICES[0]
    cur = min(viable, key=lambda c: abs(c - rec.block_k))
    idx = viable.index(cur)
    if g < cfg.low_efficiency and idx > 0:
        return viable[idx - 1]
    if g > cfg.high_efficiency and idx < len(viable) - 1:
        return viable[idx + 1]
    return cur


def fit_site(rec: SiteTraceRecord, cfg: FitConfig = FitConfig()) -> SiteTunables:
    """Solve one site's tunables from its measured operating point."""
    w_bytes, macs, book_j = _per_step_costs(rec)
    measured_reuse = rec.tile_skip_rate > 0.0 or (
        rec.mode == "reuse" and rec.steps > 0
    )
    g = rec.harvest_efficiency if measured_reuse else 0.0
    if g <= 0.0:
        g = cfg.prior_efficiency

    saveable_j = _saved_per_step_j(w_bytes, macs, g, 1.0)
    if saveable_j <= 0.0:
        break_even = 1.0  # nothing to harvest; threshold clamps to max
    else:
        break_even = book_j / saveable_j
    sim_threshold = min(
        max(cfg.safety_margin * break_even, cfg.min_threshold),
        cfg.max_threshold,
    )

    # min_work: admit the site if its MEASURED operating point is net-positive
    # (harvest at the observed hit rate beats the bookkeeping), else pin it
    # basic — the per-site replacement for the one global small-layer cutoff.
    net_j = _saved_per_step_j(w_bytes, macs, g, rec.hit_rate) - book_j
    if net_j > 0.0:
        min_work = min(DEFAULT_MIN_WORK_FLOPS,
                       cfg.min_work_admit_factor * rec.work_flops)
    else:
        min_work = max(DEFAULT_MIN_WORK_FLOPS,
                       cfg.min_work_reject_factor * rec.work_flops)

    flip_rate = rec.mode_transitions / max(rec.steps, 1)
    churny = flip_rate > cfg.churn_flip_rate or rec.suppressed_flips > 0

    # Execution substrate: above the break-even skip rate the compacted tier
    # converts the measured skip into elided grid steps / a shrunken GEMM.
    # The shrink scales with gk, so when promoting a site we also cap block_k
    # at a compactable granularity (gk >= 2); the budget is the measured
    # occupancy plus headroom (overflow steps fall back at runtime, so a
    # tight guess costs a fallback, never a wrong answer).
    block_k = _pick_block_k(rec, g, cfg)
    exec_path: str | None = None
    max_active_k: int | None = None
    if measured_reuse and rec.tile_skip_rate >= cfg.ragged_min_skip:
        compactable = [c for c in BLOCK_K_CHOICES if 2 * c <= rec.in_features]
        if compactable:
            block_k = min(block_k, compactable[-1])
            gk = -(-rec.in_features // block_k)
            exec_path = "ragged" if cfg.pallas_target else "compact"
            max_active_k = ReusePolicy.ragged_budget(gk, rec.tile_skip_rate)

    base = SiteTunables()
    return SiteTunables(
        sim_threshold=sim_threshold,
        min_work_flops=min_work,
        block_k=block_k,
        hysteresis_margin=base.hysteresis_margin * (2.0 if churny else 1.0),
        hysteresis_steps=base.hysteresis_steps * (2 if churny else 1),
        exec_path=exec_path,
        max_active_k=max_active_k,
    )


def fit_trace(
    trace: Trace, cfg: FitConfig = FitConfig()
) -> dict[str, SiteTunables]:
    return {name: fit_site(rec, cfg) for name, rec in sorted(trace.sites.items())}


def summary_lines(
    trace: Trace, tunables: dict[str, SiteTunables]
) -> list[str]:
    default = SiteTunables()
    lines = [
        f"fitted {len(tunables)} sites from {trace.n_rows} rows "
        f"({trace.path})",
        f"{'site':24s} {'thr':>6s} {'blk_k':>6s} {'exec':>8s} {'min_work':>10s} "
        f"{'hit':>5s} {'eff':>5s}  vs default",
    ]
    for name, t in tunables.items():
        rec = trace.sites[name]
        diffs = []
        if abs(t.sim_threshold - default.sim_threshold) > 1e-9:
            diffs.append(f"thr {default.sim_threshold:.2f}->{t.sim_threshold:.2f}")
        if t.block_k != rec.block_k:
            diffs.append(f"block_k {rec.block_k}->{t.block_k}")
        if t.exec_path is not None:
            budget = f"@{t.max_active_k}" if t.max_active_k is not None else ""
            diffs.append(f"exec {rec.exec_path}->{t.exec_path}{budget}")
        if t.min_work_flops != default.min_work_flops:
            diffs.append(f"min_work {default.min_work_flops:.2e}->"
                         f"{t.min_work_flops:.2e}")
        lines.append(
            f"{name:24s} {t.sim_threshold:6.3f} {t.block_k!s:>6s} "
            f"{t.exec_path or 'auto':>8s} "
            f"{t.min_work_flops:10.3e} {rec.hit_rate:5.2f} "
            f"{rec.harvest_efficiency:5.2f}  {'; '.join(diffs) or 'unchanged'}"
        )
    return lines


def main() -> None:
    import argparse

    from repro.tune.table import save_table
    from repro.tune.trace import load_trace

    ap = argparse.ArgumentParser(
        description="Fit per-site ReusePolicy tunables from a sensor trace "
        "(serve with --sensor-jsonl, fit, serve with --tuned-policy)."
    )
    ap.add_argument("--trace", required=True, help="sensor JSONL trace path")
    ap.add_argument("--out", required=True, help="tuned-table JSON output path")
    ap.add_argument("--safety-margin", type=float,
                    default=FitConfig.safety_margin)
    ap.add_argument("--prior-efficiency", type=float,
                    default=FitConfig.prior_efficiency)
    ap.add_argument("--pallas-target", action="store_true",
                    help="fit the Pallas compacted-grid path (exec_path="
                    "'ragged') for high-skip sites instead of the jnp "
                    "gather path ('compact', the CPU serving default)")
    args = ap.parse_args()

    cfg = FitConfig(safety_margin=args.safety_margin,
                    prior_efficiency=args.prior_efficiency,
                    pallas_target=args.pallas_target)
    trace = load_trace(args.trace)
    tunables = fit_trace(trace, cfg)
    print("\n".join(summary_lines(trace, tunables)))
    save_table(args.out, tunables,
               meta={"trace": args.trace, "n_rows": trace.n_rows})
    print(f"tuned table written to {args.out}")


if __name__ == "__main__":
    main()
