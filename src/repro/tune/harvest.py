"""Shared break-even/harvest math — ONE cost model for offline and online.

The offline fitter (`repro.tune.fit`, JSONL trace in) and the online retuner
(`repro.control.retune`, live windowed counters in) must never disagree on
cost-model units: both feed a :class:`~repro.tune.trace.SiteTraceRecord`
describing one measured operating point into :func:`solve_site` and get the
same :class:`~repro.core.policy.SiteTunables` back. The record is the contract
— offline it comes from a parsed trace row, online it is built straight from
windowed counter deltas — and this module is the only place the harvest model
lives (tests/test_control.py locks the two paths to it with an equivalence
test).

Per-step harvest model for one site (batch M, weights [K, N]):

    saved(r)  = g · r · (W_bytes · E_HBM  +  MACs · 2 · E_MAC)
    book      = (M·K·(x + prev_q + cur_q + delta)  +  M·N·(read + write O_p))
                · E_HBM

where r is the stream's code-hit rate, and g is the site's measured *harvest
efficiency* — the fraction of similarity the current tile granularity turns
into actually-skipped weight traffic (weight_byte_skip_rate / hit_rate).
The break-even hit rate r* solves saved(r*) = book; the fitted sim_threshold
is r* padded by a safety margin. Sites whose measured operating point is
net-positive get min_work_flops lowered to admit them; net-negative sites get
it raised to pin them basic. block_k steps down when g shows the granularity
is wasting similarity (tiles too coarse) and up when the harvest is already
saturated; churny sites (high mode_transitions/steps) get stiffer hysteresis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.policy import (
    DEFAULT_MIN_WORK_FLOPS,
    RAGGED_BREAK_EVEN_SKIP,
    ReusePolicy,
    SiteTunables,
)
from repro.sensor.cost_model import E_HBM, E_MAC, FLOPS_PER_MAC
from repro.tune.trace import SiteTraceRecord

# Bookkeeping bytes per element, charged at HBM rates (conservative — much of
# this traffic stays on-chip): read x f32 + prev_q int8, write cur_q int8 +
# delta f32 per [M, K] element; read + write the f32 [M, N] prev_out panel.
BOOKKEEP_BYTES_PER_XK = 4.0 + 1.0 + 1.0 + 4.0
BOOKKEEP_BYTES_PER_MN = 4.0 + 4.0

BLOCK_K_CHOICES = (64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class FitConfig:
    safety_margin: float = 1.25     # threshold = margin × break-even hit rate
    min_threshold: float = 0.05
    max_threshold: float = 0.95
    # harvest-efficiency prior for sites with no measured reuse steps
    # (granularity.py measures 0.7-0.9 at block_k=256; stay conservative)
    prior_efficiency: float = 0.7
    low_efficiency: float = 0.5     # below: halve block_k (tiles too coarse)
    high_efficiency: float = 0.9    # above: double block_k (harvest saturated)
    churn_flip_rate: float = 0.10   # transitions/step above this = churny
    min_work_admit_factor: float = 0.5
    min_work_reject_factor: float = 2.0
    # Measured tile-skip rate above which the compacted execution tier
    # (ragged grid / gathered GEMM) is fitted instead of the masked walk.
    ragged_min_skip: float = RAGGED_BREAK_EVEN_SKIP
    # True fits "ragged" (Pallas compacted-grid kernel — the TPU target);
    # False fits "compact" (jnp gather — what CPU serving actually runs).
    pallas_target: bool = False
    # Measured per-(site, layer, exec_path) wall-clock (an
    # `repro.obs.latency.LatencyTable`). When set, break-even hit rates,
    # net-positive admission, and exec-path pins are priced from these
    # MEASURED latencies instead of the energy-model constants above.
    latency: Any = None


def per_step_costs(rec: SiteTraceRecord) -> tuple[float, float, float]:
    """(dense weight bytes, dense MACs, bookkeeping joules) per evaluation."""
    steps = max(rec.steps, 1)
    gm = -(-rec.batch // rec.block_m)
    gk = -(-rec.in_features // rec.block_k)
    if rec.total_weight_bytes > 0:
        w_bytes = rec.total_weight_bytes / steps
    else:  # trace without byte totals: assume f32 weights on the padded grid
        w_bytes = gm * gk * rec.block_k * rec.out_features * 4.0
    if rec.total_macs > 0:
        macs = rec.total_macs / steps
    else:
        macs = gm * gk * rec.block_m * rec.block_k * rec.out_features
    book_j = (
        rec.batch * rec.in_features * BOOKKEEP_BYTES_PER_XK
        + rec.batch * rec.out_features * BOOKKEEP_BYTES_PER_MN
    ) * E_HBM
    return w_bytes, macs, book_j


def saved_per_step_j(w_bytes: float, macs: float, g: float, r: float) -> float:
    return g * r * (w_bytes * E_HBM + macs * FLOPS_PER_MAC * E_MAC)


def pick_block_k(rec: SiteTraceRecord, g: float, cfg: FitConfig) -> int:
    # Cap at the largest choice that doesn't exceed the (padded) K extent —
    # a block_k beyond K degenerates to all-or-nothing skipping.
    viable = [c for c in BLOCK_K_CHOICES if c <= rec.in_features]
    if not viable:
        return BLOCK_K_CHOICES[0]
    cur = min(viable, key=lambda c: abs(c - rec.block_k))
    idx = viable.index(cur)
    if g < cfg.low_efficiency and idx > 0:
        return viable[idx - 1]
    if g > cfg.high_efficiency and idx < len(viable) - 1:
        return viable[idx + 1]
    return cur


def measured_costs(rec: SiteTraceRecord, cfg: FitConfig,
                   g: float) -> dict[str, Any] | None:
    """Price the site from MEASURED wall-clock when `cfg.latency` covers it.

    The probe measures the basic-mode dense GEMM (`t_basic`) and each reuse
    substrate at the site's operating skip rate. The harvest model stays
    linear in hit rate, but in time units: t_reuse(r) = t_basic + t_book −
    g·r·t_basic. From the measured point (t_cur at the record's hit rate)
    the bookkeeping tax and break-even hit rate follow directly:

        t_book     = t_cur − t_basic + g·r_meas·t_basic
        r*         = t_book / (g·t_basic)
        net_s      = t_basic − t_cur     (reuse pays, measured, iff > 0)

    Returns None when the table lacks a basic baseline or any reuse path for
    this site — the caller falls back to the energy-model constants.
    """
    lat = cfg.latency
    if lat is None:
        return None
    basic = lat.stat(rec.site, "basic", layer=rec.layer)
    if basic is None or basic.mean_s <= 0.0:
        return None
    paths = {p: st for p, st in lat.paths_for(rec.site, layer=rec.layer).items()
             if p != "basic" and st.mean_s > 0.0}
    if not paths:
        return None
    cur_path = rec.exec_path if rec.exec_path in paths else \
        min(paths, key=lambda p: paths[p].mean_s)
    best_path = min(paths, key=lambda p: paths[p].mean_s)
    t_basic = basic.mean_s
    t_cur = paths[cur_path].mean_s
    t_book = t_cur - t_basic + g * rec.hit_rate * t_basic
    break_even = max(t_book, 0.0) / max(g * t_basic, 1e-12)
    return {
        "t_basic": t_basic,
        "t_cur": t_cur,
        "cur_path": cur_path,
        "t_book": t_book,
        "break_even": break_even,
        "net_s": t_basic - t_cur,
        "best_path": best_path,
        "t_best": paths[best_path].mean_s,
    }


def measured_latency_note(rec: SiteTraceRecord,
                          cfg: FitConfig) -> str | None:
    """Human-readable evidence string when a solve was priced from measured
    latencies — journaled with retune decisions so the journal records which
    decisions consumed measured (not constant) inputs."""
    measured_reuse = rec.tile_skip_rate > 0.0 or (
        rec.mode == "reuse" and rec.steps > 0
    )
    g = rec.harvest_efficiency if measured_reuse else 0.0
    if g <= 0.0:
        g = cfg.prior_efficiency
    meas = measured_costs(rec, cfg, g)
    if meas is None:
        return None
    return (
        f"measured basic={meas['t_basic'] * 1e6:.0f}us "
        f"{meas['cur_path']}={meas['t_cur'] * 1e6:.0f}us "
        f"r*={meas['break_even']:.2f}"
    )


def solve_site(rec: SiteTraceRecord, cfg: FitConfig = FitConfig()) -> SiteTunables:
    """Solve one site's tunables from its measured operating point."""
    w_bytes, macs, book_j = per_step_costs(rec)
    measured_reuse = rec.tile_skip_rate > 0.0 or (
        rec.mode == "reuse" and rec.steps > 0
    )
    g = rec.harvest_efficiency if measured_reuse else 0.0
    if g <= 0.0:
        g = cfg.prior_efficiency

    meas = measured_costs(rec, cfg, g)
    if meas is not None:
        # Measured pricing: break-even and admission from observed wall-clock.
        break_even = meas["break_even"]
    else:
        saveable_j = saved_per_step_j(w_bytes, macs, g, 1.0)
        if saveable_j <= 0.0:
            break_even = 1.0  # nothing to harvest; threshold clamps to max
        else:
            break_even = book_j / saveable_j
    sim_threshold = min(
        max(cfg.safety_margin * break_even, cfg.min_threshold),
        cfg.max_threshold,
    )

    # min_work: admit the site if its MEASURED operating point is net-positive
    # (harvest at the observed hit rate beats the bookkeeping), else pin it
    # basic — the per-site replacement for the one global small-layer cutoff.
    net_j = saved_per_step_j(w_bytes, macs, g, rec.hit_rate) - book_j
    net_positive = meas["net_s"] > 0.0 if meas is not None else net_j > 0.0
    if net_positive:
        min_work = min(DEFAULT_MIN_WORK_FLOPS,
                       cfg.min_work_admit_factor * rec.work_flops)
    else:
        min_work = max(DEFAULT_MIN_WORK_FLOPS,
                       cfg.min_work_reject_factor * rec.work_flops)

    flip_rate = rec.mode_transitions / max(rec.steps, 1)
    churny = flip_rate > cfg.churn_flip_rate or rec.suppressed_flips > 0

    # Execution substrate: above the break-even skip rate the compacted tier
    # converts the measured skip into elided grid steps / a shrunken GEMM.
    # The shrink scales with gk, so when promoting a site we also cap block_k
    # at a compactable granularity (gk >= 2); the budget is the measured
    # occupancy plus headroom (overflow steps fall back at runtime, so a
    # tight guess costs a fallback, never a wrong answer).
    block_k = pick_block_k(rec, g, cfg)
    exec_path: str | None = None
    max_active_k: int | None = None
    if meas is not None:
        # Measured gate: pin the compacted tier iff it actually measured
        # fastest for this site — the measured replacement for the constant
        # RAGGED_BREAK_EVEN_SKIP threshold (both promotion when the constant
        # gate would refuse, and demotion when it would promote a site whose
        # compacted path measures slower).
        promote = (measured_reuse and rec.tile_skip_rate > 0.0
                   and meas["best_path"] in ("ragged", "compact"))
    else:
        promote = (measured_reuse
                   and rec.tile_skip_rate >= cfg.ragged_min_skip)
    if promote:
        compactable = [c for c in BLOCK_K_CHOICES if 2 * c <= rec.in_features]
        if compactable:
            block_k = min(block_k, compactable[-1])
            gk = -(-rec.in_features // block_k)
            if meas is not None:
                exec_path = meas["best_path"]  # fastest MEASURED substrate
            else:
                exec_path = "ragged" if cfg.pallas_target else "compact"
            max_active_k = ReusePolicy.ragged_budget(gk, rec.tile_skip_rate)

    base = SiteTunables()
    return SiteTunables(
        sim_threshold=sim_threshold,
        min_work_flops=min_work,
        block_k=block_k,
        hysteresis_margin=base.hysteresis_margin * (2.0 if churny else 1.0),
        hysteresis_steps=base.hysteresis_steps * (2 if churny else 1),
        exec_path=exec_path,
        max_active_k=max_active_k,
    )


def derive_break_even_skip(points) -> float:
    """Measured break-even skip rate from a compiled skip-rate sweep.

    `points` is a sequence of (skip_rate, best_reuse_seconds, dense_seconds)
    triples — one per measured skip rate (the compiled sweep
    `benchmarks/wallclock.py` appends to the BENCH trajectory emits them).
    Returns the skip rate where the best reuse path first matches the dense
    GEMM, linearly interpolating the crossing between the last losing and
    first winning sweep points. When reuse never wins, returns 2.0 — an
    unreachable gate, so `ReusePolicy(ragged_break_even_skip=...)` demotes
    every site to the masked/dense walk (the honest outcome the acceptance
    criteria allow the sweep to record).
    """
    pts = sorted((float(s), float(r), float(d)) for s, r, d in points)
    if not pts:
        return RAGGED_BREAK_EVEN_SKIP
    margins = [(s, d - r) for s, r, d in pts]  # > 0 = reuse wins
    for i, (s, m) in enumerate(margins):
        if m >= 0.0:
            if i == 0:
                return s
            s0, m0 = margins[i - 1]
            if m == m0:
                return s
            t = -m0 / (m - m0)  # m0 < 0 <= m: crossing fraction in (0, 1]
            return s0 + t * (s - s0)
    return 2.0


def record_from_sensor(s, *, mode: str | None = None) -> SiteTraceRecord:
    """A solver-ready record from an in-memory SiteSensor — the JSONL-free
    equivalent of parsing the row `SensorReport.write_jsonl` would emit for
    it. Keeps the online path on exactly the offline contract."""
    return SiteTraceRecord(
        site=s.site,
        mode=mode if mode is not None else s.mode,
        steps=int(s.steps),
        batch=len(s.slot_steps),
        in_features=int(s.in_features),
        out_features=int(s.out_features),
        block_m=int(s.block_m),
        block_k=int(s.block_k),
        block_n=int(s.block_n),
        tile_skip_rate=float(s.tile_skip_rate),
        mac_skip_rate=float(s.mac_skip_rate),
        weight_byte_skip_rate=float(s.weight_byte_skip_rate),
        hit_rate=float(s.hit_rate),
        mode_transitions=int(s.mode_transitions),
        suppressed_flips=int(s.suppressed_flips),
        total_weight_bytes=float(s.total_weight_bytes),
        total_macs=float(s.total_macs),
        exec_path=str(s.exec_path),
        grid_steps=float(s.grid_steps),
        grid_step_skip_rate=float(s.grid_step_skip_rate),
        overflow_fallbacks=int(getattr(s, "overflow_fallbacks", 0)),
        layer=getattr(s, "layer", None),
        budget_occupancy=float(getattr(s, "budget_occupancy", 0.0)),
    )
