"""Tuned-table serialization: {site: SiteTunables} ⇄ versioned JSON.

The table file is the contract between the offline fitter and the serving
processes that consume it (`--tuned-policy` on launch/serve.py and the
measured benchmarks): a flat JSON document, one entry per site, plus a
schema version and free-form provenance metadata (which trace it was fitted
from, when). Unknown sites in the table are harmless — `ReusePolicy.resolve`
only consults entries for sites the engine actually registers.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.core.policy import ReusePolicy, SiteTunables

TUNED_TABLE_SCHEMA_VERSION = 1
TUNED_TABLE_KIND = "reuse_tuned_table"


class TableSchemaError(ValueError):
    pass


def save_table(
    path: str,
    tunables: dict[str, SiteTunables],
    *,
    meta: dict[str, Any] | None = None,
) -> None:
    doc = {
        "schema_version": TUNED_TABLE_SCHEMA_VERSION,
        "kind": TUNED_TABLE_KIND,
        "meta": meta or {},
        "sites": {name: t.to_dict() for name, t in sorted(tunables.items())},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load_table(path: str) -> dict[str, SiteTunables]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("kind") != TUNED_TABLE_KIND:
        raise TableSchemaError(f"{path}: not a {TUNED_TABLE_KIND} document")
    ver = doc.get("schema_version")
    if ver != TUNED_TABLE_SCHEMA_VERSION:
        raise TableSchemaError(
            f"{path}: schema_version {ver} != supported "
            f"{TUNED_TABLE_SCHEMA_VERSION}"
        )
    return {
        name: SiteTunables.from_dict(d) for name, d in doc["sites"].items()
    }


def load_tuned_policy(
    path: str, *, base: ReusePolicy | None = None
) -> ReusePolicy:
    """A ReusePolicy whose per-site table comes from a tuned-table file.
    Global defaults (and the dataflow bias) come from `base`."""
    return dataclasses.replace(
        base if base is not None else ReusePolicy(),
        site_tunables=load_table(path),
    )
