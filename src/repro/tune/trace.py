"""Sensor-trace loader — parses `--sensor-jsonl` output for the fitter.

A sensor trace is the JSONL file the serving driver / measured benchmarks
append :class:`~repro.sensor.aggregate.SensorReport` rows to. Counters are
cumulative, and a long-running server appends a report per emission, so for
each site the LAST row wins — it covers the whole measured window.

The loader is strict about provenance: every row must carry the
``schema_version`` this tree emits (`SENSOR_SCHEMA_VERSION`). Traces recorded
by older builds (no version field, or no site geometry) are refused with a
:class:`TraceSchemaError` rather than silently mis-fitted — the fitter's
bookkeeping model needs the geometry fields that only versioned rows carry.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.sensor.aggregate import SENSOR_SCHEMA_VERSION


class TraceSchemaError(ValueError):
    """Raised when a trace row is missing/mismatched on schema_version or
    lacks the fields the fitter needs."""


@dataclasses.dataclass(frozen=True)
class SiteTraceRecord:
    """One site's measured operating point over the trace window (or one
    LAYER's slice of a stacked site, when `layer` is set — layer rows carry
    the same counters at per-layer granularity)."""

    site: str
    mode: str
    steps: int
    batch: int                 # serving lanes (len of slot_steps)
    in_features: int
    out_features: int
    block_m: int
    block_k: int
    block_n: int
    tile_skip_rate: float
    mac_skip_rate: float
    weight_byte_skip_rate: float
    hit_rate: float
    mode_transitions: int
    suppressed_flips: int
    total_weight_bytes: float
    total_macs: float
    # Schema-v3 fields: the execution substrate the site ran on and the
    # measured grid-step walk (dense baseline = total_tiles · gn).
    exec_path: str = "auto"
    grid_steps: float = 0.0
    grid_step_skip_rate: float = 0.0
    # Schema-v4 field: evaluations whose live tile count overflowed the
    # compacted-path budget (the lax.cond full-extent fallback fired).
    overflow_fallbacks: int = 0
    # Schema-v5 fields: which layer of a stacked site this row slices
    # (None = whole site) and the ctrl block's live-tile-fraction EMA.
    layer: int | None = None
    budget_occupancy: float = 0.0

    @property
    def work_flops(self) -> float:
        """Dense per-row work of the site (the policy's min_work metric)."""
        return 2.0 * self.in_features * self.out_features

    @property
    def harvest_efficiency(self) -> float:
        """Measured skip-per-similarity ratio: how much of the stream's code
        similarity the current block_k actually converts into skipped weight
        traffic. 1.0 = every similar code lands in a fully-skipped tile."""
        if self.hit_rate <= 0.0:
            return 0.0
        return min(self.weight_byte_skip_rate / self.hit_rate, 1.0)


@dataclasses.dataclass(frozen=True)
class Trace:
    """Parsed trace: last snapshot per site (and per layer) + the last
    model-level row."""

    sites: dict[str, SiteTraceRecord]
    model: dict[str, Any] | None
    n_rows: int
    path: str
    # {site: {layer: record}} from "layer" rows — stacked sites' per-layer
    # operating points, which the fitter turns into "site@layer" tunables
    # rows. Empty for traces recorded from unstacked engines.
    layers: dict[str, dict[int, SiteTraceRecord]] = dataclasses.field(
        default_factory=dict
    )


_REQUIRED_SITE_FIELDS = (
    "site", "mode", "steps", "in_features", "out_features",
    "block_m", "block_k", "block_n", "tile_skip_rate", "mac_skip_rate",
    "weight_byte_skip_rate", "hit_rate", "slot_steps",
)


# v2-v5 rows lack only fields this loader defaults (grid_steps + exec_path on
# v2, overflow_fallbacks on v2/v3, budget_occupancy below v5, sentinel_trips
# below v6), so they stay loadable; v1 (unversioned) rows lack the geometry
# and are refused.
SUPPORTED_SCHEMA_VERSIONS = (2, 3, 4, 5, SENSOR_SCHEMA_VERSION)


def _check_version(row: dict[str, Any], lineno: int, path: str) -> None:
    ver = row.get("schema_version")
    if ver is None:
        raise TraceSchemaError(
            f"{path}:{lineno}: row has no schema_version — trace predates the "
            f"versioned emission; re-record with --sensor-jsonl on this build"
        )
    if ver not in SUPPORTED_SCHEMA_VERSIONS:
        raise TraceSchemaError(
            f"{path}:{lineno}: schema_version {ver} not in supported "
            f"{SUPPORTED_SCHEMA_VERSIONS}"
        )


def _site_record(row: dict[str, Any], lineno: int, path: str) -> SiteTraceRecord:
    missing = [f for f in _REQUIRED_SITE_FIELDS if f not in row]
    if missing:
        raise TraceSchemaError(f"{path}:{lineno}: site row missing {missing}")
    # The fitter divides by every one of these; zero means the row was
    # recorded without real site specs.
    zeroed = [f for f in ("in_features", "out_features", "block_m", "block_k")
              if not row[f]]
    if zeroed or not row["slot_steps"]:
        raise TraceSchemaError(
            f"{path}:{lineno}: site row carries no geometry "
            f"({zeroed or ['slot_steps']} empty) — recorded by an engine "
            f"without specs?"
        )
    return SiteTraceRecord(
        site=row["site"],
        mode=row["mode"],
        steps=int(row["steps"]),
        batch=len(row["slot_steps"]),
        in_features=int(row["in_features"]),
        out_features=int(row["out_features"]),
        block_m=int(row["block_m"]),
        block_k=int(row["block_k"]),
        block_n=int(row["block_n"]),
        tile_skip_rate=float(row["tile_skip_rate"]),
        mac_skip_rate=float(row["mac_skip_rate"]),
        weight_byte_skip_rate=float(row["weight_byte_skip_rate"]),
        hit_rate=float(row["hit_rate"]),
        mode_transitions=int(row.get("mode_transitions", 0)),
        suppressed_flips=int(row.get("suppressed_flips", 0)),
        total_weight_bytes=float(row.get("total_weight_bytes", 0.0)),
        total_macs=float(row.get("total_macs", 0.0)),
        exec_path=str(row.get("exec_path", "auto")),
        grid_steps=float(row.get("grid_steps", 0.0)),
        grid_step_skip_rate=float(row.get("grid_step_skip_rate", 0.0)),
        overflow_fallbacks=int(row.get("overflow_fallbacks", 0)),
        layer=row["layer"] if isinstance(row.get("layer"), int) else None,
        budget_occupancy=float(row.get("budget_occupancy", 0.0)),
    )


def load_trace(path: str) -> Trace:
    """Parse a sensor JSONL trace; last row per site wins (cumulative
    counters). Raises TraceSchemaError on version/field mismatch."""
    sites: dict[str, SiteTraceRecord] = {}
    layers: dict[str, dict[int, SiteTraceRecord]] = {}
    model: dict[str, Any] | None = None
    n_rows = 0
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceSchemaError(f"{path}:{lineno}: not JSON ({e})") from e
            _check_version(row, lineno, path)
            n_rows += 1
            kind = row.get("kind")
            if kind == "site":
                rec = _site_record(row, lineno, path)
                sites[rec.site] = rec
            elif kind == "layer":
                # stacked sites' per-layer slices — the per-layer fitter's
                # input (last row per (site, layer) wins, like site rows)
                rec = _site_record(row, lineno, path)
                if rec.layer is not None:
                    layers.setdefault(rec.site, {})[rec.layer] = rec
            elif kind == "model":
                model = row
    if not sites:
        raise TraceSchemaError(f"{path}: no site rows found")
    return Trace(sites=sites, model=model, n_rows=n_rows, path=path,
                 layers=layers)
