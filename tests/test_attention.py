"""Pair-scan attention vs naive reference across mask patterns + gradients."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (
    _chunk_pairs,
    apply_mrope,
    apply_rope,
    blockwise_attention,
    decode_attention,
)


def naive(q, k, v, causal, window):
    d = q.shape[-1]
    rep = q.shape[2] // k.shape[2]
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / math.sqrt(d)
    i = jnp.arange(q.shape[1])[:, None]
    j = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        m &= i >= j
    if window:
        m &= j > i - window
    s = jnp.where(m[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


CASES = [
    (True, None, 32, 32),
    (True, 48, 32, 32),
    (True, 16, 16, 64),
    (False, None, 64, 32),
    (True, None, 128, 128),   # single chunk
]


@pytest.mark.parametrize("causal,window,cq,ckv", CASES)
def test_blockwise_matches_naive(rng, causal, window, cq, ckv):
    B, S, H, KV, D = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)).astype(np.float32))
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              chunk_q=cq, chunk_kv=ckv)
    ref = naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_pair_list_excludes_masked_work():
    """The FLOPs honesty claim: pair count ~ S·W/(cq·ckv) for windowed, not
    S²; causal halves the full grid."""
    full = _chunk_pairs(8, 8, 64, 64, causal=False, window=None)
    causal = _chunk_pairs(8, 8, 64, 64, causal=True, window=None)
    windowed = _chunk_pairs(8, 8, 64, 64, causal=True, window=64)
    assert len(full) == 64
    assert len(causal) == 36          # lower triangle of chunks (incl diag)
    assert len(windowed) == 8 + 7     # diagonal + one off-diagonal band


def test_blockwise_grads_finite(rng):
    B, S, H, KV, D = 1, 64, 2, 1, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)).astype(np.float32))

    def f(q, k, v):
        return blockwise_attention(q, k, v, causal=True, chunk_q=16,
                                   chunk_kv=16).sum()

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.max(jnp.abs(g))) > 0


def test_decode_attention_masks_invalid_positions(rng):
    B, S, KV, D = 2, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, 4, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)).astype(np.float32))
    out1 = decode_attention(q, k, v, jnp.int32(10))
    # garbage beyond position 10 must not matter
    k2 = k.at[:, 10:].set(99.0)
    v2 = v.at[:, 10:].set(-99.0)
    out2 = decode_attention(q, k2, v2, jnp.int32(10))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_mrope_degenerates_to_rope_for_text(rng):
    """With identical position streams, M-RoPE must equal plain RoPE."""
    B, S, H, D = 2, 16, 4, 32
    x = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(pos[None], (3, B, S))
    half = D // 2
    t = half - 2 * (3 * half // 8)
    sections = (t, 3 * half // 8, 3 * half // 8)
    a = apply_rope(x, pos, 10000.0)
    b = apply_mrope(x, pos3, 10000.0, sections)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
