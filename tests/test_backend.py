"""Compiled execution plane: substrate resolution, compiled-vs-interpret
bitwise parity, buffer donation, break-even derivation, provenance tags.

Parity methodology: operands are integer-valued floats with small magnitude,
so every f32 accumulation is EXACT regardless of summation order — the
compiled tier (XLA lowerings on CPU, compiled Pallas on TPU) is asserted
BITWISE equal to the interpret-mode Pallas oracle, not allclose. The four
regimes pinned here are the ones the dispatch logic branches on: all-skip,
no-skip, ragged per-row counts, and the budget-overflow fallback.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import RAGGED_BREAK_EVEN_SKIP
from repro.core.reuse_linear import _interpret_arg
from repro.core.similarity import block_zero_mask
from repro.kernels import backend, ops
from repro.obs.latency import TAG_FIELDS, LatencyTable, table_provenance
from repro.roofline.model_cost import (
    predict_kernel_speedup,
    predicted_break_even_skip,
    reuse_kernel_cost,
)
from repro.roofline.validate import validate_kernel_sweep
from repro.tune.harvest import derive_break_even_skip


# ---------------------------------------------------------------------------
# substrate resolution
# ---------------------------------------------------------------------------


def test_best_is_compiled_and_cached():
    sub = backend.best()
    assert sub.compiled
    assert sub is backend.best()  # one resolution per process


def test_resolve_modes():
    assert backend.resolve(None) is backend.best()
    assert backend.resolve(True) is backend.INTERPRET
    assert not backend.INTERPRET.compiled
    if backend.best().use_pallas:
        assert backend.resolve(False) is backend.best()
    else:
        # no compiled Pallas on this host: explicit interpret=False must
        # raise, never silently interpret
        with pytest.raises(ValueError, match="no compiled Pallas"):
            backend.resolve(False)


def test_for_impl_mapping():
    assert backend.for_impl("jnp") is backend.XLA
    assert backend.for_impl("pallas_interpret") is backend.INTERPRET
    assert backend.for_impl("pallas").compiled  # degrades, never interprets
    with pytest.raises(ValueError):
        backend.for_impl("mystery")


def test_interpret_arg_threading():
    # the one explicit value reuse_linear threads into every kernel wrapper
    assert _interpret_arg("pallas_interpret") is True
    assert _interpret_arg("jnp") is None
    assert _interpret_arg("pallas") is None


def test_tag_fields():
    t = backend.tag()
    assert set(t) == set(TAG_FIELDS)
    assert t["backend"] == backend.best().name
    assert t["interpret"] is False
    it = backend.tag(backend.INTERPRET)
    assert it["backend"] == "interpret" and it["interpret"] is True


# ---------------------------------------------------------------------------
# compiled-vs-interpret bitwise parity (4 regimes)
# ---------------------------------------------------------------------------

M, K, N, BM, BN, BK = 16, 512, 256, 8, 128, 128
GK = K // BK


def _operands(rng, keep_prob):
    delta = rng.integers(-2, 3, size=(M, K)).astype(np.float32)
    for i in range(M // BM):
        for j in range(GK):
            if rng.random() >= keep_prob:
                delta[i * BM:(i + 1) * BM, j * BK:(j + 1) * BK] = 0.0
    w = rng.integers(-3, 4, size=(K, N)).astype(np.float32)
    prev = rng.integers(-5, 6, size=(M, N)).astype(np.float32)
    return jnp.asarray(delta), jnp.asarray(w), jnp.asarray(prev)


# keep_prob, ragged budget (None = occupancy-sized, no overflow)
REGIMES = [
    pytest.param(0.0, None, id="all-skip"),
    pytest.param(1.0, None, id="no-skip"),
    pytest.param(0.5, None, id="ragged-counts"),
    pytest.param(0.5, 1, id="overflow-fallback"),
]


@pytest.mark.parametrize("keep,budget", REGIMES)
def test_masked_kernel_parity(rng, keep, budget):
    delta, w, prev = _operands(rng, keep)
    mask = block_zero_mask(delta, BM, BK)
    compiled = ops.reuse_matmul(
        delta, w, prev, mask, block_m=BM, block_n=BN, block_k=BK)
    oracle = ops.reuse_matmul(
        delta, w, prev, mask, block_m=BM, block_n=BN, block_k=BK,
        interpret=True)
    assert bool(jnp.all(compiled == oracle))
    assert bool(jnp.all(
        compiled == ops.reuse_matmul_ref(delta, w, prev, mask, BM, BK)))


@pytest.mark.parametrize("keep,budget", REGIMES)
def test_ragged_parity(rng, keep, budget):
    delta, w, prev = _operands(rng, keep)
    mask = block_zero_mask(delta, BM, BK)
    counts = np.asarray(mask).sum(axis=1)
    if budget is None:
        budget = max(1, int(counts.max()))
    else:
        # the overflow regime must actually overflow: per-row active blocks
        # exceed the budget so the lax.cond fallback engages
        assert int(counts.max()) > budget
    kw = dict(block_m=BM, block_n=BN, block_k=BK, max_active_k=budget)
    compiled = ops.reuse_matmul_ragged(delta, w, prev, mask, **kw)
    oracle = ops.reuse_matmul_ragged(delta, w, prev, mask, **kw,
                                     interpret=True)
    assert bool(jnp.all(compiled == oracle))
    assert bool(jnp.all(
        compiled == ops.reuse_matmul_ref(delta, w, prev, mask, BM, BK)))


def test_delta_quant_parity(rng):
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    prev_q = jnp.asarray(rng.integers(-80, 80, size=(M, K)).astype(np.int8))
    scale = jnp.float32(0.05)
    kw = dict(block_m=BM, block_k=BK)
    q_c, d_c, m_c = ops.delta_quant_fused(x, prev_q, scale, **kw)
    q_i, d_i, m_i = ops.delta_quant_fused(x, prev_q, scale, **kw,
                                          interpret=True)
    assert bool(jnp.all(q_c == q_i))
    assert bool(jnp.all(d_c == d_i))
    assert bool(jnp.all(m_c == m_i))


def test_int8_parity(rng):
    delta, w, prev = _operands(rng, 0.5)
    dq = delta.astype(jnp.int8)
    wq = w.astype(jnp.int8)
    acc = jnp.zeros((M, N), jnp.int32)
    mask = block_zero_mask(delta, BM, BK)
    kw = dict(block_m=BM, block_n=BN, block_k=BK)
    compiled = ops.reuse_matmul_int8(dq, wq, acc, mask, **kw)
    oracle = ops.reuse_matmul_int8(dq, wq, acc, mask, **kw, interpret=True)
    assert bool(jnp.all(compiled == oracle))


# ---------------------------------------------------------------------------
# buffer donation (the serve step donates serve-state + reuse cache)
# ---------------------------------------------------------------------------


def test_donated_cache_buffer_is_consumed():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(cache, x):
        return {"prev": cache["prev"] + x}

    cache = {"prev": jnp.arange(1024, dtype=jnp.float32)}
    buf = cache["prev"]
    out = step(cache, jnp.float32(1.0))
    jax.block_until_ready(out)
    # donation consumed the input buffer: the old cache pytree is dead, its
    # storage was handed to the output instead of a fresh allocation
    assert buf.is_deleted()
    assert bool(jnp.all(out["prev"] == jnp.arange(1024) + 1.0))


def test_undonated_buffer_survives():
    @jax.jit
    def step(cache, x):
        return {"prev": cache["prev"] + x}

    cache = {"prev": jnp.arange(16, dtype=jnp.float32)}
    jax.block_until_ready(step(cache, jnp.float32(1.0)))
    assert not cache["prev"].is_deleted()


# ---------------------------------------------------------------------------
# measured break-even derivation + gate
# ---------------------------------------------------------------------------


def test_derive_break_even_empty_falls_back():
    assert derive_break_even_skip([]) == RAGGED_BREAK_EVEN_SKIP


def test_derive_break_even_interpolates_crossing():
    pts = [(0.0, 2.0, 1.0), (0.5, 1.0, 1.0), (1.0, 0.5, 1.0)]
    assert derive_break_even_skip(pts) == pytest.approx(0.5)
    pts = [(0.0, 1.5, 1.0), (0.5, 0.5, 1.0)]  # crossing inside the segment
    assert derive_break_even_skip(pts) == pytest.approx(0.25)


def test_derive_break_even_never_wins_codes_two():
    pts = [(s, 2.0, 1.0) for s in (0.0, 0.5, 0.9)]
    assert derive_break_even_skip(pts) == 2.0


def test_derive_break_even_wins_everywhere():
    pts = [(0.1, 0.5, 1.0), (0.9, 0.2, 1.0)]
    assert derive_break_even_skip(pts) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# latency-table provenance
# ---------------------------------------------------------------------------


def _table(*tag_rows, meta=None):
    t = LatencyTable()
    for i, tags in enumerate(tag_rows):
        t.record("site", None, f"path{i}", 1e-4, tags=tags)
    if meta:
        t.meta.update(meta)
    return t


def test_provenance_untagged_is_unknown():
    assert table_provenance(_table(None)) == "unknown"


def test_provenance_compiled_interpret_mixed():
    compiled = backend.tag()
    interp = backend.tag(backend.INTERPRET)
    assert table_provenance(_table(compiled)) == "compiled"
    assert table_provenance(_table(interp)) == "interpret"
    assert table_provenance(_table(compiled, interp)) == "mixed"


def test_provenance_meta_fallback():
    assert table_provenance(_table(None, meta={"interpret": True})) \
        == "interpret"
    assert table_provenance(_table(None, meta={"interpret": False})) \
        == "compiled"


def test_roundtrip_preserves_tags(tmp_path):
    from repro.obs.latency import load_latency_table

    t = _table(backend.tag())
    path = tmp_path / "latency_table.json"
    t.save(str(path))
    assert table_provenance(load_latency_table(str(path))) == "compiled"


# ---------------------------------------------------------------------------
# roofline kernel work model + sweep validation
# ---------------------------------------------------------------------------


def test_parity_paths_cost_dense_work():
    dense = reuse_kernel_cost(64, 2048, 256, path="dense", block_k=256)
    for p in ("kernel", "masked"):
        c = reuse_kernel_cost(64, 2048, 256, path=p, skip=0.9, block_k=256)
        assert c.flops == dense.flops and c.bytes == dense.bytes


def test_compact_speedup_monotone_in_skip():
    ups = [predict_kernel_speedup(64, 2048, 256, path="compact", skip=s,
                                  block_k=256)
           for s in (0.0, 0.25, 0.5, 0.75, 0.9)]
    assert all(b >= a for a, b in zip(ups, ups[1:]))
    assert ups[0] < 1.0 < ups[-1]  # gather overhead loses at 0, wins at 0.9


def test_predicted_break_even_in_sweep_range():
    be = predicted_break_even_skip(64, 2048, 256, path="compact",
                                   block_k=256)
    assert 0.0 < be < 1.0


def test_ragged_xla_group_duplication_can_never_win():
    # per-M-group weight gather on the XLA tier: at gm=8 the duplicated
    # traffic swamps the savings at every skip level
    be = predicted_break_even_skip(64, 2048, 256, path="ragged",
                                   block_m=8, block_k=256)
    assert be == 2.0


def _sweep_rows(us_by_path):
    rows = []
    for skip, paths in us_by_path.items():
        for path, us in paths.items():
            rows.append({
                "skip": skip, "path": path, "us": us,
                "m": 64, "k": 2048, "n": 256, "block_m": 8, "block_k": 256,
                "max_active_k": None if path != "ragged" else 8,
            })
    return rows


def test_validate_kernel_sweep_model_consistent():
    # measurements manufactured FROM the model: every check must pass
    us = {}
    for skip in (0.0, 0.25, 0.5, 0.75, 0.9):
        us[skip] = {"dense_gemm": 100.0}
        for p in ("compact", "ragged"):
            pred = predict_kernel_speedup(64, 2048, 256, path=p, skip=skip,
                                          block_k=256, max_active_k=8
                                          if p == "ragged" else None)
            us[skip][p] = 100.0 / pred
    rep = validate_kernel_sweep(_sweep_rows(us))
    assert rep["ok"]
    assert rep["rank_ok"] and rep["direction_ok"]
    assert all(c == pytest.approx(1.0)
               for c in rep["rank_correlation"].values() if c is not None)


def test_validate_kernel_sweep_refutes_early_win():
    # measurement claims compaction wins at EVERY skip level — left of the
    # model's overhead-free lower bound, so the one-sided check must fail
    us = {skip: {"dense_gemm": 100.0, "compact": 50.0}
          for skip in (0.0, 0.25, 0.5, 0.75, 0.9)}
    rep = validate_kernel_sweep(_sweep_rows(us))
    assert not rep["ok"]
    assert not rep["break_even_within_tol"]

    # measured crossing RIGHT of the prediction (overhead shifts it late)
    # is exactly what the one-sided bound permits
    us = {skip: {"dense_gemm": 100.0,
                 "compact": 80.0 if skip >= 0.75 else 300.0 - 100.0 * skip}
          for skip in (0.0, 0.25, 0.5, 0.75, 0.9)}
    rep = validate_kernel_sweep(_sweep_rows(us))
    assert rep["break_even_within_tol"]
