"""Checkpointing: roundtrip exactness, atomicity, GC, async, fault recovery."""

import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    CorruptCheckpointError,
    gc_checkpoints,
    latest_step,
    latest_valid_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ckpt.recovery import LoopConfig, ResilientLoop


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (16, 32), jnp.float32),
            "b16": jax.random.normal(k, (8, 8), jnp.float32).astype(jnp.bfloat16),
            "nested": {"v": jnp.arange(10, dtype=jnp.int32)},
        },
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(
            np.asarray(x, np.float32) if x.dtype == jnp.bfloat16 else np.asarray(x),
            np.asarray(y, np.float32) if y.dtype == jnp.bfloat16 else np.asarray(y),
        )


def test_roundtrip_exact(tmp_path):
    state = make_state()
    save_checkpoint(tmp_path, 3, state)
    assert latest_step(tmp_path) == 3
    out = restore_checkpoint(tmp_path, 3, jax.eval_shape(lambda: make_state()))
    assert_tree_equal(state, out)


def test_incomplete_checkpoint_not_restorable(tmp_path):
    state = make_state()
    save_checkpoint(tmp_path, 5, state)
    # simulate a torn save at step 9: files exist but no COMPLETE marker
    step_dir = tmp_path / "step_000009"
    step_dir.mkdir()
    (step_dir / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 5  # 9 invisible


def test_gc_keeps_latest(tmp_path):
    state = make_state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, state)
    gc_checkpoints(tmp_path, keep=2)
    assert latest_step(tmp_path) == 5
    assert not (tmp_path / "step_000001").exists()
    assert (tmp_path / "step_000004").exists()


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    state = make_state()
    ck.save(1, state)
    ck.wait()
    assert latest_step(tmp_path) == 1
    out = restore_checkpoint(tmp_path, 1, jax.eval_shape(lambda: make_state()))
    assert_tree_equal(state, out)


def test_multihost_manifest_merge(tmp_path):
    """Elastic restore merges shards from N save-time hosts into one tree
    (here: disjoint key subsets written as separate host files)."""
    state = make_state()
    keys, leaves, _ = __import__(
        "repro.ckpt.checkpoint", fromlist=["x"]
    )._flatten_with_paths(state)
    # host 0 writes everything via the normal path but claim n_hosts=2 ...
    save_checkpoint(tmp_path, 1, state, host_id=0, n_hosts=2)
    assert latest_step(tmp_path) is None  # not complete until host 1 lands
    save_checkpoint(tmp_path, 1, state, host_id=1, n_hosts=2)
    assert latest_step(tmp_path) == 1
    out = restore_checkpoint(tmp_path, 1, jax.eval_shape(lambda: make_state()))
    assert_tree_equal(state, out)


def test_resilient_loop_recovers_from_injected_faults(tmp_path):
    """Step 7 explodes twice; the loop restores from the step-5 checkpoint and
    replays deterministically to completion."""
    calls = {"fails": 0}

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {"loss": jnp.float32(0.0)}

    def batch_fn(step):
        return jnp.asarray(float(step))

    def fail_injector(step):
        if step == 7 and calls["fails"] < 2:
            calls["fails"] += 1
            raise RuntimeError("injected device failure")

    loop = ResilientLoop(
        step_fn, batch_fn,
        LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_retries=3),
    )
    state = loop.run({"x": jnp.float32(0.0)}, 0, 10,
                     fail_injector=fail_injector)
    assert calls["fails"] == 2
    # sum of 0..9 regardless of the mid-flight failures
    assert float(state["x"]) == sum(range(10))


def test_corrupt_checkpoint_detected_and_walked_past(tmp_path):
    """A COMPLETE marker proves the save finished, not that the bytes are
    still good: bitrot behind the marker must raise (never restore silently
    wrong weights) and `latest_valid_step` must walk past it to the newest
    step whose hashes verify."""
    from repro.guard.inject import FaultInjector

    state = make_state()
    save_checkpoint(tmp_path, 1, state)
    save_checkpoint(tmp_path, 2, state)
    # flip bytes mid-file in the newest step's payload (the production
    # corruption path the chaos injector drives)
    FaultInjector("corrupt-ckpt").corrupt_checkpoint(tmp_path)

    struct = jax.eval_shape(lambda: make_state())
    with pytest.raises(CorruptCheckpointError, match="sha256 mismatch"):
        restore_checkpoint(tmp_path, 2, struct)
    assert latest_step(tmp_path) == 2        # the marker still lies
    assert latest_valid_step(tmp_path) == 1  # the hashes don't
    assert_tree_equal(state, restore_checkpoint(tmp_path, 1, struct))

    # the resilient loop resumes from the older VALID step, not the marker
    loop = ResilientLoop(
        lambda s, b: (s, {}), lambda s: None,
        LoopConfig(ckpt_dir=str(tmp_path)),
    )
    resumed, start = loop.resume_or_init(make_state)
    assert start == 2
    assert_tree_equal(state, resumed)


def test_missing_manifest_behind_marker_is_corrupt(tmp_path):
    save_checkpoint(tmp_path, 3, make_state())
    (tmp_path / "step_000003" / "manifest.json").unlink()
    with pytest.raises(CorruptCheckpointError, match="manifest.json missing"):
        restore_checkpoint(tmp_path, 3, jax.eval_shape(lambda: make_state()))
    assert latest_valid_step(tmp_path) is None


def test_preemption_saves_final_checkpoint_and_resumes(tmp_path):
    """SIGTERM mid-run → synchronous final checkpoint before exit, and a
    fresh loop resumes from exactly that step (cloud preemption semantics).
    The signal is raised from inside a step so the handler fires on the
    main thread, like a real preemption notice."""

    def step_fn(state, batch):
        if int(state["x"]) == 3:
            os.kill(os.getpid(), signal.SIGTERM)
        return {"x": state["x"] + 1.0}, {}

    # ckpt_every far beyond the run: the ONLY checkpoint is the preemption one
    loop = ResilientLoop(
        step_fn, lambda s: None,
        LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=1000),
    )
    state = loop.run({"x": jnp.float32(0.0)}, 0, 10)
    assert float(state["x"]) == 4.0          # stopped early, step 3 finished
    assert latest_valid_step(tmp_path) == 3  # final save committed + verified

    loop2 = ResilientLoop(
        step_fn, lambda s: None,
        LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=1000),
    )
    resumed, start = loop2.resume_or_init(lambda: {"x": jnp.float32(0.0)})
    assert start == 4 and float(resumed["x"]) == 4.0
    final = loop2.run(resumed, start, 6)
    assert float(final["x"]) == 10.0         # the run completes exactly


def test_straggler_watchdog_flags_slow_steps(tmp_path):
    times = iter([0.01] * 10 + [0.2] + [0.01] * 5)

    def step_fn(state, batch):
        time.sleep(next(times))
        return state, {}

    loop = ResilientLoop(
        step_fn, lambda s: None,
        LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                   straggler_factor=3.0),
    )
    loop.run({}, 0, 16)
    assert len(loop.straggler_events) >= 1
    assert loop.straggler_events[0]["action"].startswith("recommend")
