"""repro.control — the online adaptive control plane.

Load-bearing properties:

* `reset_slot` clears per-slot admission-predictor state on slot recycle
  (regression: a new session must not inherit the previous occupant's
  similarity estimate);
* offline fitter and online retuner share ONE harvest model — equivalence
  locked through the JSONL serialization boundary;
* controller guardrails under an adversarial oscillating-similarity stream:
  bounded flip count (hysteresis vetoes counted in `suppressed_flips`),
  bounded per-interval knob moves;
* the budget adapter widens `max_active_k` from the measured
  `overflow_fallbacks` counter and re-tightens when windows run clean;
* closed-loop e2e: starting from the DEFAULT (untuned) policy on a
  high-similarity stream, the controller converges to decisions whose
  measured mac_skip / grid_step_skip_rate are no worse than the offline
  `--tuned-policy` baseline, with bitwise-exact outputs vs the dense oracle,
  and the overflow counter drives at least one max_active_k adjustment in
  the decision journal.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.control import (
    AdmissionPredictor,
    ControlConfig,
    Controller,
    bounded_tunables,
    load_journal,
)
from repro.core import ReuseEngine, ReusePolicy, SiteTunables
from repro.serve.scheduler import Request, reset_slot
from repro.tune.harvest import FitConfig, record_from_sensor, solve_site


def _req(rid, slot, session=None, hit=None, steps=5):
    r = Request(rid=rid, prompt=np.zeros(4, np.int32), session=session)
    r.slot = slot
    if hit is not None:
        r.telemetry = {"slot": slot, "steps": steps, "hit_rate": hit,
                       "n_sites": 1}
    return r


# ------------------------------------------------- admission predictor state

def test_reset_slot_clears_admission_state():
    """Satellite regression: slot recycle must clear the predictor's
    per-slot occupant state (binding + running estimate), with or without a
    reuse cache, so telemetry after the recycle can't be attributed to the
    departed session and the next occupant starts from its own prior."""
    pred = AdmissionPredictor(decay=1.0, prior=0.5)
    a = _req(0, slot=1, session="A", hit=0.9)
    pred.on_placed(a)
    assert pred.slot_session[1] == "A"

    # recycle WITHOUT retirement (e.g. an abandoned slot): state cleared
    assert reset_slot(None, 1, admission=pred) is None
    assert 1 not in pred.slot_session

    # telemetry arriving after the recycle must not update session A via the
    # (now-cleared) slot binding — only via the request's own key
    pred.sessions.clear()
    b = _req(1, slot=1, session="B", hit=0.2)
    pred.observe_retirement(b)
    assert "A" not in pred.sessions
    assert pred.sessions["B"] == pytest.approx(0.2)

    # a brand-new session on the recycled slot predicts from its own prior,
    # not the previous occupant's estimate
    c = _req(2, slot=1, session="C")
    assert pred.predict(c) == pred.global_est


def test_reset_slot_clears_cache_and_admission_together():
    engine = ReuseEngine()
    engine.register("s", 64, 32, block_m=2, block_k=32)
    cache = engine.init_cache(4)
    cache["s"]["sensor"]["slot_hit_sum"] = jnp.ones((4,))
    pred = AdmissionPredictor()
    pred.on_placed(_req(0, slot=2, session="X"))
    new = reset_slot(cache, 2, admission=pred)
    assert float(new["s"]["sensor"]["slot_hit_sum"][2]) == 0.0
    assert float(new["s"]["sensor"]["slot_hit_sum"][0]) == 1.0
    assert 2 not in pred.slot_session


def test_admission_predictor_learns_sessions():
    pred = AdmissionPredictor(decay=0.5, prior=0.3)
    for rid in range(8):  # sticky session retires high, one-shots retire low
        hi = rid % 2 == 0
        r = _req(rid, slot=rid % 2, session="sticky" if hi else f"one-{rid}",
                 hit=0.9 if hi else 0.1)
        pred.on_placed(r)
        pred.observe_retirement(r)
    sticky = _req(9, 0, session="sticky")
    fresh = _req(10, 0, session="never-seen")
    assert pred.predict(sticky) > 0.7
    assert pred.predict(fresh) == pred.global_est < pred.predict(sticky)
    # lane character (affinity signal) reflects the last retired stream
    assert pred.slot_affinity(0) == pytest.approx(0.9)
    assert pred.slot_affinity(3) == 0.0
    # zero-step telemetry (never decoded) is not a measurement
    dud = _req(11, 1, session="dud", hit=0.0, steps=0)
    pred.observe_retirement(dud)
    assert "dud" not in pred.sessions

    # the session store is bounded (least-recently-updated eviction): a
    # long-lived server full of one-shot (rid-keyed) sessions can't leak
    small = AdmissionPredictor(max_sessions=2)
    for rid in range(5):
        r = _req(rid, slot=0, hit=0.5)  # session=None -> keyed by rid
        small.observe_retirement(r)
    assert len(small.sessions) == 2
    assert 4 in small.sessions and 3 in small.sessions


# ------------------------------------- shared harvest model (offline=online)

def test_harvest_equivalence_offline_online(tmp_path):
    """Satellite lock: the offline fitter (JSONL trace → fit_site) and the
    online retuner's solver (in-memory SiteSensor → solve_site) must produce
    IDENTICAL tunables for the same measured operating point — one harvest
    model, one set of cost-model units."""
    from repro.sensor.runner import run_measured_decode
    from repro.tune import fit_site, load_trace

    md = run_measured_decode("qwen3-32b", steps=6, batch=2, correlation=0.95)
    path = tmp_path / "trace.jsonl"
    md.report.write_jsonl(str(path), mode="w")
    trace = load_trace(str(path))
    assert set(trace.sites) == {s.site for s in md.report.per_site}
    for s in md.report.per_site:
        offline = fit_site(trace.sites[s.site])
        online = solve_site(record_from_sensor(s))
        assert offline == online, s.site
        # and through a non-default shared config too
        cfg = FitConfig(safety_margin=2.0, pallas_target=True)
        assert fit_site(trace.sites[s.site], cfg) == solve_site(
            record_from_sensor(s), cfg)


def test_bounded_tunables_guardrails():
    cur = SiteTunables(sim_threshold=0.50, min_work_flops=1e6, block_k=256)
    tgt = SiteTunables(sim_threshold=0.05, min_work_flops=9e9, block_k=64,
                       exec_path="compact", max_active_k=1)
    out, reasons = bounded_tunables(
        cur, tgt, current_block_k=256,
        max_threshold_step=0.1, max_min_work_raise=8.0,
    )
    # threshold moves at most one step toward the target
    assert out.sim_threshold == pytest.approx(0.40)
    # min_work RAISES are throttled ...
    assert out.min_work_flops == pytest.approx(8e6)
    # ... block_k moves one notch, so the compacted-exec pin (solved at
    # block_k=64) is deferred until the granularity is reached
    assert out.block_k == 128
    assert out.exec_path is None and out.max_active_k is None
    assert reasons
    # min_work LOWERING (admission) applies immediately
    out2, _ = bounded_tunables(
        cur, dataclasses.replace(tgt, min_work_flops=8.0),
        current_block_k=256, max_threshold_step=0.1, max_min_work_raise=8.0,
    )
    assert out2.min_work_flops == pytest.approx(8.0)
    # a below-break-even window RELEASES the pin (the spec keeps its path
    # and budget until the cumulative refresh demotes it — a never-released
    # pin would make refresh_exec_paths demotion unreachable)
    cur64 = dataclasses.replace(cur, block_k=64, exec_path="compact",
                                max_active_k=3)
    out3, r3 = bounded_tunables(
        cur64, dataclasses.replace(tgt, exec_path=None, max_active_k=None),
        current_block_k=64, max_threshold_step=0.1, max_min_work_raise=8.0,
    )
    assert out3.exec_path is None and out3.max_active_k is None
    assert any("released" in r for r in r3)


def test_apply_tunables_rescales_budget_on_block_k_move():
    """max_active_k is in K-blocks OF block_k: a granularity move must
    rescale the budget so the covered K extent survives (and sync the
    policy table so the old-unit number can't come back)."""
    policy = ReusePolicy(site_tunables={"s": SiteTunables(
        block_k=256, exec_path="compact", max_active_k=4)})
    engine = ReuseEngine(policy=policy)
    engine.register("s", 2048, 64, block_k=256)  # gk=8, budget 4 = 1024 K
    assert engine.sites["s"].max_active_k == 4
    moved = SiteTunables(block_k=128, exec_path="compact", max_active_k=4)
    assert engine.apply_tunables("s", moved)
    spec = engine.sites["s"]
    assert spec.block_k == 128
    assert spec.max_active_k == 8  # same 1024-K extent at the new unit
    assert engine.policy.resolve("s").max_active_k == 8  # table synced


# --------------------------------------------- overflow counter (schema v4)

def _drive(engine, cache, name, x, w):
    out, entry, stats = engine.apply(name, x, w, None, cache[name])
    cache[name] = entry
    return out


def test_overflow_fallbacks_counter_and_v3_traces(tmp_path):
    """The compact path's full-extent fallback increments the new counter;
    rows emit schema v4; v3 rows (no overflow field) still load."""
    policy = ReusePolicy(site_tunables={"s": SiteTunables(
        min_work_flops=0.0, exec_path="compact", max_active_k=1, block_k=32)})
    engine = ReuseEngine(policy=policy)
    engine.register("s", 128, 64, block_m=2, block_k=32)  # gk = 4
    assert engine.sites["s"].max_active_k == 1
    cache = engine.init_cache(2)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 64), jnp.float32)

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128), jnp.float32)
    _drive(engine, cache, "s", x, w)          # cold start: every block live
    assert int(cache["s"]["sensor"]["overflow_fallbacks"]) == 1
    _drive(engine, cache, "s", x, w)          # identical input: zero blocks
    assert int(cache["s"]["sensor"]["overflow_fallbacks"]) == 1

    report = engine.sensor_report(cache)
    rows = report.to_dicts()
    from repro.sensor.aggregate import SENSOR_SCHEMA_VERSION

    assert all(r["schema_version"] == SENSOR_SCHEMA_VERSION for r in rows)
    site_row = next(r for r in rows if r["kind"] == "site")
    assert site_row["overflow_fallbacks"] == 1
    assert report.model["overflow_fallbacks"] == 1

    # a v3 trace (pre-overflow schema) still loads, field defaulted
    from repro.tune import load_trace

    v3 = dict(site_row, schema_version=3)
    v3.pop("overflow_fallbacks")
    p = tmp_path / "v3.jsonl"
    p.write_text(json.dumps(v3) + "\n")
    rec = load_trace(str(p)).sites["s"]
    assert rec.overflow_fallbacks == 0


def test_budget_adapter_widens_then_tightens():
    """max_active_k closes its loop on the measured fallback rate: a stream
    whose live tile count overflows the budget widens it one block per
    interval; clean windows with occupancy slack tighten it back."""
    policy = ReusePolicy(site_tunables={"s": SiteTunables(
        sim_threshold=0.0, min_work_flops=0.0,
        exec_path="compact", max_active_k=1, block_k=64)})
    engine = ReuseEngine(policy=policy)
    engine.register("s", 256, 64, block_m=2, block_k=64)  # gk = 4
    cache = engine.init_cache(2)
    # freeze the granularity knob (harvest efficiency can never leave the
    # keep-band) so this test isolates the BUDGET loop; shrink the
    # overflowed-floor streak so the calmed-stream retighten fits the run
    ctl = Controller(ControlConfig(
        min_window_steps=2,
        tighten_floor_streak=3,
        fit=FitConfig(low_efficiency=0.0, high_efficiency=1.01),
    ))
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64), jnp.float32)
    base = jax.random.normal(jax.random.PRNGKey(1), (2, 256), jnp.float32)

    # half-dissimilar inputs: the first 2 of 4 K-blocks churn every step
    # (live count 2 overflows the budget of 1) while the tail stays sticky,
    # so the site remains profitably compacted (skip 0.5) but every
    # evaluation takes the full-extent fallback
    _drive(engine, cache, "s", base, w)  # cold start
    for i in range(2, 8):
        churn = jax.random.normal(jax.random.PRNGKey(100 + i), (2, 128))
        x = base.at[:, :128].set(churn)
        _drive(engine, cache, "s", x, w)
        if i % 2 == 0:
            ctl.step(engine, cache, step=i)
    widens = [d for r in ctl.reports for d in r.decisions
              if d.kind == "budget" and d.after > d.before]
    assert widens, "overflowing stream must widen the budget"
    assert all("overflow_fallbacks" in d.reason for d in widens)
    assert engine.sites["s"].max_active_k > 1
    # bounded step: one block per interval
    assert all(d.after == d.before + 1 for d in widens)

    # now a fully-sticky stream (back on the original base, so only the
    # churned head blocks settle): zero fallbacks -> tighten, gated on the
    # clean-window streak and the overflowed-floor streak
    widened = engine.sites["s"].max_active_k
    x = base
    for i in range(8, 16):
        _drive(engine, cache, "s", x, w)
        if i % 2 == 0:
            ctl.step(engine, cache, step=i)
    tightens = [d for r in ctl.reports for d in r.decisions
                if d.kind == "budget" and d.after < d.before]
    assert tightens, "clean low-occupancy windows must tighten the budget"
    assert engine.sites["s"].max_active_k < widened


# ------------------------------------------------- guardrails under attack

def test_controller_guardrails_oscillating_stream():
    """Adversarial alternating high/low-similarity stream: the policy keeps
    WANTING to flip kernelMode every phase, but hysteresis + cooldown bound
    the realized flips (vetoes land in `suppressed_flips`) and every retune
    decision stays within its per-interval step bound."""
    policy = ReusePolicy(min_work_flops=0.0)
    engine = ReuseEngine(policy=policy)
    engine.register("s", 256, 128, block_m=2, block_k=64)
    cache = engine.init_cache(2)
    # pin the solved threshold to 0.5 so the oscillation is guaranteed to
    # cross it (the adversarial setting); guardrails stay default
    cfg = ControlConfig(
        min_window_steps=3,
        fit=FitConfig(min_threshold=0.5, max_threshold=0.5),
    )
    ctl = Controller(cfg)
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128), jnp.float32)
    sticky = jax.random.normal(jax.random.PRNGKey(1), (2, 256), jnp.float32)

    rng = np.random.default_rng(0)
    n_intervals = 0
    for i in range(1, 49):
        phase_high = ((i - 1) // 8) % 2 == 0
        x = sticky if phase_high else jnp.asarray(
            rng.normal(size=(2, 256)).astype(np.float32))
        _drive(engine, cache, "s", x, w)
        if i % 4 == 0:
            ctl.step(engine, cache, step=i)
            n_intervals += 1

    sensor = cache["s"]["sensor"]
    transitions = int(sensor["mode_transitions"])
    suppressed = int(sensor["suppressed_flips"])
    # 6 phase reversals all try to flip; guardrails must veto some and the
    # realized flip count stays well below one per interval
    assert suppressed >= 1, "hysteresis/cooldown never vetoed a flip"
    assert transitions <= n_intervals // 2 + 1, (transitions, n_intervals)
    # every threshold move respected the bounded step
    thr_moves = [d for r in ctl.reports for d in r.decisions
                 if d.kind == "retune" and d.field == "sim_threshold"]
    for d in thr_moves:
        assert abs(d.after - d.before) <= cfg.max_threshold_step + 1e-9
    # block_k moves (if any) are single-notch (before=None is the first
    # materialization of a table entry from the spec default)
    for d in (d for r in ctl.reports for d in r.decisions
              if d.kind == "retune" and d.field == "block_k"):
        assert d.after in {32, 64, 128, 256, 512}
        if d.before is not None:
            assert abs(np.log2(d.after) - np.log2(d.before)) == 1


# ------------------------------------------------------- the closed loop e2e

def test_closed_loop_control_matches_tuned_baseline(tmp_path):
    """Acceptance: from the DEFAULT (untuned) policy on a ≥70%-similarity
    stream, the live controller converges within the run to decisions whose
    measured window mac_skip and grid_step_skip_rate are at least the
    offline `--tuned-policy` baseline's, bitwise-exact vs the dense oracle,
    with the overflow counter driving a max_active_k adjustment recorded in
    the decision journal."""
    from repro.sensor.runner import run_measured_decode
    from repro.tune import fit_trace, load_trace, load_tuned_policy, save_table

    # A fully-anchored stream is stationary-high-similarity at reduced scale
    # (every post-cold-start step skips every tile), which makes the
    # converged-window comparison deterministic.
    arch, batch, corr = "qwen3-32b", 2, 1.0

    # ---- offline baseline: record -> fit -> serve with the tuned table
    md_rec = run_measured_decode(arch, steps=8, batch=batch, correlation=corr)
    tp = tmp_path / "trace.jsonl"
    md_rec.report.write_jsonl(str(tp), mode="w")
    table_path = tmp_path / "tuned.json"
    save_table(str(table_path), fit_trace(load_trace(str(tp))))
    tuned = load_tuned_policy(str(table_path))
    md_tuned = run_measured_decode(arch, steps=26, batch=batch,
                                   correlation=corr, refresh_policy=True,
                                   policy=tuned)
    base_mac = md_tuned.report.model["mac_skip_rate"]
    base_grid = md_tuned.report.model["grid_step_skip_rate"]
    assert base_mac > 0.5  # the offline loop really harvests on this stream

    # ---- controlled run, default (untuned) policy: converge on the sticky
    # phase (steps 1-18; the converged window 11-18 is the measurement), then
    # a dissimilarity burst (19-22) spikes tile occupancy over the adapted
    # budget so the overflow loop has something to react to
    journal_path = tmp_path / "decisions.jsonl"
    ctl = Controller(
        ControlConfig(min_window_steps=2, journal_path=str(journal_path)),
    )
    reports = {}

    def on_step(i, engine, cache):
        if i % 2 == 0:
            ctl.step(engine, cache, step=i)
        if i in (10, 18):  # converged-window bounds: snapshot counters
            reports[i] = engine.sensor_report(cache)

    md_ctl = run_measured_decode(
        arch, steps=26, batch=batch, correlation=corr, on_step=on_step,
        burst=(19, 22),
    )

    # converged decisions: sites admitted to reuse and on a compacted tier
    modes = md_ctl.engine.mode_summary(md_ctl.cache)
    assert any(m in ("reuse", "mixed") for m in modes.values())
    assert any(s.exec_path in ("compact", "ragged")
               for s in md_ctl.engine.sites.values())

    # converged-window rates (steps 11-18, counter deltas) vs the tuned
    # baseline's whole-run rates
    w0, w1 = reports[10], reports[18]
    win_mac = (w1.model["skipped_macs"] - w0.model["skipped_macs"]) / max(
        w1.model["total_macs"] - w0.model["total_macs"], 1e-9)
    assert win_mac >= base_mac - 1e-9, (win_mac, base_mac)
    assert win_mac > 0.5

    w0_sites = {s.site: s for s in w0.per_site}
    win_dense = win_grid_steps = 0.0
    for s in w1.per_site:
        m = w0_sites[s.site]
        gn = -(-s.out_features // s.block_n)
        win_dense += (s.total_tiles - m.total_tiles) * gn
        win_grid_steps += s.grid_steps - m.grid_steps
    win_grid = max(0.0, 1.0 - win_grid_steps / max(win_dense, 1e-9))
    assert win_grid >= base_grid - 1e-9, (win_grid, base_grid)
    assert win_grid > 0.0  # the compacted tier truly elided grid steps

    # the overflow counter measured real fallbacks and drove ≥1 budget move
    assert md_ctl.report.model["overflow_fallbacks"] > 0
    rows = load_journal(str(journal_path))
    assert any(r["kind"] == "interval" for r in rows)
    budget_rows = [r for r in rows if r.get("decision_kind") == "budget"]
    assert budget_rows, "no max_active_k adjustment in the decision journal"
    assert any("overflow_fallbacks" in r["reason"] for r in budget_rows)

    # zero accuracy deviation: at the converged decisions, every reuse-mode
    # site's compacted execution is bitwise-exact vs the dense oracle on the
    # live cache state
    from repro.core.reuse_linear import reuse_linear

    rng = np.random.default_rng(7)
    checked = 0
    for name, spec in md_ctl.engine.sites.items():
        if md_ctl.engine.site_mode(md_ctl.cache, name) == "basic":
            continue
        entry = md_ctl.cache[name]
        sliced = jax.tree.map(
            lambda a: a[0] if md_ctl.engine.stacking[name] else a, entry)
        x = jnp.asarray(rng.normal(size=(batch, spec.in_features))
                        .astype(np.float32))
        w = jnp.asarray(rng.normal(size=(spec.in_features, spec.out_features))
                        .astype(np.float32))
        out, _, _ = reuse_linear(x, w, None, sliced, spec, mode="reuse")
        oracle_spec = dataclasses.replace(spec, exec_path="dense",
                                          max_active_k=None)
        ref, _, _ = reuse_linear(x, w, None, sliced, oracle_spec, mode="reuse")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        checked += 1
    assert checked >= 1
