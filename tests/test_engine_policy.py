"""ReuseEngine / ReusePolicy behaviour: mode decisions, EMA, stats, scheduler
slot recycling."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReuseEngine, ReusePolicy, ReuseSiteSpec
from repro.serve.scheduler import ContinuousBatcher, Request, reset_slot


def test_policy_demotes_low_similarity_sites():
    pol = ReusePolicy(sim_threshold=0.3, min_work_flops=1000)
    big = ReuseSiteSpec("big", 4096, 4096, mode="auto")
    assert pol.decide_mode(big, sim_ema=0.5) == "reuse"
    assert pol.decide_mode(big, sim_ema=0.1) == "basic"
    # explicit kernelMode wins over similarity
    forced = ReuseSiteSpec("f", 4096, 4096, mode="reuse")
    assert pol.decide_mode(forced, sim_ema=0.0) == "reuse"


def test_policy_demotes_small_sites():
    """Paper Fig. 12: small layers see little gain even at high similarity."""
    pol = ReusePolicy(min_work_flops=2**24)
    small = ReuseSiteSpec("s", 64, 64, mode="auto")
    assert pol.decide_mode(small, sim_ema=0.99) == "basic"


def test_policy_dataflow_choice():
    """Paper Sec. VI-A (3DUnet): large-input/small-output prefers input
    stationary; otherwise output stationary."""
    pol = ReusePolicy()
    assert pol.decide_dataflow(16384, 256) == "input"
    assert pol.decide_dataflow(4096, 4096) == "output"


def test_refresh_modes_roundtrip(rng):
    eng = ReuseEngine(policy=ReusePolicy(sim_threshold=0.5,
                                         min_work_flops=1000))
    eng.register("site", 512, 512)
    cache = eng.init_cache(batch=4)
    assert eng.modes["site"] == "reuse"
    cache["site"]["sim_ema"] = jnp.float32(0.1)
    changed = eng.refresh_modes(cache)
    assert changed == {"site": "basic"}
    cache["site"]["sim_ema"] = jnp.float32(0.9)
    changed = eng.refresh_modes(cache)
    assert changed == {"site": "reuse"}


def test_stacked_cache_shapes():
    eng = ReuseEngine()
    eng.register("site", 128, 256, n_layers=6)
    cache = eng.init_cache(batch=4)
    assert cache["site"]["prev_q"].shape == (6, 4, 128)
    assert cache["site"]["prev_out"].shape == (6, 4, 256)


def test_scheduler_completes_all_requests(rng):
    """Pure-logic batcher test with a fake model."""
    def prefill_fn(prompt, slot):
        return int(prompt[0, -1]) % 100

    def decode_fn(tokens):
        return (tokens + 1) % 100

    b = ContinuousBatcher(batch_slots=3, prefill_fn=prefill_fn,
                          decode_fn=decode_fn, max_steps=200)
    for i in range(7):
        b.submit(Request(rid=i,
                         prompt=np.asarray([i, i + 1], np.int32),
                         max_new_tokens=5))
    done = b.run()
    assert len(done) == 7
    for req in done:
        assert len(req.output) == 5
        # deterministic fake model: strictly incrementing tokens
        for a, c in zip(req.output, req.output[1:]):
            assert c == (a + 1) % 100


def test_reset_slot_zeroes_one_lane():
    eng = ReuseEngine()
    eng.register("site", 64, 32, n_layers=2)
    cache = eng.init_cache(batch=3)
    cache["site"]["prev_q"] = jnp.ones_like(cache["site"]["prev_q"])
    cache["site"]["prev_out"] = jnp.ones_like(cache["site"]["prev_out"])
    out = reset_slot(cache, slot=1)
    pq = np.asarray(out["site"]["prev_q"])
    assert np.all(pq[:, 1, :] == 0)
    assert np.all(pq[:, 0, :] == 1) and np.all(pq[:, 2, :] == 1)
