"""ReuseEngine / ReusePolicy behaviour: mode decisions, per-site tunables,
hysteresis, EMA, stats, scheduler slot recycling + affinity placement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReuseEngine, ReusePolicy, ReuseSiteSpec, SiteTunables
from repro.serve.scheduler import ContinuousBatcher, Request, reset_slot


def test_policy_demotes_low_similarity_sites():
    pol = ReusePolicy(sim_threshold=0.3, min_work_flops=1000)
    big = ReuseSiteSpec("big", 4096, 4096, mode="auto")
    assert pol.decide_mode(big, sim_ema=0.5) == "reuse"
    assert pol.decide_mode(big, sim_ema=0.1) == "basic"
    # explicit kernelMode wins over similarity
    forced = ReuseSiteSpec("f", 4096, 4096, mode="reuse")
    assert pol.decide_mode(forced, sim_ema=0.0) == "reuse"


def test_policy_demotes_small_sites():
    """Paper Fig. 12: small layers see little gain even at high similarity."""
    pol = ReusePolicy(min_work_flops=2**24)
    small = ReuseSiteSpec("s", 64, 64, mode="auto")
    assert pol.decide_mode(small, sim_ema=0.99) == "basic"


def test_policy_dataflow_choice():
    """Paper Sec. VI-A (3DUnet): large-input/small-output prefers input
    stationary; otherwise output stationary."""
    pol = ReusePolicy()
    assert pol.decide_dataflow(16384, 256) == "input"
    assert pol.decide_dataflow(4096, 4096) == "output"


def test_policy_dataflow_aspect_ratio_boundary():
    """The input-stationary switch is strict: exactly 4x (times the bias)
    stays output-stationary; one past it flips to input-stationary."""
    pol = ReusePolicy()  # dataflow_output_bias = 1.0
    assert pol.decide_dataflow(4 * 256, 256) == "output"
    assert pol.decide_dataflow(4 * 256 + 1, 256) == "input"
    # the bias scales the boundary
    biased = ReusePolicy(dataflow_output_bias=2.0)
    assert biased.decide_dataflow(8 * 256, 256) == "output"
    assert biased.decide_dataflow(8 * 256 + 1, 256) == "input"


def test_policy_per_site_tunables_override_globals():
    pol = ReusePolicy(
        sim_threshold=0.5, min_work_flops=1000,
        site_tunables={"special": SiteTunables(sim_threshold=0.1,
                                               min_work_flops=10.0,
                                               block_k=64)},
    )
    plain = ReuseSiteSpec("plain", 64, 64, mode="auto")     # work 8192
    special = ReuseSiteSpec("special", 64, 64, mode="auto")
    # plain follows the globals: work 8192 >= 1000, threshold 0.5
    assert pol.decide_mode(plain, sim_ema=0.3) == "basic"
    # special's tuned threshold (0.1) admits the same similarity
    assert pol.decide_mode(special, sim_ema=0.3) == "reuse"
    assert pol.resolve_block_k("special", 256) == 64
    assert pol.resolve_block_k("plain", 256) == 256


def test_tuned_block_k_reaches_site_spec_and_kernel_dispatch(rng):
    """A tuned block_k must land in the registered spec (which is what
    reuse_linear hands the kernels) and still produce the exact output."""
    pol = ReusePolicy(site_tunables={"site": SiteTunables(block_k=64)})
    eng = ReuseEngine(policy=pol)
    eng.register("site", 256, 128)          # caller default block_k=256
    assert eng.sites["site"].block_k == 64  # tunable wins
    cache = eng.init_cache(batch=4)
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    out, entry, _ = eng.apply("site", x, w, None, cache["site"])
    # vs a default-geometry engine: same math, different tiling
    eng2 = ReuseEngine()
    eng2.register("site", 256, 128)
    out2, _, _ = eng2.apply("site", x, w, None, eng2.init_cache(4)["site"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
    # finer tiles -> more tiles on the grid: 4 K-blocks instead of 1
    assert int(entry["sensor"]["computed_tiles"]) == 4


def test_refresh_modes_roundtrip(rng):
    eng = ReuseEngine(policy=ReusePolicy(sim_threshold=0.5,
                                         min_work_flops=1000))
    eng.register("site", 512, 512)
    cache = eng.init_cache(batch=4)
    assert eng.site_mode(cache, "site") == "reuse"
    cache["site"]["sim_ema"] = jnp.full((4,), 0.1, jnp.float32)
    assert eng.refresh_modes(cache) == {}  # mode flips never retrace
    assert eng.site_mode(cache, "site") == "basic"
    assert eng.last_mode_events == [{
        "site": "site", "layer": None, "before": "reuse", "after": "basic",
        "sim_ema": pytest.approx(0.1),
    }]
    # immediately wanting back up is vetoed by the flip cooldown ...
    cache["site"]["sim_ema"] = jnp.full((4,), 0.9, jnp.float32)
    eng.refresh_modes(cache)
    assert eng.last_mode_events == []
    assert eng.site_mode(cache, "site") == "basic"
    assert int(jnp.max(cache["site"]["sensor"]["suppressed_flips"])) == 1
    # ... and allowed once the cooldown has drained
    eng.refresh_modes(cache)
    assert eng.site_mode(cache, "site") == "reuse"
    assert [e["after"] for e in eng.last_mode_events] == ["reuse"]


def test_refresh_modes_hysteresis_band_blocks_marginal_flips():
    """Similarity hovering just inside the hysteresis band must not flip the
    mode at all (no decision churn) — the decision is sticky around the
    threshold by +/- hysteresis_margin."""
    eng = ReuseEngine(policy=ReusePolicy(sim_threshold=0.5,
                                         min_work_flops=1000,
                                         hysteresis_margin=0.1))
    eng.register("site", 512, 512)
    cache = eng.init_cache(batch=4)
    assert eng.site_mode(cache, "site") == "reuse"
    # below threshold but inside the band: stays in reuse, not even suppressed
    cache["site"]["sim_ema"] = jnp.full((4,), 0.45, jnp.float32)
    eng.refresh_modes(cache)
    assert eng.last_mode_events == []
    assert eng.site_mode(cache, "site") == "reuse"
    assert int(jnp.max(cache["site"]["sensor"]["suppressed_flips"])) == 0
    # clearly below the band: demotes
    cache["site"]["sim_ema"] = jnp.full((4,), 0.3, jnp.float32)
    eng.refresh_modes(cache)
    assert eng.site_mode(cache, "site") == "basic"
    # just above threshold but inside the band: stays basic (drain the flip
    # cooldown first with a neutral pass to isolate the band)
    eng.refresh_modes(cache)
    cache["site"]["sim_ema"] = jnp.full((4,), 0.55, jnp.float32)
    eng.refresh_modes(cache)
    assert eng.last_mode_events == []
    assert eng.site_mode(cache, "site") == "basic"


def test_decide_exec_path_break_even_and_impl():
    """Above the break-even skip rate the compacted tier wins ("ragged" on
    Pallas, "compact" on jnp); below it the masked walk is cheaper; a
    single-K-tile site has nothing to compact."""
    pol = ReusePolicy()
    spec = ReuseSiteSpec("s", 1024, 512, block_k=256)  # gk = 4
    assert pol.decide_exec_path(spec, 0.8, impl="jnp") == "compact"
    assert pol.decide_exec_path(spec, 0.8, impl="pallas") == "ragged"
    assert pol.decide_exec_path(spec, 0.8, impl="pallas_interpret") == "ragged"
    assert pol.decide_exec_path(spec, 0.1, impl="jnp") == "dense"
    assert pol.decide_exec_path(spec, 0.1, impl="pallas") == "kernel"
    tiny = ReuseSiteSpec("t", 256, 512, block_k=256)   # gk = 1
    assert pol.decide_exec_path(tiny, 0.9, impl="pallas") == "kernel"
    # a tuned exec_path pins the decision regardless of the measurement
    pinned = ReusePolicy(site_tunables={"s": SiteTunables(exec_path="kernel")})
    assert pinned.decide_exec_path(spec, 0.9, impl="pallas") == "kernel"


def test_site_tunables_rejects_unknown_exec_path():
    """A typo'd tuned table must fail at load/fit time, not inside the
    traced serve step."""
    with pytest.raises(ValueError, match="exec_path"):
        SiteTunables(exec_path="raged")


def test_ragged_budget_clamps():
    assert ReusePolicy.ragged_budget(8, 0.875) == 2   # ceil(8*.125*1.25)
    assert ReusePolicy.ragged_budget(8, 0.0) == 8
    assert ReusePolicy.ragged_budget(8, 1.0) == 1
    assert ReusePolicy.ragged_budget(1, 0.5) == 1


@pytest.mark.parametrize("exec_path,impl", [
    ("compact", "jnp"), ("dense", "jnp"),
    ("ragged", "pallas_interpret"), ("kernel", "pallas_interpret"),
])
def test_tuned_exec_path_reaches_spec_and_dispatch(rng, exec_path, impl):
    """A tuned exec_path must land in the registered spec and every substrate
    must produce the same output as the default dispatch."""
    pol = ReusePolicy(site_tunables={
        "site": SiteTunables(exec_path=exec_path, max_active_k=1)})
    eng = ReuseEngine(policy=pol, impl=impl)
    eng.register("site", 512, 128)
    assert eng.sites["site"].exec_path == exec_path
    assert eng.sites["site"].max_active_k == 1
    cache = eng.init_cache(batch=4)
    w = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    eng2 = ReuseEngine()  # default: exec_path auto -> jnp dense
    eng2.register("site", 512, 128)
    cache2 = eng2.init_cache(4)
    x = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    for _ in range(2):  # step 2 exercises the actual skip machinery
        out, cache["site"], _ = eng.apply("site", x, w, None, cache["site"])
        out2, cache2["site"], _ = eng2.apply("site", x, w, None, cache2["site"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                                   rtol=1e-5, atol=1e-5)


def test_compacted_tier_saves_measured_grid_steps(rng):
    """The sensor's grid_steps counter must show the compacted tier walking
    fewer steps than the masked kernel on a high-skip stream — and the
    cold-start overflow falling back to the full extent."""
    pol = ReusePolicy(site_tunables={
        "site": SiteTunables(exec_path="ragged", max_active_k=1)})
    eng = ReuseEngine(policy=pol, impl="pallas_interpret")
    spec = eng.register("site", 512, 128)
    gm = -(-4 // spec.block_m)
    gk = -(-512 // spec.block_k)
    gn = -(-128 // spec.block_n)
    cache = eng.init_cache(batch=4)
    w = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    # step 1: cold start, everything computed -> budget 1 overflows -> full gk
    _, entry, _ = eng.apply("site", x, w, None, cache["site"])
    assert float(entry["sensor"]["grid_steps"]) == gm * gk * gn
    # step 2: identical input, all tiles skip -> budgeted extent only
    _, entry, st = eng.apply("site", x, w, None, entry)
    assert float(st.skip_fraction) == 1.0
    assert float(entry["sensor"]["grid_steps"]) == gm * gk * gn + gm * 1 * gn


def test_refresh_exec_paths_promotes_measured_high_skip(rng):
    """A site whose measured stream turns out highly skippable is promoted
    onto the compacted tier by the host-side refresh (with a budget derived
    from the measured occupancy), and the change is reported for retrace."""
    eng = ReuseEngine(policy=ReusePolicy(min_work_flops=1000))
    eng.register("site", 512, 128)          # gk = 2 at block_k 256
    cache = eng.init_cache(batch=4)
    w = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    entry = cache["site"]
    for _ in range(4):  # identical input -> measured skip rate -> 1 as steps grow
        _, entry, _ = eng.apply("site", x, w, None, entry)
    cache["site"] = entry
    changed = eng.refresh_modes(cache)
    assert changed.get("site") == "exec:compact"
    spec = eng.sites["site"]
    assert spec.exec_path == "compact"
    assert spec.max_active_k == 1            # 75% skip over 4 steps, gk=2
    # a second refresh at the same operating point is a no-op (no churn)
    assert eng.refresh_exec_paths(cache) == {}


def test_stacked_cache_shapes():
    eng = ReuseEngine()
    eng.register("site", 128, 256, n_layers=6)
    cache = eng.init_cache(batch=4)
    assert cache["site"]["prev_q"].shape == (6, 4, 128)
    assert cache["site"]["prev_out"].shape == (6, 4, 256)


def test_scheduler_completes_all_requests(rng):
    """Pure-logic batcher test with a fake model."""
    def prefill_fn(prompt, slot):
        return int(prompt[0, -1]) % 100

    def decode_fn(tokens):
        return (tokens + 1) % 100

    b = ContinuousBatcher(batch_slots=3, prefill_fn=prefill_fn,
                          decode_fn=decode_fn, max_steps=200)
    for i in range(7):
        b.submit(Request(rid=i,
                         prompt=np.asarray([i, i + 1], np.int32),
                         max_new_tokens=5))
    done = b.run()
    assert len(done) == 7
    for req in done:
        assert len(req.output) == 5
        # deterministic fake model: strictly incrementing tokens
        for a, c in zip(req.output, req.output[1:]):
            assert c == (a + 1) % 100


def test_scheduler_affinity_places_by_predicted_similarity():
    """With a slot_sim_fn, admission matches requests to the free slot whose
    lane similarity history is closest to the request's prediction."""
    lane_sim = {0: 0.9, 1: 0.1, 2: 0.5}

    def prefill_fn(prompt, slot):
        return 1

    def decode_fn(tokens):
        return tokens + 1

    b = ContinuousBatcher(
        batch_slots=3, prefill_fn=prefill_fn, decode_fn=decode_fn,
        max_steps=50, slot_sim_fn=lambda s: lane_sim[s],
    )
    b.submit(Request(rid=0, prompt=np.asarray([1], np.int32),
                     max_new_tokens=2, predicted_sim=0.15))
    b.submit(Request(rid=1, prompt=np.asarray([2], np.int32),
                     max_new_tokens=2, predicted_sim=0.85))
    b.submit(Request(rid=2, prompt=np.asarray([3], np.int32),
                     max_new_tokens=2))                   # no prediction
    done = {r.rid: r for r in b.run()}
    assert done[0].slot == 1     # low-sim stream -> low-sim lane
    assert done[1].slot == 0     # sticky stream -> high-sim lane
    assert done[2].slot == 2     # unpredicted -> the remaining (first-free) slot
    assert b.stats["affinity_placements"] == 2


def test_scheduler_affinity_falls_back_to_first_free():
    """No slot_sim_fn (or no prediction) keeps the original first-free order."""
    def prefill_fn(prompt, slot):
        return 1

    b = ContinuousBatcher(batch_slots=2, prefill_fn=prefill_fn,
                          decode_fn=lambda t: t + 1, max_steps=20)
    b.submit(Request(rid=0, prompt=np.asarray([1], np.int32),
                     max_new_tokens=2, predicted_sim=0.9))
    b.submit(Request(rid=1, prompt=np.asarray([2], np.int32),
                     max_new_tokens=2))
    done = {r.rid: r for r in b.run()}
    assert {done[0].slot, done[1].slot} == {0, 1}
    assert b.stats["affinity_placements"] == 0


def test_reset_slot_zeroes_one_lane():
    eng = ReuseEngine()
    eng.register("site", 64, 32, n_layers=2)
    cache = eng.init_cache(batch=3)
    cache["site"]["prev_q"] = jnp.ones_like(cache["site"]["prev_q"])
    cache["site"]["prev_out"] = jnp.ones_like(cache["site"]["prev_out"])
    out = reset_slot(cache, slot=1)
    pq = np.asarray(out["site"]["prev_q"])
    assert np.all(pq[:, 1, :] == 0)
    assert np.all(pq[:, 0, :] == 1) and np.all(pq[:, 2, :] == 1)
