"""Per-(slot, expert) reuse extension: exactness + skip accounting.

Central invariants:
  1. lane output == quantized dense expert output, regardless of expert
     switches (cold-start identity per lane);
  2. a slot that keeps its expert AND its input codes skips everything
     (wi_skip/wo_skip -> 1 for that slot);
  3. an expert switch never corrupts the output (it just can't skip).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.expert_reuse import (
    init_expert_reuse_cache,
    layer_slice,
    moe_reuse_forward,
)
from repro.models import moe
from repro.models.layers import apply_norm
from repro.quant import dequantize_int8, quantize_int8


@pytest.fixture
def setup():
    cfg = dataclasses.replace(ARCHS["mixtral-8x7b"].reduced(), top_k=1)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    return cfg, p, rng


def dense_reference(p, cfg, x, scale, act_scale):
    """Quantized-at-both-sites dense top-1 MoE (what reuse must equal)."""
    b, _, d = x.shape
    h = apply_norm(p["norm"], x, cfg.norm_eps).reshape(b, d)
    logits = h.astype(jnp.float32) @ p["router"]
    top_e = jnp.argmax(logits, axis=-1)
    gate = jax.nn.softmax(logits, axis=-1)[jnp.arange(b), top_e]
    hq = dequantize_int8(quantize_int8(h, scale), scale)
    hi = jnp.einsum("bd,bdf->bf", hq, p["wi"][top_e].astype(jnp.float32))
    g, u = jnp.split(hi, 2, axis=-1)
    act = jax.nn.silu(g) * u
    actq = dequantize_int8(quantize_int8(act, act_scale), act_scale)
    out = jnp.einsum("bf,bfd->bd", actq, p["wo"][top_e].astype(jnp.float32))
    return (out * gate[:, None]).reshape(b, 1, d), top_e


def test_lane_exactness_over_steps_with_switches(setup):
    cfg, p, rng = setup
    b = 4
    cache = layer_slice(init_expert_reuse_cache(cfg, b), 0)
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    for step in range(8):
        # drift inputs so routing switches sometimes
        x = x + 0.3 * jnp.asarray(
            rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
        out, cache, stats = moe_reuse_forward(p, cfg, x, cache, block_k=32)
        ref, top_e = dense_reference(p, cfg, x, cache["scale"],
                                     cache["act_scale"])
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-3, atol=5e-3,
        )


def test_identical_revisit_skips_everything(setup):
    cfg, p, rng = setup
    b = 4
    cache = layer_slice(init_expert_reuse_cache(cfg, b), 0)
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    _, cache, s0 = moe_reuse_forward(p, cfg, x, cache, block_k=32)
    # identical input => same expert, zero deltas at both sites
    out, cache, s1 = moe_reuse_forward(p, cfg, x, cache, block_k=32)
    assert float(s1.sticky_fraction) == 1.0
    assert float(s1.wi_skip) == 1.0
    assert float(s1.wo_skip) == 1.0
    ref, _ = dense_reference(p, cfg, x, cache["scale"], cache["act_scale"])
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-3, atol=5e-3,
    )


def test_expert_switch_is_cold_but_correct(setup):
    cfg, p, rng = setup
    b = 2
    cache = layer_slice(init_expert_reuse_cache(cfg, b), 0)
    x1 = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    _, cache, _ = moe_reuse_forward(p, cfg, x1, cache, block_k=32)
    # violently different input: near-certain expert switch
    x2 = -3.0 * x1 + jnp.asarray(
        rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    out, cache, stats = moe_reuse_forward(p, cfg, x2, cache, block_k=32)
    ref, _ = dense_reference(p, cfg, x2, cache["scale"], cache["act_scale"])
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-3, atol=5e-3,
    )


def test_skip_fraction_tracks_similarity(setup):
    cfg, p, rng = setup
    b = 8
    cache = layer_slice(init_expert_reuse_cache(cfg, b), 0)
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    _, cache, _ = moe_reuse_forward(p, cfg, x, cache, block_k=32)
    # RMSNorm couples channels: perturbing ANY channel of a token shifts
    # every normalized channel, so partial-channel similarity does not
    # survive the norm — the harvestable structure at normed sites is
    # per-TOKEN (a slot whose whole input is unchanged skips all its row
    # tiles). Mixed batch: slots 0..3 change, 4..7 revisit identically —
    # the skip fraction must be the unchanged-slot fraction.
    xv = np.asarray(x).copy()
    xv[:4] += 0.2 * rng.normal(size=(4, 1, cfg.d_model))
    out, cache, stats = moe_reuse_forward(
        p, cfg, jnp.asarray(xv), cache, block_k=32)
    assert abs(float(stats.wi_skip) - 0.5) < 0.15, float(stats.wi_skip)
    assert abs(float(stats.sticky_fraction) - 0.5) < 0.15
    # and the output still matches the quantized dense reference
    ref, _ = dense_reference(p, cfg, jnp.asarray(xv), cache["scale"],
                             cache["act_scale"])
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-3, atol=5e-3,
    )
