"""repro.obs fleet plane — stream tailing, aggregation, health, SLO watch.

Load-bearing properties:

* `tail_jsonl` consumes only newline-terminated rows, holds a partial tail
  back for the next poll, forgives exactly one torn FINAL line (counted)
  when the writer is known dead, and raises on mid-file corruption;
* a `FleetAggregator` result is insensitive to poll interleaving across
  replica tails (host clock skew / lagging readers reorder nothing that
  matters: every windowed statistic is keyed to its own replica's row
  sequence);
* duplicate run ids across replicas are rejected (a copied obs dir must not
  silently double-count);
* a single-replica fleet rollup is BITWISE-equal to the replica's own
  SensorReport numbers (same formulas, same guards, same order);
* ReplicaHealth counts quarantined lanes / stalls / trips from the journal
  stream, and the SLO watcher attributes skip collapse, p95 burn, and
  quarantine spikes to exactly the offending replica — clean replicas stay
  alert-free.
"""

import json
import os

import pytest

from repro.obs import events
from repro.obs.fleet import FleetAggregator, ReplicaHealth
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import SLOConfig, SLOWatcher, load_alerts
from repro.obs.stream import (
    ReplicaStream,
    TailCursor,
    discover_replica_streams,
    tail_jsonl,
)


@pytest.fixture(autouse=True)
def _clean_ids():
    events.clear_ids()
    yield
    events.clear_ids()


# --------------------------------------------------------- synthetic streams

def _model_row(skipped, computed, *, steps=1, trips=0, run="run-a",
               replica=None, hit=0.5):
    total = skipped + computed
    row = {
        "kind": "model", "schema_version": 6, "steps": steps,
        "skipped_macs": float(skipped), "computed_macs": float(computed),
        "total_macs": float(total),
        "mac_skip_rate": skipped / max(total, 1e-9),
        "skipped_tiles": float(skipped) / 64.0,
        "computed_tiles": float(computed) / 64.0,
        "total_tiles": float(total) / 64.0,
        "tile_skip_rate": skipped / max(total, 1e-9),
        "skipped_weight_bytes": float(skipped) * 2,
        "total_weight_bytes": float(total) * 2,
        "weight_byte_skip_rate": skipped / max(total, 1e-9),
        "grid_steps": float(computed) / 64.0,
        "grid_step_skip_rate": 0.0,
        "hit_rate": hit, "sentinel_trips": trips, "n_sites": 1,
    }
    trace = {"run": run}
    if replica is not None:
        trace["replica"] = replica
    row["trace"] = trace
    return row


def _site_row(site, skipped, computed, *, run="run-a", replica=None):
    total = skipped + computed
    row = {
        "kind": "site", "schema_version": 6, "site": site, "layer": None,
        "steps": 1, "mode": "coarse", "exec_path": "compact",
        "skipped_macs": float(skipped), "computed_macs": float(computed),
        "mac_skip_rate": skipped / max(total, 1e-9),
        "tile_skip_rate": skipped / max(total, 1e-9),
        "grid_step_skip_rate": 0.0, "hit_rate": 0.5,
        "total_tiles": 8, "out_features": 64, "block_n": 32,
        "sentinel_trips": 0,
    }
    trace = {"run": run}
    if replica is not None:
        trace["replica"] = replica
    row["trace"] = trace
    return row


def _append(path, rows):
    with open(path, "a") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")


def _mk_replica_dir(tmp_path, name):
    d = tmp_path / f"replica-{name}"
    d.mkdir(exist_ok=True)
    return d


# ------------------------------------------------------------------- tailing

def test_tail_jsonl_holds_back_partial_line(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text(json.dumps({"a": 1}) + "\n" + '{"par')
    cur = TailCursor()
    assert tail_jsonl(str(p), cur) == [{"a": 1}]
    assert cur.rows == 1 and cur.torn == 0
    # the partial line was NOT consumed: finishing it yields the row
    with open(p, "a") as f:
        f.write('tial": 2}\n')
    assert tail_jsonl(str(p), cur) == [{"partial": 2}]
    assert cur.rows == 2 and cur.torn == 0
    # nothing new: empty poll
    assert tail_jsonl(str(p), cur) == []


def test_tail_jsonl_final_torn_line_forgiven_and_counted(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text(json.dumps({"a": 1}) + "\n" + '{"torn')
    cur = TailCursor()
    rows = tail_jsonl(str(p), cur, final=True)
    assert rows == [{"a": 1}]
    assert cur.torn == 1
    # torn newline-terminated last line is forgiven too
    p2 = tmp_path / "s2.jsonl"
    p2.write_text(json.dumps({"a": 1}) + "\n" + '{"bad\n')
    cur2 = TailCursor()
    assert tail_jsonl(str(p2), cur2, final=True) == [{"a": 1}]
    assert cur2.torn == 1


def test_tail_jsonl_midfile_corruption_raises(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text('{"bad\n' + json.dumps({"a": 1}) + "\n")
    with pytest.raises(ValueError, match="corruption"):
        tail_jsonl(str(p), TailCursor(), final=True)
    # non-final polls refuse it too (rows follow, so it is not a tail)
    with pytest.raises(ValueError, match="corruption"):
        tail_jsonl(str(p), TailCursor())


def test_replica_stream_rejects_conflicting_replica_stamp(tmp_path):
    d = _mk_replica_dir(tmp_path, "r0")
    _append(d / "sensor.jsonl", [_model_row(1, 1, replica="r9")])
    stream = ReplicaStream(str(d))
    assert stream.replica == "r0"  # replica- prefix stripped from basename
    with pytest.raises(ValueError, match="replica"):
        stream.poll()


def test_discover_replica_streams(tmp_path):
    for name in ("r1", "r0"):
        d = _mk_replica_dir(tmp_path, name)
        _append(d / "sensor.jsonl", [_model_row(1, 1)])
    (tmp_path / "not-a-replica").mkdir()
    (tmp_path / "fleet_report.json").write_text("{}")
    streams = discover_replica_streams(str(tmp_path))
    assert [s.replica for s in streams] == ["r0", "r1"]


# --------------------------------------------------------------- aggregation

def test_duplicate_run_ids_across_replicas_rejected(tmp_path):
    for name in ("r0", "r1"):
        d = _mk_replica_dir(tmp_path, name)
        _append(d / "sensor.jsonl",
                [_model_row(1, 1, run="same-run", replica=name)])
    agg = FleetAggregator.from_fleet_dir(str(tmp_path))
    with pytest.raises(ValueError, match="unique fleet-wide"):
        agg.poll()


def test_torn_tail_in_one_replica_tolerated_and_counted(tmp_path):
    d0 = _mk_replica_dir(tmp_path, "r0")
    _append(d0 / "sensor.jsonl",
            [_model_row(50, 50, run="run-0", replica="r0")])
    d1 = _mk_replica_dir(tmp_path, "r1")
    _append(d1 / "sensor.jsonl",
            [_model_row(40, 60, run="run-1", replica="r1")])
    with open(d1 / "sensor.jsonl", "a") as f:
        f.write('{"died mid-app')  # replica r1 crashed mid-append
    agg = FleetAggregator.from_fleet_dir(str(tmp_path))
    agg.poll(final=True)  # not fatal
    assert agg.health("r0").torn_lines == 0
    assert agg.health("r1").torn_lines == 1
    rep = agg.fleet_report()
    assert rep["n_replicas"] == 2
    assert rep["fleet"]["torn_lines"] == 1
    # both replicas' consumed rows still aggregate
    assert {r["replica"]: r["run"] for r in rep["per_replica"]} == \
        {"r0": "run-0", "r1": "run-1"}


def test_out_of_order_polls_equivalent_to_one_shot(tmp_path):
    """Cross-replica arrival order (clock skew, lagging tails) must not
    change the rollup: replica B fully lands before replica A in one
    aggregation, interleaved window-by-window in the other."""
    windows = [
        (10, 90), (20, 80), (35, 65), (50, 50),
    ]

    def _write_all(root):
        for name in ("ra", "rb"):
            d = _mk_replica_dir(root, name)
            cum_s = cum_c = 0.0
            for i, (s, c) in enumerate(windows):
                cum_s += s
                cum_c += c
                _append(d / "sensor.jsonl", [
                    _model_row(cum_s, cum_c, steps=i + 1,
                               run=f"run-{name}", replica=name),
                    _site_row("site0", cum_s, cum_c,
                              run=f"run-{name}", replica=name),
                ])

    one_shot = tmp_path / "one"
    one_shot.mkdir()
    _write_all(one_shot)
    agg1 = FleetAggregator.from_fleet_dir(str(one_shot))
    agg1.poll(final=True)

    skewed = tmp_path / "skewed"
    skewed.mkdir()
    da = _mk_replica_dir(skewed, "ra")
    db = _mk_replica_dir(skewed, "rb")
    agg2 = FleetAggregator(
        [ReplicaStream(str(da)), ReplicaStream(str(db))])
    # replica B lands entirely first; A trickles in one window per poll
    cum = {"ra": [0.0, 0.0], "rb": [0.0, 0.0]}

    def _one_window(d, name, idx):
        s, c = windows[idx]
        cum[name][0] += s
        cum[name][1] += c
        _append(d / "sensor.jsonl", [
            _model_row(cum[name][0], cum[name][1], steps=idx + 1,
                       run=f"run-{name}", replica=name),
            _site_row("site0", cum[name][0], cum[name][1],
                      run=f"run-{name}", replica=name),
        ])

    for i in range(len(windows)):
        _one_window(db, "rb", i)
    agg2.poll()
    for i in range(len(windows)):
        _one_window(da, "ra", i)
        agg2.poll()
    agg2.poll(final=True)

    assert json.dumps(agg1.fleet_report(), sort_keys=True) == \
        json.dumps(agg2.fleet_report(), sort_keys=True)


def test_single_replica_rollup_bitwise_equals_sensor_report(tmp_path):
    from repro.sensor.cost_model import sensor_energy
    from repro.sensor.runner import run_measured_decode

    md = run_measured_decode("qwen3-32b", steps=8, batch=2, correlation=0.9)
    report = md.report
    d = _mk_replica_dir(tmp_path, "solo")
    with events.context(run="run-solo", replica="solo"):
        report.write_jsonl(str(d / "sensor.jsonl"))
    agg = FleetAggregator.from_fleet_dir(str(tmp_path))
    agg.poll(final=True)
    fleet_rep = agg.fleet_report()
    assert fleet_rep["n_replicas"] == 1
    solo = fleet_rep["per_replica"][0]
    model = report.model
    # per-replica rollup carries the replica's own model numbers verbatim
    for key in ("mac_skip_rate", "tile_skip_rate", "weight_byte_skip_rate",
                "grid_step_skip_rate", "hit_rate"):
        assert solo[key] == model[key], key
    # fleet-level rates are RECOMPUTED from summed counters with
    # build_report's exact formulas — bitwise-equal for one replica
    f = fleet_rep["fleet"]
    for key in ("mac_skip_rate", "tile_skip_rate", "weight_byte_skip_rate",
                "grid_step_skip_rate", "hit_rate"):
        assert f[key] == model[key], key
    energy = sensor_energy(report)
    for key in ("baseline_dynamic_j", "measured_dynamic_j",
                "saved_dynamic_j", "dynamic_reduction"):
        assert solo["energy"][key] == energy[key], key
        assert f["energy"][key] == energy[key], key


# -------------------------------------------------------------------- health

def _quarantine_row(site, layer, before, after, *, run="run-a", replica=None):
    row = {"kind": "decision", "decision_kind": "quarantine",
           "field": "state", "site": site, "layer": layer,
           "before": before, "after": after, "step": 12,
           "schema_version": 4}
    trace = {"run": run}
    if replica is not None:
        trace["replica"] = replica
    row["trace"] = trace
    return row


def test_replica_health_from_journal_stream(tmp_path):
    d = _mk_replica_dir(tmp_path, "r0")
    _append(d / "sensor.jsonl",
            [_model_row(50, 50, steps=6, trips=2, run="run-0",
                        replica="r0")])
    _append(d / "journal.jsonl", [
        _quarantine_row("mlp_in", 0, "active", "quarantined",
                        run="run-0", replica="r0"),
        _quarantine_row("attn_qkv", 1, "active", "quarantined",
                        run="run-0", replica="r0"),
        _quarantine_row("attn_qkv", 1, "quarantined", "probation",
                        run="run-0", replica="r0"),
        {"kind": "decision", "decision_kind": "quarantine",
         "field": "stall_windows", "site": "", "layer": None,
         "before": 0, "after": 1, "step": 18, "schema_version": 4,
         "trace": {"run": "run-0", "replica": "r0"}},
    ])
    agg = FleetAggregator.from_fleet_dir(str(tmp_path))
    agg.poll(final=True)
    h = agg.health("r0")
    assert isinstance(h, ReplicaHealth)
    assert h.quarantined_lanes == 1       # attn_qkv@1 moved on to probation
    assert h.sentinel_trips == 2          # from the sensor model row
    assert h.stall_windows == 1
    assert h.run == "run-0"
    assert h.status == "quarantined"
    assert h.to_dict()["status"] == "quarantined"


def test_replica_health_quarantine_gauge_fallback(tmp_path):
    # journal-less stream (plain serve --obs-dir): the guard gauge carries
    # the quarantined-lane count instead
    d = _mk_replica_dir(tmp_path, "r0")
    _append(d / "sensor.jsonl", [_model_row(10, 90, run="run-0",
                                            replica="r0")])
    _append(d / "metrics.jsonl", [
        {"name": "guard_quarantined_lanes", "labels": {}, "type": "gauge",
         "value": 3.0, "snap": 1, "trace": {"run": "run-0",
                                            "replica": "r0"}}])
    agg = FleetAggregator.from_fleet_dir(str(tmp_path))
    agg.poll(final=True)
    assert agg.health("r0").quarantined_lanes == 3


# ----------------------------------------------------------------- SLO watch

def _fleet_two(tmp_path):
    d0 = _mk_replica_dir(tmp_path, "r0")
    d1 = _mk_replica_dir(tmp_path, "r1")
    agg = FleetAggregator([ReplicaStream(str(d0)), ReplicaStream(str(d1))])
    return d0, d1, agg


def test_slo_skip_collapse_attributes_injected_replica(tmp_path):
    d0, d1, agg = _fleet_two(tmp_path)
    registry = MetricsRegistry()
    alerts_path = tmp_path / "alerts.jsonl"
    watcher = SLOWatcher(
        agg, SLOConfig(collapse_frac=0.6, collapse_consecutive=2),
        registry=registry, alerts_path=str(alerts_path))
    # r0 steady at 0.5 windowed skip; r1 matches, then collapses to 0
    r0_windows = [(50, 50)] * 8
    r1_windows = [(50, 50)] * 4 + [(0, 100)] * 4
    cum = {"r0": [0.0, 0.0], "r1": [0.0, 0.0]}
    for i in range(8):
        for name, d, (s, c) in (("r0", d0, r0_windows[i]),
                                ("r1", d1, r1_windows[i])):
            cum[name][0] += s
            cum[name][1] += c
            _append(d / "sensor.jsonl",
                    [_model_row(cum[name][0], cum[name][1], steps=i + 1,
                                run=f"run-{name}", replica=name)])
        agg.poll()
        watcher.evaluate()
    kinds = [(a["alert_kind"], a["replica"], a["site"])
             for a in watcher.alerts]
    # exactly one collapse alert, replica-level, on r1; r0 stays alert-free
    assert kinds == [("skip_collapse", "r1", "")]
    assert agg.health("r0").alerts == 0
    assert agg.health("r1").alerts == 1
    a = watcher.alerts[0]
    assert a["value"] < 0.6 * a["baseline"]
    assert a["run"] == "run-r1"
    # counted on the registry, attributed by label
    assert registry.counter("fleet_alerts_total", alert="skip_collapse",
                            replica="r1").value == 1.0
    # persisted journal-style, loadable with torn-tail forgiveness
    assert load_alerts(str(alerts_path)) == watcher.alerts
    with open(alerts_path, "a") as f:
        f.write('{"torn')
    assert load_alerts(str(alerts_path)) == watcher.alerts


def test_slo_collapse_ignores_warmup_and_rising_skip(tmp_path):
    d0, _, agg = _fleet_two(tmp_path)
    watcher = SLOWatcher(agg, SLOConfig())
    # skip RISES from zero (warm-up): baseline below current, and the early
    # windows are under min_baseline_skip — no alert either way
    cum = [0.0, 0.0]
    for i, (s, c) in enumerate([(0, 100), (1, 99), (10, 90), (30, 70),
                                (50, 50), (50, 50)]):
        cum[0] += s
        cum[1] += c
        _append(d0 / "sensor.jsonl",
                [_model_row(cum[0], cum[1], steps=i + 1, run="run-r0",
                            replica="r0")])
        agg.poll()
        watcher.evaluate()
    assert watcher.alerts == []


def test_slo_per_site_collapse_names_site(tmp_path):
    d0, _, agg = _fleet_two(tmp_path)
    watcher = SLOWatcher(
        agg, SLOConfig(collapse_frac=0.6, collapse_consecutive=2))
    model_cum = [0.0, 0.0]
    site_cum = [0.0, 0.0]
    # model-level skip stays healthy; ONE site collapses (a quarantined
    # lane dents the replica total ~1/n_sites but halves its site)
    for i in range(8):
        model_cum[0] += 50
        model_cum[1] += 50
        s, c = (40, 60) if i < 4 else (0, 100)
        site_cum[0] += s
        site_cum[1] += c
        _append(d0 / "sensor.jsonl", [
            _model_row(model_cum[0], model_cum[1], steps=i + 1,
                       run="run-r0", replica="r0"),
            _site_row("attn_qkv", site_cum[0], site_cum[1],
                      run="run-r0", replica="r0"),
        ])
        agg.poll()
        watcher.evaluate()
    assert [(a["alert_kind"], a["replica"], a["site"])
            for a in watcher.alerts] == [("skip_collapse", "r0",
                                          "attn_qkv")]


def test_slo_quarantine_spike(tmp_path):
    d0, d1, agg = _fleet_two(tmp_path)
    watcher = SLOWatcher(agg, SLOConfig())
    _append(d0 / "journal.jsonl",
            [_quarantine_row("mlp_in", 0, "active", "quarantined",
                             run="run-r0", replica="r0")])
    agg.poll()
    alerts = watcher.evaluate()
    assert [(a["alert_kind"], a["replica"]) for a in alerts] == \
        [("quarantine_spike", "r0")]
    # no re-alert while the count holds
    assert watcher.evaluate() == []
    # recovery then a NEW spike alerts again
    _append(d0 / "journal.jsonl", [
        _quarantine_row("mlp_in", 0, "quarantined", "active",
                        run="run-r0", replica="r0")])
    agg.poll()
    assert watcher.evaluate() == []
    _append(d0 / "journal.jsonl", [
        _quarantine_row("attn_qkv", 1, "active", "quarantined",
                        run="run-r0", replica="r0")])
    agg.poll()
    assert [a["alert_kind"] for a in watcher.evaluate()] == \
        ["quarantine_spike"]


def test_slo_p95_burn(tmp_path):
    d0, d1, agg = _fleet_two(tmp_path)
    watcher = SLOWatcher(agg, SLOConfig(p95_target_s=0.010, p95_min_count=5))
    spans = [{"name": "serve_step", "span_id": i + 1, "parent_id": 0,
              "dur_s": 0.002, "trace": {"run": "run-r0", "replica": "r0"}}
             for i in range(6)]
    _append(d0 / "spans.jsonl", spans)
    agg.poll()
    assert watcher.evaluate() == []  # under target
    slow = [{"name": "serve_step", "span_id": 10 + i, "parent_id": 0,
             "dur_s": 0.050, "trace": {"run": "run-r0", "replica": "r0"}}
            for i in range(10)]
    _append(d0 / "spans.jsonl", slow)
    agg.poll()
    alerts = watcher.evaluate()
    assert [(a["alert_kind"], a["replica"]) for a in alerts] == \
        [("p95_burn", "r0")]
    assert alerts[0]["value"] > 0.010
    # one alert per episode
    assert watcher.evaluate() == []


# ---------------------------------------------------------- exports and view

def test_export_fleet_metrics_series(tmp_path):
    from repro.obs.export import parse_prometheus, write_prometheus
    from repro.obs.fleet import export_fleet_metrics

    d0, d1, agg = _fleet_two(tmp_path)
    _append(d0 / "sensor.jsonl",
            [_model_row(50, 50, steps=4, run="run-r0", replica="r0")])
    _append(d1 / "sensor.jsonl",
            [_model_row(25, 75, steps=4, run="run-r1", replica="r1")])
    agg.poll(final=True)
    reg = MetricsRegistry()
    export_fleet_metrics(reg, agg)
    p = tmp_path / "fleet.prom"
    write_prometheus(str(p), reg)
    parsed = parse_prometheus(p.read_text())
    assert parsed["fleet_mac_skip"]['{replica="r0"}'] == pytest.approx(0.5)
    assert parsed["fleet_mac_skip"]['{replica="r1"}'] == pytest.approx(0.25)
    assert parsed["fleet_mac_skip"]['{scope="fleet"}'] == \
        pytest.approx(75 / 200)
    assert parsed["fleet_replicas"]['{scope="fleet"}'] == 2.0


def test_top_fleet_view_and_clear_errors(tmp_path, capsys):
    from repro.obs.top import main as top_main

    # missing metrics file: clear one-line error, rc 1, no traceback
    rc = top_main([str(tmp_path / "nope" / "metrics.jsonl"), "--once"])
    assert rc == 1
    err = capsys.readouterr().err
    assert "no such metrics stream" in err
    # fleet dir with no replica subdirs: same contract
    rc = top_main([str(tmp_path), "--fleet", "--once"])
    assert rc == 1
    assert "no replica obs dirs" in capsys.readouterr().err
    # a real fleet dir renders per-replica columns
    for name, skipped in (("r0", 50), ("r1", 25)):
        d = _mk_replica_dir(tmp_path, name)
        _append(d / "sensor.jsonl",
                [_model_row(skipped, 100 - skipped, steps=4,
                            run=f"run-{name}", replica=name)])
    rc = top_main([str(tmp_path), "--fleet", "--once"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "r0" in out and "r1" in out and "status" in out
    assert "run-r0" in out and "run-r1" in out
