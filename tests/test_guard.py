"""repro.guard — serving-plane fault containment (ISSUE 8 acceptance).

Load-bearing properties:

* array sentinels detect every injected corruption class (non-finite
  prev_out, sim_ema range, ctrl-lane garbage, counter-conservation breaks)
  and name the offending (site, layer, check) with measured evidence;
* the quarantine breaker contains a tripped lane the SAME control interval:
  mode pinned basic via ctrl write, poisoned state scrubbed, replayable
  journal decision; lockout drains to probation and clean windows re-admit,
  with exponential backoff on re-offense and stalls voiding probation;
* the fault injector is deterministic and replayable (named scenarios,
  `from_spec` round trip), and its at-rest targets (torn journal, corrupt
  checkpoint) drive the durable-state hardening satellites;
* chaos e2e: a NaN poisoned into a live reuse lane reaches the outputs
  (real blast radius), the controller+guard cadence quarantines it, and
  post-containment outputs are finite AND bitwise-exact vs the dense
  oracle while the journal chains quarantined→probation→active and
  replays cleanly; the same stream without injection trips nothing.
"""

import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.control import AdmissionPredictor, ControlConfig, Controller, load_journal
from repro.control.replay import replay_rows
from repro.control.report import ControlReport, Decision, DecisionJournal
from repro.core import ReuseEngine, ReusePolicy, SiteTunables
from repro.guard import (
    SCENARIOS,
    FaultInjector,
    GuardConfig,
    QuarantineBreaker,
    evaluate_snapshot,
    sentinel_lanes,
    shadow_check,
)

L, M, K, N = 2, 2, 64, 32


def _engine(mode="auto", site="stack"):
    """Stacked integer-exact site (scale 1.0: reuse telescoping is bitwise
    against the quantized dense oracle) with a permissive policy so lanes
    sit in reuse mode — the state a poisoned prev_out lane persists in."""
    policy = ReusePolicy(site_tunables={site: SiteTunables(
        sim_threshold=0.0, min_work_flops=0.0, exec_path="dense",
    )})
    eng = ReuseEngine(policy=policy)
    eng.register(site, K, N, n_layers=L, block_m=2, block_k=32, mode=mode)
    eng.sites[site] = dataclasses.replace(eng.sites[site], fixed_scale=1.0)
    return eng


def _make_step(eng, w, site="stack"):
    @jax.jit
    def step(xs, entry):
        def body(carry, sl):
            x_l, e_l = sl
            out, new_e, _ = eng.apply(site, x_l, w, None, e_l)
            return carry, (out, new_e)

        _, (outs, new_entry) = jax.lax.scan(body, 0, (xs, entry))
        return outs, new_entry

    return step


def _sticky_inputs():
    rng = np.random.default_rng(7)
    return jnp.asarray(rng.integers(-3, 4, size=(L, M, K)).astype(np.float32))


def _weights():
    rng = np.random.default_rng(8)
    return jnp.asarray(rng.integers(-2, 3, size=(K, N)).astype(np.float32))


# ------------------------------------------------------------ array sentinels

def test_sentinel_lanes_detect_each_corruption_class():
    eng = _engine()
    cache = eng.init_cache(M)
    entry = cache["stack"]

    lanes = {k: np.asarray(v) for k, v in sentinel_lanes(entry).items()}
    assert evaluate_snapshot("stack", lanes, stacked=True) == []

    # non-finite prev_out, layer 1 only
    bad = dict(entry, prev_out=entry["prev_out"].at[1, 0, 0].set(jnp.nan))
    trips = evaluate_snapshot("stack", sentinel_lanes(bad), stacked=True)
    assert [(t.layer, t.check) for t in trips] == [(1, "nonfinite_out")]
    assert "1 non-finite" in trips[0].evidence

    # sim_ema outside [0, 1] (an EMA of match fractions can't leave the range)
    bad = dict(entry, sim_ema=entry["sim_ema"].at[0, 0].set(1.5))
    trips = evaluate_snapshot("stack", sentinel_lanes(bad), stacked=True)
    assert [(t.layer, t.check) for t in trips] == [(0, "sim_range")]

    # ctrl garbage: every range check lands in the bitmask evidence
    ctrl = dict(entry["ctrl"])
    ctrl["mode_id"] = ctrl["mode_id"].at[0].set(7)
    ctrl["cooldown"] = ctrl["cooldown"].at[0].set(-3)
    ctrl["sim_threshold"] = ctrl["sim_threshold"].at[0].set(9.0)
    trips = evaluate_snapshot(
        "stack", sentinel_lanes(dict(entry, ctrl=ctrl)), stacked=True)
    assert [(t.layer, t.check) for t in trips] == [(0, "ctrl_range")]
    for name in ("mode_id", "cooldown", "sim_threshold"):
        assert name in trips[0].evidence


def test_sentinel_counter_conservation_window():
    """Δskipped + Δcomputed must equal Δsteps·gm·gk per layer; a block_k
    move (caller passes tiles_per_eval=None) invalidates one window instead
    of tripping falsely."""
    prev = {"skipped_l": np.array([4, 4]), "computed_l": np.array([0, 0]),
            "steps_l": np.array([1, 1])}
    ok = {"bad_out": np.zeros(2, np.int32), "bad_sim": np.zeros(2, np.int32),
          "skipped_l": np.array([10, 8]), "computed_l": np.array([2, 4]),
          "steps_l": np.array([3, 3])}
    assert evaluate_snapshot(
        "s", ok, stacked=True, tiles_per_eval=4, prev=prev) == []

    broken = dict(ok, skipped_l=np.array([11, 8]))  # phantom skip, layer 0
    trips = evaluate_snapshot(
        "s", broken, stacked=True, tiles_per_eval=4, prev=prev)
    assert [(t.layer, t.check) for t in trips] == [(0, "conservation")]
    assert "9 != " in trips[0].evidence and "8" in trips[0].evidence

    # geometry moved this window: the delta mixes tile units — no verdict
    assert evaluate_snapshot(
        "s", broken, stacked=True, tiles_per_eval=None, prev=prev) == []


def test_sentinel_lanes_ride_the_ctrl_snapshot():
    """Detection must not cost an extra device→host pass: the guard lanes
    arrive inside the engine's one control snapshot."""
    eng = _engine()
    cache = eng.init_cache(M)
    snap = eng.ctrl_snapshot(cache)["stack"]
    for lane in ("bad_out", "bad_sim", "ctrl_bad", "quarantine",
                 "skipped_l", "computed_l", "steps_l"):
        assert lane in snap, lane


# ------------------------------------------------------- quarantine breaker

def test_breaker_lifecycle_trip_probation_readmit_backoff():
    eng = _engine()
    cache = eng.init_cache(M)
    br = QuarantineBreaker(GuardConfig(
        quarantine_intervals=1, probation_windows=1))

    # poison layer 1, then one breaker pass: contained the same interval
    cache["stack"] = dict(
        cache["stack"],
        prev_out=cache["stack"]["prev_out"].at[1, 0, 0].set(jnp.nan))
    rep = br.step(eng, cache, step=1)
    assert rep.tripped and rep.quarantined_lanes == 1
    assert rep.frozen_sites == {"stack"}
    assert br.lane_states()[("stack", 1)] == "quarantined"
    assert eng.layer_modes(cache, "stack")[1] == "basic"
    assert int(np.asarray(cache["stack"]["ctrl"]["quarantine"])[1]) == 1
    # poisoned state scrubbed, trip counter bumped
    assert np.isfinite(np.asarray(cache["stack"]["prev_out"])).all()
    assert int(np.asarray(
        cache["stack"]["sensor"]["sentinel_trips"]).sum()) == 1
    assert eng.exec_cooldown["stack"] >= 1
    d = [x for x in rep.decisions if x.field == "state"]
    assert (d[0].before, d[0].after, d[0].layer) == ("active", "quarantined", 1)
    assert "nonfinite_out" in d[0].reason

    # lockout (1 interval) drains -> probation; site stays frozen meanwhile
    rep = br.step(eng, cache, step=2)
    assert not rep.tripped
    assert br.lane_states()[("stack", 1)] == "probation"
    assert int(np.asarray(cache["stack"]["ctrl"]["quarantine"])[1]) == 0

    # a stalled window proves nothing: probation credit is voided
    br.note_stall({"step": 2, "seconds": 0.5, "median": 0.01,
                   "action": "recommend re-shard / evict host"})
    rep = br.step(eng, cache, step=3)
    assert rep.stalled and br.stall_windows == 1
    assert br.lane_states()[("stack", 1)] == "probation"
    assert any(x.field == "stall_windows" for x in rep.decisions)

    # one clean window re-admits (probation_windows=1)
    rep = br.step(eng, cache, step=4)
    assert br.lane_states()[("stack", 1)] == "active"
    d = [x for x in rep.decisions if x.field == "state"]
    assert (d[0].before, d[0].after) == ("probation", "active")

    # re-offense: exponential backoff doubles the lockout
    cache["stack"] = dict(
        cache["stack"],
        prev_out=cache["stack"]["prev_out"].at[1, 0, 0].set(jnp.inf))
    rep = br.step(eng, cache, step=5)
    assert br.lane_states()[("stack", 1)] == "quarantined"
    assert br._lanes[("stack", 1)].lockout == 2
    assert int(np.asarray(cache["stack"]["ctrl"]["quarantine"])[1]) == 2
    d = [x for x in rep.decisions if x.field == "state"]
    assert "offense #2" in d[0].reason and "lockout 2" in d[0].reason


def test_breaker_rebuilds_garbage_ctrl_lanes_from_policy():
    """A ctrl_range trip means the very lanes the breaker writes may be
    garbage — containment rebuilds the lane's operating point from the
    policy table, not from the corrupted block."""
    eng = _engine()
    cache = eng.init_cache(M)
    inj = FaultInjector("ctrl-garbage", at_step=1, layer=0)
    cache = inj.on_cache_update(cache, 1)
    assert int(np.asarray(cache["stack"]["ctrl"]["mode_id"])[0]) == 7

    br = QuarantineBreaker()
    rep = br.step(eng, cache, step=1)
    assert [t.check for t in rep.trips] == ["ctrl_range"]
    ctrl = cache["stack"]["ctrl"]
    t = eng.policy.resolve("stack", layer=0)
    assert int(np.asarray(ctrl["mode_id"])[0]) in (0, 1)
    assert float(np.asarray(ctrl["sim_threshold"])[0]) == t.sim_threshold
    assert float(np.asarray(ctrl["min_work"])[0]) == t.min_work_flops
    assert int(np.asarray(ctrl["cooldown"])[0]) >= 0


def test_shadow_check_proves_current_operating_point(monkeypatch):
    eng = _engine()
    ok, detail = shadow_check(eng, "stack")
    assert ok and "bitwise-exact" in detail

    # a diverging substrate quarantines the whole site (layer=None)
    cache = eng.init_cache(M)
    br = QuarantineBreaker(GuardConfig(shadow_every=1))
    monkeypatch.setattr("repro.guard.quarantine.shadow_check",
                        lambda *a, **k: (False, "forced divergence"))
    rep = br.step(eng, cache, step=1)
    assert rep.shadow == ("stack", False, "forced divergence")
    assert [(t.check, t.layer) for t in rep.trips] == [("shadow", None)]
    assert br.lane_states()[("stack", None)] == "quarantined"
    assert set(eng.layer_modes(cache, "stack")) == {"basic"}


# ----------------------------------------------------------- fault injector

def test_injector_spec_roundtrip_and_validation():
    inj = FaultInjector.from_spec("poison-nan:at_step=3,site=s,layer=1,seed=5")
    assert (inj.scenario, inj.site, inj.layer, inj.seed) == (
        "poison-nan", "s", 1, 5)
    assert inj.params["at_step"] == 3

    with pytest.raises(ValueError, match="unknown fault scenario"):
        FaultInjector("nope")
    with pytest.raises(ValueError, match="unknown"):
        FaultInjector("stall", bogus=1)
    with pytest.raises(ValueError, match="bad injector spec"):
        FaultInjector.from_spec("stall:seconds")
    assert set(SCENARIOS) >= {
        "poison-nan", "poison-sim", "ctrl-garbage", "poison-counters",
        "lying-telemetry", "torn-journal", "corrupt-ckpt", "stall"}


def test_injector_cache_scenarios_fire_deterministically():
    eng = _engine()
    cache = eng.init_cache(M)

    inj = FaultInjector("poison-nan", at_step=4)
    assert inj.on_cache_update(cache, 3) is cache and not inj.fired
    poisoned = inj.on_cache_update(cache, 4)
    assert inj.fired[0]["step"] == 4 and inj.fired[0]["layer"] == 0
    assert not np.isfinite(np.asarray(poisoned["stack"]["prev_out"])).all()
    # the input cache is not mutated in place
    assert np.isfinite(np.asarray(cache["stack"]["prev_out"])).all()

    sim = FaultInjector("poison-sim", at_step=1, layer=1)
    out = sim.on_cache_update(cache, 1)
    assert math.isnan(float(np.asarray(out["stack"]["sim_ema"])[1, 0]))

    cnt = FaultInjector("poison-counters", at_step=1, bump=5)
    out = cnt.on_cache_update(cache, 1)
    delta = (np.asarray(out["stack"]["sensor"]["skipped_tiles"])
             - np.asarray(cache["stack"]["sensor"]["skipped_tiles"]))
    assert delta.sum() == 5

    lie = FaultInjector("lying-telemetry", at_step=2, value=float("nan"))
    t = {"slot": 0, "steps": 5, "hit_rate": 0.5}
    assert lie.on_telemetry(t, 1) == t          # before at_step: untouched
    lied = lie.on_telemetry(t, 2)
    assert math.isnan(lied["hit_rate"]) and t["hit_rate"] == 0.5
    assert lie.on_telemetry(t, 3) == t          # fires once


# ------------------------------------- durable state: journal + checkpoints

def _report(step, interval, before, after):
    return ControlReport(
        step=step, interval=interval, window_steps={}, retrace={},
        decisions=[Decision(step=step, site="s", kind="retune",
                            field="sim_threshold", before=before,
                            after=after, reason="test")])


def test_torn_journal_tail_tolerated_mid_file_refused(tmp_path):
    path = tmp_path / "journal.jsonl"
    j = DecisionJournal(str(path))
    j.append(_report(1, 1, 0.1, 0.2))
    j.append(_report(2, 2, 0.2, 0.3))
    assert len(load_journal(str(path))) == 4  # 2 interval + 2 decision rows

    FaultInjector("torn-journal").tear_journal(path)
    rows = load_journal(str(path))
    assert rows[-1]["kind"] == "torn_tail" and rows[-1]["prefix"]
    # the surviving prefix still replays (the torn row lost, not corrupted)
    assert replay_rows(rows).ok

    # mid-file garbage is NOT a crash artifact — refuse the whole journal
    lines = path.read_text().splitlines()
    lines[1] = lines[1][: len(lines[1]) // 2]
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(ValueError, match="mid-file"):
        load_journal(str(path))


def test_chaos_quarantine_e2e_bitwise_recovery(tmp_path):
    """Acceptance: NaN poisoned into a live reuse lane reaches the outputs;
    the controller+guard cadence quarantines the lane, scrubs it, and every
    post-containment step is finite and bitwise-exact vs the dense oracle;
    the journal chains quarantined→probation→active and replays; the lane
    re-promotes to reuse once the quarantine drains."""
    w = _weights()
    xs = _sticky_inputs()

    eng = _engine()
    cache = eng.init_cache(M)
    step = _make_step(eng, w)

    oracle = _engine(mode="basic")
    ocache = oracle.init_cache(M)
    ostep = _make_step(oracle, w)

    inj = FaultInjector("poison-nan", at_step=5, layer=0)
    journal = DecisionJournal(str(tmp_path / "journal.jsonl"))
    br = QuarantineBreaker(GuardConfig(
        quarantine_intervals=1, probation_windows=1))
    # min_window_steps far above the run isolates the guard plane: the
    # retuner accumulates forever while the breaker acts every interval
    ctl = Controller(ControlConfig(min_window_steps=100),
                     journal=journal, guard=br)

    saw_poisoned_output = False
    for t in range(1, 15):
        outs, cache["stack"] = step(xs, cache["stack"])
        oouts, ocache["stack"] = ostep(xs, ocache["stack"])
        outs = np.asarray(outs)
        if t == 6:
            # blast radius is real: the skipped lane serves the NaN
            saw_poisoned_output = not np.isfinite(outs).all()
        elif t >= 7:
            assert np.isfinite(outs).all(), f"step {t} not contained"
            np.testing.assert_array_equal(outs, np.asarray(oouts))
        cache = inj.on_cache_update(cache, t)
        if t % 2 == 0:
            rep = ctl.step(eng, cache, step=t)
            assert not rep.changed  # containment never forces a retrace
    assert saw_poisoned_output, "fault never reached an output"
    assert inj.fired and br.total_trips >= 1

    # lifecycle drained: lane re-admitted, mode re-promoted, ctrl clean
    assert br.lane_states()[("stack", 0)] == "active"
    assert int(np.asarray(cache["stack"]["ctrl"]["quarantine"]).max()) == 0
    assert eng.layer_modes(cache, "stack")[0] == "reuse"

    # journal chains the full lifecycle for (stack, layer 0) and replays
    rows = load_journal(str(tmp_path / "journal.jsonl"))
    chain = [(r["before"], r["after"]) for r in rows
             if r.get("decision_kind") == "quarantine"
             and r.get("field") == "state" and r.get("layer") == 0]
    assert chain == [("active", "quarantined"), ("quarantined", "probation"),
                     ("probation", "active")]
    assert replay_rows(rows).ok

    # negative control: same stream, no injection -> zero trips
    eng2 = _engine()
    cache2 = eng2.init_cache(M)
    step2 = _make_step(eng2, w)
    br2 = QuarantineBreaker(GuardConfig(
        quarantine_intervals=1, probation_windows=1))
    ctl2 = Controller(ControlConfig(min_window_steps=100), guard=br2)
    for t in range(1, 15):
        outs2, cache2["stack"] = step2(xs, cache2["stack"])
        if t % 2 == 0:
            ctl2.step(eng2, cache2, step=t)
    assert br2.total_trips == 0
    assert not any(d.kind == "quarantine"
                   for r in ctl2.reports for d in r.decisions)


# --------------------------------------------- hardened admission predictor

def test_admission_rejects_lying_telemetry():
    class _Req:
        def __init__(self, rid, slot, session, hit, steps=5):
            self.rid, self.slot, self.session = rid, slot, session
            self.telemetry = {"slot": slot, "steps": steps, "hit_rate": hit,
                              "n_sites": 1}

    pred = AdmissionPredictor(decay=1.0, prior=0.5)
    pred.observe_retirement(_Req(0, 0, "liar", float("nan")))
    assert "liar" not in pred.sessions
    assert pred.rejected_observations == 1
    pred.observe_retirement(_Req(1, 0, "liar", float("inf")))
    assert pred.rejected_observations == 2

    # out-of-range finite values are clamped, not trusted
    pred.observe_retirement(_Req(2, 0, "hype", 5.0))
    assert pred.sessions["hype"] == 1.0
    pred.observe_retirement(_Req(3, 0, "doom", -2.0))
    assert pred.sessions["doom"] == 0.0
    assert pred.stats()["rejected_observations"] == 2


# ------------------------------------------------------------- observability

def test_guard_metrics_land_in_registry():
    from repro.guard.quarantine import GuardReport
    from repro.guard.sentinel import Trip
    from repro.obs.metrics import MetricsRegistry, observe_guard_report

    reg = MetricsRegistry()
    rep = GuardReport(
        step=8, interval=1,
        trips=[Trip(site="s", layer=0, check="nonfinite_out", evidence="e")],
        decisions=[], frozen_sites={"s"}, stalled=True, quarantined_lanes=1)
    observe_guard_report(reg, rep)
    rows = {(r["name"], tuple(sorted(r["labels"].items()))): r
            for r in reg.snapshot()}
    assert rows[("guard_sentinel_trips",
                 (("check", "nonfinite_out"), ("site", "s")))]["value"] == 1
    assert rows[("guard_stall_windows", ())]["value"] == 1
    assert rows[("guard_quarantined_lanes", ())]["value"] == 1
