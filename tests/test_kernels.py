"""Per-kernel validation: shape/dtype/mask sweeps against the ref.py oracles,
in Pallas interpret mode (executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.delta import delta_encode_int8
from repro.core.similarity import block_zero_mask
from repro.kernels import ops
from repro.kernels.reuse_matmul import _skip_sel
from repro.quant import quantize_int8


def make_blocky_delta(rng, m, k, bm, bk, keep_prob, dtype=np.float32):
    """Delta tensor with a controlled fraction of all-zero tiles."""
    delta = rng.normal(size=(m, k)).astype(dtype)
    gm, gk = -(-m // bm), -(-k // bk)
    for i in range(gm):
        for j in range(gk):
            if rng.random() >= keep_prob:
                delta[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0.0
    return delta


SWEEP = [
    # (M, K, N, bm, bn, bk, keep)
    (32, 256, 128, 8, 128, 128, 0.5),
    (64, 512, 256, 32, 128, 128, 0.3),
    (128, 1024, 128, 64, 128, 256, 0.7),
    (8, 256, 384, 8, 128, 128, 0.0),    # fully skippable
    (16, 512, 128, 16, 128, 512, 1.0),  # nothing skippable
    (24, 384, 128, 8, 128, 128, 0.4),   # M not multiple of bm after pad? 24%8==0
]


@pytest.mark.parametrize("m,k,n,bm,bn,bk,keep", SWEEP)
@pytest.mark.parametrize("dataflow", ["output", "input"])
def test_reuse_matmul_vs_ref(rng, m, k, n, bm, bn, bk, keep, dataflow):
    delta = jnp.asarray(make_blocky_delta(rng, m, k, bm, bk, keep))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    out = ops.reuse_matmul(
        delta, w, prev, mask, block_m=bm, block_n=bn, block_k=bk,
        dataflow=dataflow, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reuse_matmul_dtypes(rng, dtype):
    m, k, n, bm, bn, bk = 32, 512, 256, 8, 128, 128
    delta = jnp.asarray(make_blocky_delta(rng, m, k, bm, bk, 0.5)).astype(dtype)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    out = ops.reuse_matmul(
        delta, w, prev, mask, block_m=bm, block_n=bn, block_k=bk, interpret=True
    )
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 10,
    )


def test_mask_zero_blocks_never_loaded_semantics(rng):
    """Tiles masked out contribute nothing even if delta there is nonzero —
    proves the kernel consumes the MASK (load-skip), not the data."""
    m, k, n, bm, bk = 16, 512, 128, 8, 128
    delta = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))  # dense!
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.zeros((m, n), jnp.float32)
    mask = jnp.zeros((m // bm, k // bk), jnp.int32).at[0, 1].set(1)
    out = ops.reuse_matmul(
        delta, w, prev, mask, block_m=bm, block_n=128, block_k=bk, interpret=True
    )
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)
    # and the unmasked-row result is NOT the dense product (skips happened)
    dense = prev + delta @ w
    assert not np.allclose(np.asarray(out), np.asarray(dense))


def test_skip_sel_repeats_previous_index():
    mask = jnp.asarray([[0, 1, 0, 0, 1], [1, 0, 0, 1, 0]], jnp.int32)
    sel = np.asarray(_skip_sel(mask))
    np.testing.assert_array_equal(sel, [[0, 1, 1, 1, 4], [0, 0, 0, 3, 3]])


@pytest.mark.parametrize("m,k,n", [(32, 512, 128), (64, 256, 256)])
def test_reuse_matmul_int8_vs_ref(rng, m, k, n):
    bm, bn, bk = 8, 128, 128
    cur = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    prev = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    acc = jnp.asarray(rng.integers(-1000, 1000, size=(m, n)), jnp.int32)
    enc = delta_encode_int8(cur, prev, block_m=bm, block_k=bk)
    out = ops.reuse_matmul_int8(
        enc.lo, wq, acc, enc.lo_mask, block_m=bm, block_n=bn, block_k=bk,
        interpret=True,
    )
    ref = ops.reuse_matmul_int8_ref(enc.lo, wq, acc, enc.lo_mask, bm, bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_int8_overflow_split_is_exact(rng):
    """Paper Sec. IV-B: |q_c - q_p| can exceed 127; split into lo+hi, both
    in-range, and the two-pass kernel result equals the exact int32 GEMM."""
    m, k, n, bm, bk = 16, 256, 128, 8, 128
    cur = jnp.full((m, k), 127, jnp.int8)
    prev = jnp.full((m, k), -127, jnp.int8)     # delta = 254 everywhere
    wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    acc = jnp.zeros((m, n), jnp.int32)
    enc = delta_encode_int8(cur, prev, block_m=bm, block_k=bk)
    assert bool(enc.has_overflow)
    assert int(jnp.max(jnp.abs(enc.lo.astype(jnp.int32)))) <= 127
    assert int(jnp.max(jnp.abs(enc.hi.astype(jnp.int32)))) <= 127
    lo = ops.reuse_matmul_int8(enc.lo, wq, acc, enc.lo_mask,
                               block_m=bm, block_n=128, block_k=bk, interpret=True)
    out = ops.reuse_matmul_int8(enc.hi, wq, lo, enc.hi_mask,
                                block_m=bm, block_n=128, block_k=bk, interpret=True)
    exact = (cur.astype(jnp.int32) - prev.astype(jnp.int32)) @ wq.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exact))


@pytest.mark.parametrize("m,k,bm,bk", [(32, 512, 8, 128), (64, 256, 16, 256),
                                       (128, 1024, 128, 256)])
def test_delta_quant_fused_vs_ref(rng, m, k, bm, bk):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    prev_q = quantize_int8(
        jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)), jnp.float32(0.05)
    )
    q, d, msk = ops.delta_quant_fused(
        x, prev_q, jnp.float32(0.05), block_m=bm, block_k=bk, interpret=True
    )
    q2, d2, msk2 = ops.delta_quant_ref(x, prev_q, jnp.float32(0.05), bm, bk)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(
        np.asarray(d, np.float32), np.asarray(d2, np.float32), atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(msk), np.asarray(msk2))


RAGGED_SWEEP = [
    # (M, K, N, bm, bn, bk, keep, budget)
    (32, 1024, 256, 8, 128, 128, 0.3, None),   # ragged counts, full extent
    (32, 1024, 256, 8, 128, 128, 0.3, 4),      # ragged counts, tight budget
    (16, 512, 128, 8, 128, 128, 0.0, 1),       # all rows skipped
    (16, 512, 128, 8, 128, 128, 1.0, 2),       # all rows computed (overflow)
    (24, 384, 128, 8, 128, 128, 0.4, 2),       # non-multiple K via ops pad
    (20, 300, 130, 8, 128, 128, 0.5, None),    # every dim non-multiple
]


@pytest.mark.parametrize("m,k,n,bm,bn,bk,keep,budget", RAGGED_SWEEP)
def test_reuse_matmul_ragged_vs_ref(rng, m, k, n, bm, bn, bk, keep, budget):
    """Compacted-grid kernel == oracle across raggedness, budgets (including
    the overflow fallback) and the ops padding entry."""
    delta = jnp.asarray(make_blocky_delta(rng, m, k, bm, bk, keep))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    out = ops.reuse_matmul_ragged(
        delta, w, prev, mask, block_m=bm, block_n=bn, block_k=bk,
        max_active_k=budget, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_ragged_all_rows_skipped_passes_prev_through(rng):
    m, k, n, bm, bk = 16, 512, 128, 8, 128
    delta = jnp.zeros((m, k), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = jnp.zeros((m // bm, k // bk), jnp.int32)
    out = ops.reuse_matmul_ragged(
        delta, w, prev, mask, block_m=bm, block_n=128, block_k=bk,
        max_active_k=1, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(prev))


def test_ragged_budget_overflow_falls_back_exactly(rng):
    """A budget the live counts overflow must not drop contributions — the
    wrapper re-runs the full k-extent (the budget is a hint, not a
    correctness contract)."""
    m, k, n, bm, bk = 8, 512, 128, 8, 128
    delta = jnp.asarray(make_blocky_delta(rng, m, k, bm, bk, 1.0))  # 4 live
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    assert int(jnp.max(jnp.sum(mask, axis=1))) == 4
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    out = ops.reuse_matmul_ragged(
        delta, w, prev, mask, block_m=bm, block_n=128, block_k=bk,
        max_active_k=1, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_ragged_consumes_mask_not_data(rng):
    """Like the masked kernel: tiles outside the compacted index list
    contribute nothing even when their delta is dense."""
    m, k, n, bm, bk = 16, 512, 128, 8, 128
    delta = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))  # dense!
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.zeros((m, n), jnp.float32)
    mask = jnp.zeros((m // bm, k // bk), jnp.int32).at[0, 2].set(1)
    out = ops.reuse_matmul_ragged(
        delta, w, prev, mask, block_m=bm, block_n=128, block_k=bk,
        max_active_k=2, interpret=True,
    )
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)
    assert not np.allclose(np.asarray(out), np.asarray(prev + delta @ w))


def test_compact_block_indices_count_zero():
    from repro.core.delta import compact_block_indices, compact_rows

    idx, count = compact_block_indices(jnp.zeros((6,), jnp.int32))
    assert int(count) == 0
    np.testing.assert_array_equal(np.asarray(idx), np.zeros(6, np.int32))
    # and the row-batched variant keeps per-row zeros independent
    mask = jnp.asarray([[0, 0, 0], [0, 1, 0]], jnp.int32)
    idx2, counts = compact_rows(mask)
    np.testing.assert_array_equal(np.asarray(counts), [0, 1])
    np.testing.assert_array_equal(np.asarray(idx2[1]), [1, 1, 1])


def test_compact_non_multiple_k_via_padding_entry(rng):
    """K not a block_k multiple goes through the ops padding entry: padded
    blocks carry zero deltas and inactive mask bits, so values are exact."""
    m, k, n, bk = 12, 300, 96, 128
    delta = rng.normal(size=(m, k)).astype(np.float32)
    delta[:, bk:2 * bk] = 0.0  # middle block dead
    w = rng.normal(size=(k, n)).astype(np.float32)
    prev = rng.normal(size=(m, n)).astype(np.float32)
    kmask = jnp.asarray([1, 0, 1], jnp.int32)  # ceil(300/128) = 3 blocks
    out = ops.reuse_matmul_compact(
        jnp.asarray(delta), jnp.asarray(w), jnp.asarray(prev), kmask,
        block_k=bk,
    )
    np.testing.assert_allclose(np.asarray(out), prev + delta @ w,
                               rtol=1e-4, atol=1e-3)
    # budgeted + overflow fallback on the same shapes
    out2 = ops.reuse_matmul_compact(
        jnp.asarray(delta), jnp.asarray(w), jnp.asarray(prev), kmask,
        block_k=bk, max_blocks=1,
    )
    np.testing.assert_allclose(np.asarray(out2), prev + delta @ w,
                               rtol=1e-4, atol=1e-3)


def test_compact_path_matches_shared_k_ref(rng):
    m, k, n, bk = 48, 1024, 192, 128
    delta = make_blocky_delta(rng, m, k, m, bk, 0.4)  # shared-K blocky
    w = rng.normal(size=(k, n)).astype(np.float32)
    prev = rng.normal(size=(m, n)).astype(np.float32)
    kmask = (np.abs(delta).reshape(m, k // bk, bk).sum(axis=(0, 2)) > 0)
    out = ops.reuse_matmul_compact(
        jnp.asarray(delta), jnp.asarray(w), jnp.asarray(prev),
        jnp.asarray(kmask, jnp.int32), block_k=bk,
    )
    ref = prev + delta @ w
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-3)
