"""Per-kernel validation: shape/dtype/mask sweeps against the ref.py oracles,
in Pallas interpret mode (executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.delta import delta_encode_int8
from repro.core.similarity import block_zero_mask
from repro.kernels import ops
from repro.kernels.reuse_matmul import _skip_sel
from repro.quant import quantize_int8


def make_blocky_delta(rng, m, k, bm, bk, keep_prob, dtype=np.float32):
    """Delta tensor with a controlled fraction of all-zero tiles."""
    delta = rng.normal(size=(m, k)).astype(dtype)
    gm, gk = -(-m // bm), -(-k // bk)
    for i in range(gm):
        for j in range(gk):
            if rng.random() >= keep_prob:
                delta[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0.0
    return delta


SWEEP = [
    # (M, K, N, bm, bn, bk, keep)
    (32, 256, 128, 8, 128, 128, 0.5),
    (64, 512, 256, 32, 128, 128, 0.3),
    (128, 1024, 128, 64, 128, 256, 0.7),
    (8, 256, 384, 8, 128, 128, 0.0),    # fully skippable
    (16, 512, 128, 16, 128, 512, 1.0),  # nothing skippable
    (24, 384, 128, 8, 128, 128, 0.4),   # M not multiple of bm after pad? 24%8==0
]


@pytest.mark.parametrize("m,k,n,bm,bn,bk,keep", SWEEP)
@pytest.mark.parametrize("dataflow", ["output", "input"])
def test_reuse_matmul_vs_ref(rng, m, k, n, bm, bn, bk, keep, dataflow):
    delta = jnp.asarray(make_blocky_delta(rng, m, k, bm, bk, keep))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    out = ops.reuse_matmul(
        delta, w, prev, mask, block_m=bm, block_n=bn, block_k=bk,
        dataflow=dataflow, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reuse_matmul_dtypes(rng, dtype):
    m, k, n, bm, bn, bk = 32, 512, 256, 8, 128, 128
    delta = jnp.asarray(make_blocky_delta(rng, m, k, bm, bk, 0.5)).astype(dtype)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    prev = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    mask = block_zero_mask(delta, bm, bk)
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    out = ops.reuse_matmul(
        delta, w, prev, mask, block_m=bm, block_n=bn, block_k=bk, interpret=True
    )
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 10,
    )


def test_mask_zero_blocks_never_loaded_semantics(rng):
    """Tiles masked out contribute nothing even if delta there is nonzero —
    proves the kernel consumes the MASK (load-skip), not the data."""
    m, k, n, bm, bk = 16, 512, 128, 8, 128
    delta = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))  # dense!
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    prev = jnp.zeros((m, n), jnp.float32)
    mask = jnp.zeros((m // bm, k // bk), jnp.int32).at[0, 1].set(1)
    out = ops.reuse_matmul(
        delta, w, prev, mask, block_m=bm, block_n=128, block_k=bk, interpret=True
    )
    ref = ops.reuse_matmul_ref(delta, w, prev, mask, bm, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)
    # and the unmasked-row result is NOT the dense product (skips happened)
    dense = prev + delta @ w
    assert not np.allclose(np.asarray(out), np.asarray(dense))


def test_skip_sel_repeats_previous_index():
    mask = jnp.asarray([[0, 1, 0, 0, 1], [1, 0, 0, 1, 0]], jnp.int32)
    sel = np.asarray(_skip_sel(mask))
    np.testing.assert_array_equal(sel, [[0, 1, 1, 1, 4], [0, 0, 0, 3, 3]])


@pytest.mark.parametrize("m,k,n", [(32, 512, 128), (64, 256, 256)])
def test_reuse_matmul_int8_vs_ref(rng, m, k, n):
    bm, bn, bk = 8, 128, 128
    cur = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    prev = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    acc = jnp.asarray(rng.integers(-1000, 1000, size=(m, n)), jnp.int32)
    enc = delta_encode_int8(cur, prev, block_m=bm, block_k=bk)
    out = ops.reuse_matmul_int8(
        enc.lo, wq, acc, enc.lo_mask, block_m=bm, block_n=bn, block_k=bk,
        interpret=True,
    )
    ref = ops.reuse_matmul_int8_ref(enc.lo, wq, acc, enc.lo_mask, bm, bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_int8_overflow_split_is_exact(rng):
    """Paper Sec. IV-B: |q_c - q_p| can exceed 127; split into lo+hi, both
    in-range, and the two-pass kernel result equals the exact int32 GEMM."""
    m, k, n, bm, bk = 16, 256, 128, 8, 128
    cur = jnp.full((m, k), 127, jnp.int8)
    prev = jnp.full((m, k), -127, jnp.int8)     # delta = 254 everywhere
    wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    acc = jnp.zeros((m, n), jnp.int32)
    enc = delta_encode_int8(cur, prev, block_m=bm, block_k=bk)
    assert bool(enc.has_overflow)
    assert int(jnp.max(jnp.abs(enc.lo.astype(jnp.int32)))) <= 127
    assert int(jnp.max(jnp.abs(enc.hi.astype(jnp.int32)))) <= 127
    lo = ops.reuse_matmul_int8(enc.lo, wq, acc, enc.lo_mask,
                               block_m=bm, block_n=128, block_k=bk, interpret=True)
    out = ops.reuse_matmul_int8(enc.hi, wq, lo, enc.hi_mask,
                                block_m=bm, block_n=128, block_k=bk, interpret=True)
    exact = (cur.astype(jnp.int32) - prev.astype(jnp.int32)) @ wq.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exact))


@pytest.mark.parametrize("m,k,bm,bk", [(32, 512, 8, 128), (64, 256, 16, 256),
                                       (128, 1024, 128, 256)])
def test_delta_quant_fused_vs_ref(rng, m, k, bm, bk):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    prev_q = quantize_int8(
        jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)), jnp.float32(0.05)
    )
    q, d, msk = ops.delta_quant_fused(
        x, prev_q, jnp.float32(0.05), block_m=bm, block_k=bk, interpret=True
    )
    q2, d2, msk2 = ops.delta_quant_ref(x, prev_q, jnp.float32(0.05), bm, bk)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(
        np.asarray(d, np.float32), np.asarray(d2, np.float32), atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(msk), np.asarray(msk2))


def test_compact_path_matches_shared_k_ref(rng):
    m, k, n, bk = 48, 1024, 192, 128
    delta = make_blocky_delta(rng, m, k, m, bk, 0.4)  # shared-K blocky
    w = rng.normal(size=(k, n)).astype(np.float32)
    prev = rng.normal(size=(m, n)).astype(np.float32)
    kmask = (np.abs(delta).reshape(m, k // bk, bk).sum(axis=(0, 2)) > 0)
    out = ops.reuse_matmul_compact(
        jnp.asarray(delta), jnp.asarray(w), jnp.asarray(prev),
        jnp.asarray(kmask, jnp.int32), block_k=bk,
    )
    ref = prev + delta @ w
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-3)
