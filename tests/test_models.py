"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates at reduced scale and runs one forward + one train step on
CPU with shape and finiteness assertions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import forward, init_decode_state, init_params, output_logits
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

ARCH_NAMES = sorted(ARCHS)


def _inputs(cfg, b, s, with_labels=False):
    if cfg.frontend == "audio":
        d = {"embeds": jnp.asarray(
            np.random.default_rng(0).normal(size=(b, s, cfg.d_model)),
            jnp.float32)}
    else:
        d = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, size=(b, s)),
            jnp.int32)}
    if with_labels:
        d["labels"] = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, size=(b, s)),
            jnp.int32)
    return d


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    h, _, _, _ = forward(params, cfg, _inputs(cfg, b, s))
    assert h.shape == (b, s, cfg.d_model)
    logits = output_logits(params, cfg, h)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = ARCHS[arch].reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    batch = _inputs(cfg, 2, 64, with_labels=True)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        state["params"], new_state["params"],
    )
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if ARCHS[a].family != "audio"])
def test_decode_step_shapes(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    state = init_decode_state(cfg, b, cache_len=96)
    tok = jnp.zeros((b, 1), jnp.int32) + 5
    h, new_state, _, _ = forward(params, cfg, {"tokens": tok},
                                 decode_state=state)
    assert h.shape == (b, 1, cfg.d_model)
    assert int(new_state["len"]) == 1
    assert bool(jnp.all(jnp.isfinite(h)))


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if ARCHS[a].family != "audio"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Serving correctness: prefill(prompt) + decode(next) must produce the
    same hidden states as one forward over the concatenated sequence.

    MoE archs run with dropless capacity here: capacity dropping is rank-
    order dependent across the token axis, so a 33-token forward and a
    32+1 prefill/decode legitimately drop different tokens otherwise."""
    import dataclasses

    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s + 1)), jnp.int32)

    # full forward over s+1 tokens (no cache)
    h_full, _, _, _ = forward(params, cfg, {"tokens": toks})

    # prefill s, then decode token s
    state = init_decode_state(cfg, b, cache_len=s + 8)
    h_pre, state, _, _ = forward(params, cfg, {"tokens": toks[:, :s]},
                                 decode_state=state)
    h_dec, state, _, _ = forward(params, cfg, {"tokens": toks[:, s:s + 1]},
                                 decode_state=state)
    np.testing.assert_allclose(
        np.asarray(h_dec[:, 0], np.float32),
        np.asarray(h_full[:, s], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_vision_stub_merges_patch_embeddings():
    cfg = ARCHS["qwen2-vl-7b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s, p = 2, 32, 4
    rng = np.random.default_rng(0)
    inputs = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "vision_embeds": jnp.asarray(
            rng.normal(size=(b, p, cfg.d_model)), jnp.float32),
        "vision_positions": jnp.asarray(
            np.stack([np.arange(2, 2 + p)] * b), jnp.int32),
    }
    h, _, _, _ = forward(params, cfg, inputs)
    assert bool(jnp.all(jnp.isfinite(h)))
    # and the vision positions actually influence the output
    inputs2 = dict(inputs, vision_embeds=inputs["vision_embeds"] + 1.0)
    h2, _, _, _ = forward(params, cfg, inputs2)
    assert float(jnp.max(jnp.abs(h - h2))) > 0


def test_param_count_formulas():
    """Config param_count must track actual init within tolerance (embeddings
    + lora/norm slop) — used by the roofline's 6·N·D bookkeeping."""
    for arch in ARCH_NAMES:
        cfg = ARCHS[arch].reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert 0.5 < actual / predicted < 2.0, (
            arch, actual, predicted)
