"""MoE dispatch: conservation, capacity bounds, combine-weight correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import moe


@pytest.fixture
def cfg():
    return ARCHS["mixtral-8x7b"].reduced()


def test_moe_forward_finite_and_shaped(rng, cfg):
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    out = moe.moe_forward(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_dropless_equals_dense_expert_sum(rng, cfg):
    """With capacity >= all tokens, scatter-dispatch must equal the explicit
    per-token weighted expert sum."""
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    b, s, d = 2, 8, cfg.d_model
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))

    out = moe.moe_forward(p, cfg, x)

    # reference: evaluate every expert densely, combine with top-k gates
    from repro.models.layers import apply_norm

    h = apply_norm(p["norm"], x, cfg.norm_eps).reshape(-1, d)
    logits = h.astype(jnp.float32) @ p["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, cfg.top_k)
    top_g = top_g / jnp.sum(top_g, -1, keepdims=True)
    ref = jnp.zeros((b * s, d), jnp.float32)
    for e in range(cfg.n_experts):
        hi = h @ p["wi"][e]
        g, u = jnp.split(hi, 2, axis=-1)
        act = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
        ye = act.astype(h.dtype) @ p["wo"][e]
        for kk in range(cfg.top_k):
            w = jnp.where(top_e[:, kk] == e, top_g[:, kk], 0.0)
            ref = ref + ye.astype(jnp.float32) * w[:, None]
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, d), np.asarray(ref),
        rtol=5e-3, atol=5e-3,
    )


def test_capacity_drops_are_bounded(rng, cfg):
    """With tight capacity, dropped tokens produce zero contribution (never
    garbage) and the drop fraction matches the capacity math."""
    cfg = dataclasses.replace(cfg, capacity_factor=0.5)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
    out = moe.moe_forward(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_router_aux_loss_range(rng, cfg):
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    aux = moe.router_aux_loss(p, cfg, x)
    # perfectly balanced -> 1.0; pathological -> up to E
    assert 0.5 < float(aux) <= cfg.n_experts + 1e-3
