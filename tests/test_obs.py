"""repro.obs — the unified tracing / metrics / measured-latency plane.

Load-bearing properties:

* spans nest (parent ids), carry tags + correlation ids, and the DISABLED
  path is a shared no-op with near-zero per-call overhead (the <3 % serve
  acceptance bar, locked here with a generous absolute bound);
* `events.stamp` is byte-identity when no ids are set — pre-obs consumers
  emit exactly what they emitted before the obs plane existed;
* the Prometheus textfile writer and `parse_prometheus` are inverses;
* the latency table round-trips save→load, falls back layer→None on lookup,
  and — handed to the harvest model via `FitConfig.latency` — changes fitted
  tunables vs the constant energy-model pricing (the ROADMAP payoff);
* the control journal emits the current schema (stamped when ids are set),
  still loads every prior version's emissions, and rejects future versions
  loudly;
* checkpoint-vs-tuned-table restore precedence: covered lanes re-sync to the
  table, uncovered lanes adopt the checkpointed values into the policy
  table, every resolution journals as a replayable kind="restore" Decision.
"""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.control.replay import apply_to_engine, replay_rows
from repro.control.report import (
    CONTROL_JOURNAL_SCHEMA_VERSION,
    ControlReport,
    Decision,
    DecisionJournal,
    load_journal,
)
from repro.control.restore import resolve_restored_ctrl
from repro.core import ReuseEngine, ReusePolicy, SiteTunables
from repro.obs import events
from repro.obs import trace as obs_trace
from repro.obs.export import (
    load_snapshots,
    parse_prometheus,
    write_jsonl,
    write_prometheus,
)
from repro.obs.latency import (
    BASIC_PATH,
    LatencyTable,
    LatencyTableError,
    build_from_spans,
    load_latency_table,
    probe_latency_table,
)
from repro.obs.metrics import MetricsRegistry
from repro.tune.harvest import FitConfig, measured_latency_note, solve_site
from repro.tune.trace import SiteTraceRecord


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Obs state is module-global (single-threaded host loop); isolate it."""
    events.clear_ids()
    obs_trace.disable()
    obs_trace.drain_spans()
    yield
    events.clear_ids()
    obs_trace.disable()
    obs_trace.drain_spans()
    obs_trace._STATE["max_spans"] = 262_144


# ------------------------------------------------------------------ tracing

def test_span_nesting_parent_ids_and_tags():
    obs_trace.enable()
    with obs_trace.span("outer", phase="serve") as outer:
        with obs_trace.span("inner") as inner:
            inner.tag(tokens=3)
        assert inner.parent_id == outer.span_id
    rows = obs_trace.spans()
    assert [r["name"] for r in rows] == ["inner", "outer"]  # close order
    by_name = {r["name"]: r for r in rows}
    assert by_name["inner"]["parent_id"] == by_name["outer"]["span_id"]
    assert by_name["outer"]["parent_id"] == 0
    assert by_name["inner"]["tokens"] == 3
    assert by_name["outer"]["phase"] == "serve"
    assert all(r["dur_s"] >= 0.0 for r in rows)


def test_span_records_correlation_ids():
    obs_trace.enable()
    with events.context(run="r1", request=7):
        with obs_trace.span("prefill"):
            pass
    with obs_trace.span("bare"):
        pass
    rows = {r["name"]: r for r in obs_trace.spans()}
    assert rows["prefill"]["trace"] == {"run": "r1", "request": 7}
    assert "trace" not in rows["bare"]


def test_disabled_span_is_shared_noop_and_records_nothing():
    assert not obs_trace.is_enabled()
    a = obs_trace.span("serve_step", exec_path="compact")
    b = obs_trace.span("another")
    assert a is b  # ONE shared no-op object: no per-call allocation
    with a as sp:
        val = object()
        assert sp.sync(val) is val
        assert sp.tag(k=1) is sp
    assert obs_trace.spans() == []


def test_disabled_span_overhead_is_negligible():
    """The acceptance bar is <3 % serve-step overhead with obs off; a serve
    step is milliseconds, so lock an absolute per-call bound with ~30x
    headroom over the measured dict-lookup cost."""
    n = 2000
    t0 = obs_trace.now()
    for _ in range(n):
        with obs_trace.span("serve_step"):
            pass
    per_call = (obs_trace.now() - t0) / n
    assert per_call < 10e-6, f"disabled span cost {per_call * 1e6:.2f}us/call"


def test_span_buffer_cap_counts_drops():
    obs_trace.enable(max_spans=2)
    for i in range(4):
        with obs_trace.span(f"s{i}"):
            pass
    assert len(obs_trace.spans()) == 2
    assert obs_trace._STATE["dropped"] == 2
    drained = obs_trace.drain_spans()
    assert [r["name"] for r in drained] == ["s0", "s1"]
    assert obs_trace.spans() == [] and obs_trace._STATE["dropped"] == 0


def test_write_spans_jsonl_round_trip(tmp_path):
    obs_trace.enable()
    with obs_trace.span("a", site="mlp_in"):
        pass
    p = tmp_path / "spans.jsonl"
    assert obs_trace.write_spans_jsonl(str(p)) == 1
    assert obs_trace.spans() == []  # drained
    row = json.loads(p.read_text().strip())
    assert row["name"] == "a" and row["site"] == "mlp_in"


# ----------------------------------------------------------- correlation ids

def test_stamp_is_identity_with_no_ids():
    row = {"kind": "site", "site": "s"}
    assert events.stamp(row) is row  # byte-identical pre-obs emission


def test_context_nesting_restores_outer_ids():
    events.set_ids(run="R")
    with events.context(window=3):
        assert events.current_ids() == {"run": "R", "window": 3}
        with events.context(window=4, request=9):
            assert events.current_ids() == {
                "run": "R", "window": 4, "request": 9}
        assert events.current_ids() == {"run": "R", "window": 3}
    assert events.current_ids() == {"run": "R"}
    stamped = events.stamp({"x": 1})
    assert stamped == {"x": 1, "trace": {"run": "R"}}
    events.clear_ids()
    assert events.current_ids() == {}


# ----------------------------------------------------------- metrics/export

def test_registry_keying_and_histogram_percentiles():
    reg = MetricsRegistry()
    assert reg.counter("c", site="a") is reg.counter("c", site="a")
    assert reg.counter("c", site="a") is not reg.counter("c", site="b")
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100 and h.mean == pytest.approx(50.5)
    assert h.percentile(0.5) == pytest.approx(50.5)
    assert h.percentile(0.95) == pytest.approx(95.05)
    s = h.summary()
    assert s["min"] == 1.0 and s["max"] == 100.0 and "p99" in s


def test_prometheus_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("control_decisions", kind="retune").inc(3)
    reg.gauge("reuse_site_hit_rate", site="mlp_in").set(0.875)
    h = reg.histogram("span_serve_step_seconds")
    for v in (0.001, 0.002, 0.003):
        h.observe(v)
    p = tmp_path / "metrics.prom"
    n = write_prometheus(str(p), reg)
    assert n > 0
    parsed = parse_prometheus(p.read_text())
    assert parsed["control_decisions"]['{kind="retune"}'] == 3.0
    assert parsed["reuse_site_hit_rate"]['{site="mlp_in"}'] == \
        pytest.approx(0.875)
    assert parsed["span_serve_step_seconds_count"][""] == 3.0
    assert parsed["span_serve_step_seconds_sum"][""] == pytest.approx(0.006)
    assert parsed["span_serve_step_seconds"]['{quantile="0.5"}'] == \
        pytest.approx(0.002)


def test_prometheus_round_trip_hostile_labels(tmp_path):
    # exposition-format escaping: raw interpolation of these values would
    # corrupt the textfile (a quote closes the label early, a newline splits
    # the sample, a brace fools brace-terminated parsers)
    hostile = {
        "quote": 'va"lue',
        "backslash": "back\\slash",
        "newline": "line1\nline2",
        "brace": "cl}osing",
        "comma": "a,b=c",
        "all": 'x"\\\n}y',
    }
    reg = MetricsRegistry()
    for i, (key, val) in enumerate(sorted(hostile.items())):
        reg.gauge("hostile_gauge", **{key: val}).set(float(i))
    p = tmp_path / "metrics.prom"
    write_prometheus(str(p), reg)
    parsed = parse_prometheus(p.read_text())
    from repro.obs.export import _prom_labels, parse_labels

    for i, (key, val) in enumerate(sorted(hostile.items())):
        label_str = _prom_labels({key: val})
        assert parsed["hostile_gauge"][label_str] == float(i)
        # parse_labels is the exact inverse of the writer's label emission
        assert parse_labels(label_str[1:-1]) == {key: val}


def test_parse_prometheus_rejects_untyped_samples():
    with pytest.raises(ValueError, match="TYPE"):
        parse_prometheus("orphan_metric 1.0\n")
    with pytest.raises(ValueError, match="not a prometheus sample"):
        parse_prometheus("# TYPE x gauge\nx = what\n")


def test_jsonl_snapshots_group_and_stamp(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("g").set(1.0)
    p = tmp_path / "metrics.jsonl"
    events.set_ids(run="RR")
    write_jsonl(str(p), reg)
    reg.gauge("g").set(2.0)
    write_jsonl(str(p), reg)
    snaps = load_snapshots(str(p))
    assert len(snaps) == 2
    assert snaps[0][0]["value"] == 1.0 and snaps[1][0]["value"] == 2.0
    assert snaps[0][0]["trace"]["run"] == "RR"
    assert snaps[0][0]["snap"] < snaps[1][0]["snap"]


# ------------------------------------------------------------- latency table

def test_latency_table_layer_fallback_and_paths():
    t = LatencyTable()
    t.record("s", None, "basic", 1e-4)
    t.record("s", None, "dense", 8e-5)
    t.record("s", 2, "dense", 5e-5)
    # layer lookup prefers the layer row, falls back to site-wide
    assert t.stat("s", "dense", layer=2).mean_s == pytest.approx(5e-5)
    assert t.stat("s", "dense", layer=7).mean_s == pytest.approx(8e-5)
    assert t.stat("s", "basic", layer=2).mean_s == pytest.approx(1e-4)
    assert t.stat("s", "ragged") is None
    paths = t.paths_for("s", layer=2)
    assert paths["dense"].mean_s == pytest.approx(5e-5)  # layer row wins
    assert paths["basic"].mean_s == pytest.approx(1e-4)


def test_latency_table_save_load_round_trip(tmp_path):
    t = LatencyTable()
    for v in (1e-4, 1.2e-4, 1.4e-4):
        t.record("mlp_in", None, "basic", v)
    t.record("mlp_in", 0, "compact", 4e-5)
    p = tmp_path / "lat.json"
    t.save(str(p), meta={"arch": "qwen3-32b"})
    r = load_latency_table(str(p))
    assert r.meta["arch"] == "qwen3-32b"
    assert len(r) == len(t) == 2
    st, sr = t.stat("mlp_in", "basic"), r.stat("mlp_in", "basic")
    assert sr.count == st.count and sr.mean_s == pytest.approx(st.mean_s)
    assert r.stat("mlp_in", "compact", layer=0).mean_s == pytest.approx(4e-5)

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"kind": "nope", "schema_version": 1}))
    with pytest.raises(LatencyTableError, match="obs_latency_table"):
        load_latency_table(str(bad))
    bad.write_text(json.dumps({"kind": "obs_latency_table",
                               "schema_version": 99, "rows": []}))
    with pytest.raises(LatencyTableError, match="schema_version"):
        load_latency_table(str(bad))


def test_build_from_spans_keys_on_tags():
    rows = [
        {"name": "site_probe", "dur_s": 1e-4, "site": "s",
         "exec_path": "basic"},
        {"name": "site_probe", "dur_s": 2e-4, "site": "s",
         "exec_path": "basic"},
        {"name": "serve_step", "dur_s": 9.0},  # no site tag: skipped
    ]
    t = build_from_spans(rows)
    assert len(t) == 1
    assert t.stat("s", "basic").count == 2
    assert t.stat("s", "basic").mean_s == pytest.approx(1.5e-4)


def test_probe_latency_table_measures_every_viable_path():
    engine = ReuseEngine()
    engine.register("s", 64, 32, block_m=2, block_k=32)  # gk=2: compactable
    table = probe_latency_table(engine, 2, skip_rates={"s": 0.5},
                                iters=3, warmup=1)
    assert set(table.paths_for("s")) == {BASIC_PATH, "dense", "compact"}
    for path, stat in table.paths_for("s").items():
        assert stat.count == 3 and stat.mean_s > 0.0, path
    assert table.meta["impl"] == "jnp" and table.meta["batch"] == 2
    # the probe leaves the trace plane the way it found it (disabled here)
    assert not obs_trace.is_enabled()


# ------------------------------------------- measured pricing in the fitter

def _rec(**kw):
    base = dict(
        site="mlp_in", mode="reuse", steps=10, batch=4,
        in_features=512, out_features=256, block_m=8, block_k=128,
        block_n=128, tile_skip_rate=0.8, mac_skip_rate=0.7,
        weight_byte_skip_rate=0.7, hit_rate=0.9, mode_transitions=0,
        suppressed_flips=0, total_weight_bytes=0.0, total_macs=0.0,
    )
    base.update(kw)
    return SiteTraceRecord(**base)


def test_fit_with_latency_table_changes_tunables():
    """The ROADMAP payoff: the same operating point solves to different
    tunables when priced from MEASURED wall-clock. Here the constant
    skip-rate gate would promote the compacted tier, but the measurement
    says the plain masked walk is the fastest reuse substrate — the
    measured fit demotes, and the break-even threshold moves too."""
    rec = _rec()
    lat = LatencyTable()
    lat.record(rec.site, None, "basic", 100e-6)
    lat.record(rec.site, None, "dense", 80e-6)
    lat.record(rec.site, None, "compact", 150e-6)  # measured SLOWER

    const = solve_site(rec, FitConfig())
    meas = solve_site(rec, FitConfig(latency=lat))
    assert const.exec_path == "compact"       # constant gate: skip >= 0.25
    assert meas.exec_path is None             # measured gate: dense fastest
    assert meas.sim_threshold != pytest.approx(const.sim_threshold)

    # flip the measurement: compact fastest -> the measured fit pins it even
    # though nothing else about the record changed
    lat2 = LatencyTable()
    lat2.record(rec.site, None, "basic", 100e-6)
    lat2.record(rec.site, None, "dense", 80e-6)
    lat2.record(rec.site, None, "compact", 30e-6)
    fast = solve_site(rec, FitConfig(latency=lat2))
    assert fast.exec_path == "compact"
    assert fast.max_active_k is not None

    note = measured_latency_note(rec, FitConfig(latency=lat))
    assert note is not None and note.startswith("measured basic=")
    assert measured_latency_note(rec, FitConfig()) is None


def test_measured_pricing_falls_back_without_coverage():
    rec = _rec()
    empty = LatencyTable()                       # no rows at all
    no_basic = LatencyTable()
    no_basic.record(rec.site, None, "dense", 80e-6)  # no baseline
    for cfg in (FitConfig(latency=empty), FitConfig(latency=no_basic)):
        assert solve_site(rec, cfg) == solve_site(rec, FitConfig())


# ------------------------------------------------------------ journal v3

def test_journal_v4_rows_and_stamping(tmp_path):
    rep = ControlReport(
        step=8, interval=1, window_steps={"s": 8},
        decisions=[Decision(step=8, site="s", kind="retune",
                            field="sim_threshold", before=0.1, after=0.2,
                            reason="window 8 steps")],
        retrace={},
    )
    plain = rep.to_dicts()
    assert all(r["schema_version"] == CONTROL_JOURNAL_SCHEMA_VERSION == 5
               for r in plain)
    assert all("trace" not in r for r in plain)  # no ids -> v2 byte layout
    with events.context(run="RJ", window=1):
        stamped = rep.to_dicts()
    assert all(r["trace"] == {"run": "RJ", "window": 1} for r in stamped)

    p = tmp_path / "journal.jsonl"
    j = DecisionJournal(str(p))
    with events.context(run="RJ", window=1):
        j.append(rep)
    rows = load_journal(str(p))
    assert len(rows) == 2 and rows[1]["trace"]["run"] == "RJ"
    assert replay_rows(rows).ok


def test_journal_loads_v1_v2_rejects_future(tmp_path):
    p = tmp_path / "mixed.jsonl"
    v1_dec = {"kind": "decision", "schema_version": 1, "site": "s",
              "decision_kind": "retune", "field": "sim_threshold",
              "before": 0.1, "after": 0.2, "interval": 1, "step": 4,
              "reason": "r"}
    v2_dec = dict(v1_dec, schema_version=2, layer=3, before=0.2, after=0.3,
                  interval=2)
    p.write_text(json.dumps(v1_dec) + "\n" + json.dumps(v2_dec) + "\n")
    rows = load_journal(str(p))
    assert rows[0]["layer"] is None  # v1 predates per-layer lanes
    assert rows[1]["layer"] == 3
    assert replay_rows(rows).ok

    fut = tmp_path / "future.jsonl"
    next_ver = CONTROL_JOURNAL_SCHEMA_VERSION + 1
    fut.write_text(json.dumps(dict(v1_dec, schema_version=next_ver)) + "\n")
    with pytest.raises(
            ValueError,
            match=rf"future.jsonl:1.*schema_version {next_ver}"):
        load_journal(str(fut))


def test_decision_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        Decision(step=0, site="s", kind="vibes", field="f",
                 before=0, after=1, reason="")


# ------------------------------------------------------- restore precedence

def test_restore_precedence_table_wins_uncovered_adopts(tmp_path):
    # site "a" has a tuned-table row; site "b" does not
    table_row = SiteTunables(sim_threshold=0.4, min_work_flops=1e5)
    engine = ReuseEngine(
        policy=ReusePolicy(site_tunables={"a": table_row}))
    engine.register("a", 64, 32, block_m=2, block_k=32)
    engine.register("b", 64, 32, block_m=2, block_k=32)
    cache = engine.init_cache(2)
    default_thr = ReusePolicy().resolve("b").sim_threshold

    # simulate a restored checkpoint whose ctrl lanes drifted from both the
    # table ("a": 0.9 vs fitted 0.4) and the defaults ("b": 0.77)
    for name, thr in (("a", 0.9), ("b", 0.77)):
        cache[name] = dict(cache[name], ctrl=dict(
            cache[name]["ctrl"],
            sim_threshold=jnp.full_like(
                cache[name]["ctrl"]["sim_threshold"], thr)))

    jpath = tmp_path / "restore.jsonl"
    decisions = resolve_restored_ctrl(
        engine, cache, journal=DecisionJournal(str(jpath)), step=0)

    assert decisions and all(d.kind == "restore" for d in decisions)
    # covered lane: the TABLE wins, checkpoint value journaled as `before`
    a_thr = float(np.atleast_1d(
        np.asarray(cache["a"]["ctrl"]["sim_threshold"]))[0])
    assert a_thr == pytest.approx(0.4)
    d_a = next(d for d in decisions
               if d.site == "a" and d.field == "sim_threshold")
    assert d_a.before == pytest.approx(0.9) and d_a.after == pytest.approx(0.4)
    # uncovered lane: checkpoint ADOPTED into the policy table and kept live
    b_thr = float(np.atleast_1d(
        np.asarray(cache["b"]["ctrl"]["sim_threshold"]))[0])
    assert b_thr == pytest.approx(0.77)
    assert engine.policy.site_tunables["b"].sim_threshold == \
        pytest.approx(0.77)
    d_b = next(d for d in decisions
               if d.site == "b" and d.field == "sim_threshold")
    assert d_b.before == pytest.approx(default_thr)
    assert d_b.after == pytest.approx(0.77)

    # the journal is current-schema and REPLAYABLE: driving the restore
    # rows through a fresh engine reproduces the resolved thresholds
    rows = load_journal(str(jpath))
    assert all(r["schema_version"] == CONTROL_JOURNAL_SCHEMA_VERSION
               for r in rows)
    assert replay_rows(rows).ok
    fresh = ReuseEngine(policy=ReusePolicy(site_tunables={"a": table_row}))
    fresh.register("a", 64, 32, block_m=2, block_k=32)
    fresh.register("b", 64, 32, block_m=2, block_k=32)
    fcache = fresh.init_cache(2)
    apply_to_engine(rows, fresh, fcache)
    assert fresh.policy.resolve("a").sim_threshold == pytest.approx(0.4)
    assert fresh.policy.resolve("b").sim_threshold == pytest.approx(0.77)


def test_restore_noop_when_checkpoint_matches(tmp_path):
    engine = ReuseEngine()
    engine.register("s", 64, 32, block_m=2, block_k=32)
    cache = engine.init_cache(2)
    # ctrl lanes fresh from init: nothing differs, nothing to journal
    jpath = tmp_path / "noop.jsonl"
    decisions = resolve_restored_ctrl(
        engine, cache, journal=DecisionJournal(str(jpath)), step=0)
    assert decisions == []
    assert not jpath.exists()  # empty resolutions append nothing
    assert "s" not in engine.policy.site_tunables  # no spurious adoption
