"""Array-resident per-layer control block (ISSUE 5 acceptance).

Load-bearing properties:

* closed loop on a bimodal stacked stream (dissimilar early layers, sticky
  late layers): `refresh_modes` settles DISTINCT modes for distinct layers of
  the SAME site, the similar layers' measured mac_skip beats the single-mode
  compromise baseline, outputs stay bitwise-exact vs the dense (basic-kernel)
  oracle, and the mode flips never rebuild the jitted scan step — only
  spec-level changes (block_k / exec_path) signal a retrace;
* per-layer hysteresis cannot oscillate: lanes hovering inside the band
  don't flip, and a lane's immediate flip-back is cooldown-vetoed
  (counted in suppressed_flips);
* slot recycling resets the per-layer sensor lanes of a stacked site;
* the controller journals layer-scoped decisions for stacked sites and the
  journal replays consistently (repro.control.replay).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReuseEngine, ReusePolicy, SiteTunables
from repro.serve.scheduler import reset_slot

L, M, K, N = 4, 4, 128, 64
SIMILAR = (2, 3)     # late layers: identical input every step
DISSIMILAR = (0, 1)  # early layers: fresh random codes every step


def _bimodal_engine(mode="auto"):
    """Stacked site with integer-exact quantization (scale 1.0) so reuse
    telescoping is bitwise against the quantized dense oracle. The exec path
    is pinned (dense) so the only live decisions are per-layer kernelModes —
    the object under test."""
    policy = ReusePolicy(site_tunables={"stack": SiteTunables(
        sim_threshold=0.6, min_work_flops=0.0, hysteresis_margin=0.05,
        exec_path="dense",
    )})
    eng = ReuseEngine(policy=policy)
    eng.register("stack", K, N, n_layers=L, block_m=2, block_k=32, mode=mode)
    eng.sites["stack"] = dataclasses.replace(
        eng.sites["stack"], fixed_scale=1.0)
    return eng


def _make_step(eng, w):
    """Jitted scan-over-layers step; counts traces via a Python side effect
    (incremented only while TRACING, so a cached call adds nothing)."""
    traces = []

    @jax.jit
    def step(xs, entry):
        traces.append(1)

        def body(carry, sl):
            x_l, e_l = sl
            out, new_e, _ = eng.apply("stack", x_l, w, None, e_l)
            return carry, (out, new_e)

        _, (outs, new_entry) = jax.lax.scan(body, 0, (xs, entry))
        return outs, new_entry

    return step, traces


def _bimodal_inputs(rng, t):
    """[L, M, K] integer-valued stack input: sticky lanes repeat one matrix,
    dissimilar lanes draw fresh codes every step."""
    base = np.random.default_rng(12345).integers(-3, 4, size=(M, K))
    xs = np.zeros((L, M, K), np.float32)
    for layer in range(L):
        if layer in SIMILAR:
            xs[layer] = base
        else:
            xs[layer] = rng.integers(-3, 4, size=(M, K))
    return jnp.asarray(xs)


def test_bimodal_stack_settles_mixed_modes_bitwise_exact_no_retrace():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-2, 3, size=(K, N)).astype(np.float32))

    eng = _bimodal_engine()
    cache = eng.init_cache(M)
    step, traces = _make_step(eng, w)

    # dense oracle: the same stream through the basic (quantized dense)
    # kernel on every layer — the single-mode "reuse off" compromise AND the
    # exactness reference in one
    oracle = _bimodal_engine(mode="basic")
    ocache = oracle.init_cache(M)
    ostep, _ = _make_step(oracle, w)

    # 20 steps: the sticky lanes' sim EMA must climb from the cold start past
    # the promotion band (0.6 + 0.05) — the optimistic start demotes at the
    # first refresh, then the measured similarity re-admits only those lanes
    for t in range(20):
        xs = _bimodal_inputs(rng, t)
        outs, cache["stack"] = step(xs, cache["stack"])
        oouts, ocache["stack"] = ostep(xs, ocache["stack"])
        # bitwise: reuse telescoping == quantized dense, every layer, even
        # while modes are mid-flight mixed
        np.testing.assert_array_equal(np.asarray(outs), np.asarray(oouts))
        assert eng.refresh_modes(cache) == {}  # exec pinned: nothing retraces

    # distinct modes for distinct layers of the SAME site
    modes = eng.layer_modes(cache, "stack")
    assert [modes[i] for i in SIMILAR] == ["reuse", "reuse"]
    assert [modes[i] for i in DISSIMILAR] == ["basic", "basic"]
    assert eng.site_mode(cache, "stack") == "mixed"

    # the single-mode compromise would be BASIC here (mean-over-layers sim
    # ~0.55 sits under the 0.6 threshold), harvesting nothing; the per-layer
    # block keeps the sticky layers reusing
    sim_l = np.asarray(cache["stack"]["sim_ema"]).mean(axis=-1)
    assert sim_l.mean() < 0.6 < sim_l[list(SIMILAR)].min()
    report = eng.sensor_report(cache)
    by_layer = {r.layer: r for r in report.per_layer}
    base_report = oracle.sensor_report(ocache)
    for layer in SIMILAR:
        base_row = {r.layer: r for r in base_report.per_layer}[layer]
        assert by_layer[layer].mac_skip_rate > base_row.mac_skip_rate
        assert by_layer[layer].mac_skip_rate > 0.3  # whole-run incl. basic era
        assert by_layer[layer].mode == "reuse"
    for layer in DISSIMILAR:
        assert by_layer[layer].mode == "basic"

    # every mode flip across the whole run was an array write: ONE trace
    assert len(traces) == 1
    # ... while a spec-level change (block_k) does signal a retrace
    assert eng.apply_tunables(
        "stack",
        dataclasses.replace(eng.policy.resolve("stack"), block_k=64),
        cache,
    )


def test_per_layer_hysteresis_cannot_oscillate():
    eng = ReuseEngine(policy=ReusePolicy(sim_threshold=0.5,
                                         min_work_flops=0.0,
                                         hysteresis_margin=0.1))
    eng.register("s", 256, 128, n_layers=3)
    cache = eng.init_cache(2)
    assert eng.layer_modes(cache, "s") == ["reuse"] * 3

    # lanes hovering inside the band (threshold 0.5 ± 0.1): no flip, no veto
    cache["s"]["sim_ema"] = jnp.broadcast_to(
        jnp.asarray([0.45, 0.42, 0.48], jnp.float32)[:, None], (3, 2)).copy()
    for _ in range(3):
        eng.refresh_modes(cache)
        assert eng.last_mode_events == []
    assert eng.layer_modes(cache, "s") == ["reuse"] * 3
    assert int(jnp.max(cache["s"]["sensor"]["suppressed_flips"])) == 0

    # one lane leaves the band: only that lane flips
    cache["s"]["sim_ema"] = jnp.broadcast_to(
        jnp.asarray([0.1, 0.45, 0.45], jnp.float32)[:, None], (3, 2)).copy()
    eng.refresh_modes(cache)
    assert [(e["layer"], e["after"]) for e in eng.last_mode_events] == [
        (0, "basic")]
    assert eng.layer_modes(cache, "s") == ["basic", "reuse", "reuse"]

    # an immediate want-back on that lane is cooldown-vetoed and counted
    cache["s"]["sim_ema"] = jnp.broadcast_to(
        jnp.asarray([0.9, 0.45, 0.45], jnp.float32)[:, None], (3, 2)).copy()
    eng.refresh_modes(cache)
    assert eng.last_mode_events == []
    assert eng.layer_modes(cache, "s")[0] == "basic"
    assert int(jnp.max(cache["s"]["sensor"]["suppressed_flips"])) == 1
    # ... and lands once the lane's cooldown drained
    eng.refresh_modes(cache)
    assert [(e["layer"], e["after"]) for e in eng.last_mode_events] == [
        (0, "reuse")]


def test_slot_recycle_resets_per_layer_sensor_lanes(rng):
    eng = ReuseEngine(policy=ReusePolicy(min_work_flops=0.0))
    eng.register("s", 64, 32, n_layers=2, block_m=2, block_k=32)
    cache = eng.init_cache(3)
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    entry = cache["s"]
    for _ in range(2):
        def body(c, sl):
            x_l, e_l = sl
            _, ne, _ = eng.apply("s", x_l, w, None, e_l)
            return c, ne

        xs = jnp.asarray(rng.normal(size=(2, 3, 64)).astype(np.float32))
        _, entry = jax.lax.scan(body, 0, (xs, entry))
    cache["s"] = entry
    before = np.asarray(entry["sensor"]["slot_steps"])
    assert before.shape == (2, 3) and np.all(before == 2)

    out = reset_slot(cache, slot=1)["s"]
    # the recycled lane restarts across EVERY layer slice ...
    assert np.all(np.asarray(out["sensor"]["slot_steps"])[:, 1] == 0)
    assert np.all(np.asarray(out["sensor"]["slot_hit_sum"])[:, 1] == 0.0)
    assert np.all(np.asarray(out["sim_ema"])[:, 1] == 0.0)
    assert np.all(np.asarray(out["prev_q"])[:, 1, :] == 0)
    # ... other lanes keep their per-layer history
    assert np.all(np.asarray(out["sensor"]["slot_steps"])[:, [0, 2]] == 2)
    # the ctrl block is per-LAYER state, not per-slot: recycling keeps it
    np.testing.assert_array_equal(
        np.asarray(out["ctrl"]["mode_id"]),
        np.asarray(cache["s"]["ctrl"]["mode_id"]))


def test_controller_journals_layer_scoped_decisions(tmp_path):
    """Stacked site under the online controller: per-layer windows feed the
    harvest model, land as 'site@layer' rows (ctrl-lane writes, NO retrace)
    and journal with a layer; the journal replays consistently."""
    from repro.control import ControlConfig, Controller, load_journal
    from repro.control.replay import replay_rows

    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.integers(-2, 3, size=(K, N)).astype(np.float32))
    eng = _bimodal_engine()
    cache = eng.init_cache(M)
    step, traces = _make_step(eng, w)

    journal = tmp_path / "decisions.jsonl"
    ctl = Controller(ControlConfig(min_window_steps=2,
                                   journal_path=str(journal)))
    reports = []
    for t in range(1, 11):
        xs = _bimodal_inputs(rng, t)
        _, cache["stack"] = step(xs, cache["stack"])
        if t % 2 == 0:
            rep = ctl.step(eng, cache, step=t)
            reports.append(rep)
            if rep.changed:  # spec-level move (e.g. block_k): rebuild
                step, traces = _make_step(eng, w)
    # retraces only ever come from SPEC-level moves — never from a
    # layer-scoped decision (those are ctrl-array writes)
    for rep in reports:
        layer_decided = {d.site for d in rep.decisions
                         if d.layer is not None and d.kind == "retune"}
        spec_decided = {d.site for d in rep.decisions
                        if d.layer is None and d.kind in ("retune", "budget",
                                                          "exec")}
        assert set(rep.retrace) <= spec_decided | set(), (
            rep.retrace, layer_decided)

    rows = load_journal(str(journal))
    layer_rows = [r for r in rows if r.get("kind") == "decision"
                  and r.get("layer") is not None]
    assert layer_rows, "stacked site produced no layer-scoped decisions"
    assert {r["decision_kind"] for r in layer_rows} >= {"retune"}
    # per-layer rows landed in the policy table and the ctrl lanes
    assert any("@" in k for k in eng.policy.site_tunables)
    thr = np.asarray(cache["stack"]["ctrl"]["sim_threshold"])
    assert thr.shape == (L,)

    result = replay_rows(rows)
    assert result.ok, result.summary_lines()
    assert result.n_layer_scoped == len(layer_rows)

    # a corrupted journal (forged before-value on a knob the trajectory
    # already visited) is detected
    forged = dict(layer_rows[0], before="bogus")
    assert not replay_rows(rows + [forged]).ok
