"""Pipeline parallelism: GPipe over the pod axis must be bit-exact vs the
sequential model. Needs >1 device, so it re-executes itself in a subprocess
with a forced 8-device host platform (tests must otherwise see 1 device)."""

import os
import subprocess
import sys

import pytest

pytest.importorskip("repro.dist.pipeline", reason="repro.dist not implemented yet")

_PAYLOAD = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import numpy as np, jax, jax.numpy as jnp
from repro.configs import ARCHS
from repro.models import init_params, forward
from repro.dist.pipeline import pipeline_forward

cfg = ARCHS['qwen3-32b'].reduced()
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
params = init_params(cfg, jax.random.PRNGKey(0))
toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (8, 32)), jnp.int32)
h_ref, _, _, _ = forward(params, cfg, {'tokens': toks})
with mesh:
    h_pp = jax.jit(lambda p, t: pipeline_forward(cfg, p, t, n_micro=4, mesh=mesh))(params, toks)
err = float(jnp.max(jnp.abs(h_pp.astype(jnp.float32) - h_ref.astype(jnp.float32))))
assert err < 1e-4, err
# gradient path: loss differentiates through ppermute/psum
from repro.dist.pipeline import pipeline_train_loss
batch = {'tokens': toks, 'labels': jnp.roll(toks, -1, 1)}
with mesh:
    g = jax.jit(jax.grad(lambda p: pipeline_train_loss(cfg, p, batch, n_micro=4, mesh=mesh)))(params)
leaves = [x for x in jax.tree.leaves(g)]
assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)
assert max(float(jnp.max(jnp.abs(x))) for x in leaves) > 0
print('PIPELINE_OK', err)
"""


@pytest.mark.slow
def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PAYLOAD],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout
