"""Quantization properties (hypothesis): the substrate the similarity
measurements stand on."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant import (
    QuantSpec,
    calibrate_scale,
    dequantize_int8,
    fake_quantize,
    quantize_int8,
)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 10.0))
def test_roundtrip_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32)) * scale
    s = calibrate_scale(x)
    err = jnp.abs(dequantize_int8(quantize_int8(x, s), s) - x)
    assert float(jnp.max(err)) <= float(s) / 2 + 1e-7


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_symmetric_codes(seed):
    """q(-x) == -q(x): required for the delta algebra to be sign-stable."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    s = calibrate_scale(x)
    q_pos = np.asarray(quantize_int8(x, s), np.int32)
    q_neg = np.asarray(quantize_int8(-x, s), np.int32)
    np.testing.assert_array_equal(q_pos, -q_neg)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_identical_inputs_identical_codes(seed):
    """The premise of the whole paper: equal values -> equal codes, and small
    perturbations below scale/2 collapse onto the same code (that is WHY
    int8 models show such high similarity)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    s = calibrate_scale(x)
    eps = float(s) * 0.49
    x2 = x + eps * jnp.asarray(rng.uniform(-1, 1, size=(64,)).astype(np.float32))
    q1 = np.asarray(quantize_int8(x, s))
    q2 = np.asarray(quantize_int8(x2, s))
    assert np.mean(q1 == q2) > 0.4  # perturbation below half-step mostly collapses


def test_per_channel_scale_shape():
    x = jnp.ones((4, 8, 16))
    spec = QuantSpec(per_channel=True, channel_axis=-1)
    s = calibrate_scale(x, spec)
    assert s.shape == (16,)


def test_fake_quantize_idempotent():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(32,)), jnp.float32)
    y = fake_quantize(x)
    # scale is recalibrated from y: max-abs preserved => same grid => fixpoint
    z = fake_quantize(y)
    np.testing.assert_allclose(np.asarray(y), np.asarray(z), atol=1e-6)
