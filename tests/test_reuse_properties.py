"""Property-based tests (hypothesis) for the system's central invariants.

The load-bearing property of the whole scheme (paper Eqns. 2-4): after any
number of reuse steps, the accumulated output equals the quantized dense
output of the *current* input — the deltas telescope. If this holds, reuse
can never change model outputs, only costs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ReuseEngine, block_zero_mask, delta_encode_int8
from repro.core.delta import compact_block_indices
from repro.core.similarity import harvestable_similarity
from repro.quant import dequantize_int8, quantize_int8


shapes = st.tuples(
    st.integers(1, 12),          # batch
    st.sampled_from([64, 128, 256]),   # in_features
    st.sampled_from([64, 128]),  # out_features
)


@settings(max_examples=20, deadline=None)
@given(shape=shapes, n_steps=st.integers(1, 5),
       similarity=st.floats(0.0, 1.0), seed=st.integers(0, 2**16))
def test_telescoping_invariant(shape, n_steps, similarity, seed):
    """reuse(x_1..x_t) == quantized_dense(x_t), for any stream."""
    b, k, n = shape
    rng = np.random.default_rng(seed)
    eng = ReuseEngine(impl="jnp")
    eng.register("site", k, n, block_m=8, block_k=64)
    cache = eng.init_cache(batch=b)["site"]
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.1)

    x = rng.normal(size=(b, k)).astype(np.float32)
    for _ in range(n_steps):
        keep = rng.random((b, k)) < similarity
        x = np.where(keep, x, rng.normal(size=(b, k)).astype(np.float32))
        out, cache, _ = eng.apply("site", jnp.asarray(x), w, None, cache)

    xq = dequantize_int8(quantize_int8(jnp.asarray(x), cache["scale"]),
                         cache["scale"])
    dense = xq @ w
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(dense), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_cold_start_equals_quantized_dense(seed):
    """First-ever call (zero cache) must already equal the quantized GEMM —
    no special-casing/branching needed (DESIGN.md §reuse_linear)."""
    rng = np.random.default_rng(seed)
    b, k, n = 4, 128, 64
    eng = ReuseEngine(impl="jnp")
    eng.register("site", k, n, block_m=8, block_k=64)
    cache = eng.init_cache(batch=b)["site"]
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.1)
    x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    out, cache, _ = eng.apply("site", x, w, None, cache)
    xq = dequantize_int8(quantize_int8(x, cache["scale"]), cache["scale"])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(xq @ w), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_overflow_split_bounds_and_exactness(seed):
    rng = np.random.default_rng(seed)
    cur = jnp.asarray(rng.integers(-127, 128, size=(8, 128)), jnp.int8)
    prev = jnp.asarray(rng.integers(-127, 128, size=(8, 128)), jnp.int8)
    enc = delta_encode_int8(cur, prev, block_m=8, block_k=64)
    lo = enc.lo.astype(np.int32)
    hi = enc.hi.astype(np.int32)
    assert np.abs(np.asarray(lo)).max() <= 127
    assert np.abs(np.asarray(hi)).max() <= 127
    exact = np.asarray(cur, np.int32) - np.asarray(prev, np.int32)
    np.testing.assert_array_equal(np.asarray(lo) + np.asarray(hi), exact)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), bm=st.sampled_from([4, 8]),
       bk=st.sampled_from([32, 64]))
def test_block_mask_covers_every_nonzero(seed, bm, bk):
    """mask == 0 for a tile ⟹ the tile is entirely zero (never drops data)."""
    rng = np.random.default_rng(seed)
    delta = rng.normal(size=(16, 128)) * (rng.random((16, 128)) < 0.1)
    mask = np.asarray(block_zero_mask(jnp.asarray(delta), bm, bk))
    for i in range(mask.shape[0]):
        for j in range(mask.shape[1]):
            tile = delta[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk]
            if mask[i, j] == 0:
                assert np.all(tile == 0)
            else:
                assert np.any(tile != 0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_harvestable_similarity_monotone_in_granularity(seed):
    """Coarser skip granularity can only harvest less similarity — the TPU
    analogue of the paper's sdot (13.9%) vs mla8 observation."""
    rng = np.random.default_rng(seed)
    cur = jnp.asarray(rng.integers(-4, 5, size=(32, 512)), jnp.int8)
    keep = rng.random((32, 512)) < 0.8
    prev = jnp.asarray(np.where(keep, np.asarray(cur), 0), jnp.int8)
    h = [
        float(harvestable_similarity(cur, prev, 1, bk))
        for bk in (1, 32, 128, 512)
    ]
    assert all(h[i] >= h[i + 1] - 1e-9 for i in range(len(h) - 1))
    # element-granularity harvest == raw similarity
    raw = float(jnp.mean((cur == prev).astype(jnp.float32)))
    assert abs(h[0] - raw) < 1e-6


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), gk=st.integers(1, 16))
def test_compact_block_indices(seed, gk):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.integers(0, 2, size=(gk,)), jnp.int32)
    idx, count = compact_block_indices(mask)
    idx, count = np.asarray(idx), int(count)
    expected = np.nonzero(np.asarray(mask))[0]
    assert count == len(expected)
    np.testing.assert_array_equal(idx[:count], expected)
    if count:  # tail clamps to a valid (already-counted) block
        assert np.all(np.isin(idx[count:], expected))
